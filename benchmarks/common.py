"""Shared benchmark utilities: datasets, timed OBP runs, result records.

The container is offline (no MNIST/UCI), so the paper's tables are
reproduced on synthetic datasets spanning the same regimes: clustered
(gaussian mixture), imbalanced heavy-tail, and higher-dimensional blobs.
Scales are CPU-budgeted; the qualitative claims under test are listed in
DESIGN.md §8 ("Paper claims under test").
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, sampling, solver
from repro.data.embeddings import gaussian_mixture, heavy_tail

SMALL_DATASETS = {
    "blobs3k": lambda seed: gaussian_mixture(3000, 16, centers=25, seed=seed),
    "heavy3k": lambda seed: heavy_tail(3000, 32, seed=seed),
    "wide2k": lambda seed: gaussian_mixture(2000, 64, centers=15, seed=seed),
}
LARGE_DATASETS = {
    "blobs30k": lambda seed: gaussian_mixture(30_000, 16, centers=40,
                                              seed=seed),
    "heavy20k": lambda seed: heavy_tail(20_000, 24, seed=seed),
}


@dataclasses.dataclass
class Run:
    method: str
    dataset: str
    k: int
    seconds: float
    objective: float
    n_dissim: int


def run_obp(x: np.ndarray, k: int, variant: str, seed: int,
            m: int | None = None, strategy: str = "batched",
            chunk_size: int | None = None, metric: str = "l1") -> Run:
    """Timed OneBatchPAM run. ``chunk_size`` streams the distance build in
    row chunks (core/streaming.py) — same numbers, bounded intermediates;
    the ``-stream`` suffix marks those rows in figure CSVs."""
    xj = jnp.asarray(x)
    n = x.shape[0]
    m = m or min(sampling.default_batch_size(n, k), n // 2)
    key = jax.random.PRNGKey(seed)

    def go():
        res, _ = solver.one_batch_pam(key, xj, k, m=m, variant=variant,
                                      metric=metric, strategy=strategy,
                                      backend="ref", chunk_size=chunk_size)
        return res.medoid_idx.block_until_ready()

    go()  # compile
    t0 = time.perf_counter()
    med = go()
    dt = time.perf_counter() - t0
    obj = float(solver.objective(xj, med, metric=metric, backend="ref",
                                 chunk_size=chunk_size))
    suffix = "" if metric == "l1" else f"-{metric}"
    suffix += "" if strategy == "batched" else f"-{strategy}"
    suffix += "" if chunk_size is None else "-stream"
    return Run(f"obp-{variant}{suffix}", "", k, dt, obj, n * m)


def run_baseline(name: str, x: np.ndarray, k: int, seed: int, **kw) -> Run:
    oracle = baselines.Oracle(x, metric="l1")
    fn = baselines.ALL_BASELINES[name]
    res = fn(np.random.default_rng(seed), oracle, k, **kw)
    return Run(name, "", k, res.seconds, res.objective, res.n_dissim)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
