"""Paper Figure 1: runtime/objective evolution vs n (k fixed) and vs k
(n fixed) for the five headline competitors, plus the streaming OBP row
(chunk_size bounds peak intermediate memory; numbers must coincide with
the one-shot row — DESIGN.md §4)."""
from __future__ import annotations

from benchmarks.common import csv_line, run_baseline, run_obp

CHUNK = 2048  # streaming row-chunk: peak intermediates ~ CHUNK * m floats


def run() -> list[str]:
    from repro.data.embeddings import gaussian_mixture

    lines = []
    # left panel: vs n at k=10
    for n in (1000, 2000, 4000, 8000):
        x = gaussian_mixture(n, 16, centers=20, seed=0)
        rows = {
            "kmeans_pp": run_baseline("kmeans_pp", x, 10, 0),
            "clara-5": run_baseline("clara", x, 10, 0, repeats=5),
            "obp-nniw": run_obp(x, 10, "nniw", 0),
            "obp-nniw-stream": run_obp(x, 10, "nniw", 0, chunk_size=CHUNK),
        }
        if n <= 4000:  # FasterPAM infeasible past this scale on CPU here
            rows["fasterpam"] = run_baseline("fasterpam", x, 10, 0)
            rows["banditpam_lite"] = run_baseline("banditpam_lite", x, 10, 0)
        for name, r in rows.items():
            lines.append(csv_line(f"fig1/vs_n/{name}/n{n}", r.seconds * 1e6,
                                  f"obj={r.objective:.4f}"))
    # right panel: vs k at n=3000
    x = gaussian_mixture(3000, 16, centers=40, seed=0)
    for k in (5, 10, 25, 50):
        rows = {
            "kmeans_pp": run_baseline("kmeans_pp", x, k, 0),
            "clara-5": run_baseline("clara", x, k, 0, repeats=5),
            "obp-nniw": run_obp(x, k, "nniw", 0),
            "fasterpam": run_baseline("fasterpam", x, k, 0),
        }
        for name, r in rows.items():
            lines.append(csv_line(f"fig1/vs_k/{name}/k{k}", r.seconds * 1e6,
                                  f"obj={r.objective:.4f}"))
    return lines
