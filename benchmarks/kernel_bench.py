"""Kernel-layer microbenchmarks (ours): the n x m distance block and the
swap sweep, including the fused swap-select path (ISSUE 2) and the
matrix-free fused sweep (ISSUE 4). On this CPU container we time the jnp
reference paths and report the arithmetic and HBM-byte quantities the
Pallas kernels are tiled around; TPU wall-time comes from the roofline
analysis.

``smoke=True`` (CI) shrinks shapes, drops repetitions, and runs the
interpret-mode swap_select kernel on ragged shapes so kernel regressions
(shape mismatches, interpret breaks, select/argmax divergence) fail fast
without timing flakiness. The analytic byte-accounting records are
always emitted at the full standard shape (they cost no timing), so the
committed BENCH_PR*.json baselines carry them in every mode.

The selection byte accounting is the PR 2 / PR 4 acceptance metric: per
sweep the naive path writes and re-reads the (n, k) f32 gain matrix on
top of the (n, m) block read; the fused path reads the block once and
writes O(n/TN) scalar partials; a bf16 block halves the dominant read
term; and the matrix-free sweep replaces the block read entirely with
O((n + m)·p) operand reads — the block never exists (DESIGN.md §2b).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line
from repro.core import sampling, solver
from repro.kernels import ops, ref
from repro.kernels.swap_gain import SG_TN


def _time(fn, *args, reps=3):
    """Best-of-reps wall time after a warmup call: the min is the
    standard noise-robust microbenchmark statistic (scheduler hiccups
    and frequency wobble only ever add time), which is what lets
    tools/bench_compare.py hold a 1.5x regression gate across runs."""
    fn(*args).block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def selection_bytes(n: int, m: int, k: int, block_bytes: int,
                    p: int | None = None, x_bytes: int = 4) -> dict:
    """HBM bytes one swap-selection sweep moves, by strategy.

    naive:       read the (n, m) block + write the (n, k) f32 gain matrix
                 + re-read it for the host argmax.
    fused:       read the (n, m) block + write ceil(n/TN) (f32 gain, i32
                 flat) partials; the gain tiles stay in VMEM.
    matrix_free: read X (n, p) once, plus B (m, p), the one-hot (m, k),
                 and the m-vectors (w/d1/d2/owner) ONCE — they are
                 VMEM-resident across the whole grid (constant-index
                 BlockSpecs in kernels/fused_sweep.py), not re-fetched
                 per n-row-tile revisit — and write the same partials;
                 the block is recomputed in VMEM and never exists
                 (needs ``p``). Conservatively, the block strategies'
                 own per-revisit one-hot re-fetch traffic is NOT
                 counted against them (PR 2 convention: d-derived
                 traffic only), while matrix-free counts every operand
                 it touches.
    """
    tiles = -(-n // SG_TN)
    out = {
        "block_read": n * m * block_bytes,
        "naive": n * m * block_bytes + 2 * n * k * 4,
        "fused": n * m * block_bytes + tiles * 8,
        "partials": tiles * 8,
    }
    if p is not None:
        out["matrix_free"] = ((n * p + m * p) * x_bytes
                              + m * k * 4 + 4 * m * 4 + tiles * 8)
    return out


def _bench_selection(lines, n, m, k, reps):
    """Time one selection step naive vs fused on identical inputs, and
    emit the byte accounting for f32 and bf16 blocks."""
    kd, k1, kn = jax.random.split(jax.random.PRNGKey(1), 3)
    d = jax.random.uniform(kd, (n, m), minval=0.1, maxval=10.0)
    a = jax.random.uniform(k1, (m,), minval=0.0, maxval=10.0)
    d1, d2 = a, a + 0.5
    nh = jax.nn.one_hot(jax.random.randint(kn, (m,), 0, k), k,
                        dtype=jnp.float32)

    def naive_select(d_, d1_, d2_, nh_):
        gain = ref.swap_gain(d_, d1_, d2_, nh_)
        flat = jnp.argmax(gain)
        return gain.reshape(-1)[flat]

    fused_select = jax.jit(lambda *a_: ops.swap_select(*a_, backend="ref")[0])
    t_naive = _time(jax.jit(naive_select), d, d1, d2, nh, reps=reps)
    t_fused = _time(fused_select, d, d1, d2, nh, reps=reps)
    for name, t, bts in (("naive", t_naive, selection_bytes(n, m, k, 4)["naive"]),
                         ("fused", t_fused, selection_bytes(n, m, k, 4)["fused"])):
        lines.append(csv_line(
            f"kernel/swap_select/{name}", t * 1e6,
            f"hbm_bytes_per_sweep={bts} gbps={bts/t/1e9:.2f}"))
    b16 = selection_bytes(n, m, k, 2)
    b32 = selection_bytes(n, m, k, 4)
    lines.append(csv_line(
        "kernel/swap_select/bytes_fused_bf16", 0.0,
        f"hbm_bytes_per_sweep={b16['fused']} "
        f"vs_naive_f32={b32['naive']/b16['fused']:.2f}x "
        f"partials_bytes={b16['partials']}"))


def _bytes_matrix_free(lines, n, m, p, k):
    """PR 4 acceptance records, analytic (no timing): per-sweep HBM bytes
    of the matrix-free fused sweep vs the block sweeps at this shape —
    the matrix-free kernel must come in >= 2x under the bf16 block."""
    b = selection_bytes(n, m, k, 4, p=p)
    b16 = selection_bytes(n, m, k, 2, p=p)
    mf = b["matrix_free"]
    lines.append(csv_line(
        f"kernel/fused_sweep/bytes_matrix_free_{n}x{m}x{p}", 0.0,
        f"hbm_bytes_per_sweep={mf} "
        f"vs_block_f32={b['fused']/mf:.2f}x "
        f"vs_block_bf16={b16['fused']/mf:.2f}x "
        f"resident_bytes={(n*p + m*p)*4 + 3*m*4} "
        f"block_resident_would_be={n*m*4}"))


def _bench_matrix_free(lines, n, m, p, k, reps):
    """Time one matrix-free selection step vs the block step on identical
    inputs (jnp reference paths; the byte accounting above is the kernel
    claim), then assert the end-to-end trajectory identity in-bench."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    idx = jnp.asarray(rng.choice(n, size=m, replace=False)).astype(jnp.int32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=m).astype(np.float32))
    d = ops.pairwise_distance(x, x[idx], backend="ref") * w[None, :]
    a = jnp.asarray(rng.uniform(0.0, 3.0, size=m).astype(np.float32))
    d1, d2 = a, a + 0.5
    nh = jax.nn.one_hot(jnp.asarray(rng.integers(0, k, size=m)), k,
                        dtype=jnp.float32)

    block = jax.jit(lambda *args: ops.swap_select(*args, backend="ref")[0])
    mfree = jax.jit(lambda xx, bb, ww, e1, e2, oh: ops.fused_swap_select(
        xx, bb, ww, e1, e2, oh, backend="ref")[0])
    t_blk = _time(block, d, d1, d2, nh, reps=reps)
    t_mf = _time(mfree, x, x[idx], w, d1, d2, nh, reps=reps)
    bts = selection_bytes(n, m, k, 4, p=p)
    for name, t, key in (("block", t_blk, "fused"),
                         ("matrix_free", t_mf, "matrix_free")):
        lines.append(csv_line(
            f"kernel/fused_sweep/{name}", t * 1e6,
            f"hbm_bytes_per_sweep={bts[key]} gbps={bts[key]/t/1e9:.2f} "
            f"flops={3*n*m*p/t/1e9:.2f}gf"))


def _bench_solver_sweep(lines, n, m, k, reps):
    """Whole-solve comparison: pre-fusion vs fused vs fused+bf16 on the
    same block — per-iteration time, swaps/sec, and the trajectory-identity
    claim check (AssertionError surfaces via run.py)."""
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.uniform(0.1, 10.0, (n, m)).astype(np.float32))
    init = jnp.asarray(rng.choice(n, size=k, replace=False))

    runs = {
        "naive": (solver.solve_batched_naive, d),
        "fused": (solver.solve_batched, d),
        "fused_bf16": (solver.solve_batched, d.astype(jnp.bfloat16)),
    }
    results = {}
    for name, (fn, dd) in runs.items():
        def go(dd=dd, fn=fn):
            return fn(dd, init, backend="ref")
        res = go()
        iters = int(res.n_swaps) + 1          # +1 converging sweep
        t = _time(lambda _=None: go().medoid_idx, None, reps=reps)
        results[name] = res
        lines.append(csv_line(
            f"solver/sweep/{name}", t * 1e6,
            f"us_per_iter={t*1e6/iters:.1f} swaps={int(res.n_swaps)} "
            f"swaps_per_s={int(res.n_swaps)/t:.1f}"))
    assert np.array_equal(np.asarray(results["naive"].medoid_idx),
                          np.asarray(results["fused"].medoid_idx)), \
        "fused solver diverged from the pre-fusion trajectory"

    # Matrix-free end-to-end column on a real e2e instance (the matrix
    # case above has no X to recompute from), trajectory pinned in-bench.
    rng = np.random.default_rng(1)
    p = 16
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    blk = sampling.build_batch(key, x, m, variant="nniw", backend="ref")
    mf = sampling.build_batch(key, x, m, variant="nniw", backend="ref",
                              materialize=False)
    init_e2e = jnp.asarray(rng.choice(n, size=k, replace=False))

    def go_blk():
        return solver.solve_batched(blk.d, init_e2e, backend="ref")

    def go_mf():
        return solver.solve_matrix_free(x, mf.idx, mf.weights, init_e2e,
                                        backend="ref")
    r_blk, r_mf = go_blk(), go_mf()
    assert np.array_equal(np.asarray(r_blk.medoid_idx),
                          np.asarray(r_mf.medoid_idx)), \
        "matrix-free solver diverged from the block trajectory"
    for name, go, res in (("block_nniw", go_blk, r_blk),
                          ("matrix_free_nniw", go_mf, r_mf)):
        t = _time(lambda _=None: go().medoid_idx, None, reps=reps)
        iters = int(res.n_swaps) + 1
        lines.append(csv_line(
            f"solver/sweep/{name}", t * 1e6,
            f"us_per_iter={t*1e6/iters:.1f} swaps={int(res.n_swaps)}"))


def _bench_pruned(lines, n, m, p, k, reps):
    """Time the bound-pruned whole solve vs the matrix-free solve on the
    same block-free batch (ISSUE 6), trajectory identity pinned in-bench
    — the pruned sweep's entire claim is doing strictly less exact
    scoring work while making the *same* swaps."""
    from repro.core import pruned
    rng = np.random.default_rng(5)
    centers = rng.integers(0, 64, size=(k, p)).astype(np.float32)
    x = jnp.asarray(centers[rng.integers(0, k, size=n)]
                    + rng.integers(-2, 3, size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(5)
    mf = sampling.build_batch(key, x, m, variant="nniw", metric="l2",
                              backend="ref", materialize=False)
    init = jnp.asarray(rng.choice(n, size=k, replace=False))

    def go_mf():
        return solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                        metric="l2", backend="ref")

    def go_pr():
        return pruned.solve_pruned(x, mf.idx, mf.weights, init,
                                   metric="l2", backend="ref")
    r_mf, r_pr = go_mf(), go_pr()
    assert np.array_equal(np.asarray(r_mf.medoid_idx),
                          np.asarray(r_pr.medoid_idx)) \
        and int(r_mf.n_swaps) == int(r_pr.n_swaps), \
        "pruned solver diverged from the matrix-free trajectory"
    for name, go, res in (("matrix_free", go_mf, r_mf),
                          ("pruned", go_pr, r_pr)):
        t = _time(lambda _=None: go().medoid_idx, None, reps=reps)
        iters = int(res.n_swaps) + 1
        lines.append(csv_line(
            f"solver/pruned/{name}", t * 1e6,
            f"us_per_iter={t*1e6/iters:.1f} swaps={int(res.n_swaps)}"))


def _pruned_scored_stats(lines, n, m, p, k, max_swaps):
    """ISSUE 6 acceptance record, always emitted at the full standard
    shape: mean exact scorings per sweep of the bound-pruned sweep vs the
    unpruned sweep's n-candidates-every-sweep, on a k-clustered dyadic
    instance (integer features, unit weights: every distance / gain /
    bound comparison is exact in f32, so the recorded counts are
    machine-independent and tools/bench_compare.py holds them to *exact*
    equality like the hbm byte columns). The matrix-free solve runs the
    identical capped sweep budget and the trajectories must agree —
    the count is only meaningful because the swaps are the same."""
    from repro.core import pruned
    rng = np.random.default_rng(6)
    centers = rng.integers(0, 64, size=(k, p)).astype(np.float32)
    x = jnp.asarray(centers[rng.integers(0, k, size=n)]
                    + rng.integers(-2, 3, size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(6)
    idx = jax.random.choice(key, n, shape=(m,), replace=False)
    w = jnp.ones((m,), jnp.float32)
    init = jax.random.choice(jax.random.fold_in(key, 1), n, shape=(k,),
                             replace=False)
    res, st = pruned.solve_pruned_stats(x, idx, w, init, metric="l2",
                                        backend="ref", max_swaps=max_swaps)
    ref_res = solver.solve_matrix_free(x, idx, w, init, metric="l2",
                                       backend="ref", max_swaps=max_swaps)
    assert np.array_equal(np.asarray(res.medoid_idx),
                          np.asarray(ref_res.medoid_idx)) \
        and int(res.n_swaps) == int(ref_res.n_swaps), \
        "pruned solver diverged from the matrix-free trajectory (stats)"
    sw = int(st.sweeps)
    scored = np.asarray(st.scored)[:sw]
    fb = int(np.asarray(st.fallback)[:sw].sum())
    mean = int(scored.sum()) / sw
    lines.append(csv_line(
        f"kernel/pruned_sweep/scored_{n}x{m}x{k}", 0.0,
        f"candidates_scored_per_sweep={mean:.1f} "
        f"vs_unpruned={n/mean:.2f}x sweeps={sw} fallback_sweeps={fb} "
        f"prune_m={pruned.default_prune_m(m)}"))


def _bench_guard_overhead(lines, n, m, k, reps):
    """The validate= tiers' cost at a bench shape (DESIGN.md §6).
    ``off`` IS the historical jitted while_loop solve — the
    ``one_batch_pam`` default path, untouched, so its record doubles as
    the zero-overhead claim check; ``cheap`` runs the host-driven
    runtime loop plus O(m) per-sweep invariant scalars; ``paranoid``
    adds a full exact (n, k) selection-oracle sweep per sweep. All
    three must make the identical swaps (asserted in-bench)."""
    from repro.core import runtime
    rng = np.random.default_rng(9)
    p = 16
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(9)

    def go_off():
        return solver.one_batch_pam(key, x, k, m=m, backend="ref")[0]

    def go(mode):
        return runtime.solve_fault_tolerant(
            key, x, k, m=m, backend="ref", validate=mode)[0]

    res = go_off()
    iters = int(res.n_swaps) + 1
    ts = {"off": _time(lambda _=None: go_off().medoid_idx, None,
                       reps=reps)}
    for mode in ("cheap", "paranoid"):
        r = go(mode)
        assert np.array_equal(np.asarray(r.medoid_idx),
                              np.asarray(res.medoid_idx)), \
            f"validate={mode} diverged from the plain solve"
        ts[mode] = _time(lambda _=None, mode=mode: go(mode).medoid_idx,
                         None, reps=reps)
    for mode, t in ts.items():
        lines.append(csv_line(
            f"kernel/guards/validate_{mode}", t * 1e6,
            f"us_per_sweep={t*1e6/iters:.1f} "
            f"overhead_vs_off={t/ts['off']:.2f}x sweeps={iters}"))


def _bench_telemetry(lines, reps):
    """PR 10 acceptance record: the full telemetry stack (registry
    counters/histograms + span tracer) riding the host-driven runtime
    loop vs the untouched jitted ``one_batch_pam`` path that
    ``telemetry="off"`` resolves to. The shape is fixed (NOT the smoke
    sweep shape): per-sweep compute must dominate the host loop's
    Python dispatch or the record measures the interpreter, not the
    telemetry — at 1024x64 the ratio is ~1.9x from dispatch alone
    while the telemetry hooks are microseconds. 4096x128x16 puts
    ~100ms of kernel work behind each solve, where the same-machine
    ratio sits near 1.15x, and tools/bench_compare.py holds
    ``telemetry_overhead_vs_off`` <= 1.5x as an *absolute* gate (both
    sides of the ratio ran in the same process). The medoid trajectory
    is asserted bitwise identical in-bench — telemetry must observe
    the solve, never steer it."""
    from repro.core import runtime
    n, m, k, p = 4096, 128, 16, 16
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(10)

    def go_off():
        return solver.one_batch_pam(key, x, k, m=m, backend="ref")[0]

    def go_on():
        return runtime.solve_fault_tolerant(
            key, x, k, m=m, backend="ref", telemetry="on")[0]

    r_off, r_on = go_off(), go_on()
    assert np.array_equal(np.asarray(r_off.medoid_idx),
                          np.asarray(r_on.medoid_idx)) \
        and np.float32(r_off.est_objective) == np.float32(r_on.est_objective), \
        "telemetry='on' diverged from the telemetry-off trajectory"
    iters = int(r_off.n_swaps) + 1
    t_off = _time(lambda _=None: go_off().medoid_idx, None, reps=reps)
    t_on = _time(lambda _=None: go_on().medoid_idx, None, reps=reps)
    lines.append(csv_line(
        f"kernel/telemetry/solve_on_{n}x{m}x{k}", t_on * 1e6,
        f"us_per_sweep={t_on*1e6/iters:.1f} sweeps={iters} "
        f"telemetry_overhead_vs_off={t_on/t_off:.2f}x"))
    lines.append(csv_line(
        f"kernel/telemetry/solve_off_{n}x{m}x{k}", t_off * 1e6,
        f"us_per_sweep={t_off*1e6/iters:.1f} sweeps={iters}"))


def _smoke_select_checks(lines):
    """Interpret-mode kernel sanity on ragged shapes: fail-fast coverage
    for shape/pad/tie regressions, no timing involved."""
    for n, m, k in ((100, 33, 7), (300, 260, 130), (256, 64, 4)):
        kd, k1, kn = jax.random.split(jax.random.fold_in(
            jax.random.PRNGKey(2), n), 3)
        d = jnp.round(jax.random.uniform(kd, (n, m), maxval=10.0) * 2) / 2
        a = jax.random.uniform(k1, (m,), maxval=10.0)
        d1, d2 = a, a + 0.25
        nh = jax.nn.one_hot(jax.random.randint(kn, (m,), 0, k), k,
                            dtype=jnp.float32)
        g_i, i_i, l_i = ops.swap_select(d, d1, d2, nh, backend="interpret")
        gain = ops.swap_gain(d, d1, d2, nh, backend="interpret")
        flat = int(jnp.argmax(gain))
        assert (int(i_i), int(l_i)) == (flat // k, flat % k), \
            f"swap_select/interpret mismatch at {(n, m, k)}"
        assert np.float32(g_i) == np.float32(gain.reshape(-1)[flat])
        lines.append(csv_line(f"kernel/swap_select/interpret_{n}x{m}x{k}",
                              0.0, "check=ok"))


def _smoke_matrix_free_checks(lines):
    """Interpret-mode matrix-free sweep == block swap_select on ragged
    shapes across all registered metrics — the PR 4 fail-fast net."""
    from repro.kernels import metrics as metrics_mod
    for i, metric in enumerate(metrics_mod.names()):
        n, m, p, k = 90 + 7 * i, 21 + i, 5 + i, 3 + i
        kd, k1, kn = jax.random.split(jax.random.fold_in(
            jax.random.PRNGKey(4), i), 3)
        x = jax.random.normal(kd, (n, p), jnp.float32)
        idx = jax.random.choice(k1, n, shape=(m,), replace=False)
        w = jax.random.uniform(k1, (m,), minval=0.5, maxval=1.5)
        d = ops.pairwise_distance(x, x[idx], metric=metric,
                                  backend="interpret") * w[None, :]
        a = jax.random.uniform(kn, (m,), maxval=3.0)
        d1, d2 = a, a + 0.25
        nh = jax.nn.one_hot(jax.random.randint(kn, (m,), 0, k), k,
                            dtype=jnp.float32)
        g_b, i_b, l_b = ops.swap_select(d, d1, d2, nh, backend="interpret")
        g_m, i_m, l_m = ops.fused_swap_select(
            x, x[idx], w, d1, d2, nh, metric=metric, backend="interpret")
        assert (int(i_m), int(l_m)) == (int(i_b), int(l_b)), metric
        assert np.float32(g_m) == np.float32(g_b), metric
        lines.append(csv_line(f"kernel/fused_sweep/interpret_{metric}",
                              0.0, "check=ok"))


def run(smoke: bool = False) -> list[str]:
    lines = []
    key = jax.random.PRNGKey(0)
    if smoke:
        n, m, p, k = 2048, 128, 16, 16
        sweep_n, sweep_m, sweep_k = 1024, 64, 8
        reps = 5   # best-of-5: stable enough for the bench_compare gate
    else:
        n, m, p, k = 32_768, 512, 64, 64
        sweep_n, sweep_m, sweep_k = 8192, 256, 32
        reps = 3
    x = jax.random.normal(key, (n, p))
    b = x[:m]

    naive = jax.jit(ref.pairwise_l1)
    tiled = jax.jit(lambda a, c: ref.pairwise_l1_chunked(a, c))
    t_naive = _time(naive, x, b, reps=reps)
    t_tiled = _time(tiled, x, b, reps=reps)
    flops = 3 * n * m * p
    lines.append(csv_line("kernel/pairwise_l1/naive", t_naive * 1e6,
                          f"gflops={flops/t_naive/1e9:.2f}"))
    lines.append(csv_line("kernel/pairwise_l1/tiled", t_tiled * 1e6,
                          f"gflops={flops/t_tiled/1e9:.2f}"))

    d = naive(x, b)
    d1 = d.min(axis=0) + 0.1
    d2 = d1 + 0.5
    nh = jax.nn.one_hot(jnp.zeros(m, jnp.int32), k)
    sg = jax.jit(lambda *a: ref.swap_gain(*a))
    t_sg = _time(sg, d, d1, d2, nh, reps=reps)
    bytes_touched = d.size * 4 * 2 + n * k * 4
    lines.append(csv_line("kernel/swap_gain/sweep", t_sg * 1e6,
                          f"gbps={bytes_touched/t_sg/1e9:.2f}"))

    _bench_selection(lines, n, m, k, reps)
    # PR 4 acceptance bytes, always at the full standard shape (analytic).
    _bytes_matrix_free(lines, 32_768, 512, 64, 64)
    _bench_matrix_free(lines, n, m, p, k, reps)
    _bench_solver_sweep(lines, sweep_n, sweep_m, sweep_k, reps)
    _bench_pruned(lines, sweep_n, sweep_m, p, sweep_k, reps)
    _bench_guard_overhead(lines, sweep_n, sweep_m, sweep_k, reps)
    # PR 10 acceptance ratio, always at its own fixed shape (see the
    # helper's docstring for why it must not shrink with --smoke).
    _bench_telemetry(lines, reps)
    # ISSUE 6 acceptance counts, always at the full standard shape (the
    # sweep budget is capped so the record stays cheap enough for CI).
    _pruned_scored_stats(lines, 32_768, 512, 64, 64, max_swaps=10)
    if smoke:
        _smoke_select_checks(lines)
        _smoke_matrix_free_checks(lines)

    t_l2 = _time(jax.jit(lambda a, c: ref.pairwise_l2(a, c)), x, b, reps=reps)
    lines.append(csv_line("kernel/pairwise_l2/mxu_form", t_l2 * 1e6,
                          f"gflops={2*n*m*p/t_l2/1e9:.2f}"))
    return lines
