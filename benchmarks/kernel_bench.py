"""Kernel-layer microbenchmarks (ours): the n x m distance block and the
fused swap-gain sweep. On this CPU container we time the jnp reference
paths (naive vs tiled) and report the arithmetic quantities the Pallas
kernels are tiled around; TPU wall-time comes from the roofline analysis."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run() -> list[str]:
    lines = []
    key = jax.random.PRNGKey(0)
    n, m, p, k = 32_768, 512, 64, 64
    x = jax.random.normal(key, (n, p))
    b = x[:m]

    naive = jax.jit(ref.pairwise_l1)
    tiled = jax.jit(lambda a, c: ref.pairwise_l1_chunked(a, c))
    t_naive = _time(naive, x, b)
    t_tiled = _time(tiled, x, b)
    flops = 3 * n * m * p
    lines.append(csv_line("kernel/pairwise_l1/naive", t_naive * 1e6,
                          f"gflops={flops/t_naive/1e9:.2f}"))
    lines.append(csv_line("kernel/pairwise_l1/tiled", t_tiled * 1e6,
                          f"gflops={flops/t_tiled/1e9:.2f}"))

    d = naive(x, b)
    d1 = d.min(axis=0) + 0.1
    d2 = d1 + 0.5
    nh = jax.nn.one_hot(jnp.zeros(m, jnp.int32), k)
    sg = jax.jit(lambda *a: ref.swap_gain(*a))
    t_sg = _time(sg, d, d1, d2, nh)
    bytes_touched = d.size * 4 * 2 + n * k * 4
    lines.append(csv_line("kernel/swap_gain/sweep", t_sg * 1e6,
                          f"gbps={bytes_touched/t_sg/1e9:.2f}"))

    t_l2 = _time(jax.jit(lambda a, c: ref.pairwise_l2(a, c)), x, b)
    lines.append(csv_line("kernel/pairwise_l2/mxu_form", t_l2 * 1e6,
                          f"gflops={2*n*m*p/t_l2/1e9:.2f}"))
    return lines
