"""Kernel-layer microbenchmarks (ours): the n x m distance block and the
swap sweep, including the fused swap-select path (ISSUE 2). On this CPU
container we time the jnp reference paths and report the arithmetic and
HBM-byte quantities the Pallas kernels are tiled around; TPU wall-time
comes from the roofline analysis.

``smoke=True`` (CI) shrinks shapes, drops repetitions, and runs the
interpret-mode swap_select kernel on ragged shapes so kernel regressions
(shape mismatches, interpret breaks, select/argmax divergence) fail fast
without timing flakiness.

The selection byte accounting is the PR 2 acceptance metric: per sweep the
naive path writes and re-reads the (n, k) f32 gain matrix on top of the
(n, m) block read, while the fused path reads the block once and writes
O(n/TN) scalar partials; a bf16 block halves the dominant read term.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line
from repro.core import solver
from repro.kernels import ops, ref
from repro.kernels.swap_gain import SG_TN


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def selection_bytes(n: int, m: int, k: int, block_bytes: int) -> dict:
    """HBM bytes one swap-selection sweep moves, by strategy.

    naive:  read the (n, m) block + write the (n, k) f32 gain matrix +
            re-read it for the host argmax.
    fused:  read the (n, m) block + write ceil(n/TN) (f32 gain, i32 flat)
            partials; the gain tiles stay in VMEM.
    """
    tiles = -(-n // SG_TN)
    return {
        "block_read": n * m * block_bytes,
        "naive": n * m * block_bytes + 2 * n * k * 4,
        "fused": n * m * block_bytes + tiles * 8,
        "partials": tiles * 8,
    }


def _bench_selection(lines, n, m, k, reps):
    """Time one selection step naive vs fused on identical inputs, and
    emit the byte accounting for f32 and bf16 blocks."""
    kd, k1, kn = jax.random.split(jax.random.PRNGKey(1), 3)
    d = jax.random.uniform(kd, (n, m), minval=0.1, maxval=10.0)
    a = jax.random.uniform(k1, (m,), minval=0.0, maxval=10.0)
    d1, d2 = a, a + 0.5
    nh = jax.nn.one_hot(jax.random.randint(kn, (m,), 0, k), k,
                        dtype=jnp.float32)

    def naive_select(d_, d1_, d2_, nh_):
        gain = ref.swap_gain(d_, d1_, d2_, nh_)
        flat = jnp.argmax(gain)
        return gain.reshape(-1)[flat]

    fused_select = jax.jit(lambda *a_: ops.swap_select(*a_, backend="ref")[0])
    t_naive = _time(jax.jit(naive_select), d, d1, d2, nh, reps=reps)
    t_fused = _time(fused_select, d, d1, d2, nh, reps=reps)
    for name, t, bts in (("naive", t_naive, selection_bytes(n, m, k, 4)["naive"]),
                         ("fused", t_fused, selection_bytes(n, m, k, 4)["fused"])):
        lines.append(csv_line(
            f"kernel/swap_select/{name}", t * 1e6,
            f"hbm_bytes_per_sweep={bts} gbps={bts/t/1e9:.2f}"))
    b16 = selection_bytes(n, m, k, 2)
    b32 = selection_bytes(n, m, k, 4)
    lines.append(csv_line(
        "kernel/swap_select/bytes_fused_bf16", 0.0,
        f"hbm_bytes_per_sweep={b16['fused']} "
        f"vs_naive_f32={b32['naive']/b16['fused']:.2f}x "
        f"partials_bytes={b16['partials']}"))


def _bench_solver_sweep(lines, n, m, k, reps):
    """Whole-solve comparison: pre-fusion vs fused vs fused+bf16 on the
    same block — per-iteration time, swaps/sec, and the trajectory-identity
    claim check (AssertionError surfaces via run.py)."""
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.uniform(0.1, 10.0, (n, m)).astype(np.float32))
    init = jnp.asarray(rng.choice(n, size=k, replace=False))

    runs = {
        "naive": (solver.solve_batched_naive, d),
        "fused": (solver.solve_batched, d),
        "fused_bf16": (solver.solve_batched, d.astype(jnp.bfloat16)),
    }
    results = {}
    for name, (fn, dd) in runs.items():
        def go(dd=dd, fn=fn):
            return fn(dd, init, backend="ref")
        res = go()
        iters = int(res.n_swaps) + 1          # +1 converging sweep
        t = _time(lambda _=None: go().medoid_idx, None, reps=reps)
        results[name] = res
        lines.append(csv_line(
            f"solver/sweep/{name}", t * 1e6,
            f"us_per_iter={t*1e6/iters:.1f} swaps={int(res.n_swaps)} "
            f"swaps_per_s={int(res.n_swaps)/t:.1f}"))
    assert np.array_equal(np.asarray(results["naive"].medoid_idx),
                          np.asarray(results["fused"].medoid_idx)), \
        "fused solver diverged from the pre-fusion trajectory"


def _smoke_select_checks(lines):
    """Interpret-mode kernel sanity on ragged shapes: fail-fast coverage
    for shape/pad/tie regressions, no timing involved."""
    for n, m, k in ((100, 33, 7), (300, 260, 130), (256, 64, 4)):
        kd, k1, kn = jax.random.split(jax.random.fold_in(
            jax.random.PRNGKey(2), n), 3)
        d = jnp.round(jax.random.uniform(kd, (n, m), maxval=10.0) * 2) / 2
        a = jax.random.uniform(k1, (m,), maxval=10.0)
        d1, d2 = a, a + 0.25
        nh = jax.nn.one_hot(jax.random.randint(kn, (m,), 0, k), k,
                            dtype=jnp.float32)
        g_i, i_i, l_i = ops.swap_select(d, d1, d2, nh, backend="interpret")
        gain = ops.swap_gain(d, d1, d2, nh, backend="interpret")
        flat = int(jnp.argmax(gain))
        assert (int(i_i), int(l_i)) == (flat // k, flat % k), \
            f"swap_select/interpret mismatch at {(n, m, k)}"
        assert np.float32(g_i) == np.float32(gain.reshape(-1)[flat])
        lines.append(csv_line(f"kernel/swap_select/interpret_{n}x{m}x{k}",
                              0.0, "check=ok"))


def run(smoke: bool = False) -> list[str]:
    lines = []
    key = jax.random.PRNGKey(0)
    if smoke:
        n, m, p, k = 2048, 128, 16, 16
        sweep_n, sweep_m, sweep_k = 1024, 64, 8
        reps = 1
    else:
        n, m, p, k = 32_768, 512, 64, 64
        sweep_n, sweep_m, sweep_k = 8192, 256, 32
        reps = 3
    x = jax.random.normal(key, (n, p))
    b = x[:m]

    naive = jax.jit(ref.pairwise_l1)
    tiled = jax.jit(lambda a, c: ref.pairwise_l1_chunked(a, c))
    t_naive = _time(naive, x, b, reps=reps)
    t_tiled = _time(tiled, x, b, reps=reps)
    flops = 3 * n * m * p
    lines.append(csv_line("kernel/pairwise_l1/naive", t_naive * 1e6,
                          f"gflops={flops/t_naive/1e9:.2f}"))
    lines.append(csv_line("kernel/pairwise_l1/tiled", t_tiled * 1e6,
                          f"gflops={flops/t_tiled/1e9:.2f}"))

    d = naive(x, b)
    d1 = d.min(axis=0) + 0.1
    d2 = d1 + 0.5
    nh = jax.nn.one_hot(jnp.zeros(m, jnp.int32), k)
    sg = jax.jit(lambda *a: ref.swap_gain(*a))
    t_sg = _time(sg, d, d1, d2, nh, reps=reps)
    bytes_touched = d.size * 4 * 2 + n * k * 4
    lines.append(csv_line("kernel/swap_gain/sweep", t_sg * 1e6,
                          f"gbps={bytes_touched/t_sg/1e9:.2f}"))

    _bench_selection(lines, n, m, k, reps)
    _bench_solver_sweep(lines, sweep_n, sweep_m, sweep_k, reps)
    if smoke:
        _smoke_select_checks(lines)

    t_l2 = _time(jax.jit(lambda a, c: ref.pairwise_l2(a, c)), x, b, reps=reps)
    lines.append(csv_line("kernel/pairwise_l2/mxu_form", t_l2 * 1e6,
                          f"gflops={2*n*m*p/t_l2/1e9:.2f}"))
    return lines
