"""Paper Appendix D: time/objective Pareto fronts. Reports which methods
are Pareto-optimal per dataset (paper: k-means++, FasterCLARA-5,
OBP-nniw, FasterPAM on small scale)."""
from __future__ import annotations

from benchmarks.common import SMALL_DATASETS, csv_line, run_baseline, run_obp


def _pareto(points: dict) -> set:
    opt = set()
    for a, (ta, oa) in points.items():
        dominated = any(tb <= ta and ob <= oa and (tb < ta or ob < oa)
                        for b, (tb, ob) in points.items() if b != a)
        if not dominated:
            opt.add(a)
    return opt


def run() -> list[str]:
    lines = []
    for ds, make in SMALL_DATASETS.items():
        x = make(seed=0)
        k = 10
        runs = {
            "fasterpam": run_baseline("fasterpam", x, k, 0),
            "clara-5": run_baseline("clara", x, k, 0, repeats=5),
            "kmeans_pp": run_baseline("kmeans_pp", x, k, 0),
            "obp-nniw": run_obp(x, k, "nniw", 0),
            "random": run_baseline("random", x, k, 0),
        }
        points = {m: (r.seconds, r.objective) for m, r in runs.items()}
        front = _pareto(points)
        for m, r in runs.items():
            lines.append(csv_line(
                f"pareto/{ds}/{m}", r.seconds * 1e6,
                f"obj={r.objective:.4f};on_front={m in front}"))
        lines.append(csv_line(f"pareto/{ds}/front", 0.0,
                              "front=" + "|".join(sorted(front))))
    return lines
