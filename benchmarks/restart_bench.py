"""Multi-restart sweep (ISSUE 3): quality and wall-time of the vmapped
restart engine, R ∈ {1, 4, 16}, against 16 sequential single-restart runs.

Configuration note: the sweep runs metric="sqeuclidean" — the MXU-style
gram build is the configuration the engine targets on accelerators; the
pure-CPU l1 broadcast would measure Eigen's (n, m, p) materialisation,
not the engine. m is fixed small so R·m ≪ n holds at R = 16.

Claims asserted (failures surface through run.py):
  * quality — the elected R=16 medoid set's exact objective is no worse
    than the *median* of 16 sequential single-restart runs: best-of-R
    election must at least beat the typical draw.
  * amortisation — one pooled build + one vmapped sweep beats paying
    per-run dispatch/build overhead 16 times: t(R=16) < 0.75 × the
    measured 16-sequential-runs wall time, on any hardware.
  * lane parallelism — t(R=16) < 4 × t(R=1). This is the accelerator
    claim (the vmapped lanes batch into the same kernel program), so it
    is asserted only where lanes can actually run in parallel
    (device_count >= 4 or a TPU backend); on a 2-core CPU host 16× the
    FLOPs cannot cost < 4× wall and the measured ratio is recorded in
    the JSON instead (see BENCH_PR3.json for this container's numbers).

``smoke`` shrinks shapes and drops the wall-time claims (CI timing
variance is not a correctness signal); the quality claim stays.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SMALL_DATASETS, csv_line
from repro.core import restarts, solver

R_SWEEP = (1, 4, 16)
SEQ_RUNS = 16
METRIC = "sqeuclidean"


def _timed(fn, reps=3):
    fn()  # warm caches (jit traces, compiled executables)
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _lanes_parallel() -> bool:
    return jax.device_count() >= 4 or jax.default_backend() == "tpu"


def run(smoke: bool = False):
    lines = []
    datasets = {"blobs3k": SMALL_DATASETS["blobs3k"]}
    if not smoke:
        datasets["heavy3k"] = SMALL_DATASETS["heavy3k"]
    k, m, eval_m = (6, 16, 64) if smoke else (10, 32, 256)
    for name, make in datasets.items():
        x = jnp.asarray(make(seed=0))
        key = jax.random.PRNGKey(0)

        # 16 sequential single-restart runs (fresh key each): the quality
        # and amortisation baseline. One untimed warmup first so the
        # baseline doesn't carry the one-time jit compilation.
        jax.block_until_ready(solver.one_batch_pam(
            jax.random.PRNGKey(99), x, k, m=m, metric=METRIC,
            backend="ref")[0].medoid_idx)
        seq_objs = []
        t_seq = 0.0
        for s in range(SEQ_RUNS):
            t0 = time.perf_counter()
            res, _ = solver.one_batch_pam(jax.random.PRNGKey(s), x, k, m=m,
                                          metric=METRIC, backend="ref")
            jax.block_until_ready(res.medoid_idx)
            t_seq += time.perf_counter() - t0
            seq_objs.append(float(solver.objective(x, res.medoid_idx,
                                                   metric=METRIC,
                                                   backend="ref")))
        seq_median = float(np.median(seq_objs))
        seq_best = float(np.min(seq_objs))
        lines.append(csv_line(
            f"restarts/{name}-seq{SEQ_RUNS}", t_seq * 1e6 / SEQ_RUNS,
            f"median_obj={seq_median:.4f} best_obj={seq_best:.4f}"))

        times, objs = {}, {}
        for r in R_SWEEP:
            def go(r=r):
                rr, _ = restarts.one_batch_pam_restarts(
                    key, x, k, restarts=r, m=m, eval_m=eval_m,
                    metric=METRIC, backend="ref")
                return rr.best.medoid_idx
            dt, med = _timed(go)
            times[r] = dt
            objs[r] = float(solver.objective(x, med, metric=METRIC,
                                             backend="ref"))
            lines.append(csv_line(
                f"restarts/{name}-R{r}", dt * 1e6,
                f"obj={objs[r]:.4f} t_rel={dt / times[R_SWEEP[0]]:.2f}x "
                f"vs_seq_median={objs[r] / seq_median:.3f}x"))

        # Quality: elected best-of-16 <= sequential median (tiny slack for
        # the held-out-estimate vs exact-objective gap).
        assert objs[16] <= seq_median * 1.001, (
            f"{name}: R=16 objective {objs[16]:.4f} worse than the "
            f"sequential median {seq_median:.4f}")
        if not smoke:
            lines.append(csv_line(
                f"restarts/{name}-amortisation", times[16] * 1e6,
                f"r16_over_r1={times[16] / times[1]:.2f}x "
                f"r16_over_seq16={times[16] / t_seq:.2f}x "
                f"lanes_parallel={_lanes_parallel()}"))
            assert times[16] < 0.75 * t_seq, (
                f"{name}: R=16 took {times[16]:.3f}s, not amortising the "
                f"16 sequential runs' {t_seq:.3f}s")
            if _lanes_parallel():
                assert times[16] < 4.0 * times[1], (
                    f"{name}: R=16 took {times[16]:.3f}s "
                    f">= 4x R=1 {times[1]:.3f}s with parallel lanes")
    return lines
