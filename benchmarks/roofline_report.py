"""Roofline summary from the dry-run artifacts (launch/dryrun.py must have
been run; EXPERIMENTS.md §Roofline is generated from the same JSONs)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv_line

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "dryrun")


def run() -> list[str]:
    lines = []
    files = sorted(glob.glob(os.path.join(ARTIFACTS, "*.json")))
    files = [f for f in files if not f.endswith("skips.json")]
    if not files:
        return [csv_line("roofline/missing", 0.0,
                         "run `python -m repro.launch.dryrun --all` first")]
    for f in files:
        d = json.load(open(f))
        rl = d["roofline"]
        mem = d["memory"]
        name = f'{d["arch"]}/{d["shape"]}/{d["mesh"]}'
        hbm_gb = ((mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0)) / 2**30
        lines.append(csv_line(
            f"roofline/{name}", rl["step_s"] * 1e6,
            f"bottleneck={rl['bottleneck']};mfu={rl['mfu']:.4f};"
            f"useful={rl['useful_ratio']:.3f};hbm_gb={hbm_gb:.2f}"))
    skips = os.path.join(ARTIFACTS, "skips.json")
    if os.path.exists(skips):
        for s in json.load(open(skips)):
            lines.append(csv_line(
                f"roofline/{s['arch']}/{s['shape']}/{s['mesh']}", 0.0,
                "SKIP=" + s["skip"].replace(",", ";")))
    return lines
