"""Benchmark driver: one module per paper table/figure (+ ours).
Prints ``name,us_per_call,derived`` CSV. Select with --only."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table3,fig1,pareto,kernel,roofline")
    args = ap.parse_args()

    from benchmarks import (fig1_scaling, kernel_bench, pareto,
                            roofline_report, table1_complexity,
                            table3_quality, theorem1)
    suites = {
        "table1": table1_complexity.run,
        "table3": table3_quality.run,
        "fig1": fig1_scaling.run,
        "pareto": pareto.run,
        "theorem1": theorem1.run,
        "kernel": kernel_bench.run,
        "roofline": roofline_report.run,
    }
    selected = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    failed = False
    for name in selected:
        t0 = time.perf_counter()
        try:
            for line in suites[name]():
                print(line)
        except AssertionError as e:  # claim-check failures are visible
            print(f"{name}/ASSERTION,0.0,failed={e}")
            failed = True
        print(f"{name}/total,{(time.perf_counter() - t0) * 1e6:.0f},done")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
