"""Benchmark driver: one module per paper table/figure (+ ours).
Prints ``name,us_per_call,derived`` CSV. Select with --only.

``--json PATH`` additionally writes the records as structured JSON (the
machine-readable perf trajectory; BENCH_PR2.json in-repo is the committed
snapshot). ``--smoke`` shrinks shapes and drops repetitions for suites
that support it (kernel_bench) — the CI mode that catches kernel
regressions fast without timing flakiness.
"""
from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import sys
import time

# ``python benchmarks/run.py`` puts benchmarks/ itself on sys.path, not the
# repo root the ``benchmarks.*`` imports need — add it regardless of cwd.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def _parse_record(line: str) -> dict:
    """CSV line -> JSON record; ``derived`` is space-separated k=v pairs."""
    name, us, derived = line.split(",", 2)
    rec = {"name": name, "us_per_call": float(us), "derived": {}}
    for kv in derived.split():
        if "=" in kv:
            key, val = kv.split("=", 1)
            try:
                rec["derived"][key] = float(val.rstrip("x"))
            except ValueError:
                rec["derived"][key] = val
        else:
            rec["derived"][kv] = True
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table3,fig1,pareto,kernel,"
                         "roofline,restarts,serving")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write records as structured JSON (e.g. "
                         "BENCH_PR2.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, 1 rep, extra interpret-mode kernel "
                         "checks — the CI fail-fast mode")
    args = ap.parse_args()

    from benchmarks import (fig1_scaling, kernel_bench, pareto,
                            restart_bench, roofline_report, serving_bench,
                            table1_complexity, table3_quality, theorem1)
    suites = {
        "table1": table1_complexity.run,
        "table3": table3_quality.run,
        "fig1": fig1_scaling.run,
        "pareto": pareto.run,
        "theorem1": theorem1.run,
        "kernel": kernel_bench.run,
        "roofline": roofline_report.run,
        "restarts": restart_bench.run,
        "serving": serving_bench.run,
    }
    selected = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    records = []
    failed = False
    for name in selected:
        fn = suites[name]
        kwargs = ({"smoke": True} if args.smoke
                  and "smoke" in inspect.signature(fn).parameters else {})
        t0 = time.perf_counter()
        try:
            for line in fn(**kwargs):
                print(line)
                records.append(_parse_record(line))
        except AssertionError as e:  # claim-check failures are visible
            print(f"{name}/ASSERTION,0.0,failed={e}")
            records.append({"name": f"{name}/ASSERTION", "us_per_call": 0.0,
                            "derived": {"failed": str(e)}})
            failed = True
        print(f"{name}/total,{(time.perf_counter() - t0) * 1e6:.0f},done")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"driver": "benchmarks/run.py", "smoke": args.smoke,
                       "suites": selected, "records": records}, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
