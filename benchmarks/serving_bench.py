"""Serving-path benchmark (ISSUE 8): the AssignmentEngine's batched
assign kernel vs the host ``stream_assign`` loop it replaced, at the
acceptance shape n_queries=32768, p=512, k=64 (the PR 4 standard shape
with the roles flipped: medoids are the batch, queries are the rows).

Records:

  serving/assign/engine_32768x512x64      us_per_call = one full pass
      derived: us_per_query, qps, p50_us/p95_us (per-micro-batch wall
      latency from the engine's StepTimer, warmup excluded),
      speedup_vs_loop (>= 1.0 is the ISSUE 8 acceptance bar —
      tools/bench_compare.py holds it as an absolute gate, not
      machine-normalised: both sides ran on the same machine). Runs
      ``validate="off"`` — the PR 8 fast path, pinned unguarded
      (DESIGN.md §9a); this record holding its gate IS the proof the
      guard work left that path untouched.
  serving/guards/validate_cheap_32768x512x64  the same pass under the
      ``validate="cheap"`` admission tier (finiteness scan + clean-path
      branch, clean queries). derived: overhead_vs_off — the cheap/off
      wall ratio, same machine same instant, held absolutely (<= 1.5x)
      by tools/bench_compare.py.
  serving/telemetry/engine_32768x512x64   the same pass with the PR 10
      telemetry stack on (latency histogram, spans, served counters).
      derived: telemetry_overhead_vs_off — the on/off wall ratio, same
      machine same instant, held absolutely (<= 1.5x) by
      tools/bench_compare.py; labels/d1 bitwise-pinned in-bench.
  serving/assign/stream_loop_32768x512x64 the replaced path, same shape.

Labels and d1 are asserted *bitwise* equal between the two paths
in-bench (AssertionError surfaces via run.py): the engine's whole claim
is throughput, not answers. ``smoke=True`` drops to best-of-1 timing and
adds an interpret-backend end-to-end engine check at a small shape (the
kernel-lane fail-fast net; the timed records stay at the full shape so
the committed BENCH_PR8.json carries the acceptance numbers).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line
from repro.core import MedoidSelector, streaming
from repro.serving import AssignmentEngine

N_QUERIES, P, K = 32_768, 512, 64
MICRO_BATCH = 4096


def _synthetic_selector(x: np.ndarray, k: int, *,
                        backend: str = "auto") -> MedoidSelector:
    """A fitted-shaped selector without paying for a fit: the engine only
    reads the fitted artifacts (medoids_, est_objective_, config)."""
    sel = MedoidSelector(k=k, metric="l1", backend=backend)
    sel.medoids_ = x[:k].copy()
    sel.medoid_indices_ = np.arange(k, dtype=np.int32)
    sel.est_objective_ = 1.0
    sel.n_swaps_ = 0
    return sel


def _time_pass(fn, reps: int) -> tuple[float, tuple]:
    """Best-of-reps wall seconds for one full pass (after the caller's
    warmup call), plus the last result for the bitwise check."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _smoke_interpret_check(lines: list) -> None:
    """End-to-end engine on the interpret backend at a small shape:
    labels must match the ref backend exactly (cross-backend the d1
    contract is labels, not bits — DESIGN.md §9)."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((300, 24)).astype(np.float32)
    labels = {}
    for backend in ("ref", "interpret"):
        eng = AssignmentEngine.from_selector(
            _synthetic_selector(x, 8, backend=backend),
            micro_batch=128, auto_refit=False)
        labels[backend], _ = eng.assign(x)
    assert np.array_equal(labels["ref"], labels["interpret"]), \
        "serving engine labels diverged across backends"
    lines.append(csv_line("serving/assign/interpret_e2e", 0.0, "check=ok"))


def run(smoke: bool = False) -> list[str]:
    lines: list[str] = []
    reps = 1 if smoke else 3
    rng = np.random.default_rng(0)
    x = rng.standard_normal((N_QUERIES, P)).astype(np.float32)
    shape = f"{N_QUERIES}x{P}x{K}"

    sel = _synthetic_selector(x, K)
    eng = AssignmentEngine.from_selector(sel, micro_batch=MICRO_BATCH,
                                         auto_refit=False, warmup=1,
                                         validate="off")
    eng.assign(x)                       # compile + warm
    t_eng, (l_eng, d_eng) = _time_pass(lambda: eng.assign(x), reps)

    # The cheap admission tier on the same pass: shares the lru-cached
    # jit with eng, so the delta is pure guard overhead (one O(n*p)
    # finiteness scan + the clean-path branch).
    eng_cheap = AssignmentEngine.from_selector(
        sel, micro_batch=MICRO_BATCH, auto_refit=False, warmup=1,
        validate="cheap")
    eng_cheap.assign(x)
    t_cheap, (l_cheap, d_cheap) = _time_pass(
        lambda: eng_cheap.assign(x), reps)
    assert np.array_equal(l_cheap, l_eng) and np.array_equal(
        d_cheap.view(np.uint32), d_eng.view(np.uint32)), \
        "validate='cheap' diverged from the fast path on clean queries"

    # PR 10: the same pass with the full telemetry stack on (per-micro-
    # batch latency histogram + span per micro-batch + served counters).
    # Shares the lru-cached jit with eng, so the delta is pure telemetry
    # hook cost — held absolutely <= 1.5x by tools/bench_compare.py, and
    # the labels/d1 must stay bitwise identical (telemetry observes the
    # serve path, never steers it).
    eng_tel = AssignmentEngine.from_selector(
        sel, micro_batch=MICRO_BATCH, auto_refit=False, warmup=1,
        validate="off", telemetry="on")
    eng_tel.assign(x)
    t_tel, (l_tel, d_tel) = _time_pass(lambda: eng_tel.assign(x), reps)
    assert np.array_equal(l_tel, l_eng) and np.array_equal(
        d_tel.view(np.uint32), d_eng.view(np.uint32)), \
        "telemetry='on' diverged from the telemetry-off serve path"

    # The replaced path: host loop over eager stream_assign calls, same
    # micro-batching (per-call trace + dispatch is exactly the overhead
    # the engine's cached donated jit removes).
    b = jnp.asarray(sel.medoids_)

    def loop():
        labels = np.empty((N_QUERIES,), np.int32)
        d1 = np.empty((N_QUERIES,), np.float32)
        for s in range(0, N_QUERIES, MICRO_BATCH):
            la, da = streaming.stream_assign(
                jnp.asarray(x[s:s + MICRO_BATCH]), b, metric=sel.metric,
                backend=sel.backend)
            labels[s:s + MICRO_BATCH] = np.asarray(la)
            d1[s:s + MICRO_BATCH] = np.asarray(da)
        return labels, d1

    loop()                              # warm its compile cache too
    t_loop, (l_loop, d_loop) = _time_pass(loop, reps)

    assert np.array_equal(l_eng, l_loop) and np.array_equal(
        d_eng.view(np.uint32), d_loop.view(np.uint32)), \
        "serving engine diverged from the stream_assign loop"

    lat = eng.timer.summary()
    lines.append(csv_line(
        f"serving/assign/engine_{shape}", t_eng * 1e6,
        f"us_per_query={t_eng*1e6/N_QUERIES:.2f} "
        f"qps={N_QUERIES/t_eng:.0f} "
        f"p50_us={lat['p50']*1e6:.0f} p95_us={lat['p95']*1e6:.0f} "
        f"micro_batch={MICRO_BATCH} speedup_vs_loop={t_loop/t_eng:.2f}x"))
    lines.append(csv_line(
        f"serving/guards/validate_cheap_{shape}", t_cheap * 1e6,
        f"us_per_query={t_cheap*1e6/N_QUERIES:.2f} "
        f"qps={N_QUERIES/t_cheap:.0f} "
        f"overhead_vs_off={t_cheap/t_eng:.2f}x"))
    lines.append(csv_line(
        f"serving/telemetry/engine_{shape}", t_tel * 1e6,
        f"us_per_query={t_tel*1e6/N_QUERIES:.2f} "
        f"qps={N_QUERIES/t_tel:.0f} "
        f"telemetry_overhead_vs_off={t_tel/t_eng:.2f}x"))
    lines.append(csv_line(
        f"serving/assign/stream_loop_{shape}", t_loop * 1e6,
        f"us_per_query={t_loop*1e6/N_QUERIES:.2f} "
        f"qps={N_QUERIES/t_loop:.0f} micro_batch={MICRO_BATCH}"))

    if smoke:
        _smoke_interpret_check(lines)
    return lines
