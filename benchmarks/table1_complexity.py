"""Paper Table 1: dissimilarity-computation counts vs theory.

Measures the number of pairwise dissimilarity evaluations each algorithm
performs (the quantity Table 1 bounds) and the empirical scaling exponent
in n, confirming: FasterPAM ~ n^2, OneBatchPAM ~ n log n, k-means++ ~ kn,
FasterCLARA ~ I(m^2 + kn), banditpam-lite ~ T n log n.
"""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import csv_line, run_baseline, run_obp
from repro.data.embeddings import gaussian_mixture

NS = (1000, 2000, 4000)
K = 10


def run() -> list[str]:
    lines = []
    counts: dict = {}
    for n in NS:
        x = gaussian_mixture(n, 16, centers=20, seed=0)
        rows = {
            "fasterpam": run_baseline("fasterpam", x, K, 0),
            "clara": run_baseline("clara", x, K, 0),
            "kmeans_pp": run_baseline("kmeans_pp", x, K, 0),
            "banditpam_lite": run_baseline("banditpam_lite", x, K, 0),
            "obp-nniw": run_obp(x, K, "nniw", 0),
        }
        for name, r in rows.items():
            counts.setdefault(name, []).append(r.n_dissim)
            lines.append(csv_line(
                f"table1/{name}/n{n}", r.seconds * 1e6,
                f"dissim={r.n_dissim};obj={r.objective:.4f}"))
    # empirical scaling exponent between first and last n
    for name, c in counts.items():
        slope = math.log(c[-1] / c[0]) / math.log(NS[-1] / NS[0])
        lines.append(csv_line(f"table1/{name}/exponent", 0.0,
                              f"n_scaling_exp={slope:.2f}"))
    # theory checks (paper sets m = 100*log(k*n) => counts ~ 100 n log(kn))
    n = NS[-1]
    assert counts["fasterpam"][-1] >= n * n, "fasterpam must be O(n^2)"
    bound = 110 * n * math.log(K * n)
    assert counts["obp-nniw"][-1] <= bound, \
        f"obp {counts['obp-nniw'][-1]} > {bound:.0f}"
    assert counts["kmeans_pp"][-1] <= 2 * K * n, "kmeans++ must be O(kn)"
    return lines
