"""Paper Table 3: aggregated Relative Time / Delta Relative Objective.

Small scale: reference algorithm = FasterPAM (best objective, RT=100%).
Large scale: FasterPAM/Alternate/BanditPAM are infeasible (as in the
paper) — reference = OneBatchPAM-nniw.

Validated claims (EXPERIMENTS.md §Paper-claims):
  C1  OBP-nniw ΔRO within a few % of FasterPAM (paper: 1.7%);
  C2  OBP runs a large factor faster than FasterPAM (paper: ~7x);
  C3  FasterCLARA/k-means++ are faster but much worse in objective
      (paper: 13% / 30% small-scale);
  C4  nniw is the best OBP variant; debias >= unif; lwcs degrades.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (LARGE_DATASETS, SMALL_DATASETS, csv_line,
                               run_baseline, run_obp)

KS = (5, 10, 25)
REPS = 3
VARIANTS = ("unif", "debias", "nniw", "lwcs")


def _aggregate(scale: str, datasets: dict, methods: dict, ref_method: str):
    per_method: dict = {}
    for ds_name, make in datasets.items():
        for k in KS:
            runs: dict = {}
            for rep in range(REPS):
                x = make(seed=rep)
                for m_name, fn in methods.items():
                    r = fn(x, k, rep)
                    runs.setdefault(m_name, []).append(r)
            ref_obj = np.mean([r.objective for r in runs[ref_method]])
            ref_t = np.mean([max(r.seconds, 1e-9)
                             for r in runs[ref_method]])
            for m_name, rs in runs.items():
                dro = (np.mean([r.objective for r in rs]) / ref_obj - 1) * 100
                rt = np.mean([r.seconds for r in rs]) / ref_t * 100
                per_method.setdefault(m_name, []).append((rt, dro))
    return {m: (float(np.mean([v[0] for v in vals])),
                float(np.mean([v[1] for v in vals])))
            for m, vals in per_method.items()}


def run() -> list[str]:
    lines = []

    small_methods = {
        "fasterpam": lambda x, k, s: run_baseline("fasterpam", x, k, s),
        "random": lambda x, k, s: run_baseline("random", x, k, s),
        "clara-5": lambda x, k, s: run_baseline("clara", x, k, s, repeats=5),
        "kmeans_pp": lambda x, k, s: run_baseline("kmeans_pp", x, k, s),
        "kmc2-20": lambda x, k, s: run_baseline("kmc2", x, k, s, chain=20),
        "ls_kmeans_pp-5": lambda x, k, s: run_baseline("ls_kmeans_pp", x, k,
                                                       s, local_steps=5),
        "alternate": lambda x, k, s: run_baseline("alternate", x, k, s),
        "banditpam_lite": lambda x, k, s: run_baseline("banditpam_lite",
                                                       x, k, s),
        **{f"obp-{v}": (lambda v: lambda x, k, s: run_obp(x, k, v, s))(v)
           for v in VARIANTS},
        "obp-nniw-eager": lambda x, k, s: run_obp(x, k, "nniw", s,
                                                  strategy="eager"),
    }
    small = _aggregate("small", SMALL_DATASETS, small_methods, "fasterpam")
    for m, (rt, dro) in sorted(small.items()):
        lines.append(csv_line(f"table3/small/{m}", 0.0,
                              f"RT={rt:.1f}%;dRO={dro:.2f}%"))

    large_methods = {
        "random": lambda x, k, s: run_baseline("random", x, k, s),
        "clara-5": lambda x, k, s: run_baseline("clara", x, k, s, repeats=5),
        "kmeans_pp": lambda x, k, s: run_baseline("kmeans_pp", x, k, s),
        "kmc2-20": lambda x, k, s: run_baseline("kmc2", x, k, s, chain=20),
        **{f"obp-{v}": (lambda v: lambda x, k, s: run_obp(x, k, v, s))(v)
           for v in VARIANTS},
    }
    large = _aggregate("large", LARGE_DATASETS, large_methods, "obp-nniw")
    for m, (rt, dro) in sorted(large.items()):
        lines.append(csv_line(f"table3/large/{m}", 0.0,
                              f"RT={rt:.1f}%;dRO={dro:.2f}%"))

    # paper-claims checks (loose CPU-scale bounds)
    checks = {
        "C1_obp_close_to_fasterpam": small["obp-nniw"][1] < 8.0,
        "C2_obp_faster_than_fasterpam": small["obp-nniw"][0] < 60.0,
        "C3a_clara_worse_objective": large["clara-5"][1] > large["obp-nniw"][1] + 1.0,
        "C3b_kmeanspp_worse_objective": large["kmeans_pp"][1] > large["obp-nniw"][1] + 2.0,
        "C4_nniw_best_variant": small["obp-nniw"][1] <= min(
            small["obp-unif"][1], small["obp-lwcs"][1]) + 0.5,
    }
    for name, ok in checks.items():
        lines.append(csv_line(f"table3/claim/{name}", 0.0,
                              f"pass={bool(ok)}"))
    return lines
