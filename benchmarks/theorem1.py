"""Theorem 1, empirically: P(OneBatchPAM returns FasterPAM's medoids)
as a function of the batch size m — the paper's central guarantee says
m = O(log n) suffices for agreement with high probability, reaching
certainty at m = n (the estimate becomes exact).

Protocol: same dataset, same random init, same candidate order for both
solvers (eager/first-improvement); OBP uses an unweighted uniform batch.
Also reports the m-sensitivity of the objective around the paper's
m = 100*log(k*n) heuristic (n = 4000)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line
from repro.core import baselines, solver
from repro.data.embeddings import gaussian_mixture
from repro.kernels import ops


def run() -> list[str]:
    lines = []

    # --- agreement probability vs m (small n: exact FasterPAM feasible)
    n, k, p, seeds = 240, 4, 6, 10
    for m in (8, 16, 32, 64, 128, n):
        matches, dro = 0, []
        for s in range(seeds):
            rng = np.random.default_rng(s)
            x = jnp.asarray(gaussian_mixture(n, p, centers=k, seed=s))
            d_full = ops.pairwise_distance(x, x, metric="l1", backend="ref")
            init = rng.choice(n, size=k, replace=False)
            ref = baselines._eager_pam(np.asarray(d_full), init)

            bidx = rng.choice(n, size=m, replace=False)
            d_b = np.asarray(d_full)[:, bidx]
            res = solver.solve_eager(jnp.asarray(d_b), jnp.asarray(init))
            got = np.sort(np.asarray(res.medoid_idx))
            matches += int(np.array_equal(got, np.sort(ref)))

            obj_got = float(np.asarray(d_full)[got].min(0).mean())
            obj_ref = float(np.asarray(d_full)[np.sort(ref)].min(0).mean())
            dro.append(obj_got / obj_ref - 1)
        lines.append(csv_line(
            f"theorem1/agree/m{m}", 0.0,
            f"p_match={matches/seeds:.2f};mean_dRO={np.mean(dro)*100:.2f}%"))
    # the limit case must be exact (same swaps, Theorem 1 with m = n)
    assert "p_match=1.00" in lines[-1], lines[-1]

    # --- m-sensitivity of the objective at n = 4000 (batched solver)
    n2, k2 = 4000, 10
    x2 = jnp.asarray(gaussian_mixture(n2, 16, centers=20, seed=0))
    m_paper = int(100 * math.log(k2 * n2))
    for m in (50, 100, 200, 400, 800, m_paper):
        objs = []
        for s in range(3):
            res, _ = solver.one_batch_pam(
                __import__("jax").random.PRNGKey(s), x2, k2, m=m,
                variant="nniw", backend="ref")
            objs.append(float(solver.objective(x2, res.medoid_idx,
                                               backend="ref")))
        tag = " (paper heuristic)" if m == m_paper else ""
        lines.append(csv_line(
            f"theorem1/m_sens/m{m}", 0.0,
            f"obj={np.mean(objs):.4f};std={np.std(objs):.4f}{tag}"))
    return lines
