"""Large-scale OneBatchPAM: the paper's workload at 200k points, all four
batch variants, plus the distributed (shard_map) solver on host devices.

    PYTHONPATH=src python examples/cluster_embeddings.py
    # distributed path (8 forced host devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/cluster_embeddings.py --distributed
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MedoidSelector, sampling, solver
from repro.data import heavy_tail

N, P, K = 200_000, 24, 64


def single_process():
    x = heavy_tail(N, P, seed=0)
    print(f"== OneBatchPAM variants on {N} x {P} (k={K}) ==")
    m = sampling.default_batch_size(N, K)
    print(f"batch size m = 100*log(k*n) = {m}  "
          f"({N * m:,} distance evals vs n^2 = {N * N:,})")
    for variant in sampling.VARIANTS:
        t0 = time.perf_counter()
        sel = MedoidSelector(k=K, variant=variant, seed=0).fit(x)
        dt = time.perf_counter() - t0
        print(f"{variant:7s}: obj={sel.objective(x):.4f} time={dt:5.1f}s "
              f"swaps={sel.n_swaps_}")


def distributed():
    from jax.sharding import NamedSharding, PartitionSpec as P_
    from repro.core.distributed import make_distributed_obp

    n_dev = jax.device_count()
    assert n_dev >= 4, "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
    mesh = jax.make_mesh((n_dev // 2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    x = heavy_tail(N, P + 8, seed=0)  # p=32, divisible by model axis
    rng = np.random.default_rng(0)
    m = sampling.default_batch_size(N, K)
    batch_idx = jnp.asarray(rng.choice(N, m, replace=False))
    weights = jnp.ones((m,), jnp.float32)
    init = jnp.asarray(rng.choice(N, K, replace=False))

    run = make_distributed_obp(mesh, k=K, metric="l1")
    xs = jax.device_put(jnp.asarray(x),
                        NamedSharding(mesh, P_(("data",), "model")))
    t0 = time.perf_counter()
    res = run(xs, batch_idx, weights, init)
    jax.block_until_ready(res)
    dt = time.perf_counter() - t0
    obj = float(solver.objective(jnp.asarray(x), res.medoid_idx))
    print(f"distributed OBP on {n_dev} devices: obj={obj:.4f} "
          f"time={dt:.1f}s swaps={int(res.n_swaps)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()
    distributed() if args.distributed else single_process()
