"""Large-scale OneBatchPAM: the paper's workload at 200k points, all four
batch variants with a streamed distance build, plus the distributed
(shard_map) solver on host devices with the batch built in-mesh.

    PYTHONPATH=src python examples/cluster_embeddings.py
    # bound peak intermediate memory to ~chunk x m floats:
    PYTHONPATH=src python examples/cluster_embeddings.py --chunk-size 8192
    # best-of-8 vmapped restarts with held-out election (DESIGN.md §2a):
    PYTHONPATH=src python examples/cluster_embeddings.py --restarts 8
    # matrix-free sweep: the (n, m) block never exists (DESIGN.md §2b) —
    # resident memory drops from O(n*m) to O(n*p):
    PYTHONPATH=src python examples/cluster_embeddings.py --matrix-free
    # distributed path (8 forced host devices), n sharded over the mesh:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/cluster_embeddings.py --distributed
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MedoidSelector, sampling, solver
from repro.data import heavy_tail

N, P, K = 200_000, 24, 64


def single_process(chunk_size: int | None, restarts: int = 1,
                   matrix_free: bool = False):
    x = heavy_tail(N, P, seed=0)
    print(f"== OneBatchPAM variants on {N} x {P} (k={K}) ==")
    m = sampling.default_batch_size(N, K)
    print(f"batch size m = 100*log(k*n) = {m}  "
          f"({N * m:,} distance evals vs n^2 = {N * N:,})")
    if matrix_free:
        print(f"matrix-free: block would be {N * m * 4 / 2**20:.0f} MiB f32; "
              f"resident instead: X = {N * P * 4 / 2**20:.0f} MiB "
              f"(DESIGN.md §2b, swaps identical to the block path)")
    if restarts > 1:
        print(f"restarts: R={restarts} vmapped searches on one pooled "
              f"R*m column sample, held-out election (DESIGN.md §2a)")
    if chunk_size:
        # Per-chunk f32 working set: (chunk, m) output on the TPU kernel
        # path; the CPU ref path's broadcast slab is larger (up to a
        # factor of p) — the exact accounting is in DESIGN.md §7.
        print(f"streaming: chunk_size={chunk_size} "
              f"((chunk, m) block slice = {chunk_size * m * 4 / 2**20:.0f} "
              f"MiB per chunk; CPU ref intermediates peak higher, see "
              f"DESIGN.md §7)")
    strategy = "matrix_free" if matrix_free else "batched"
    for variant in sampling.VARIANTS:
        t0 = time.perf_counter()
        sel = MedoidSelector(k=K, variant=variant, seed=0, strategy=strategy,
                             chunk_size=chunk_size, restarts=restarts).fit(x)
        dt = time.perf_counter() - t0
        extra = (f" restart={sel.best_restart_}/{restarts}"
                 if restarts > 1 else "")
        print(f"{variant:7s}: obj={sel.objective(x):.4f} time={dt:5.1f}s "
              f"swaps={sel.n_swaps_}{extra}")


def distributed(chunk_size: int | None):
    from repro.core.distributed import make_distributed_obp_e2e, shard_over_batch

    n_dev = jax.device_count()
    assert n_dev >= 4, "run with XLA_FLAGS=--xla_force_host_platform_device_count=8"
    mesh = jax.make_mesh((n_dev,), ("data",))
    x = heavy_tail(N, P, seed=0)
    rng = np.random.default_rng(0)
    m = sampling.default_batch_size(N, K)
    batch_idx = jnp.asarray(rng.choice(N, m, replace=False))
    init = jnp.asarray(rng.choice(N, K, replace=False))

    # e2e: the nniw weights are built in-mesh from the sharded rows (one
    # (m,)-float psum), the solve sweeps data-parallel (DESIGN.md §5).
    run = make_distributed_obp_e2e(mesh, k=K, metric="l1", variant="nniw",
                                   chunk_size=chunk_size)
    xs = shard_over_batch(mesh, jnp.asarray(x))
    t0 = time.perf_counter()
    res, weights = run(xs, batch_idx, init)
    jax.block_until_ready(res)
    dt = time.perf_counter() - t0
    obj = float(solver.objective(jnp.asarray(x), res.medoid_idx,
                                 chunk_size=chunk_size))
    print(f"distributed OBP on {n_dev} devices: obj={obj:.4f} "
          f"time={dt:.1f}s swaps={int(res.n_swaps)} "
          f"nniw weight mean={float(jnp.mean(weights)):.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="stream the n axis in row chunks of this size")
    ap.add_argument("--restarts", type=int, default=1,
                    help="vmapped multi-restart best-of-R (DESIGN.md §2a)")
    ap.add_argument("--matrix-free", action="store_true",
                    help="block-free fused sweep (DESIGN.md §2b)")
    args = ap.parse_args()
    if args.distributed:
        distributed(args.chunk_size)
    else:
        single_process(args.chunk_size, args.restarts, args.matrix_free)
