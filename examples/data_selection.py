"""End-to-end driver: OneBatchPAM data curation inside a real training run.

    PYTHONPATH=src python examples/data_selection.py

Pipeline (the paper's "subset selection" use case, productionised):
  1. train a ~15M-param TinyLlama-family model for a few hundred steps on
     a synthetic corpus (checkpointed, resumable — kill it mid-run and
     rerun: it resumes);
  2. embed a pool of candidate sequences with the model's final hidden
     states;
  3. OneBatchPAM-nniw selects k medoid sequences (diverse, representative);
  4. continue training on the curated subset vs a random subset of the
     same size and report the eval-loss difference.
"""
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get, reduced
from repro.core import MedoidSelector
from repro.data import TokenIterator, build_synthetic
from repro.models import transformer
from repro.training import OptConfig, init_train_state, make_train_step

STEPS_BASE = 150
STEPS_FT = 60
CKPT = "/tmp/repro_data_selection"


def eval_loss(step_fn_loss, params, cfg, batches):
    tot = 0.0
    for b in batches:
        tot += float(step_fn_loss(params, b))
    return tot / len(batches)


def main():
    cfg = dataclasses.replace(
        reduced(get("tinyllama-1.1b")), d_model=128, num_layers=4,
        vocab_size=2048)
    oc = OptConfig(lr=2e-3, warmup_steps=20, total_steps=STEPS_BASE + STEPS_FT)
    step_fn = jax.jit(make_train_step(cfg, oc))

    os.makedirs(CKPT, exist_ok=True)
    store = build_synthetic(os.path.join(CKPT, "corpus.bin"), 3_000_000,
                            cfg.vocab_size, seed=0)
    it = TokenIterator(store, 16, 64, seed=0)

    state = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    start = 0
    if ckpt.latest_step(CKPT) is not None:
        target = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), state)
        state, extra = ckpt.restore(CKPT, target)
        it.restore(extra["data"])
        start = int(extra["step"])
        print(f"[resume] continuing from step {start}")

    print(f"== phase 1: base training ({STEPS_BASE} steps) ==")
    t0 = time.perf_counter()
    for step in range(start, STEPS_BASE):
        batch = it.__next__()
        state, m = step_fn(state, {"tokens": jnp.asarray(batch["tokens"])})
        if step % 25 == 0:
            print(f"step {step:4d} loss {float(m['loss']):.4f}")
        if (step + 1) % 50 == 0:
            ckpt.save(CKPT, step + 1, state,
                      extra={"data": it.state(), "step": step + 1})
    print(f"base training: {time.perf_counter() - t0:.1f}s")

    print("== phase 2: embed candidate pool, select medoids ==")
    pool_it = TokenIterator(store, 64, 64, seed=99)
    pool = np.concatenate([pool_it.__next__()["tokens"] for _ in range(8)])

    @jax.jit
    def embed(params, tokens):
        feats, _ = transformer.forward(params, cfg, tokens, features=True,
                                       remat=False)
        return feats.mean(axis=1)  # (B, d) sequence embedding

    embs = np.concatenate(
        [np.asarray(embed(state["params"], jnp.asarray(pool[i:i + 64])))
         for i in range(0, len(pool), 64)])
    k_sel = 128
    sel = MedoidSelector(k=k_sel, variant="nniw", seed=0).fit(embs)
    curated = pool[sel.medoid_indices_]
    rng = np.random.default_rng(0)
    random_subset = pool[rng.choice(len(pool), k_sel, replace=False)]
    print(f"pool={len(pool)} seqs -> curated {k_sel} medoids "
          f"(obj={sel.objective(embs):.4f})")

    print("== phase 3: fine-tune on curated vs random subset ==")
    loss_grad = jax.jit(lambda p, t: make_train_step(cfg, oc)(
        {"params": p, "m": state["m"], "v": state["v"],
         "step": state["step"]}, {"tokens": t})[1]["loss"])

    eval_batches = [jnp.asarray(TokenIterator(store, 16, 64, seed=7)
                                .__next__()["tokens"]) for _ in range(4)]

    results = {}
    for name, subset in (("curated", curated), ("random", random_subset)):
        st = jax.tree.map(jnp.copy, state)
        for step in range(STEPS_FT):
            idx = np.random.default_rng(step).choice(len(subset), 16)
            st, m = step_fn(st, {"tokens": jnp.asarray(subset[idx])})
        lo = eval_loss(lambda p, b: loss_grad(p, b), st["params"], cfg,
                       eval_batches)
        results[name] = lo
        print(f"fine-tune on {name:8s}: eval loss {lo:.4f}")

    print(f"\ncurated - random eval-loss delta: "
          f"{results['curated'] - results['random']:+.4f} "
          f"(negative = curation helped)")


if __name__ == "__main__":
    main()
