"""Quickstart: OneBatchPAM in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Cluster 20k synthetic embeddings with OneBatchPAM (paper Algorithm 1)
   and compare objective/time against FasterPAM (exact), CLARA, k-means++.
2. Use the medoids as a curated subset for a tiny LM training run.
"""
import time

import jax
import numpy as np

from repro.core import MedoidSelector, baselines
from repro.data import gaussian_mixture

N, P, K = 20_000, 32, 32


def main():
    x = gaussian_mixture(N, P, centers=K, seed=0)

    print(f"== k-medoids on {N} x {P} embeddings, k={K} ==")
    t0 = time.perf_counter()
    sel = MedoidSelector(k=K, variant="nniw", seed=0).fit(x)
    t_obp = time.perf_counter() - t0
    obj_obp = sel.objective(x)
    print(f"OneBatchPAM-nniw : obj={obj_obp:.4f}  time={t_obp:.2f}s  "
          f"swaps={sel.n_swaps_}  (distance evals ~ n*m = "
          f"{N * (sel.m or 0) if sel.m else 'n*100log(kn)'})")

    # Best-of-8: one pooled column sample, 8 vmapped local searches, the
    # winner elected on a held-out batch (DESIGN.md §2a).
    t0 = time.perf_counter()
    sel8 = MedoidSelector(k=K, variant="nniw", seed=0, restarts=8).fit(x)
    t8 = time.perf_counter() - t0
    print(f"OneBatchPAM R=8  : obj={sel8.objective(x):.4f}  time={t8:.2f}s  "
          f"elected restart {sel8.best_restart_} of 8 on held-out batch")

    # competitors (FasterPAM on a subsample — full 20k^2 is the point of
    # the paper: it would need 3.2 GB and minutes)
    sub = x[np.random.default_rng(0).choice(N, 4000, replace=False)]
    oracle = baselines.Oracle(sub)
    r = baselines.fasterpam(np.random.default_rng(0), oracle, K)
    print(f"FasterPAM (n=4000 subsample!): obj(sub)={r.objective:.4f}  "
          f"time={r.seconds:.2f}s  dissim={r.n_dissim:,}")

    oracle = baselines.Oracle(x)
    r = baselines.clara(np.random.default_rng(0), oracle, K)
    print(f"FasterCLARA-5    : obj={r.objective:.4f}  time={r.seconds:.2f}s")
    r = baselines.kmeans_pp(np.random.default_rng(0), oracle, K)
    print(f"k-means++        : obj={r.objective:.4f}  time={r.seconds:.2f}s")

    print("\n== medoid-curated subset for LM training ==")
    labels = sel.predict(x)
    sizes = np.bincount(labels, minlength=K)
    print(f"cluster sizes: min={sizes.min()} median={int(np.median(sizes))} "
          f"max={sizes.max()}")
    print("medoid rows are the k most representative examples; "
          "see examples/data_selection.py for the end-to-end trainer.")


if __name__ == "__main__":
    main()
