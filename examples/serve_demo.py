"""Batched serving demo + OBP prompt clustering.

    PYTHONPATH=src python examples/serve_demo.py

Serves a small gemma2-family model with the KV-cache engine (prefill +
batched greedy decode), then clusters the prompt embeddings with
OneBatchPAM — the serving-side use: route prompts to k representative
"canonical prompts" (prefix-cache seeding / load balancing).
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, reduced
from repro.core import MedoidSelector
from repro.models import transformer
from repro.serving import Engine
from repro.training import init_train_state, OptConfig


def main():
    cfg = reduced(get("gemma2-27b"))
    params = transformer.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=96)

    B, S0, NEW = 8, 16, 24
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(B, S0)).astype(np.int32)

    t0 = time.perf_counter()
    out = eng.generate(prompts, NEW)
    dt = time.perf_counter() - t0
    print(f"generated {B} x {NEW} tokens in {dt:.1f}s "
          f"({B * NEW / dt:.1f} tok/s on CPU)")
    assert out.shape == (B, S0 + NEW)
    print("sample continuation ids:", out[0, S0:S0 + 10].tolist())

    # prompt clustering for cache routing
    @jax.jit
    def embed(tokens):
        feats, _ = transformer.forward(params, cfg, tokens, features=True,
                                       remat=False)
        return feats.mean(axis=1)

    pool = np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(512, S0)).astype(np.int32)
    embs = np.asarray(embed(jnp.asarray(pool)))
    sel = MedoidSelector(k=8, variant="nniw", seed=0).fit(embs)
    routes = sel.predict(embs)
    print(f"prompt pool of {len(pool)} routed to {len(set(routes))} "
          f"canonical prompts; route sizes: "
          f"{np.bincount(routes, minlength=8).tolist()}")


if __name__ == "__main__":
    main()
