"""k-medoids assignment serving demo (DESIGN.md §9).

    PYTHONPATH=src python examples/serve_demo.py

Embeds a pool of prompts with a small gemma2-family model, fits
OneBatchPAM medoids on the embeddings, then serves nearest-medoid
assignment through the AssignmentEngine — the serving-side use: route
each incoming prompt to one of k representative "canonical prompts"
(prefix-cache seeding / load balancing). Ends by drifting the query
stream and letting the engine's monitor trigger a warm-start refit.

Runs with ``telemetry="on"`` (DESIGN.md §10): after the drift/refit
cycle it prints an excerpt of the live Prometheus scrape (fetched over
HTTP from ``eng.serve_metrics()``) and writes the Chrome trace to
``serve_demo_trace.json`` — load it in Perfetto / chrome://tracing to
see the per-micro-batch spans and the refit.
"""
import time
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, reduced
from repro.core import MedoidSelector
from repro.models import transformer
from repro.serving import AssignmentEngine


def main():
    cfg = reduced(get("gemma2-27b"))
    params = transformer.init_lm(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def embed(tokens):
        feats, _ = transformer.forward(params, cfg, tokens, features=True,
                                       remat=False)
        return feats.mean(axis=1)

    S0 = 16
    pool = np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(512, S0)).astype(np.int32)
    embs = np.asarray(embed(jnp.asarray(pool)))

    sel = MedoidSelector(k=8, variant="nniw", seed=0).fit(embs)
    eng = AssignmentEngine.from_selector(sel, micro_batch=256,
                                         drift_threshold=1.05,
                                         refit_window=4096,
                                         telemetry="on")

    t0 = time.perf_counter()
    routes, d1 = eng.assign(embs)
    dt = time.perf_counter() - t0
    print(f"routed {len(pool)} prompts to {len(set(routes.tolist()))} "
          f"canonical prompts in {dt * 1e3:.1f} ms "
          f"({len(pool) / dt:.0f} qps on CPU); route sizes: "
          f"{np.bincount(routes, minlength=8).tolist()}")

    # Drift the stream: new prompts from a shifted distribution push the
    # assignment objective above the fit-time estimate, the monitor arms
    # a background refit warm-started from the live medoids, and the new
    # medoid snapshot swaps in atomically under the serving loop.
    drifted = embs + np.float32(3.0)
    for _ in range(8):
        eng.assign(drifted)
    while eng.refit_in_flight:
        time.sleep(0.05)
    s = eng.stats()
    print(f"after drift: medoid_version={s['medoid_version']} "
          f"refits={s['refits']} drift_ratio={s['drift_ratio']:.3f} "
          f"p50={s['latency']['p50'] * 1e3:.2f} ms "
          f"p95={s['latency']['p95'] * 1e3:.2f} ms")

    # Observability (PR 10): scrape the live endpoint, keep the serving
    # series, and export the span trace.
    srv = eng.serve_metrics()
    with urllib.request.urlopen(srv.url, timeout=10) as resp:
        scrape = resp.read().decode()
    serving_lines = [ln for ln in scrape.splitlines()
                     if ln.startswith("serving_") and "_bucket" not in ln]
    print(f"\nprometheus scrape ({srv.url}, "
          f"{len(scrape.splitlines())} lines; serving series):")
    for ln in serving_lines:
        print(f"  {ln}")
    trace = eng.write_trace("serve_demo_trace.json")
    print(f"\nchrome trace -> {trace} (open in Perfetto / chrome://tracing)")
    eng.close()


if __name__ == "__main__":
    main()
