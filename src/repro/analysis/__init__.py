from . import hlo, roofline  # noqa: F401
