"""Loop-aware cost model over optimized (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` visits each instruction once — a 94-layer
model expressed as ``lax.scan`` reports 1/47th of its real FLOPs, and
collectives inside the scan body (FSDP all-gathers!) vanish from any naive
sum. This parser rebuilds per-device costs with while-loop bodies expanded
by their trip counts (read from XLA's ``known_trip_count`` backend config,
with a fallback to the loop-condition constant).

Costs are PER DEVICE (the compiled module is the partitioned one):
  flops            — 2*M*N*K for dots (batch dims included); elementwise
                     ops contribute #result elements (noise next to dots).
  hbm_bytes        — operand+result bytes at fusion boundaries (inner
                     fused instructions stay in registers/VMEM).
  collectives      — per class: operand bytes (the spec's definition) and
                     modeled ring wire bytes/device; DCN-crossing groups
                     (multi-pod) are flagged when group membership is
                     explicit.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INST_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    op: str
    rest: str          # operand list + attributes (raw tail of the line)
    is_root: bool = False

    def operand_refs(self):
        return _OPERAND_RE.findall(self.rest.split("), ")[0])


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_operand_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    dcn_wire_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    warnings: list = dataclasses.field(default_factory=list)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_operand_bytes += other.coll_operand_bytes * mult
        self.coll_wire_bytes += other.coll_wire_bytes * mult
        self.dcn_wire_bytes += other.dcn_wire_bytes * mult
        for k, v in other.coll_by_op.items():
            cur = self.coll_by_op.get(k, [0.0, 0.0, 0])
            self.coll_by_op[k] = [cur[0] + v[0] * mult,
                                  cur[1] + v[1] * mult,
                                  cur[2] + int(v[2] * mult)]
        self.warnings.extend(w for w in other.warnings
                             if w not in self.warnings)


def parse_computations(hlo_text: str):
    """-> (computations: name -> [Instruction], entry_name)."""
    comps: dict = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                name = m.group(2)
                comps[name] = []
                cur = name
                if m.group(1):
                    entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INST_RE.match(line)
        if m:
            comps[cur].append(Instruction(m.group(2), m.group(3),
                                          m.group(4), m.group(5),
                                          is_root=bool(m.group(1))))
    return comps, entry


def _group_info(rest: str, num_pods_boundary: int | None):
    """-> (group_size, crosses_dcn or None-if-unknown)."""
    m = _GROUPS_RE.search(rest)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x]
        crosses = None
        if num_pods_boundary:
            pods = {i // num_pods_boundary for i in ids}
            crosses = len(pods) > 1
        return max(len(ids), 1), crosses
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        # iota form [num_groups, group_size]<=[total]...
        return max(int(m.group(2)), 1), None
    return 1, None


def _wire_bytes(op: str, operand_bytes: float, result_bytes: float,
                n: int) -> float:
    if op.startswith("collective-permute"):
        return operand_bytes          # point-to-point: group size n/a
    if n <= 1:
        return 0.0
    if op.startswith("all-reduce"):
        return 2.0 * operand_bytes * (n - 1) / n
    if op.startswith("all-gather"):
        return result_bytes * (n - 1) / n
    if op.startswith("reduce-scatter"):
        return operand_bytes * (n - 1) / n
    if op.startswith("all-to-all"):
        return operand_bytes * (n - 1) / n
    if op.startswith("collective-permute"):
        return operand_bytes
    return operand_bytes


class HloCost:
    def __init__(self, hlo_text: str, *, pod_size: int | None = None):
        self.comps, self.entry = parse_computations(hlo_text)
        self.pod_size = pod_size
        self._symtab = {
            name: {i.name: i.result_type for i in insts}
            for name, insts in self.comps.items()
        }
        self._memo: dict = {}

    def _operand_types(self, comp: str, inst: Instruction):
        tab = self._symtab[comp]
        head = inst.rest.split("), ")[0]
        return [tab.get(ref) for ref in _OPERAND_RE.findall(head)
                if tab.get(ref)]

    def cost(self, comp: str | None = None, *, _in_fusion=False) -> Cost:
        comp = comp or self.entry
        key = (comp, _in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        for inst in self.comps.get(comp, []):
            total.add(self._inst_cost(comp, inst, _in_fusion))
        self._memo[key] = total
        return total

    def _inst_cost(self, comp: str, inst: Instruction,
                   in_fusion: bool) -> Cost:
        c = Cost()
        op = inst.op
        res_bytes = shape_bytes(inst.result_type)
        res_elems = shape_elems(inst.result_type)

        if op == "while":
            m = _TRIP_RE.search(inst.rest)
            trip = int(m.group(1)) if m else None
            body = _BODY_RE.search(inst.rest)
            cond = _COND_RE.search(inst.rest)
            if trip is None and cond:
                trip = self._trip_from_cond(cond.group(1))
            if trip is None:
                trip = 1
                c.warnings.append(f"while {inst.name}: unknown trip count")
            if body:
                c.add(self.cost(body.group(1)), trip)
            if cond:
                c.add(self.cost(cond.group(1)), trip)
            return c

        if op in ("call", "conditional"):
            m = _TO_APPLY_RE.search(inst.rest)
            if m:
                c.add(self.cost(m.group(1)))
            return c

        if op == "fusion":
            m = _CALLS_RE.search(inst.rest)
            called = m.group(1) if m else None
            if called:
                inner = self.cost(called, _in_fusion=True)
                c.flops += inner.flops
                c.add(Cost(coll_operand_bytes=inner.coll_operand_bytes,
                           coll_wire_bytes=inner.coll_wire_bytes,
                           dcn_wire_bytes=inner.dcn_wire_bytes,
                           coll_by_op=inner.coll_by_op))
                c.hbm_bytes += self._fusion_io_bytes(comp, inst, called,
                                                     res_bytes)
            else:
                op_bytes = sum(shape_bytes(t)
                               for t in self._operand_types(comp, inst))
                c.hbm_bytes += res_bytes + op_bytes
            return c

        if op == "dynamic-slice":
            # reads only the slice (+ writes it)
            c.hbm_bytes += 2 * res_bytes
            c.flops += res_elems
            return c

        if op == "dynamic-update-slice":
            # in-place: reads + writes the update slice only
            refs = inst.operand_refs()
            upd = (self._symtab[comp].get(refs[1])
                   if len(refs) > 1 else None)
            ub = shape_bytes(upd) if upd else res_bytes
            c.hbm_bytes += 2 * ub
            return c

        if any(op.startswith(p) for p in COLLECTIVE_OPS):
            if op.endswith("-done"):
                return c
            op_bytes = sum(shape_bytes(t)
                           for t in self._operand_types(comp, inst))
            n, crosses = _group_info(inst.rest, self.pod_size)
            wire = _wire_bytes(op, op_bytes, res_bytes, n)
            c.coll_operand_bytes += op_bytes
            c.coll_wire_bytes += wire
            if crosses:
                c.dcn_wire_bytes += wire
            base = op.replace("-start", "")
            cur = c.coll_by_op.get(base, [0.0, 0.0, 0])
            c.coll_by_op[base] = [cur[0] + op_bytes, cur[1] + wire,
                                  cur[2] + 1]
            c.hbm_bytes += res_bytes + op_bytes
            return c

        if op == "dot":
            lhs_types = self._operand_types(comp, inst)
            m = _LHS_CONTRACT_RE.search(inst.rest)
            contract = 1
            if m and lhs_types:
                dims = _first_shape_dims(lhs_types[0])
                for idx in (int(x) for x in m.group(1).split(",") if x):
                    if idx < len(dims):
                        contract *= dims[idx]
            c.flops += 2.0 * res_elems * contract
            if not in_fusion:
                op_bytes = sum(shape_bytes(t)
                               for t in self._operand_types(comp, inst))
                c.hbm_bytes += res_bytes + op_bytes
            return c

        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all"):
            return c

        if op == "convolution":
            # depthwise convs (mamba) only: approximate 2 * out * K
            c.flops += 2.0 * res_elems * 8
            if not in_fusion:
                c.hbm_bytes += res_bytes * 2
            return c

        # generic elementwise-ish op
        c.flops += res_elems
        if not in_fusion:
            op_bytes = sum(shape_bytes(t)
                           for t in self._operand_types(comp, inst))
            c.hbm_bytes += res_bytes + op_bytes
        return c

    def _fusion_io_bytes(self, comp, inst, called, res_bytes) -> float:
        """Fusion-boundary bytes with slice-aware accounting: a fused
        dynamic-slice reads only its slice; a fusion rooted in a
        dynamic-update-slice writes only the update (XLA aliases the
        buffer in place). Without this, scan bodies appear to stream the
        whole sequence buffer every timestep (1000x overcounts)."""
        insts = self.comps.get(called, [])
        if not insts:
            return res_bytes
        symtab = self._symtab[called]
        params = {}
        for i2 in insts:
            if i2.op == "parameter":
                m = _PARAM_IDX_RE.search("parameter(" + i2.rest)
                if m:
                    params[i2.name] = int(m.group(1))
        root = next((i2 for i2 in insts if i2.is_root), insts[-1])
        # uses of each parameter
        reads = 0.0
        for pname in params:
            ptype = symtab.get(pname, "")
            uses = []
            for i2 in insts:
                if i2.op == "parameter":
                    continue
                refs = i2.operand_refs()
                if pname in refs:
                    uses.append((i2, refs.index(pname)))
            if not uses:
                continue
            sliced = all(i2.op == "dynamic-slice" and pos == 0
                         for i2, pos in uses)
            dus_root = all(i2.op == "dynamic-update-slice" and pos == 0
                           and i2.is_root for i2, pos in uses)
            if sliced:
                reads += sum(shape_bytes(i2.result_type) for i2, _ in uses)
            elif dus_root:
                pass  # aliased in-place output; written below
            else:
                reads += shape_bytes(ptype)
        if root.op == "dynamic-update-slice":
            refs = root.operand_refs()
            upd = symtab.get(refs[1]) if len(refs) > 1 else None
            written = shape_bytes(upd) if upd else res_bytes
        else:
            written = res_bytes
        return reads + written

    def _trip_from_cond(self, cond_comp: str):
        for inst in self.comps.get(cond_comp, []):
            if inst.op == "constant":
                m = re.search(r"constant\((\d+)\)", "constant(" + inst.rest)
                if m:
                    return int(m.group(1))
        return None


def analyze(hlo_text: str, *, pod_size: int | None = None) -> dict:
    """Top-level: per-device cost dict for a compiled module's HLO text."""
    hc = HloCost(hlo_text, pod_size=pod_size)
    c = hc.cost()
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "coll_operand_bytes": c.coll_operand_bytes,
        "coll_wire_bytes": c.coll_wire_bytes,
        "dcn_wire_bytes": c.dcn_wire_bytes,
        "coll_by_op": {k: {"operand_bytes": v[0], "wire_bytes": v[1],
                           "count": v[2]}
                       for k, v in c.coll_by_op.items()},
        "warnings": c.warnings,
    }
