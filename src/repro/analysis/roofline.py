"""Three-term roofline model for TPU v5e (the TARGET hardware; this
container is CPU-only so terms are derived from the compiled dry-run
artifact, not measured).

  compute    = flops_per_device / PEAK_FLOPS
  memory     = hbm_bytes_per_device / HBM_BW
  collective = coll_wire_bytes_per_device / ICI_BW

Hardware constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D-torus links are modeled as one aggregate per-chip
pipe at link speed, matching the task spec); DCN (inter-pod) modeled at
12.5 GB/s/chip for the multi-pod detail rows.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 12.5e9


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dcn_s: float
    bottleneck: str
    step_s: float              # max of terms (perfect-overlap lower bound)
    model_flops: float         # 6*N*D (or 6*N_active*D)
    useful_ratio: float        # model_flops / hlo_flops (per step, global)
    mfu: float                 # model_flops / (step_s * chips * peak)

    def as_dict(self):
        return dataclasses.asdict(self)


def compute(costs: dict, *, chips: int, model_flops_global: float) -> Roofline:
    """costs: per-device dict from analysis.hlo.analyze()."""
    c = costs["flops"] / PEAK_FLOPS
    m = costs["hbm_bytes"] / HBM_BW
    ici = max(costs["coll_wire_bytes"] - costs["dcn_wire_bytes"], 0.0) / ICI_BW
    dcn = costs["dcn_wire_bytes"] / DCN_BW
    coll = ici + dcn
    terms = {"compute": c, "memory": m, "collective": coll}
    bottleneck = max(terms, key=terms.get)
    step = max(terms.values())
    hlo_flops_global = costs["flops"] * chips
    useful = (model_flops_global / hlo_flops_global
              if hlo_flops_global else 0.0)
    mfu = (model_flops_global / (step * chips * PEAK_FLOPS)
           if step > 0 else 0.0)
    return Roofline(c, m, coll, dcn, bottleneck, step,
                    model_flops_global, useful, mfu)


def model_flops(cfg, shape, *, backward: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D for training (fwd 2ND + bwd 4ND), 2*N*D for
    inference, with N = active params (MoE) and D = tokens processed."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch
