from .checkpoint import (  # noqa: F401
    all_steps,
    latest_step,
    manifest,
    restore,
    restore_latest_valid,
    save,
)
