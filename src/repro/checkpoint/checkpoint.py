"""Fault-tolerant checkpointing.

Design (1000+-node posture, DESIGN.md §5):
  * atomic: a checkpoint is written to ``step_XXXXXXXX.tmp`` and renamed
    only when complete — a preempted writer can never corrupt "latest";
    stale .tmp dirs are garbage-collected on the next save/restore.
  * topology-independent: leaves are stored as full (unsharded) .npy
    arrays keyed by their pytree path; restore re-shards onto whatever
    mesh the reader is running — pods can join/leave between runs
    (elastic scaling).
  * resumable end-to-end: arbitrary JSON "extra" state rides along (data
    iterator position, RNG seeds), so ``--resume auto`` reproduces the
    exact training trajectory.
  * bounded disk: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import warnings

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _leafname(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "__".join(parts)


def _gc_tmp(root: str) -> None:
    if not os.path.isdir(root):
        return
    for d in os.listdir(root):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(root: str, step: int, state, extra: dict | None = None,
         keep: int = 3, fsync: bool = False) -> str:
    """Write `state` (pytree of arrays) atomically. Returns final dir.

    ``fsync=True`` flushes every leaf, the manifest, and the directory
    entries to stable storage *before* the rename makes the step
    visible — a checkpoint that survives power loss, not just process
    death. Off by default: solver sweep checkpoints are throwaway-
    rewritable and the flush costs real latency; serving medoid
    snapshots (DESIGN.md §9a) turn it on because a resumed process
    trusts the newest visible generation absolutely.
    """
    os.makedirs(root, exist_ok=True)
    _gc_tmp(root)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    manifest = {"step": int(step), "extra": extra or {}, "leaves": []}
    for path, leaf in flat:
        name = _leafname(path)
        arr = np.asarray(leaf)  # device -> host; gathers sharded arrays
        fname = os.path.join(tmp, name + ".npy")
        with open(fname, "wb") as f:
            np.save(f, arr)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        manifest["leaves"].append(
            {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    if os.path.isdir(final):  # overwrite-safe
        shutil.rmtree(final)
    if fsync:
        _fsync_dir(tmp)
    os.rename(tmp, final)
    if fsync:
        _fsync_dir(root)        # durable *visibility*: the rename itself

    steps = sorted(all_steps(root))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{old:08d}"),
                      ignore_errors=True)
    return final


def all_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(root, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = all_steps(root)
    return steps[-1] if steps else None


def manifest(root: str, step: int | None = None) -> dict:
    """The manifest dict of checkpoint ``step`` (default: latest) —
    ``{"step", "extra", "leaves": [{"name", "shape", "dtype"}, ...]}``.
    Callers use it to build a restore target without knowing the
    schema up front (selector ``from_checkpoint``)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    with open(os.path.join(root, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)


def restore(root: str, target, step: int | None = None, *,
            shardings=None):
    """Restore into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs). Returns (state, extra). With `shardings` (a
    matching pytree of NamedSharding), leaves are device_put sharded —
    this is the elastic-rescale path.

    Robust to schema drift in both directions: every missing /
    unloadable / shape-mismatched leaf is collected and reported in ONE
    aggregated ``ValueError`` (a schema migration sees the full diff,
    not the first casualty), and leaves present on disk but absent from
    ``target`` are tolerated with a warning (an older reader can open a
    newer writer's checkpoint).
    """
    _gc_tmp(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        man = json.load(f)
    on_disk = {leaf["name"] for leaf in man.get("leaves", [])}

    flat, _ = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves, problems, wanted = [], [], set()
    for (path, tgt), shd in zip(flat, shard_flat):
        name = _leafname(path)
        wanted.add(name)
        fname = os.path.join(d, name + ".npy")
        if not os.path.exists(fname):
            problems.append(f"{name}: missing from checkpoint "
                            f"(manifest {'lists' if name in on_disk else 'omits'} it)")
            leaves.append(None)
            continue
        try:
            arr = np.load(fname)
        except Exception as e:
            problems.append(f"{name}: unreadable ({e})")
            leaves.append(None)
            continue
        want_shape = tuple(tgt.shape)
        if tuple(arr.shape) != want_shape:
            problems.append(
                f"{name}: shape {tuple(arr.shape)} != expected {want_shape}")
            leaves.append(None)
            continue
        arr = arr.astype(tgt.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None else arr)
    if problems:
        raise ValueError(
            f"checkpoint {d} does not match the restore target "
            f"({len(problems)} leaf problem(s)):\n  " +
            "\n  ".join(problems))
    unknown = sorted(on_disk - wanted)
    if unknown:
        warnings.warn(
            f"checkpoint {d} carries {len(unknown)} leaf(s) unknown to "
            f"this reader (ignored): {', '.join(unknown)}",
            UserWarning, stacklevel=2)
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)
    return state, man.get("extra", {})


def restore_latest_valid(root: str, target, *, shardings=None):
    """Restore the newest checkpoint that actually loads, walking
    backwards over older steps when the newest is truncated/corrupt
    (each skip warns with the reason). Returns ``(state, extra, step)``;
    raises FileNotFoundError when no step restores."""
    steps = all_steps(root)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {root}")
    last_err = None
    for step in reversed(steps):
        try:
            state, extra = restore(root, target, step, shardings=shardings)
            return state, extra, step
        except Exception as e:
            last_err = e
            warnings.warn(
                f"skipping corrupt checkpoint step {step} under {root}: "
                f"{e}", UserWarning, stacklevel=2)
    raise FileNotFoundError(
        f"no restorable checkpoint under {root} "
        f"({len(steps)} step(s) present, all failed; last: {last_err})")
