"""jax version compatibility shims.

The repo targets current jax APIs; these helpers keep it importable and
correct on the older jax baked into the offline container. Each shim
prefers the modern spelling and falls back:

  * mesh context: ``jax.sharding.set_mesh`` / ``use_mesh`` (new) vs the
    classic ``with mesh:`` physical-mesh context (old).
  * current mesh: ``jax.sharding.get_abstract_mesh`` (new) vs the
    thread-resources physical mesh (old). Callers treat "no mesh" as
    None / empty axis_names, which both paths honour.
  * ``shard_map``: top-level vs experimental import, and the
    ``check_rep`` -> ``check_vma`` kwarg rename.
  * ``make_mesh``: the ``axis_types`` kwarg only exists on newer jax.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map_raw
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_raw

# The replication-check kwarg was renamed check_rep -> check_vma across jax
# versions; pick whichever this jax spells.
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map_raw).parameters else "check_rep")


def shard_map(fun=None, **kw):
    """shard_map accepting either replication-check kwarg spelling."""
    if "check_vma" in kw and _CHECK_KW != "check_vma":
        kw[_CHECK_KW] = kw.pop("check_vma")
    if fun is None:
        return lambda f: _shard_map_raw(f, **kw)
    return _shard_map_raw(fun, **kw)


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis_types where that kwarg exists (it is
    the default there, so omitting it on older jax is equivalent)."""
    if "axis_types" in inspect.signature(jax.make_mesh).parameters:
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto)
    return jax.make_mesh(shape, axes)


def get_abstract_mesh():
    """The mesh governing the current trace, or None outside any mesh."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src import mesh as _mesh_lib

    am = _mesh_lib.get_abstract_mesh()
    if getattr(am, "axis_names", ()):
        return am
    pm = _mesh_lib.thread_resources.env.physical_mesh
    return pm if pm.axis_names else None


def set_mesh(mesh):
    """Context manager entering ``mesh`` for sharding-constraint lookup."""
    for name in ("set_mesh", "use_mesh"):
        setter = getattr(jax.sharding, name, None)
        if setter is not None:
            return setter(mesh)
    return mesh  # classic API: Mesh is itself a context manager
