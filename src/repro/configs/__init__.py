from .base import ModelConfig, SHAPES, ShapeConfig, reduced  # noqa: F401
from .registry import ARCH_IDS, cells, get, get_shape  # noqa: F401
