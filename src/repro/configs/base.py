"""Config system: model architecture, input shapes, and run settings.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro/configs/`` and is selectable by ``--arch <id>`` through
``registry.get()``. ``reduced()`` produces the CPU smoke-test variant of any
config (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    logit_softcap: Optional[float] = None    # gemma2 final-logit softcap
    attn_softcap: Optional[float] = None     # gemma2 attention softcap
    sliding_window: Optional[int] = None     # window for "attn_local" layers

    # layer program: the periodic pattern of mixer types; num_layers must be
    # a multiple of len(layer_pattern). Entries: attn | attn_local | mamba |
    # mlstm | slstm.
    layer_pattern: tuple = ("attn",)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1            # apply MoE FFN on layers where
    moe_offset: int = 0            # (layer_idx % moe_period) == moe_offset
    capacity_factor: float = 1.25

    # FFN
    ffn_type: str = "swiglu"       # swiglu | gelu | none

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0           # frame positions from the (stub) frontend
    cross_attention: bool = False

    # SSM dims (mamba)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # norms / embeddings
    post_norm: bool = False        # gemma2-style extra post-norms
    tie_embeddings: bool = False
    scale_embed: bool = False      # gemma-style sqrt(d) embedding scale

    # numerics
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"   # master copy dtype
    moment_dtype: str = "float32"  # Adam m/v dtype (bf16 for the giants)

    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        assert self.num_layers % self.period == 0, \
            f"{self.name}: {self.num_layers} layers not divisible by " \
            f"pattern period {self.period}"
        return self.num_layers // self.period

    def is_moe_layer(self, pos_in_period: int) -> bool:
        if self.num_experts == 0:
            return False
        return (pos_in_period % self.moe_period) == self.moe_offset

    def mixer(self, pos_in_period: int) -> str:
        return self.layer_pattern[pos_in_period]

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer does global full attention (long_500k eligible)."""
        return all(m in ("mamba", "mlstm", "slstm", "attn_local")
                   for m in self.layer_pattern) or self.family in ("ssm",)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper via its decoder)

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += d * self.vocab_size
        for pos in range(self.period):
            mixer = self.mixer(pos)
            if mixer in ("attn", "attn_local"):
                p = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * hd * d
            elif mixer == "mamba":
                di = self.ssm_expand * d
                p = d * 2 * di + di * self.ssm_conv \
                    + di * (2 * self.ssm_state + di // 16 + 1) + di * d
            elif mixer in ("mlstm",):
                di = 2 * d
                hd = di // max(self.num_heads, 1)
                p = d * 2 * di + 3 * di * hd + di * d  # block-diag qkv
            elif mixer == "slstm":
                p = 8 * d * d
            else:
                p = 0
            if mixer in ("attn", "attn_local", "mamba"):
                if self.is_moe_layer(pos):
                    mult = 3 if self.ffn_type == "swiglu" else 2
                    p += self.num_experts * mult * d * self.d_ff \
                        + d * self.num_experts
                elif self.ffn_type != "none" and self.d_ff:
                    mult = 3 if self.ffn_type == "swiglu" else 2
                    p += mult * d * self.d_ff
            total += p * self.n_periods
        if self.encoder_layers:
            enc = self.encoder_layers * (
                4 * d * d + 2 * d * self.d_ff)
            dec_cross = self.num_layers * 4 * d * d
            total += enc + dec_cross
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: top-k experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        mult = 3 if self.ffn_type == "swiglu" else 2
        moe_layers = sum(self.is_moe_layer(p) for p in range(self.period)) \
            * self.n_periods
        expert_params = moe_layers * self.num_experts * mult * self.d_model * self.d_ff
        active_experts = moe_layers * self.experts_per_token * mult \
            * self.d_model * self.d_ff
        return full - expert_params + active_experts


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: keeps the layer
    pattern, MoE topology, GQA ratio, enc-dec structure; shrinks dims."""
    ratio = max(cfg.num_heads // max(cfg.num_kv_heads, 1), 1)
    heads = 4 if ratio <= 4 else ratio
    kv = max(heads // ratio, 1)
    return dataclasses.replace(
        cfg,
        num_layers=cfg.period * min(cfg.n_periods, 2),
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16),
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else None,
        ssm_state=8,
        compute_dtype="float32",
        moment_dtype="float32",
    )
