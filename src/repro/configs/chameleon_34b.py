"""Chameleon-34B early-fusion VLM. VQ image tokens are ordinary ids in the
unified 65536 vocab; the VQ tokenizer frontend is a STUB (input_specs()
provide token ids directly). [arXiv:2405.09818; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    ffn_type="swiglu",
    source="arXiv:2405.09818; unverified",
)
