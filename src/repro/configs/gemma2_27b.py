"""Gemma2 27B: alternating local/global attention, logit softcaps,
pre+post norms. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    layer_pattern=("attn_local", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norm=True,
    scale_embed=True,
    tie_embeddings=True,
    ffn_type="swiglu",         # gemma2 gated gelu ~ swiglu w/ gelu act
    source="arXiv:2408.00118; hf",
)
