"""Grok-1 314B. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32_768,
    vocab_size=131_072,
    num_experts=8,
    experts_per_token=2,
    ffn_type="swiglu",         # grok experts are gated (3 mats: w, v, proj)
    moment_dtype="bfloat16",   # 314B: see DESIGN.md §7 memory budget
    source="hf:xai-org/grok-1; unverified",
)
