"""Jamba v0.1 52B: Mamba + attention at 1:7, MoE (16e top-2) on every
second layer. Only 4 of 32 layers are full attention => long_500k decode
is feasible (sub-quadratic per token, cache bounded).
[arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    # period 8: attention at position 4 (jamba places attn mid-block)
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    num_experts=16,
    experts_per_token=2,
    moe_period=2,
    moe_offset=1,              # MoE on odd layers
    ssm_state=16,
    ffn_type="swiglu",
    source="arXiv:2403.19887; hf",
)
