"""Qwen1.5 32B (QKV bias). [hf:Qwen/Qwen1.5-0.5B family config; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27_392,
    vocab_size=152_064,
    qkv_bias=True,
    ffn_type="swiglu",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
