"""Qwen3-MoE 235B-A22B. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                 # per-expert intermediate
    vocab_size=151_936,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
    ffn_type="swiglu",
    moment_dtype="bfloat16",   # 235B: f32 moments do not fit one v5e pod
    source="hf:Qwen/Qwen3-30B-A3B (scaled family config); hf",
)
