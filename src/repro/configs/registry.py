"""--arch registry: id -> ModelConfig (the 10 assigned archs + the paper's
own config for the OBP data-selection pipeline)."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig, reduced  # noqa: F401

_ARCH_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "grok-1-314b": "grok_1_314b",
    "whisper-base": "whisper_base",
    "chameleon-34b": "chameleon_34b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen1.5-32b": "qwen1_5_32b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "gemma2-27b": "gemma2_27b",
    "xlstm-1.3b": "xlstm_1_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; options: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells():
    """All (arch, shape) dry-run cells with their skip status.

    long_500k requires sub-quadratic attention: runs only for ssm/hybrid
    archs (xlstm, jamba); skipped (and recorded) for pure full-attention
    archs — see DESIGN.md §6a.
    """
    out = []
    for arch_id in ARCH_IDS:
        cfg = get(arch_id)
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
                skip = "full-attention arch: 500k decode needs sub-quadratic attention"
            out.append((arch_id, shape.name, skip))
    return out
