"""Whisper-base encoder-decoder. Conv/audio frontend is a STUB: the
dry-run input_specs() provide precomputed frame embeddings (B, 1500, 512).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    encoder_layers=6,
    encoder_seq=1500,
    cross_attention=True,
    ffn_type="gelu",
    rope_theta=0.0,            # whisper uses learned/sinusoidal positions
    source="arXiv:2212.04356; unverified",
)
