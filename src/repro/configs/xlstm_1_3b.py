"""xLSTM 1.3B: mLSTM + sLSTM blocks at 7:1 (xLSTM[7:1]), 48 blocks.
Recurrent state is O(head_dim^2) per head — no KV cache, long_500k
eligible. [arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                    # blocks embed their own projections
    vocab_size=50_304,
    layer_pattern=("mlstm",) * 7 + ("slstm",),
    ffn_type="none",
    source="arXiv:2405.04517; unverified",
)
