"""OneBatchPAM — the paper's primary contribution, as a composable module.

Public API:
  one_batch_pam / fasterpam / objective   (solver.py)
  build_batch, Batch, VARIANTS            (sampling.py)
  stream_block / stream_assign            (streaming.py)
  MedoidSelector                          (selector.py)
  make_distributed_obp / _e2e             (distributed.py)
  baselines.ALL_BASELINES                 (paper competitors, counted)
"""
from .sampling import Batch, VARIANTS, build_batch, default_batch_size  # noqa: F401
from .selector import MedoidSelector  # noqa: F401
from .streaming import StreamedBlock, stream_assign, stream_block  # noqa: F401
from .solver import (  # noqa: F401
    SolveResult,
    fasterpam,
    objective,
    one_batch_pam,
    solve_batched,
    solve_batched_naive,
    solve_eager,
)
