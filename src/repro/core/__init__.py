"""OneBatchPAM — the paper's primary contribution, as a composable module.

Public API:
  one_batch_pam / fasterpam / objective   (solver.py)
  one_batch_pam_restarts, RestartResult   (restarts.py — vmapped multi-restart)
  build_batch, Batch, VARIANTS            (sampling.py)
  stream_block / stream_assign            (streaming.py)
  MedoidSelector                          (selector.py)
  make_distributed_obp / _e2e / _restarts (distributed.py)
  trace_batched / trace_eager             (trace.py — swap-sequence replay)
  solve_pruned / PrunedStats              (pruned.py — bound-pruned sweep)
  solve_fault_tolerant, SolveReport       (runtime.py — checkpoint/resume + guards)
  GuardViolation, check_inputs            (guards.py — validate= tiers)
  baselines.ALL_BASELINES                 (paper competitors, counted)
"""
from .guards import VALIDATE_MODES, GuardViolation, check_inputs  # noqa: F401
from .pruned import PrunedStats, solve_pruned, solve_pruned_stats  # noqa: F401
from .runtime import SolveReport, solve_fault_tolerant  # noqa: F401
from .restarts import Pool, RestartResult, one_batch_pam_restarts  # noqa: F401
from .sampling import Batch, VARIANTS, build_batch, default_batch_size  # noqa: F401
from .selector import MedoidSelector  # noqa: F401
from .streaming import (  # noqa: F401
    StreamedBlock,
    stream_assign,
    stream_block,
    stream_nn_counts,
)
from .trace import (  # noqa: F401
    Trajectory,
    trace_batched,
    trace_eager,
    trace_matrix_free,
    trace_pruned,
)
from .solver import (  # noqa: F401
    SolveResult,
    fasterpam,
    objective,
    one_batch_pam,
    solve_batched,
    solve_batched_naive,
    solve_eager,
    solve_matrix_free,
)
