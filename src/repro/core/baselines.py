"""Competitor k-medoids algorithms (paper's Experiments section).

These are faithful reference implementations in numpy with an explicit
dissimilarity-computation counter — they exist to reproduce the paper's
comparisons (Tables 1/3, Figure 1), where the quantities of interest are
(a) the k-medoids objective and (b) the number of pairwise dissimilarity
evaluations / wall time. The production-grade, distributed implementation
of the paper's own method lives in solver.py / distributed.py.

Implemented: Random, FasterPAM (full-matrix eager PAM), CLARA/FasterCLARA,
Alternate (Park & Jun 2009), k-means++, kmc2 (Bachem et al. 2016),
LS-k-means++ (Lattanzi & Sohler 2019), and ``banditpam_lite`` — a
simplified BanditPAM++ stand-in (per-swap re-sampled batch estimation;
the official C++ implementation is unavailable offline, see DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


def _np_l1(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.abs(a[:, None, :] - b[None, :, :]).sum(-1)


def _np_sqeuclidean(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    sq = (a * a).sum(1)[:, None] + (b * b).sum(1)[None, :] - 2 * a @ b.T
    return np.maximum(sq, 0.0)


def _np_l2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.sqrt(_np_sqeuclidean(a, b))


def _np_cosine(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    # Mirrors kernels/metrics.py: L2 row-normalise (eps-guarded), 1 - dot,
    # clip >= 0 — so zero rows behave like the jax registry's.
    an = a / np.maximum(np.linalg.norm(a, axis=1, keepdims=True), 1e-12)
    bn = b / np.maximum(np.linalg.norm(b, axis=1, keepdims=True), 1e-12)
    return np.maximum(1.0 - an @ bn.T, 0.0)


def _np_chebyshev(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.abs(a[:, None, :] - b[None, :, :]).max(-1)


# numpy mirror of the kernels/metrics.py registry: every metric the jax
# pipeline accepts works in the counted baselines too
# (tests/test_baseline_metrics.py pins agreement with ops.pairwise_distance).
NP_METRICS = {
    "l1": _np_l1,
    "sqeuclidean": _np_sqeuclidean,
    "l2": _np_l2,
    "cosine": _np_cosine,
    "chebyshev": _np_chebyshev,
}


def assign(x: np.ndarray, medoids: np.ndarray,
           metric: str = "l1") -> tuple[np.ndarray, np.ndarray]:
    """Nearest-medoid top-1, pure numpy: ``(labels, d1)`` of shapes
    (n,) i32 / (n,) f32, lowest-index tie-break (``np.argmin``).

    The framework-free mirror of ``ops.assign`` / ``ref.assign`` —
    independent ground truth for the serving path's differential suite
    (tests/test_assign.py pins label equality and distance closeness
    against the jax chain, same tolerance discipline as
    tests/test_baseline_metrics.py).
    """
    if metric not in NP_METRICS:
        raise ValueError(
            f"unknown metric {metric!r}; options {tuple(NP_METRICS)}")
    d = NP_METRICS[metric](np.asarray(x, np.float32),
                           np.asarray(medoids, np.float32))
    return d.argmin(1).astype(np.int32), d.min(1).astype(np.float32)


@dataclasses.dataclass
class Oracle:
    """Dataset + metric wrapper counting pairwise dissimilarity evaluations."""
    x: np.ndarray
    metric: str = "l1"
    count: int = 0

    def __post_init__(self):
        self.x = np.asarray(self.x, np.float32)
        if self.metric not in NP_METRICS:
            raise ValueError(
                f"unknown metric {self.metric!r}; "
                f"options {tuple(NP_METRICS)}")

    @property
    def n(self) -> int:
        return self.x.shape[0]

    def block(self, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """(len(rows), len(cols)) distance block; counts len(rows)*len(cols)."""
        a, b = self.x[rows], self.x[cols]
        self.count += a.shape[0] * b.shape[0]
        return NP_METRICS[self.metric](a, b)

    def to_all(self, cols: np.ndarray) -> np.ndarray:
        return self.block(np.arange(self.n), cols)


@dataclasses.dataclass
class BaselineResult:
    medoids: np.ndarray
    objective: float
    n_dissim: int
    seconds: float
    name: str


def _objective(oracle: Oracle, medoids: np.ndarray, counted: bool = False) -> float:
    """Mean distance to nearest medoid (final reporting is not counted)."""
    saved = oracle.count
    obj = float(oracle.to_all(np.asarray(medoids)).min(1).mean())
    if not counted:
        oracle.count = saved
    return obj


def _timed(fn):
    def wrapper(rng, oracle, k, **kw):
        start_count = oracle.count
        t0 = time.perf_counter()
        medoids = fn(rng, oracle, k, **kw)
        dt = time.perf_counter() - t0
        used = oracle.count - start_count
        return BaselineResult(np.asarray(medoids), _objective(oracle, medoids),
                              used, dt, fn.__name__)
    wrapper.__name__ = fn.__name__
    return wrapper


def _top2_from(dm: np.ndarray):
    """d1, d2, near from a (k, n) medoid-to-points matrix."""
    near = dm.argmin(0)
    d1 = dm[near, np.arange(dm.shape[1])]
    tmp = dm.copy()
    tmp[near, np.arange(dm.shape[1])] = np.inf
    d2 = tmp.min(0)
    return d1, d2, near


def _eager_pam(d: np.ndarray, init: np.ndarray, max_passes: int = 8,
               sample_cols: np.ndarray | None = None) -> np.ndarray:
    """FasterPAM eager swap loop on a precomputed (n_cand, n_ref) matrix.

    d[i, j] = dissim(candidate i, reference j); medoids are candidate
    indices. With n_cand == n_ref == n this is exact FasterPAM.
    """
    n = d.shape[0]
    med = np.array(init, np.int64).copy()
    k = len(med)
    d1, d2, near = _top2_from(d[med])
    for _ in range(max_passes):
        swapped = False
        for i in range(n):
            if (med == i).any():
                continue
            row = d[i]
            g = np.maximum(d1 - row, 0.0).sum()
            r = d1 - np.minimum(np.maximum(row, d1), d2)
            big_r = np.zeros(k)
            np.add.at(big_r, near, r)
            l = int(big_r.argmax())
            if g + big_r[l] > 1e-9:
                med[l] = i
                d1, d2, near = _top2_from(d[med])
                swapped = True
        if not swapped:
            break
    return med


@_timed
def random_select(rng: np.random.Generator, oracle: Oracle, k: int):
    return rng.choice(oracle.n, size=k, replace=False)


@_timed
def fasterpam(rng: np.random.Generator, oracle: Oracle, k: int,
              max_passes: int = 8):
    n = oracle.n
    d = oracle.block(np.arange(n), np.arange(n))      # O(n^2), the bottleneck
    init = rng.choice(n, size=k, replace=False)
    return _eager_pam(d, init, max_passes)


@_timed
def clara(rng: np.random.Generator, oracle: Oracle, k: int,
          repeats: int = 5, sub_size: int | None = None):
    """FasterCLARA: FasterPAM on subsamples, best-of over full evaluation.

    sub_size defaults to the paper's FasterCLARA setting m = 80 + 4k.
    """
    n = oracle.n
    m = min(sub_size or (80 + 4 * k), n)
    best, best_obj = None, np.inf
    for _ in range(repeats):
        sub = rng.choice(n, size=m, replace=False)
        d = oracle.block(sub, sub)                    # O(m^2)
        med_local = _eager_pam(d, rng.choice(m, size=k, replace=False))
        med = sub[med_local]
        obj = oracle.to_all(med).min(1).mean()        # O(nk) evaluation
        if obj < best_obj:
            best, best_obj = med, obj
    return best


@_timed
def alternate(rng: np.random.Generator, oracle: Oracle, k: int,
              max_iters: int = 20):
    """Park & Jun (2009): alternate assignment / per-cluster medoid update."""
    n = oracle.n
    med = rng.choice(n, size=k, replace=False)
    for _ in range(max_iters):
        assign = oracle.to_all(med).argmin(1)         # O(nk)
        new_med = med.copy()
        for c in range(k):
            members = np.where(assign == c)[0]
            if len(members) == 0:
                continue
            dm = oracle.block(members, members)       # O(n_c^2)
            new_med[c] = members[dm.sum(1).argmin()]
        if (new_med == med).all():
            break
        med = new_med
    return med


def _dist_power(oracle: Oracle) -> float:
    # k-means++ samples proportional to d^p for an l_p metric; the
    # max-norm and the bounded cosine distance behave like p = 1.
    return 1.0 if oracle.metric in ("l1", "chebyshev", "cosine") else 2.0


@_timed
def kmeans_pp(rng: np.random.Generator, oracle: Oracle, k: int):
    n = oracle.n
    first = int(rng.integers(n))
    centers = [first]
    dmin = oracle.to_all(np.array([first]))[:, 0]
    p = _dist_power(oracle)
    for _ in range(k - 1):
        probs = dmin**p
        s = probs.sum()
        probs = np.full(n, 1.0 / n) if s <= 0 else probs / s
        nxt = int(rng.choice(n, p=probs))
        centers.append(nxt)
        dmin = np.minimum(dmin, oracle.to_all(np.array([nxt]))[:, 0])
    return np.array(centers)


@_timed
def kmc2(rng: np.random.Generator, oracle: Oracle, k: int, chain: int = 20):
    """MCMC approximation of k-means++ (Bachem et al. 2016), O(L k^2) dists."""
    n = oracle.n
    centers = [int(rng.integers(n))]
    p = _dist_power(oracle)
    for _ in range(k - 1):
        cur = int(rng.integers(n))
        d_cur = oracle.block(np.array([cur]), np.array(centers)).min() ** p
        for _ in range(chain - 1):
            cand = int(rng.integers(n))
            d_cand = oracle.block(np.array([cand]), np.array(centers)).min() ** p
            if d_cur <= 0 or rng.random() < min(1.0, d_cand / d_cur):
                cur, d_cur = cand, d_cand
        centers.append(cur)
    return np.array(centers)


@_timed
def ls_kmeans_pp(rng: np.random.Generator, oracle: Oracle, k: int,
                 local_steps: int = 5):
    """k-means++ seeding + Lattanzi-Sohler single-swap local search."""
    n = oracle.n
    first = int(rng.integers(n))
    centers = [first]
    dmin = oracle.to_all(np.array([first]))[:, 0]
    p = _dist_power(oracle)
    for _ in range(k - 1):
        probs = dmin**p
        probs = probs / probs.sum() if probs.sum() > 0 else np.full(n, 1.0 / n)
        nxt = int(rng.choice(n, p=probs))
        centers.append(nxt)
        dmin = np.minimum(dmin, oracle.to_all(np.array([nxt]))[:, 0])
    med = np.array(centers)
    dm = oracle.to_all(med)                            # (n, k)
    d1, d2, near = _top2_from(dm.T)
    for _ in range(local_steps):
        probs = d1**p
        probs = probs / probs.sum() if probs.sum() > 0 else np.full(n, 1.0 / n)
        y = int(rng.choice(n, p=probs))
        dy = oracle.to_all(np.array([y]))[:, 0]        # O(n)
        # cost of swapping each center c for y
        base = np.minimum(d1, dy)
        costs = np.empty(k)
        for c in range(k):
            alt = np.where(near == c, np.minimum(d2, dy), base)
            costs[c] = alt.sum()
        c = int(costs.argmin())
        if costs[c] < d1.sum() - 1e-9:
            med[c] = y
            dm[:, c] = dy
            d1, d2, near = _top2_from(dm.T)
    return med


@_timed
def banditpam_lite(rng: np.random.Generator, oracle: Oracle, k: int,
                   swap_rounds: int = 10, batch: int | None = None):
    """Simplified BanditPAM++: each swap round re-samples a fresh reference
    batch of size O(log n) and picks the best estimated swap — capturing the
    O(T n log n) 'new dissimilarities every swap' cost profile that the
    paper contrasts with OneBatchPAM's single fixed batch."""
    n = oracle.n
    b = batch or max(int(np.ceil(40 * np.log(max(n, 2)))), 2 * k)
    b = min(b, n)
    med = rng.choice(n, size=k, replace=False)
    for _ in range(swap_rounds):
        ref = rng.choice(n, size=b, replace=False)
        d = oracle.to_all(ref)                         # O(n b) fresh each round
        d1, d2, near = _top2_from(d[med])
        g = np.maximum(d1[None, :] - d, 0.0).sum(1)
        r = d1[None, :] - np.minimum(np.maximum(d, d1[None, :]), d2[None, :])
        big_r = np.zeros((n, k))
        for c in range(k):
            big_r[:, c] = r[:, near == c].sum(1)
        gain = g[:, None] + big_r
        gain[med] = -np.inf
        i, l = np.unravel_index(gain.argmax(), gain.shape)
        if gain[i, l] <= 1e-9:
            break
        med = med.copy()
        med[l] = i
    return med


ALL_BASELINES = {
    "random": random_select,
    "fasterpam": fasterpam,
    "clara": clara,
    "alternate": alternate,
    "kmeans_pp": kmeans_pp,
    "kmc2": kmc2,
    "ls_kmeans_pp": ls_kmeans_pp,
    "banditpam_lite": banditpam_lite,
}
