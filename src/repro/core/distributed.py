"""Multi-pod distributed OneBatchPAM via shard_map.

Sharding plan (DESIGN.md §5):
  * candidates n   -> sharded over the ("pod", "data") mesh axes ("batch
                      axes"): each device owns an n_local x m block,
                      built row-chunk by row-chunk (streaming.py) so peak
                      per-device HBM is O(chunk * m).
  * batch m        -> replicated (m = O(log n) is tiny).
  * feature dim p  -> sharded over "model" during the distance build; the
                      per-feature raw partials combine with the metric's
                      registered ``reduce`` collective (psum for l1/l2,
                      pmax for chebyshev; cosine is not feature-shardable
                      — see metrics.py), after which the model axis holds
                      replicas of the block.

Per swap sweep each shard runs the fused swap_select sweep on its local
block (the (n_local, k) gain matrix never materialises — DESIGN.md §2)
and the only cross-device traffic is three scalars (gain pmax,
winner-shard pmin, winning-flat psum) plus one m-float psum to broadcast
the winning candidate's row for the incremental top-2 repair. So the
collective footprint is O(m) bytes per swap versus the O(n m) the block
would cost to gather — this is why OBP maps onto pods so well: the
O(n log n) state never moves. The e2e
entry point also builds the batch variant weights in-mesh: the nniw
nearest-neighbour histogram is counted on each shard's rows inside the
streaming chunk sweep and completed with a single (m,)-float psum (with a
"model" feature axis the counts instead come from a second pass over the
reduced block, since raw partials are not yet distances).

Entry points are shard_map-decorated and meant to be called under
``with mesh:`` from launch/ or examples/. n must be divisible by the
number of batch-axis devices (pad upstream with LARGE-distance rows).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core import solver, streaming
from repro.kernels import metrics, ops
from repro.kernels.ref import LARGE


def _batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_size(ax: str, axis_sizes=None):
    """Static mesh-axis size. Older jax has no lax.axis_size, so factories
    thread dict(mesh.shape) through; the traced psum(1) is the last resort."""
    if axis_sizes is not None:
        return axis_sizes[ax]
    if hasattr(jax.lax, "axis_size"):  # jax >= 0.4.some
        return jax.lax.axis_size(ax)
    return jax.lax.psum(1, ax)  # pragma: no cover — traced fallback


def shard_over_batch(mesh, x: jnp.ndarray) -> jnp.ndarray:
    """Place x (n, p) on the mesh: n over the batch axes, p over "model"."""
    has_model = "model" in mesh.axis_names
    n_dev = 1
    for ax in _batch_axes(mesh):
        n_dev *= mesh.shape[ax]
    if x.shape[0] % n_dev:
        raise ValueError(
            f"n={x.shape[0]} must be divisible by the {n_dev} batch-axis "
            "devices; pad upstream with LARGE-distance rows (DESIGN.md §5)")
    spec = P(_batch_axes(mesh), "model" if has_model else None)
    return jax.device_put(x, NamedSharding(mesh, spec))


def _owner_select(idx, off, n_local):
    """Global indices -> (mine, safe) for this shard: which of ``idx`` this
    shard owns, and their clipped local row numbers (safe to gather with;
    meaningful only where ``mine``). The single home for the global-to-
    local ownership idiom, so the shard linearisation has one definition.
    """
    local = idx - off
    mine = (local >= 0) & (local < n_local)
    return mine, jnp.clip(local, 0, n_local - 1)


def solve_sharded(
    d_local: jnp.ndarray,      # (n_local, m) this device's block (f32/bf16)
    init_idx: jnp.ndarray,     # (k,) global indices, replicated
    *,
    axes: Sequence[str],       # batch mesh axes, e.g. ("pod", "data")
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    axis_sizes=None,           # dict(mesh.shape) for static axis sizes
) -> solver.SolveResult:
    """Batched steepest-descent sweep with a global fused selection.

    Runs inside shard_map. Device r owns candidates [r*n_local, (r+1)*n_local).
    Each shard runs the fused ``ops.swap_select`` sweep over its local
    block — the (n_local, k) gain matrix never materialises, the shard
    emits one (best_gain, best_flat) partial — and the winner election is
    three scalar collectives. The replicated top-2 state is then repaired
    incrementally (``solver._repair_top2``) from the psum-broadcast winning
    row, so no full (k, m) recompute runs per swap either.
    """
    axes = tuple(axes)
    n_local, m = d_local.shape
    k = init_idx.shape[0]
    shard_id = _shard_id(axes, axis_sizes)
    row_offset = shard_id * n_local

    def owned_rows(idx):
        """Replicated (k, m) f32 medoid rows: each owner psum-broadcasts."""
        mine, safe = _owner_select(idx, row_offset, n_local)
        rows = jnp.where(mine[:, None], d_local[safe].astype(jnp.float32), 0.0)
        return jax.lax.psum(rows, axes)

    def init_state(idx):
        med_rows = owned_rows(idx)
        d1, d2, near, near2 = solver._top2(med_rows)
        return (idx.astype(jnp.int32), med_rows, d1, d2, near, near2,
                jnp.int32(0), jnp.bool_(False))

    state = init_state(init_idx)

    def cond(state):
        return jnp.logical_and(~state[7], state[6] < max_swaps)

    def body(state):
        idx, med_rows, d1, d2, near, near2, t, done = state
        nh = jax.nn.one_hot(near, k, dtype=jnp.float32)
        # Mask rows that are current medoids (global -> local index check).
        # ``.at[].min`` keeps the mask correct even when a clipped foreign
        # index collides with an owned row (min(1, 0) = 0 deterministically,
        # where duplicate-index .set would be order-dependent).
        mine, safe = _owner_select(idx, row_offset, n_local)
        row_mask = jnp.ones((n_local,), jnp.float32).at[safe].min(
            jnp.where(mine, 0.0, 1.0))
        best_local, i_loc, l_loc = ops.swap_select(
            d_local, d1, d2, nh, row_mask=row_mask, backend=backend)
        flat = i_loc * k + l_loc
        # Global fused selection: max gain, then the *lowest* global flat
        # index among the tied winners — exact gain ties are routine (the
        # min/max clipping in the gain plateaus values), and swap_select
        # picks the first local flat index (jnp.argmax semantics), so the
        # collective must too for the sharded sweep to be bit-for-bit with
        # solve_batched. The election is lexicographic (shard, local flat):
        # shards are ordered by row offset and the local selection already
        # picked the minimal local flat, so this equals the global minimum
        # without ever forming n*k-scale integers (which overflow int32 at
        # large n). Three scalar collectives per step, total.
        best_all = jax.lax.pmax(best_local, axes)
        is_winner = best_local >= best_all
        win_shard = jax.lax.pmin(
            jnp.where(is_winner, shard_id, jnp.iinfo(jnp.int32).max), axes)
        flat_win = jax.lax.psum(
            jnp.where(shard_id == win_shard, flat, 0), axes)
        i_glob = win_shard * n_local + flat_win // k
        l = flat_win % k
        # Broadcast the winning row (owner psum), then repair the
        # replicated top-2 state incrementally — identical floats on every
        # shard since the inputs are replicated.
        owns, li = _owner_select(i_glob, row_offset, n_local)
        row = jnp.where(owns, d_local[li].astype(jnp.float32), 0.0)
        row = jax.lax.psum(row, axes)
        # Same acceptance rule as solve_batched: d1 is replicated, so the
        # eps threshold is identical on every shard.
        improved = best_all > eps * jnp.sum(d1)
        new_rows, nd1, nd2, nnear, nnear2 = solver._repair_top2(
            med_rows, d1, d2, near, near2, row, l)
        new_state = (idx.at[l].set(i_glob.astype(jnp.int32)), new_rows,
                     nd1, nd2, nnear, nnear2, t + 1, done)
        old_state = (idx, med_rows, d1, d2, near, near2, t, jnp.bool_(True))
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state, old_state)

    state = jax.lax.while_loop(cond, body, state)
    idx, _, d1, _, _, _, t, done = state
    return solver.SolveResult(idx, t, jnp.mean(d1), done)


def solve_sharded_matrix_free(
    x_local: jnp.ndarray,      # (n_local, p) this shard's rows, PREPARED
    b: jnp.ndarray,            # (m, p) replicated batch rows, PREPARED
    weights: jnp.ndarray,      # (m,) replicated batch weights
    batch_idx: jnp.ndarray,    # (m,) replicated global batch column sources
    init_idx: jnp.ndarray,     # (k,) global indices, replicated
    *,
    axes: Sequence[str],
    metric: str = "l1",
    debias: bool = False,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
    axis_sizes=None,
) -> solver.SolveResult:
    """Matrix-free sharded sweep: no shard ever holds a distance block.

    Runs inside shard_map. Each shard runs ``ops.fused_swap_select`` over
    its local (n_local, p) rows — distance tiles recomputed on chip, one
    (best_gain, best_flat) partial out — and the election is the same
    three scalar collectives as :func:`solve_sharded`. The winning
    candidate's weighted row is recomputed O(mp) by its owner shard
    (``solver._weighted_rows``, the block path's float chain) and
    psum-broadcast for the replicated incremental repair, so per-swap
    wire traffic stays O(m) while per-shard resident memory drops from
    O(n_local·m) to O(n_local·p + km). Bit-for-bit with the host
    :func:`solver.solve_matrix_free` (tests/helpers/
    dist_matrix_free_check.py). Inputs must already carry the metric's
    ``prepare`` transform (the factory applies it once per shard).
    """
    axes = tuple(axes)
    n_local = x_local.shape[0]
    k = init_idx.shape[0]
    shard_id = _shard_id(axes, axis_sizes)
    row_offset = shard_id * n_local
    w = weights.astype(jnp.float32)
    batch_idx = batch_idx.astype(jnp.int32)

    def weighted_row(i_glob):
        """The winning candidate's weighted batch row, owner-computed and
        psum-replicated — identical floats to the host recompute."""
        owns, li = _owner_select(i_glob, row_offset, n_local)
        r = solver._weighted_rows(x_local[li][None, :], b, w, batch_idx,
                                  i_glob[None], metric=metric,
                                  debias=debias, backend=backend)[0]
        return jax.lax.psum(jnp.where(owns, r, 0.0), axes)

    def init_state(idx):
        rows = _gather_batch_rows(x_local, idx, row_offset, axes)  # (k, p)
        med_rows = solver._weighted_rows(rows, b, w, batch_idx, idx,
                                         metric=metric, debias=debias,
                                         backend=backend)
        d1, d2, near, near2 = solver._top2(med_rows)
        return (idx.astype(jnp.int32), med_rows, d1, d2, near, near2,
                jnp.int32(0), jnp.bool_(False))

    state = init_state(init_idx)

    def cond(state):
        return jnp.logical_and(~state[7], state[6] < max_swaps)

    def body(state):
        idx, med_rows, d1, d2, near, near2, t, done = state
        nh = jax.nn.one_hot(near, k, dtype=jnp.float32)
        mine, safe = _owner_select(idx, row_offset, n_local)
        row_mask = jnp.ones((n_local,), jnp.float32).at[safe].min(
            jnp.where(mine, 0.0, 1.0))
        # Debias owners in *local* row coordinates: foreign columns fall
        # outside [0, n_local) and match nothing (padded rows are masked).
        owner = (batch_idx - row_offset) if debias else None
        best_local, i_loc, l_loc = ops.fused_swap_select(
            x_local, b, w, d1, d2, nh, metric=metric, row_mask=row_mask,
            owner=owner, backend=backend, skip_prepare=True,
            row_chunk=solver._mf_chunk(chunk_size))
        flat = i_loc * k + l_loc
        # Same lexicographic (shard, local flat) election as solve_sharded:
        # three scalar collectives, bit-for-bit the host argmax.
        best_all = jax.lax.pmax(best_local, axes)
        is_winner = best_local >= best_all
        win_shard = jax.lax.pmin(
            jnp.where(is_winner, shard_id, jnp.iinfo(jnp.int32).max), axes)
        flat_win = jax.lax.psum(
            jnp.where(shard_id == win_shard, flat, 0), axes)
        i_glob = win_shard * n_local + flat_win // k
        l = flat_win % k
        row = weighted_row(i_glob)
        improved = best_all > eps * jnp.sum(d1)
        new_rows, nd1, nd2, nnear, nnear2 = solver._repair_top2(
            med_rows, d1, d2, near, near2, row, l)
        new_state = (idx.at[l].set(i_glob.astype(jnp.int32)), new_rows,
                     nd1, nd2, nnear, nnear2, t + 1, done)
        old_state = (idx, med_rows, d1, d2, near, near2, t, jnp.bool_(True))
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state, old_state)

    state = jax.lax.while_loop(cond, body, state)
    idx, _, d1, _, _, _, t, done = state
    return solver.SolveResult(idx, t, jnp.mean(d1), done)


def _shard_id(axes: Sequence[str], axis_sizes=None):
    """This device's linear index over the axes-major device grid."""
    shard_id = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        shard_id = shard_id * _axis_size(ax, axis_sizes) + jax.lax.axis_index(ax)
    return shard_id


def _shard_offset(axes: Sequence[str], n_local: int, axis_sizes=None):
    """This device's row offset in the axes-major linearised n axis."""
    return _shard_id(axes, axis_sizes) * n_local


def _gather_batch_rows(x_local, batch_idx, off, axes):
    """Replicate the m batch rows out of the n-sharded x: owners
    contribute, psum broadcasts. O(m p) bytes, once."""
    n_local = x_local.shape[0]
    mine, safe = _owner_select(batch_idx, off, n_local)
    b = jnp.where(mine[:, None], x_local[safe], 0.0)
    return jax.lax.psum(b, axes)


@functools.lru_cache(maxsize=32)
def make_distributed_obp(mesh, *, k: int, metric: str = "l1",
                         max_swaps: int = 500, eps: float = 0.0,
                         backend: str = "auto",
                         chunk_size: int | None = None,
                         block_dtype: str | None = None):
    """Build a jit-able distributed OneBatchPAM solve function.

    Returns fn(x, batch_idx, weights, init_idx) -> SolveResult, where
      x: (n, p) sharded P(batch_axes, "model"),
      batch_idx: (m,) replicated, weights: (m,) replicated,
      init_idx: (k,) replicated.

    Weights are caller-supplied (precomputed variant weights); use
    :func:`make_distributed_obp_e2e` to also build them in-mesh.
    ``chunk_size`` streams each device's local block build (DESIGN.md §4).
    ``block_dtype`` (a dtype *name*, e.g. "bfloat16", to keep the memo key
    hashable) narrows each shard's stored block after the feature reduce,
    mirroring the host path's cast order so the sharded sweep stays
    bit-for-bit with the single-device one. Both factories are memoised on
    their (mesh, options) key, so repeated calls (a seed sweep,
    MedoidSelector.fit in a loop) reuse the traced + compiled program
    instead of paying shard_map retracing per call.
    """
    batch_axes = _batch_axes(mesh)
    has_model = "model" in mesh.axis_names
    sizes = dict(mesh.shape)
    spec = metrics.get(metric)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(batch_axes, "model" if has_model else None),
                  P(), P(), P()),
        out_specs=solver.SolveResult(P(), P(), P(), P()),
        check_vma=False,
    )
    def run(x_local, batch_idx, weights, init_idx):
        n_local = x_local.shape[0]
        off = _shard_offset(batch_axes, n_local, sizes)
        b = _gather_batch_rows(x_local, batch_idx, off, batch_axes)
        # p is sharded over "model": the local block holds per-feature raw
        # partials. For additive metrics each model replica only needs its
        # own 1/|model| row-slice for the sweep (rows re-sharded over
        # model => batch x model sweep parallelism), so the reduction is a
        # reduce-scatter over rows — half the wire bytes of psum+slice and
        # no replicated block ever materialises (DESIGN.md §5). Max-reduce
        # metrics (chebyshev) have no scatter collective, so they pmax.
        raw = streaming.stream_block(x_local, b, metric=metric,
                                     backend=backend, chunk_size=chunk_size,
                                     raw=True).d
        solve_axes = batch_axes
        if has_model:
            if spec.reduce is None:
                raise ValueError(
                    f"metric {metric!r} cannot be feature-sharded; "
                    "drop the model axis")
            msize = sizes["model"]
            if spec.reduce == "sum" and n_local % msize == 0:
                raw = jax.lax.psum_scatter(raw, "model", scatter_dimension=0,
                                           tiled=True)
                solve_axes = batch_axes + ("model",)
            elif spec.reduce == "sum":
                raw = jax.lax.psum(raw, "model")
            else:
                raw = jax.lax.pmax(raw, "model")
        # Cast order mirrors the host build_batch: distances round to the
        # block dtype first, the f32 weight multiply re-promotes, and the
        # stored product rounds once — elementwise, so shard == host bits.
        d = spec.finalize(raw)
        if block_dtype is not None:
            d = d.astype(block_dtype)
        d = d * weights[None, :]
        if block_dtype is not None:
            d = d.astype(block_dtype)
        return solve_sharded(d, init_idx, axes=solve_axes,
                             max_swaps=max_swaps, eps=eps,
                             backend=backend, axis_sizes=sizes)

    return jax.jit(run)


@functools.lru_cache(maxsize=32)
def make_distributed_obp_matrix_free(mesh, *, k: int, metric: str = "l1",
                                     variant: str = "unif",
                                     max_swaps: int = 500, eps: float = 0.0,
                                     backend: str = "auto",
                                     chunk_size: int | None = None):
    """Distributed matrix-free OneBatchPAM: no device ever holds a block.

    Returns fn(x, batch_idx, init_idx) -> (SolveResult, weights (m,)),
    the matrix-free sibling of :func:`make_distributed_obp_e2e`
    (DESIGN.md §2b): per-shard resident state is the (n_local, p) rows
    plus O(km) solver state — the O(n_local·m) block of the e2e path
    never exists. Variant weights are built in-mesh block-free too: the
    nniw histogram comes from each shard's ``stream_nn_counts`` chunk
    sweep (no block materialised even transiently beyond a chunk)
    completed with one (m,)-float psum. ``chunk_size`` bounds that count
    sweep and the ref-backend solve sweep to O(chunk · m) intermediates;
    left unset it defaults to ``streaming.MF_DEFAULT_CHUNK`` rather than
    one-shot, so the no-block guarantee needs no caller cooperation.

    Feature ("model") sharding is not composed with matrix-free: the
    in-flight tile math needs full rows (prepare/finalize run per tile).
    lwcs stays host-side, as in the e2e factory.
    """
    if variant not in ("unif", "debias", "nniw"):
        raise ValueError(
            f"variant {variant!r} not supported in-mesh; build the batch "
            "host-side with sampling.build_batch + solve_matrix_free")
    if "model" in mesh.axis_names:
        raise ValueError(
            "matrix-free needs full feature rows per shard; drop the "
            "'model' axis (DESIGN.md §2b)")
    batch_axes = _batch_axes(mesh)
    sizes = dict(mesh.shape)
    spec = metrics.get(metric)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(batch_axes, None), P(), P()),
        out_specs=(solver.SolveResult(P(), P(), P(), P()), P()),
        check_vma=False,
    )
    def run(x_local, batch_idx, init_idx):
        n_local = x_local.shape[0]
        m = batch_idx.shape[0]
        off = _shard_offset(batch_axes, n_local, sizes)

        n_global = n_local
        for ax in batch_axes:
            n_global = n_global * sizes[ax]

        # Prepare once per shard and gather the batch rows once (one
        # O(mp) psum); prepare is row-local, so shard == host bits, and
        # the count pass below reuses the same prepared rows.
        xp = spec.prepare(x_local) if spec.prepare is not None else x_local
        bp = _gather_batch_rows(xp, batch_idx, off, batch_axes)

        if variant == "nniw":
            # Bounded-chunk default so no shard transiently builds its
            # local block.
            local_counts = streaming.stream_nn_counts(
                xp, bp, metric=metric, backend=backend,
                chunk_size=(streaming.MF_DEFAULT_CHUNK
                            if chunk_size is None else chunk_size),
                skip_prepare=True)
            counts = jax.lax.psum(local_counts, batch_axes)  # one (m,) psum
            weights = counts * (m / n_global)                # mean 1
        else:
            weights = jnp.ones((m,), jnp.float32)

        res = solve_sharded_matrix_free(
            xp, bp, weights, batch_idx, init_idx, axes=batch_axes,
            metric=metric, debias=(variant == "debias"),
            max_swaps=max_swaps, eps=eps, backend=backend,
            chunk_size=chunk_size, axis_sizes=sizes)
        return res, weights

    return jax.jit(run)


@functools.lru_cache(maxsize=32)
def make_distributed_obp_restarts(mesh, *, k: int, restarts: int,
                                  metric: str = "l1", variant: str = "unif",
                                  max_swaps: int = 500, eps: float = 0.0,
                                  backend: str = "auto",
                                  chunk_size: int | None = None,
                                  block_dtype: str | None = None):
    """Multi-restart OneBatchPAM with the restart axis composed with the
    shard axis (DESIGN.md §2a/§5).

    Returns fn(x, pool_idx, eval_idx, init_idx) ->
        (SolveResult stacked over R, best_restart, eval_objectives (R,),
         weights (R, m)), where
      x: (n, p) sharded P(batch_axes, "model"),
      pool_idx: (R*m,) replicated pooled columns,
      eval_idx: (eval_m,) replicated held-out evaluation columns,
      init_idx: (R, k) replicated per-restart initial medoids.

    Dataflow per shard: ONE streaming sweep builds the local (n_local,
    R·m) pooled block (per-restart nniw histograms fused via grouped
    argmin, completed with a single (R·m,)-float psum), the block slices
    into R (n_local, m) views, and ``vmap(solve_sharded)`` runs all R
    fused sweeps at once — per swap step each shard emits one
    (best_gain, best_flat) partial *per restart* and the three-scalar
    election collectives batch over the restart axis. The cross-restart
    election gathers the R·k winning medoid rows with one psum, scores
    every restart on the replicated eval batch (bf16-aware, f32
    accumulation), and argmins — identical floats on every shard.
    Bit-for-bit with the host engine (core/restarts.py) on the same
    draws: ``tests/helpers/dist_restart_check.py`` pins it.
    """
    if variant not in ("unif", "debias", "nniw"):
        raise ValueError(
            f"variant {variant!r} not supported in-mesh; run restarts "
            "host-side (mesh=None) for lwcs")
    batch_axes = _batch_axes(mesh)
    has_model = "model" in mesh.axis_names
    sizes = dict(mesh.shape)
    spec = metrics.get(metric)
    if has_model and spec.reduce is None:
        raise ValueError(
            f"metric {metric!r} cannot be feature-sharded; drop the model axis")

    result_spec = solver.SolveResult(P(), P(), P(), P())

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(batch_axes, "model" if has_model else None),
                  P(), P(), P()),
        out_specs=(result_spec, P(), P(), P()),
        check_vma=False,
    )
    def run(x_local, pool_idx, eval_idx, init_idx):
        n_local = x_local.shape[0]
        rm = pool_idx.shape[0]
        m = rm // restarts
        off = _shard_offset(batch_axes, n_local, sizes)
        b = _gather_batch_rows(x_local, pool_idx, off, batch_axes)
        eval_rows = _gather_batch_rows(x_local, eval_idx, off, batch_axes)
        want_fused = variant == "nniw" and not has_model
        if has_model:
            raw = streaming.stream_block(x_local, b, metric=metric,
                                         backend=backend,
                                         chunk_size=chunk_size, raw=True).d
            collective = (jax.lax.psum if spec.reduce == "sum"
                          else jax.lax.pmax)
            d = spec.finalize(collective(raw, "model"))
            if variant == "nniw":
                # Grouped second pass over the reduced f32 block — the
                # restart-sliced mirror of the e2e path's count pass.
                win = jnp.argmin(d.reshape(n_local, restarts, m), axis=2)
                flat = win + (jnp.arange(restarts) * m)[None, :]
                local_counts = jnp.zeros((rm,), jnp.float32).at[
                    flat.reshape(-1)].add(1.0)
            else:
                local_counts = None
            if block_dtype is not None:
                d = d.astype(block_dtype)
        else:
            sb = streaming.stream_block(x_local, b, metric=metric,
                                        backend=backend,
                                        chunk_size=chunk_size,
                                        count_nn=want_fused,
                                        count_groups=restarts,
                                        block_dtype=block_dtype)
            d = sb.d
            local_counts = sb.nn_counts if want_fused else None

        n_global = n_local
        for ax in batch_axes:
            n_global = n_global * sizes[ax]

        if variant == "nniw":
            counts = jax.lax.psum(local_counts, batch_axes)  # one (R·m,) psum
            weights = counts.reshape(restarts, m) * (m / n_global)
        else:
            weights = jnp.ones((restarts, m), jnp.float32)
        if variant == "debias":
            mine, safe = _owner_select(pool_idx, off, n_local)
            cols = jnp.arange(rm)
            d = d.at[safe, cols].set(jnp.where(mine, LARGE, d[safe, cols]))

        d = d * weights.reshape(-1)[None, :]   # block_dtype * f32 -> f32
        if block_dtype is not None:
            d = d.astype(block_dtype)
        d_pool = jnp.moveaxis(d.reshape(n_local, restarts, m), 1, 0)

        results = jax.vmap(
            lambda dd, ii: solve_sharded(dd, ii, axes=batch_axes,
                                         max_swaps=max_swaps, eps=eps,
                                         backend=backend, axis_sizes=sizes)
        )(d_pool, init_idx)

        # Election: one psum gathers the R·k winning medoid rows; scoring
        # then runs replicated (identical floats on every shard).
        med_rows = _gather_batch_rows(x_local, results.medoid_idx.reshape(-1),
                                      off, batch_axes)
        if has_model:
            raw = ops.pairwise_raw(eval_rows, med_rows, metric=metric,
                                   backend=backend)
            collective = (jax.lax.psum if spec.reduce == "sum"
                          else jax.lax.pmax)
            d_eval = spec.finalize(collective(raw, "model"))
        else:
            d_eval = ops.pairwise_distance(eval_rows, med_rows,
                                           metric=metric, backend=backend)
        if block_dtype is not None:
            d_eval = d_eval.astype(block_dtype)
        # Shared scoring contract (restarts.score_restarts): host == mesh
        # by construction, not by parallel maintenance.
        from repro.core.restarts import score_restarts
        best_r, evals = score_restarts(d_eval, restarts, k)
        return results, best_r, evals, weights

    return jax.jit(run)


@functools.lru_cache(maxsize=32)
def make_distributed_obp_e2e(mesh, *, k: int, metric: str = "l1",
                             variant: str = "unif",
                             max_swaps: int = 500, eps: float = 0.0,
                             backend: str = "auto",
                             chunk_size: int | None = None,
                             block_dtype: str | None = None):
    """Distributed OneBatchPAM with the batch build fused into the mesh.

    Returns fn(x, batch_idx, init_idx) -> (SolveResult, weights (m,)).
    Unlike :func:`make_distributed_obp`, the variant weights are computed
    data-parallel on the sharded rows (DESIGN.md §5):

      * unif   — unit weights, no extra collective.
      * debias — each owner shard LARGE-s its own batch rows' diagonal.
      * nniw   — nearest-neighbour counts accumulate per shard inside the
                 streaming chunk sweep (count_nn fusion; a second pass
                 over the reduced block when a "model" axis is present),
                 then one (m,)-float psum completes the histogram.
                 Identical numbers to sampling.build_batch.

    lwcs needs a second dataset-wide sampling pass, so it stays host-side
    (build the batch with sampling.build_batch and use
    make_distributed_obp). When the mesh has a "model" axis, the block is
    psum/pmax-reduced before counting so the fused counts see finalized
    distances.
    """
    if variant not in ("unif", "debias", "nniw"):
        raise ValueError(
            f"variant {variant!r} not supported in-mesh; build the batch "
            "host-side with sampling.build_batch + make_distributed_obp")
    batch_axes = _batch_axes(mesh)
    has_model = "model" in mesh.axis_names
    sizes = dict(mesh.shape)
    spec = metrics.get(metric)
    if has_model and spec.reduce is None:
        raise ValueError(
            f"metric {metric!r} cannot be feature-sharded; drop the model axis")

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(batch_axes, "model" if has_model else None),
                  P(), P()),
        out_specs=(solver.SolveResult(P(), P(), P(), P()), P()),
        check_vma=False,
    )
    def run(x_local, batch_idx, init_idx):
        n_local = x_local.shape[0]
        m = batch_idx.shape[0]
        off = _shard_offset(batch_axes, n_local, sizes)
        b = _gather_batch_rows(x_local, batch_idx, off, batch_axes)
        want_fused = variant == "nniw" and not has_model
        if has_model:
            # Raw partials must reduce across the model axis before they
            # are distances, so the nniw argmin cannot fuse into the chunk
            # sweep here — it runs as a second pass over the reduced block.
            raw = streaming.stream_block(x_local, b, metric=metric,
                                         backend=backend,
                                         chunk_size=chunk_size, raw=True).d
            collective = (jax.lax.psum if spec.reduce == "sum"
                          else jax.lax.pmax)
            d = spec.finalize(collective(raw, "model"))
            # Counts come off the f32 distances (before any block_dtype
            # cast) so nniw weights are storage-dtype-independent, exactly
            # like the host path's fused histogram.
            local_counts = (jnp.zeros((m,), jnp.float32).at[
                jnp.argmin(d, axis=1)].add(1.0)
                if variant == "nniw" else None)
            if block_dtype is not None:
                d = d.astype(block_dtype)
        else:
            sb = streaming.stream_block(x_local, b, metric=metric,
                                        backend=backend,
                                        chunk_size=chunk_size,
                                        count_nn=want_fused,
                                        block_dtype=block_dtype)
            d = sb.d
            local_counts = sb.nn_counts if want_fused else None

        n_global = n_local
        for ax in batch_axes:
            n_global = n_global * sizes[ax]

        if variant == "nniw":
            counts = jax.lax.psum(local_counts, batch_axes)  # the single psum
            weights = counts * (m / n_global)                # mean 1
        else:
            weights = jnp.ones((m,), jnp.float32)
        if variant == "debias":
            mine, safe = _owner_select(batch_idx, off, n_local)
            cols = jnp.arange(m)
            d = d.at[safe, cols].set(
                jnp.where(mine, LARGE, d[safe, cols]))

        d = d * weights[None, :]   # block_dtype * f32 promotes to f32
        if block_dtype is not None:
            d = d.astype(block_dtype)
        res = solve_sharded(d, init_idx, axes=batch_axes,
                            max_swaps=max_swaps, eps=eps,
                            backend=backend, axis_sizes=sizes)
        return res, weights

    return jax.jit(run)
