"""Multi-pod distributed OneBatchPAM via shard_map.

Sharding plan (DESIGN.md section 3/5):
  * candidates n   -> sharded over the ("pod", "data") mesh axes ("batch
                      axes"): each device owns an n_local x m block.
  * batch m        -> replicated (m = O(log n) is tiny).
  * feature dim p  -> sharded over "model" during the distance build; the
                      per-feature partial L1/L2 sums are psum-reduced, after
                      which the model axis holds replicas of the block.

Per swap sweep the only cross-device traffic is:
  * one (gain, index) argmax all-reduce over the batch axes,
  * one m-float psum to broadcast the winning candidate's row.
So the collective footprint is O(m) bytes per swap versus the O(n m) the
block would cost to gather — this is why OBP maps onto pods so well: the
O(n log n) state never moves.

Entry points are shard_map-decorated and meant to be called under
``with mesh:`` from launch/ or examples/. n must be divisible by the
number of batch-axis devices (pad upstream with LARGE-distance rows).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import solver
from repro.kernels import ops

try:  # jax >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


def _batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def distance_block(x_local, b, *, metric: str, model_axis: str | None,
                   backend: str = "auto"):
    """Local (n_local, m) block with the feature dim sharded over `model`.

    x_local: (n_local, p_local), b: (m, p_local). For L1 the per-feature
    partial sums add linearly, so a psum over the model axis completes the
    reduction; same for squared L2 partials.
    """
    d = ops.pairwise_distance(
        x_local, b, metric="sqeuclidean" if metric == "l2" else metric,
        backend=backend)
    if model_axis is not None:
        d = jax.lax.psum(d, model_axis)
    if metric == "l2":
        d = jnp.sqrt(jnp.maximum(d, 0.0))
    return d


def solve_sharded(
    d_local: jnp.ndarray,      # (n_local, m) this device's block
    init_idx: jnp.ndarray,     # (k,) global indices, replicated
    *,
    axes: Sequence[str],       # batch mesh axes, e.g. ("pod", "data")
    max_swaps: int = 500,
    backend: str = "auto",
) -> solver.SolveResult:
    """Batched steepest-descent sweep with a global argmax across shards.

    Runs inside shard_map. Device r owns candidates [r*n_local, (r+1)*n_local).
    """
    axes = tuple(axes)
    n_local, m = d_local.shape
    k = init_idx.shape[0]
    shard_id = jax.lax.axis_index(axes[0])
    for ax in axes[1:]:
        shard_id = shard_id * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
    row_offset = shard_id * n_local

    def owned_rows(idx):
        """Replicated (k, m) medoid rows: each owner psum-broadcasts."""
        local = idx - row_offset
        mine = (local >= 0) & (local < n_local)
        safe = jnp.clip(local, 0, n_local - 1)
        rows = jnp.where(mine[:, None], d_local[safe], 0.0)
        return jax.lax.psum(rows, axes)

    def init_state(idx):
        med_rows = owned_rows(idx)
        d1, d2, near = solver._top2(med_rows)
        return (idx.astype(jnp.int32), med_rows, d1, d2, near,
                jnp.int32(0), jnp.bool_(False))

    state = init_state(init_idx)

    def cond(state):
        return jnp.logical_and(~state[6], state[5] < max_swaps)

    def body(state):
        idx, med_rows, d1, d2, near, t, done = state
        nh = jax.nn.one_hot(near, k, dtype=jnp.float32)
        gain = ops.swap_gain(d_local, d1, d2, nh, backend=backend)
        # Mask rows that are current medoids (global -> local index check).
        local = idx - row_offset
        mine = (local >= 0) & (local < n_local)
        safe = jnp.clip(local, 0, n_local - 1)
        gain = gain.at[safe].set(
            jnp.where(mine[:, None], solver.NEG, gain[safe]))
        flat = jnp.argmax(gain)
        best_local = gain.reshape(-1)[flat]
        # Global argmax: max over (gain, encoded index).
        best_all = jax.lax.pmax(best_local, axes)
        is_winner = best_local >= best_all
        cand_global = row_offset + flat // k
        enc = jnp.where(is_winner, cand_global * k + flat % k, -1)
        enc = jax.lax.pmax(enc, axes)          # deterministic tie-break: max enc
        i_glob, l = enc // k, enc % k
        # Broadcast the winning row (owner psum).
        li = i_glob - row_offset
        owns = (li >= 0) & (li < n_local)
        row = jnp.where(owns, d_local[jnp.clip(li, 0, n_local - 1)], 0.0)
        row = jax.lax.psum(row, axes)
        improved = best_all > 0.0
        new_rows = med_rows.at[l].set(row)
        nd1, nd2, nnear = solver._top2(new_rows)
        new_state = (idx.at[l].set(i_glob.astype(jnp.int32)), new_rows,
                     nd1, nd2, nnear, t + 1, done)
        old_state = (idx, med_rows, d1, d2, near, t, jnp.bool_(True))
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state, old_state)

    state = jax.lax.while_loop(cond, body, state)
    idx, _, d1, _, _, t, done = state
    return solver.SolveResult(idx, t, jnp.mean(d1), done)


def make_distributed_obp(mesh, *, k: int, metric: str = "l1",
                         max_swaps: int = 500, backend: str = "auto"):
    """Build a jit-able distributed OneBatchPAM solve function.

    Returns fn(x, batch_idx, weights, init_idx) -> SolveResult, where
      x: (n, p) sharded P(batch_axes, "model"),
      batch_idx: (m,) replicated, weights: (m,) replicated,
      init_idx: (k,) replicated.
    """
    batch_axes = _batch_axes(mesh)
    has_model = "model" in mesh.axis_names

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(batch_axes, "model" if has_model else None),
                  P(), P(), P()),
        out_specs=solver.SolveResult(P(), P(), P(), P()),
        check_vma=False,
    )
    def run(x_local, batch_idx, weights, init_idx):
        # Gather the batch rows (global indices) from the sharded x:
        # owners contribute, psum replicates. O(m p) bytes, once.
        axes_all = batch_axes
        n_local = x_local.shape[0]
        shard_id = jax.lax.axis_index(axes_all[0])
        for ax in axes_all[1:]:
            shard_id = shard_id * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        off = shard_id * n_local
        local = batch_idx - off
        mine = (local >= 0) & (local < n_local)
        b = jnp.where(mine[:, None],
                      x_local[jnp.clip(local, 0, n_local - 1)], 0.0)
        b = jax.lax.psum(b, axes_all)
        # p is sharded over "model": the local block holds per-feature
        # partial sums. Each model replica only needs its own 1/|model|
        # row-slice for the sweep (rows re-sharded over model => batch x
        # model sweep parallelism), so the reduction is a reduce-scatter
        # over rows — half the wire bytes of psum+slice and no replicated
        # block ever materialises (§Perf obp iterations 1-2).
        metric_l = "sqeuclidean" if metric == "l2" else metric
        d = ops.pairwise_distance(x_local, b, metric=metric_l,
                                  backend=backend)
        solve_axes = batch_axes
        if has_model:
            msize = jax.lax.axis_size("model")
            if n_local % msize == 0:
                d = jax.lax.psum_scatter(d, "model", scatter_dimension=0,
                                         tiled=True)
                solve_axes = batch_axes + ("model",)
            else:
                d = jax.lax.psum(d, "model")
        if metric == "l2":
            d = jnp.sqrt(jnp.maximum(d, 0.0))
        d = d * weights[None, :]
        return solve_sharded(d, init_idx, axes=solve_axes,
                             max_swaps=max_swaps, backend=backend)

    return jax.jit(run)
