"""Runtime invariant guards: the ``validate=`` tiers (DESIGN.md §6).

The solvers' correctness rests on invariants that until now were only
*tested* (the differential/golden suites) — never *checked at run time*,
where a NaN row, a bit-flipped bound cache, or a buggy refactor poisons
a multi-minute solve silently. This module promotes the strongest of
those test-time properties to production tripwires, in three tiers:

  ``off``      — nothing. The default path is the historical jitted
      solver, untouched; zero overhead (benchmarks/kernel_bench.py
      records it, tools/bench_compare.py gates it).
  ``cheap``    — input guards at the API boundary (non-finite rows via
      ``jax.experimental.checkify``, empty/degenerate X, k > n, integer
      dtype — each a clear ``ValueError`` naming the offence) plus O(m)
      per-sweep state invariants: all state finite, ``d1 <= d2``
      everywhere (a *bitwise* property of ``_top2``/``_repair_top2`` —
      both are mins over the same candidate set), the acceptance
      comparison consistent with the step's own floats, and the batch
      objective monotone non-increasing on accepted swaps (within an
      ``m · 2^-22`` relative float slack, the pruned sweep's rounding
      envelope).
  ``paranoid`` — cheap, plus an *independent selection oracle* per
      sweep: the exact (n, k) gain matrix recomputed through the
      solver's own float chain (``_weighted_rows`` -> ``ops.swap_gain``
      — the chain the pruned phase-2 rescore and the pre-fusion naive
      solver use), reduced with the naive first-(i, l) argmax that PR 2
      pinned bitwise against the fused selection. The sweep's selected
      (gain, i, l) must match the oracle's bitwise; for the pruned
      strategy the pre-sweep bound caches must additionally *contain*
      the exact gains (lo <= G <= hi — the test-only ``bound_scale``
      harness of DESIGN.md §2c, promoted to a run-time tripwire).
      Costs one extra full sweep per sweep; that is the contract.

Violations never raise from inside the solve: ``core/runtime.py``
catches them and walks the degradation ladder (pruned -> matrix-free
for the sweep, bf16 -> f32 re-score, state re-anchor), recording every
firing in the SolveReport. A :class:`GuardViolation` escapes only when
recovery itself fails.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import solver
from repro.kernels import ops
from repro.kernels.ref import NEG

VALIDATE_MODES = ("off", "cheap", "paranoid")

# Monotonicity slack per accepted swap, relative to the pre-swap batch
# objective mass: worst-case f32 summation error is ~m * 2^-24 of the
# summed magnitudes; 2^-22 leaves the same 4x margin core/pruned.py uses
# for its interval arithmetic, so a genuine objective *increase* (state
# corruption, broken repair) can never hide inside rounding.
_MONO_REL = 2.0 ** -22


class GuardViolation(RuntimeError):
    """An invariant violation the runtime could not recover from.

    ``names`` lists the violated guard(s); ``sweep`` the sweep index the
    violation fired on (None for API-boundary input guards).
    """

    def __init__(self, names, sweep=None, detail=""):
        self.names = tuple(names)
        self.sweep = sweep
        where = f" at sweep {sweep}" if sweep is not None else ""
        msg = f"invariant violation{where}: {', '.join(self.names)}"
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


def check_validate(mode: str) -> str:
    if mode not in VALIDATE_MODES:
        raise ValueError(
            f"unknown validate mode {mode!r}; options {VALIDATE_MODES}")
    return mode


# --------------------------------------------------------------- inputs --

def _finite_scan(x):
    """checkify-guarded finite scan: fails with the bad-row census."""
    from jax.experimental import checkify
    row_ok = jnp.all(jnp.isfinite(x), axis=-1)
    checkify.check(
        jnp.all(row_ok),
        "X contains non-finite values: {nbad} row(s) affected, first at "
        "row {first}",
        nbad=jnp.sum(~row_ok), first=jnp.argmin(row_ok))


@functools.lru_cache(maxsize=1)
def _finite_scan_jit():
    """One checkified jit for the process: ``checkify.checkify`` returns
    a fresh function object per call, so wrapping it in ``jax.jit``
    inline would miss the jit cache — and recompile — on every solve."""
    from jax.experimental import checkify
    return jax.jit(checkify.checkify(_finite_scan))


def check_inputs(x, k: int, *, m: int | None = None,
                 restarts: int = 1) -> None:
    """API-boundary input guards (validate != "off"): raise a clear
    ``ValueError`` before any solver work touches a poisoned input.

    Structural checks (shape, dtype, k vs n) run on the host; the
    non-finite scan runs as one jitted ``jax.experimental.checkify``
    pass so the error carries the bad-row census without a host copy of
    X.
    """
    from jax.experimental import checkify
    if getattr(x, "ndim", None) != 2:
        raise ValueError(
            f"X must be a 2-d (n, p) array, got shape "
            f"{getattr(x, 'shape', None)}")
    n, p = x.shape
    if n == 0 or p == 0:
        raise ValueError(f"X is empty/degenerate: shape {x.shape} — every "
                         "row needs at least one feature and n >= 1")
    if not jnp.issubdtype(x.dtype, jnp.floating):
        raise ValueError(
            f"X has dtype {x.dtype}, expected a floating dtype (cast "
            "explicitly — distances on integer/bool arrays are a silent "
            "unit bug)")
    if not 1 <= k <= n:
        raise ValueError(f"k must satisfy 1 <= k <= n, got k={k}, n={n}")
    if m is not None and m < 1:
        raise ValueError(f"batch size m must be >= 1, got {m}")
    if restarts >= 1 and restarts * k > n:
        # every restart draws k distinct medoids from n rows
        raise ValueError(
            f"k={k} medoids cannot be drawn from n={n} rows "
            f"(restarts={restarts})")
    err = _finite_scan_jit()(x)[0]
    try:
        err.throw()
    except checkify.JaxRuntimeError as e:
        raise ValueError(str(e)) from None


# ---------------------------------------------------- cheap sweep tier --

def cheap_stats(prev_state, new_state, improved, best, eps, mono_scale):
    """Per-sweep invariant scalars, evaluated on-device (jit/vmap this).

    Returns four bools: ``(finite, order, accept, mono)`` — True means
    the invariant holds. ``prev_state`` is the state the sweep scored
    against, ``new_state`` the candidate post-swap state, ``improved``/
    ``best`` the step's own acceptance outputs. ``mono_scale`` scales
    the monotonicity slack (1 for steepest-descent steps; the eager
    pass uses 1 + accepted swaps, one rounding envelope per swap).

    The acceptance check re-runs the step's comparison on the identical
    floats (``jnp.sum(prev.d1)`` is the same array through the same
    reduction), so it can only fire on corruption, never on rounding.
    """
    m = prev_state.d1.shape[0]
    prev_sum = jnp.sum(prev_state.d1)
    new_sum = jnp.sum(new_state.d1)
    finite = (jnp.isfinite(prev_sum) & jnp.isfinite(new_sum)
              & jnp.all(jnp.isfinite(new_state.d2))
              & jnp.all(jnp.isfinite(new_state.med_rows)))
    order = (jnp.all(prev_state.d1 <= prev_state.d2)
             & jnp.all(new_state.d1 <= new_state.d2))
    accept = jnp.where(improved, best > eps * prev_sum, True)
    slack = jnp.abs(prev_sum) * (m * _MONO_REL) * mono_scale
    mono = jnp.where(improved, new_sum <= prev_sum + slack, True)
    return finite, order, accept, mono


def cheap_stats_eager(prev_state, new_state, swapped):
    """The eager (pass-level) cheap tier: one ``_eager_pass`` applies up
    to n swaps before control returns to the host, so the monotonicity
    slack scales with the accepted swap count (``new.t - prev.t``) and
    there is no single (best, i, l) to re-check — the acceptance flag
    comes back True vacuously. Same (finite, order, accept, mono) shape
    as :func:`cheap_stats` so the runtime shares one recovery path.
    """
    m = prev_state.d1.shape[0]
    prev_sum = jnp.sum(prev_state.d1)
    new_sum = jnp.sum(new_state.d1)
    finite = (jnp.isfinite(prev_sum) & jnp.isfinite(new_sum)
              & jnp.all(jnp.isfinite(new_state.d2))
              & jnp.all(jnp.isfinite(new_state.med_rows)))
    order = (jnp.all(prev_state.d1 <= prev_state.d2)
             & jnp.all(new_state.d1 <= new_state.d2))
    nswaps = (new_state.t - prev_state.t).astype(jnp.float32)
    slack = jnp.abs(prev_sum) * (m * _MONO_REL) * (1.0 + nswaps)
    mono = jnp.where(swapped, new_sum <= prev_sum + slack, True)
    return finite, order, jnp.bool_(True), mono


_CHEAP_NAMES = ("state_nonfinite", "top2_order", "acceptance_gain",
                "objective_increase")


def cheap_names(flags) -> list[str]:
    """Host-side: the violated guard names from a (finite, order, accept,
    mono) quadruple (scalars or, per restart lane, picked already)."""
    return [name for ok, name in zip(flags, _CHEAP_NAMES) if not bool(ok)]


# ------------------------------------------------------- paranoid tier --

def exact_gains_matrix_free(xp, b, w, batch_idx, state, *, metric: str,
                            debias: bool, backend: str,
                            chunk: int) -> jnp.ndarray:
    """The exact (n, k) gain matrix w.r.t. ``state``, recomputed through
    the solver's own float chain (``solver._weighted_rows`` ->
    ``ops.swap_gain`` — the chain the pruned phase-2 rescore uses, row
    chunks and all, so per-row floats are bitwise the sweep's own).
    Medoid rows are *not* masked here; :func:`exact_select` masks them.
    """
    n = xp.shape[0]
    k = state.medoid_idx.shape[0]
    nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
    nchunks = -(-n // chunk)

    def one(c):
        cid = c * chunk + jnp.arange(chunk, dtype=jnp.int32)
        ok = cid < n
        safe = jnp.minimum(cid, n - 1)
        # The padding sentinel n never matches a batch index, so
        # duplicated gather rows cannot pick up a spurious debias LARGE
        # (same discipline as pruned._pruned_step's phase 2).
        d_rows = solver._weighted_rows(
            xp[safe], b, w, batch_idx, jnp.where(ok, cid, n),
            metric=metric, debias=debias, backend=backend)
        g = ops.swap_gain(d_rows, state.d1, state.d2, nh, backend=backend)
        return jnp.where(ok[:, None], g, NEG)

    gains = jax.lax.map(one, jnp.arange(nchunks, dtype=jnp.int32))
    return gains.reshape(nchunks * chunk, k)[:n]


def exact_gains_block(d, state, *, backend: str) -> jnp.ndarray:
    """The exact (n, k) gain matrix from a materialised block — the
    pre-fusion naive solver's scoring pass (``ops.swap_gain``)."""
    k = state.medoid_idx.shape[0]
    nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
    return ops.swap_gain(d, state.d1, state.d2, nh, backend=backend)


def exact_select(gains, medoid_idx):
    """The naive selection reduce over an exact gain matrix: flat argmax
    with current medoids masked to NEG — first-(i, l) on ties, which
    PR 2 pinned bitwise against the fused ``swap_select`` and PR 5
    against the pruned branch-and-bound scan. Returns (best, i, l)."""
    k = gains.shape[1]
    gains = gains.at[medoid_idx].set(NEG)
    flat = jnp.argmax(gains)
    return (gains.reshape(-1)[flat], (flat // k).astype(jnp.int32),
            (flat % k).astype(jnp.int32))


def selection_mismatch(best, i, l, o_best, o_i, o_l) -> bool:
    """Host-side bitwise compare of a sweep's selection against the
    oracle's. Gains compare as raw f32 bit patterns (NaN-proof)."""
    import numpy as np
    return (np.float32(best).tobytes() != np.float32(o_best).tobytes()
            or int(i) != int(o_i) or int(l) != int(o_l))


def bound_containment(gains, ub, lb, medoid_idx):
    """Pruned-cache containment (DESIGN.md §2c promoted to run time):
    per-slot ``lb <= G <= ub`` on every non-medoid row. Returns
    ``(ok, n_bad, first_bad_row)`` — evaluate on-device, pull scalars.
    """
    n = gains.shape[0]
    valid = jnp.ones((n,), jnp.bool_).at[medoid_idx].set(False)
    row_ok = jnp.all((lb <= gains) & (gains <= ub), axis=1) | ~valid
    return jnp.all(row_ok), jnp.sum(~row_ok), jnp.argmin(row_ok)
