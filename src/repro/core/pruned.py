"""Bound-pruned matrix-free sweep: BanditPAM-style candidate elimination
that *provably* selects the same swap (DESIGN.md §2c).

The matrix-free sweep (solver.solve_matrix_free) scores every candidate
row against all m batch columns each iteration, although after the first
few swaps almost no candidate is competitive. BanditPAM / BanditPAM++
(PAPERS.md) eliminate candidates from cheap subsample estimates plus
confidence intervals; this module composes that idea with the fused
tiles — but with *deterministic, sound* intervals instead of
probabilistic Hoeffding ones, so the selected swap is not "the same with
high probability" but **identical, bitwise, always**:

  phase 1 (estimate)  — one fused rowmax pass over an m' << m positional
      column subsample S (ops.fused_swap_select_rowmax, the §2b tile
      math) gives each row's exact partial gain E_S(i, l); the unseen
      complement T contributes, per column j, an add-term in [0, d1_j]
      and a removal-term in [d1_j - d2_j, 0], so

          E_S(i,l) + negrest_l  <=  G(i,l)  <=  E_S(i,l) + H_rest
          negrest_l = sum_{j in T, near_j = l} (d1_j - d2_j)   (<= 0)
          H_rest    = sum_{j in T} d1_j                        (>= 0)

      Both interval endpoints come out of the *same* rowmax kernel, via
      its per-slot additive ``offset`` input — Hoeffding's estimate ±
      width shape, with width the deterministic column-mass remainder.
  cached bounds (reuse) — every exactly-scored row caches its full
      (k,) gain row as a two-sided bound; an accepted swap (i*, l*)
      changes only batch columns whose (d1, d2, near) moved, and the
      resulting *per-slot* drift is a column sum the whole cache shares:

        G(i, l) = g_i + sum_{j: near_j = l} r_ij, so slot l moves by
        the add-term drift (all slots, g is nondecreasing 1-Lipschitz
        in each d1_j) plus its own removal traffic:
          columns leaving l  (near_j = l -> l'): rowgain can rise by
              the departing removal magnitude, -r_ij <= (d2_j - d1_j)
          columns entering l (near_j = l' -> l): can fall by the
              arriving magnitude, <= (d2'_j - d1'_j)
          columns kept in l: r = d1 - clamp(D, d1, d2) is 1-Lipschitz
              nondecreasing in d1, nonincreasing in d2, so they
              contribute only relu(+/-delta d1) + relu(-/+delta d2).

      so caches drift by k per-slot sums instead of being discarded —
      the BanditPAM++ cached-reuse idea, made exact. Per-slot is what
      makes pruning bite: a swap at slot l* evacuates ~m/k columns with
      their full removal widths, but every *other* slot drifts only by
      the (tiny) add/kept deltas, so a row whose best slot is unrelated
      to the swap keeps a tight interval.
  phase 2 (exact rescore) — survivors = rows whose upper bound
      max_l UB(i, l) reaches the best lower bound; a ``lax.while_loop``
      streams them in *descending-UB* chunks through the exact scoring
      chain (solver._weighted_rows -> ops.swap_gain — the §2b float
      chain), refreshing their cache rows and keeping a running best.
      Branch-and-bound: the running best is an *exact* scored gain, so
      once it exceeds the next chunk's head UB no unscanned row can
      attain the max and the loop stops — in the steady state only the
      handful of rows with UB >= the true max ever get scored. When
      survivors exceed ``survivor_frac * n`` the sweep *falls back
      dense*: the survivor set widens to every valid row — same loop,
      worst case exactly one full sweep, so it never regresses (sweep
      0, with vacuous caches, lands here by construction and
      initialises the caches).

Why the selected swap is *identical* to the full sweep's, ties included:
every bound is sound w.r.t. the solver's own computed float gains, so a
non-survivor i has G(i) <= UB(i) < best_LB <= max_valid G — strictly
below the max, hence every row attaining the max survives (exact ties
too); survivors are rescored through the identical float chain the full
sweep uses and reduced with the same first-row/first-slot tie-break.
Float soundness on non-exact (non-dyadic) instances is bought with a
``slack`` inflation of every width — sized at m * 2^-22 of the column
mass, orders above worst-case f32 summation error, orders below any
real gain gap. ``bound_scale`` scales every width component (slack
included): 1.0 is sound; < 1.0 deliberately un-sound, so the test
harness can prove it *catches* a wrong swap (the adversarial mode the
differential suite pins).

vmapped restart lanes (core/restarts.py) share the positional subsample
``arange(m') * (m // m')`` — it depends only on (m, m'), never on lane
data — so the pooled-sample slice discipline of §2a carries over.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solver import (
    SolveResult,
    _State,
    _init_state_matrix_free,
    _mf_chunk,
    _prepared,
    _repair_top2,
    _weighted_rows,
)
from repro.kernels import ops
from repro.kernels.ref import NEG

BIG = jnp.float32(1e30)

# Relative width inflation per bound, times the resident column mass and
# the batch size m: worst-case f32 summation error of the gain chains is
# ~m * 2^-24 of the summed magnitudes; 2^-22 leaves a 4x margin so
# rounding on non-dyadic instances can never un-sound a bound, while
# staying far below any gain gap a swap acceptance acts on.
_SLACK_REL = 2.0 ** -22


class PrunedStats(NamedTuple):
    """Per-sweep accounting of the pruned solve (solve_pruned_stats).

    Arrays are (max_swaps + 1,), indexed by sweep; entries past
    ``sweeps`` are zero. ``scored`` counts rows exactly rescored that
    sweep — at most the (bound-)``survivors`` count (every valid row on
    a dense-fallback sweep), usually far fewer because the descending-UB
    scan stops once the running best exceeds the next chunk's head UB.
    The benchmark's ``candidates_scored_per_sweep`` column is its mean
    over executed sweeps."""
    scored: jnp.ndarray     # (max_swaps + 1,) i32
    survivors: jnp.ndarray  # (max_swaps + 1,) i32
    fallback: jnp.ndarray   # (max_swaps + 1,) bool
    sweeps: jnp.ndarray     # i32, sweeps executed (incl. the converging one)


def default_prune_m(m: int) -> int:
    """Default phase-1 subsample width: an eighth of the batch."""
    return max(1, m // 8)


def _prune_positions(m: int, prune_m: int) -> np.ndarray:
    """The shared positional subsample: ``arange(m') * (m // m')`` —
    static in (m, m'), identical across vmapped restart lanes."""
    prune_m = max(1, min(prune_m, m))
    return np.arange(prune_m) * (m // prune_m)


def _chunk_q(n: int) -> int:
    """Phase-2 rescore chunk: 8-row floor (the ref oracle's degenerate-
    matmul rule), 256 cap, scaled down for small n so the chunked
    while_loop is exercised (not just one chunk) even on test sizes."""
    return max(8, min(256, 8 * ((n + 31) // 32)))


def _phase1_bounds(xp, b, w, batch_idx, state: _State, *, metric: str,
                   debias: bool, backend: str, row_chunk, prune_m: int,
                   bound_scale: float = 1.0):
    """The phase-1 subsample interval: ``(hi_samp, lo_samp, slack)``.

    ``hi_samp``/``lo_samp`` are (n,) sound upper/lower bounds on each
    row's max swap gain from one fused rowmax pass per endpoint over the
    positional m' subsample (module docstring derivation); ``slack`` is
    the width inflation every bound in the sweep shares. Factored out of
    :func:`_pruned_step` so the property suite
    (tests/test_pruned_sweep.py) pins containment against the exact
    gains through the identical code path.
    """
    m = b.shape[0]
    k = state.medoid_idx.shape[0]
    s = jnp.float32(bound_scale)
    nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
    sel = _prune_positions(m, prune_m)
    comp = np.ones((m,), np.float32)
    comp[sel] = 0.0
    comp = jnp.asarray(comp)                       # 1 on unseen columns T
    sel = jnp.asarray(sel, jnp.int32)
    # Width inflation against f32 summation error (module docstring); d2
    # is capped at the d1 mass so a debias LARGE sentinel in d2 cannot
    # blow the slack up globally (rows whose gains carry the sentinel are
    # hugely negative and can never be an *accepted* argmax anyway).
    mass = jnp.sum(state.d1)
    slack = (mass + jnp.sum(jnp.minimum(state.d2, mass))) * (m * _SLACK_REL)
    h_rest = jnp.dot(state.d1, comp)                            # >= 0
    negrest = ((state.d1 - state.d2) * comp) @ nh               # (k,) <= 0
    sub_args = dict(metric=metric, backend=backend, skip_prepare=True,
                    row_chunk=row_chunk)
    if debias:
        sub_args["owner"] = batch_idx[sel]
    hi_raw, _ = ops.fused_swap_select_rowmax(
        xp, b[sel], w[sel], state.d1[sel], state.d2[sel],
        jax.nn.one_hot(state.near[sel], k, dtype=jnp.float32), **sub_args)
    lo_raw, _ = ops.fused_swap_select_rowmax(
        xp, b[sel], w[sel], state.d1[sel], state.d2[sel],
        jax.nn.one_hot(state.near[sel], k, dtype=jnp.float32),
        offset=s * negrest, **sub_args)
    hi_samp = hi_raw + s * (h_rest + slack)
    lo_samp = lo_raw - s * slack
    return hi_samp, lo_samp, slack


def _pruned_step(xp, b, w, batch_idx, state: _State, ub, lb, *,
                 metric: str, debias: bool = False, eps: float = 0.0,
                 backend: str = "auto", chunk_size: int | None = None,
                 prune_m: int, survivor_frac: float = 0.5,
                 bound_scale: float = 1.0):
    """One pruned steepest-descent sweep.

    Returns ``(new_state, new_ub, new_lb, improved, best, i, l,
    (scored, n_survivors, fallback))``. ``ub``/``lb`` are the (n, k)
    per-slot gain caches. The caller applies the new state/caches only
    when ``improved`` (stats are unconditional — the sweep's work
    happened either way). Scoring runs against the *old* state; the
    accepted swap then drifts every cache row by the per-slot
    column-sum deltas. The selection floats are the exact sweep's (see
    module docstring), so the (improved, best, i, l) sequence is
    bit-for-bit ``solver._matrix_free_step``'s."""
    n = xp.shape[0]
    m = b.shape[0]
    k = state.medoid_idx.shape[0]
    s = jnp.float32(bound_scale)
    nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
    valid = jnp.ones((n,), jnp.bool_).at[state.medoid_idx].set(False)
    row_chunk = _mf_chunk(chunk_size)

    # ---- phase 1: subsample interval from one rowmax pass per endpoint.
    hi_samp, lo_samp, slack = _phase1_bounds(
        xp, b, w, batch_idx, state, metric=metric, debias=debias,
        backend=backend, row_chunk=row_chunk, prune_m=prune_m,
        bound_scale=bound_scale)

    # ---- survivors: row UB (cache ∩ subsample) must reach best LB.
    ub_row = jnp.minimum(jnp.max(ub, axis=1), hi_samp)
    lb_row = jnp.maximum(jnp.max(lb, axis=1), lo_samp)
    best_lb = jnp.max(jnp.where(valid, lb_row, -BIG))
    surv_b = valid & (ub_row >= best_lb)
    n_surv = jnp.sum(surv_b.astype(jnp.int32))
    threshold = jnp.int32(int(survivor_frac * n))
    fallback = n_surv > threshold
    # Dense fallback = the survivor set widens to every valid row: same
    # rescore loop, caches refresh as far as the scan runs, and the
    # worst case (vacuous caches, sweep 0) is exactly one full sweep.
    surv = jnp.where(fallback, valid, surv_b)
    n_scan = jnp.sum(surv.astype(jnp.int32))

    # ---- phase 2: branch-and-bound rescore, descending-UB order.
    # Chunks of survivors stream through the exact scoring chain
    # (solver._weighted_rows -> ops.swap_gain — the §2b float chain)
    # best-first: once the running best (an *exact* scored gain) exceeds
    # the head UB of the next chunk, no unscanned row can attain the
    # max (UB is sound and the order is descending), so the loop stops —
    # typically right after the chunk holding the true argmax. Exact
    # ties stay exact: an equal gain is accepted only at a smaller row
    # index, reproducing the full sweep's first-row argmax no matter
    # the scan order (argsort ties keep ascending index, stable sort).
    q = _chunk_q(n)
    order = jnp.argsort(-jnp.where(surv, ub_row, -jnp.inf)).astype(jnp.int32)
    pos = jnp.arange(n, dtype=jnp.int32)
    ids = jnp.where(pos < n_scan, order, n)
    heads = jnp.where(pos < n_scan, ub_row[order], -jnp.inf)
    pad = (-n) % q
    ids = jnp.concatenate([ids, jnp.full((pad,), n, jnp.int32)])
    heads = jnp.concatenate([heads, jnp.full((pad,), -jnp.inf)])
    n_chunks = (n_scan + (q - 1)) // q

    def cond(carry):
        c, best = carry[0], carry[1]
        head = jax.lax.dynamic_slice(heads, (c * q,), (1,))[0]
        return jnp.logical_and(c < n_chunks, head >= best)

    def body(carry):
        c, best, bi, bl, sc, ub_c, lb_c = carry
        cid = jax.lax.dynamic_slice(ids, (c * q,), (q,))
        ok = cid < n
        safe = jnp.minimum(cid, n - 1)
        # cid (not safe) feeds the debias row match: the padding
        # sentinel n never equals a batch index, so duplicated
        # gather rows cannot pick up a spurious LARGE diagonal.
        d_rows = _weighted_rows(xp[safe], b, w, batch_idx, cid,
                                metric=metric, debias=debias,
                                backend=backend)
        gain = ops.swap_gain(d_rows, state.d1, state.d2, nh,
                             backend=backend)
        rmax = jnp.max(gain, axis=1)
        rslot = jnp.argmax(gain, axis=1).astype(jnp.int32)
        gm = jnp.where(ok, rmax, NEG)
        cmax = jnp.max(gm)
        # Chunk winner: smallest row index attaining the chunk max (the
        # scan order is UB-sorted, not index-sorted, so argmax alone
        # would break the full sweep's first-row tie-break).
        ci = jnp.min(jnp.where((gm == cmax) & ok, cid, n))
        cl = rslot[jnp.argmax(cid == ci)]
        take = (cmax > best) | ((cmax == best) & (ci < bi))
        best = jnp.where(take, cmax, best)
        bi = jnp.where(take, ci, bi)
        bl = jnp.where(take, cl, bl)
        # Out-of-bounds scatter indices (the n sentinel) are dropped.
        ub_c = ub_c.at[cid].set(gain)
        lb_c = lb_c.at[cid].set(gain)
        return c + 1, best, bi, bl, sc + jnp.sum(ok.astype(jnp.int32)), \
            ub_c, lb_c

    _, best, i, l, scored, ub_new, lb_new = jax.lax.while_loop(
        cond, body,
        (jnp.int32(0), jnp.float32(NEG), jnp.int32(n), jnp.int32(0),
         jnp.int32(0), ub, lb))

    improved = best > eps * jnp.sum(state.d1)

    # ---- apply the swap (identical chain to _matrix_free_step) ...
    r = _weighted_rows(xp[i][None, :], b, w, batch_idx, i[None],
                       metric=metric, debias=debias, backend=backend)[0]
    med_rows, d1, d2, near, near2 = _repair_top2(
        state.med_rows, state.d1, state.d2, state.near, state.near2, r, l)
    new_state = _State(state.medoid_idx.at[l].set(i.astype(jnp.int32)),
                       med_rows, d1, d2, near, near2,
                       state.t + 1, state.done)

    # ---- ... then drift every cache row by the per-slot column sums
    # (module docstring: full removal width only where the column's
    # owner slot moved; kept columns only their Lipschitz deltas).
    up = jnp.maximum(d1 - state.d1, 0.0)
    dn = jnp.maximum(state.d1 - d1, 0.0)
    moved = (near != state.near).astype(jnp.float32)
    kept = 1.0 - moved
    nh_new = jax.nn.one_hot(near, k, dtype=jnp.float32)
    a_up = jnp.sum(up)                              # add-term, all slots
    a_dn = jnp.sum(dn)
    out_l = (moved * (state.d2 - state.d1)) @ nh    # departures, old slot
    in_l = (moved * (d2 - d1)) @ nh_new             # arrivals, new slot
    k_up = (kept * (up + jnp.maximum(state.d2 - d2, 0.0))) @ nh
    k_dn = (kept * (dn + jnp.maximum(d2 - state.d2, 0.0))) @ nh
    drift_up = a_up + out_l + k_up                  # (k,)
    drift_dn = a_dn + in_l + k_dn                   # (k,)
    ub_new = ub_new + s * (drift_up + slack)[None, :]
    lb_new = lb_new - s * (drift_dn + slack)[None, :]

    return (new_state, ub_new, lb_new, improved, best, i, l,
            (scored, n_surv, fallback))


@functools.partial(jax.jit, static_argnames=(
    "metric", "debias", "max_swaps", "backend", "chunk_size", "prune_m",
    "survivor_frac", "bound_scale"))
def solve_pruned_stats(
    x: jnp.ndarray,            # (n, p) data rows (f32 or bf16)
    batch_idx: jnp.ndarray,    # (m,) batch column indices into x
    weights: jnp.ndarray,      # (m,) f32 batch weights
    init_idx: jnp.ndarray,     # (k,) initial medoids
    *,
    metric: str = "l1",
    debias: bool = False,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
    prune_m: int | None = None,
    survivor_frac: float = 0.5,
    bound_scale: float = 1.0,
) -> tuple[SolveResult, PrunedStats]:
    """Bound-pruned matrix-free steepest descent, with per-sweep stats.

    Bitwise the same trajectory as :func:`solver.solve_matrix_free` on
    the same backend — same swaps, same floats, same tie-breaks (module
    docstring; tests/test_pruned_sweep.py and the golden fixtures pin
    it) — with most sweeps scoring only the surviving candidate rows
    exactly. ``prune_m`` (default m // 8) is the phase-1 subsample
    width; ``survivor_frac`` the dense-fallback threshold (a sweep whose
    survivor count exceeds ``survivor_frac * n`` runs the full pass
    instead — worst case never regresses); ``bound_scale`` scales every
    interval width (1.0 sound; < 1.0 is the adversarial mode that the
    differential harness proves it can catch). See
    :class:`PrunedStats` for the accounting.
    """
    if prune_m is None:
        prune_m = default_prune_m(batch_idx.shape[0])
    n = x.shape[0]
    xp = _prepared(x, metric)
    b = xp[batch_idx]
    w = weights.astype(jnp.float32)
    batch_idx = batch_idx.astype(jnp.int32)
    state = _init_state_matrix_free(xp, b, w, batch_idx, init_idx,
                                    metric=metric, debias=debias,
                                    backend=backend)
    k = init_idx.shape[0]
    ub0 = jnp.full((n, k), BIG)
    lb0 = jnp.full((n, k), -BIG)
    stats0 = (jnp.zeros((max_swaps + 1,), jnp.int32),
              jnp.zeros((max_swaps + 1,), jnp.int32),
              jnp.zeros((max_swaps + 1,), jnp.bool_))

    def cond(carry):
        state = carry[0]
        return jnp.logical_and(~state.done, state.t < max_swaps)

    def body(carry):
        state, ub, lb, stats, sweep = carry
        new_state, ub_n, lb_n, improved, _, _, _, per = _pruned_step(
            xp, b, w, batch_idx, state, ub, lb, metric=metric,
            debias=debias, eps=eps, backend=backend, chunk_size=chunk_size,
            prune_m=prune_m, survivor_frac=survivor_frac,
            bound_scale=bound_scale)
        at = jnp.minimum(sweep, max_swaps)
        stats = (stats[0].at[at].set(per[0]), stats[1].at[at].set(per[1]),
                 stats[2].at[at].set(per[2]))
        keep = jax.tree.map(
            lambda a, b: jnp.where(improved, a, b),
            (new_state, ub_n, lb_n),
            (state._replace(done=jnp.bool_(True)), ub, lb))
        return (*keep, stats, sweep + 1)

    state, _, _, stats, sweeps = jax.lax.while_loop(
        cond, body, (state, ub0, lb0, stats0, jnp.int32(0)))
    return (SolveResult(state.medoid_idx, state.t,
                        jnp.mean(state.d1), state.done),
            PrunedStats(stats[0], stats[1], stats[2], sweeps))


def solve_pruned(x, batch_idx, weights, init_idx, **kw) -> SolveResult:
    """:func:`solve_pruned_stats` without the accounting — the
    ``SolveResult``-only entry point ``one_batch_pam`` and the restart
    engine dispatch to (same trajectory, stats discarded)."""
    return solve_pruned_stats(x, batch_idx, weights, init_idx, **kw)[0]


#: Bucket bounds for the pruning-effectiveness histograms: candidate
#: counts, 1..10^6 in decades (a swap sweep scores at most n rows).
_STATS_BUCKETS = (1.0, 10.0, 100.0, 1e3, 1e4, 1e5, 1e6)


def publish_stats(tel, per) -> None:
    """Fold one sweep's ``(scored, survivors, fallback)`` triple — the
    ``_pruned_step`` per-sweep stats the while_loop solver accumulates
    into :class:`PrunedStats` — into the telemetry registry
    (DESIGN.md §10). Accepts scalars (single solve) or R-lane vectors
    (the vmapped restart step); each lane lands as one observation.
    Host-side only: the runtime calls this after the step's outputs are
    already synced for the sweep log, so it adds no device round-trip
    the telemetry-off path doesn't have."""
    scored, surv, fb = (np.asarray(v).reshape(-1) for v in per)
    h_sc = tel.histogram("pruned_scored_per_sweep",
                         "exactly rescored candidates per pruned sweep",
                         buckets=_STATS_BUCKETS)
    h_su = tel.histogram("pruned_survivors_per_sweep",
                         "bound-surviving candidates per pruned sweep",
                         buckets=_STATS_BUCKETS)
    c_fb = tel.counter("pruned_sweep_fallbacks_total",
                       "pruned sweeps that fell back to a dense scan")
    for s, u, f in zip(scored, surv, fb):
        h_sc.observe(float(s))
        h_su.observe(float(u))
        if bool(f):
            c_fb.inc()
