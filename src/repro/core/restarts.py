"""Parallel multi-restart OneBatchPAM (DESIGN.md §2a).

OneBatchPAM's premise — a single m = O(log n) batch estimates the
k-medoids objective well — makes R independent restarts nearly free:
R·m ≪ n reference columns still cost one streaming pass over X, and the
R local searches are the same fused swap-select sweep with a leading
restart axis. This is the quality/robustness axis CLARA/FasterCLARA
(Schubert & Rousseeuw 2019) and BanditPAM++ buy with repeated
subsampling, grafted onto the one-batch estimator. Three stages, each a
single XLA program:

  1. **Pooled sampling** (:func:`build_pool`): draw one column pool of
     size R·m (plus a held-out evaluation batch of ``eval_m`` columns),
     build the (n, R·m) block in ONE streaming sweep over X
     (``stream_block``; O(chunk · R·m) peak intermediates), with the
     per-restart nniw histograms fused into that same sweep via grouped
     argmin (``count_groups=R`` — no second pass over the block). The
     pool then slices into R per-restart (n, m) blocks with per-restart
     weights.
  2. **Vmapped solve** (:func:`solve_restarts`): ``jax.vmap`` of the
     fused :func:`solver.solve_batched` sweep over the restart axis —
     all R steepest-descent searches run as one batched kernel program
     (a batched ``lax.while_loop``: lanes that converge early freeze
     while the stragglers finish).
  3. **Election** (:func:`elect`): every restart's medoid set is
     re-scored on the SAME held-out evaluation batch — streamed,
     bf16-aware (the eval block is stored in ``block_dtype``, the
     min/mean accumulates in f32) — and the argmin wins. Ties elect the
     lowest restart index (``jnp.argmin`` semantics), deterministically.

The election invariant (DESIGN.md §2a): all restarts are scored on one
shared evaluation batch, so their scores are exchangeable estimates of
the true objective and the argmin is an unbiased best-of-R selection;
scoring each restart on its *own* training batch would reward estimator
noise (the batch it overfit), not objective quality.

``restarts=1`` through :func:`solver.one_batch_pam` never enters this
module — the single-restart trajectory stays bit-for-bit the historical
one. The distributed composition (restart axis × shard axis) lives in
``core/distributed.make_distributed_obp_restarts`` and is reached via
``mesh=``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling, solver, streaming
from repro.kernels.ref import LARGE


class Pool(NamedTuple):
    """The pooled column sample: R per-restart batches + held-out eval."""
    idx: jnp.ndarray       # (R, m) int32 column indices into X_n
    weights: jnp.ndarray   # (R, m) f32 per-restart variant weights (mean ~ 1)
    d: jnp.ndarray | None  # (R, n, m) weighted blocks (None on the mesh path)
    eval_idx: jnp.ndarray  # (eval_m,) int32 held-out evaluation columns


class RestartResult(NamedTuple):
    """Outcome of a multi-restart run."""
    best: solver.SolveResult        # the elected restart's solve result
    best_restart: jnp.ndarray       # int32 index of the winner
    eval_objectives: jnp.ndarray    # (R,) f32 held-out objective estimates
    results: solver.SolveResult     # all R results, fields stacked over R


def _pool_draws(key: jax.Array, n: int, m: int, restarts: int, eval_m: int):
    """Canonical uniform pool + held-out eval draw: one permutation of n.

    The first R·m entries form the pool (without replacement, so the R
    per-restart batches are disjoint) and the next ``eval_m`` entries the
    evaluation batch — truly held out whenever R·m + eval_m <= n. When n
    is too small for disjoint eval columns, the eval batch falls back to
    an independent uniform draw (overlap with the pool possible, still
    without replacement within itself). Shared verbatim by the host and
    mesh paths so both see identical draws.
    """
    key_pool, key_eval = jax.random.split(key)
    rm = restarts * m
    perm = jax.random.permutation(key_pool, n)
    pool_flat = perm[:rm]
    if rm + eval_m <= n:
        eval_idx = perm[rm:rm + eval_m]
    else:
        eval_idx = jax.random.choice(key_eval, n, shape=(eval_m,),
                                     replace=False)
    return pool_flat.astype(jnp.int32), eval_idx.astype(jnp.int32)


def _check_pool_shape(n: int, m: int, restarts: int) -> None:
    if restarts < 1:
        raise ValueError(f"restarts must be >= 1, got {restarts}")
    if m < 1:
        raise ValueError(f"pooled batch size m must be >= 1, got {m}")
    if restarts * m > n:
        raise ValueError(
            f"pooled sample R*m = {restarts}*{m} exceeds n = {n}; lower "
            "m or restarts (one_batch_pam clamps m to n // restarts)")


def build_pool(
    key: jax.Array,
    x: jnp.ndarray,
    m: int,
    restarts: int,
    *,
    eval_m: int | None = None,
    variant: str = "nniw",
    metric: str = "l1",
    backend: str = "auto",
    chunk_size: int | None = None,
    block_dtype: str | jnp.dtype | None = None,
    materialize: bool = True,
) -> Pool:
    """Sample the pooled R·m columns + eval batch and build all R blocks
    in one O(n · R·m) streaming sweep over X.

    ``materialize=False`` is the matrix-free pool (DESIGN.md §2b): no
    (R, n, m) blocks are built — ``Pool.d`` is None, and the per-restart
    nniw histograms come from the block-free grouped streaming argmin
    (``stream_nn_counts(count_groups=R)``, bitwise the materialized
    weights). Incompatible with ``block_dtype``.

    Variant semantics per restart slice mirror ``sampling.build_batch``:
    unit weights for ``unif``; owner-diagonal LARGE for ``debias``; for
    ``nniw`` the per-restart nearest-neighbour histograms come out of the
    same sweep via grouped argmin (``count_groups=R`` — each restart's
    counts are argmins over *its own* m columns); for ``lwcs`` the pool
    is drawn from the lightweight-coreset distribution and each slice's
    inverse-probability weights are normalised to mean 1 per restart.
    ``block_dtype`` narrows the stored (R, n, m) pool with the same cast
    order as ``build_batch`` (f32 distances and weights, one rounding on
    the stored product), so weights are storage-dtype-independent.
    """
    n = x.shape[0]
    _check_pool_shape(n, m, restarts)
    if variant not in sampling.VARIANTS:
        raise ValueError(
            f"unknown variant {variant!r}; options {sampling.VARIANTS}")
    if not materialize and block_dtype is not None:
        raise ValueError(
            "materialize=False builds no pool blocks; block_dtype does "
            "not apply (DESIGN.md §2b)")
    rm = restarts * m
    eval_m = m if eval_m is None else eval_m
    eval_m = max(1, min(eval_m, n))

    if variant == "lwcs":
        key_pool, key_eval = jax.random.split(key)
        mean = jnp.mean(x, axis=0, keepdims=True)
        dmean = streaming.stream_block(
            x, mean, metric=metric, backend=backend,
            chunk_size=chunk_size).d[:, 0]
        q = 0.5 / n + 0.5 * (dmean**2) / jnp.maximum(jnp.sum(dmean**2), 1e-30)
        pool_flat = jax.random.choice(key_pool, n, shape=(rm,),
                                      replace=False, p=q).astype(jnp.int32)
        eval_idx = jax.random.choice(key_eval, n, shape=(eval_m,),
                                     replace=False).astype(jnp.int32)
        w = (1.0 / (m * q[pool_flat])).reshape(restarts, m)
        w = w * (m / jnp.sum(w, axis=1, keepdims=True))  # mean 1 per restart
    else:
        pool_flat, eval_idx = _pool_draws(key, n, m, restarts, eval_m)
        w = jnp.ones((restarts, m), jnp.float32)

    if not materialize:
        if variant == "nniw":
            # Bounded-chunk default, as in build_batch: the grouped count
            # pass must not transiently build the (n, R·m) pool block.
            counts = streaming.stream_nn_counts(
                x, x[pool_flat], metric=metric, backend=backend,
                chunk_size=(streaming.MF_DEFAULT_CHUNK
                            if chunk_size is None else chunk_size),
                count_groups=restarts)
            w = counts.reshape(restarts, m) * (m / n)       # mean 1 per slice
        return Pool(idx=pool_flat.reshape(restarts, m), weights=w, d=None,
                    eval_idx=eval_idx)

    sb = streaming.stream_block(x, x[pool_flat], metric=metric,
                                backend=backend, chunk_size=chunk_size,
                                count_nn=(variant == "nniw"),
                                count_groups=restarts,
                                block_dtype=block_dtype)
    if variant == "nniw":
        w = sb.nn_counts.reshape(restarts, m) * (m / n)     # mean 1 per slice
    d_pool = _finalize_pool(sb.d, pool_flat, w, restarts=restarts,
                            debias=(variant == "debias"),
                            block_dtype=solver._dtype_name(block_dtype))
    return Pool(idx=pool_flat.reshape(restarts, m), weights=w, d=d_pool,
                eval_idx=eval_idx)


@functools.partial(jax.jit,
                   static_argnames=("restarts", "debias", "block_dtype"))
def _finalize_pool(d, pool_flat, w, *, restarts, debias, block_dtype):
    """(n, R·m) streamed block -> weighted (R, n, m) pool, in ONE fused
    program. jit matters here for memory, not speed: eagerly, the debias
    scatter, the weight multiply, and the restart-axis transpose would
    each materialise their own (n, R·m) copy — tripling the pool's
    already R× resident footprint at exactly the scale the engine
    targets. Fused, XLA produces the single (R, n, m) output buffer next
    to the input block. The cast order mirrors build_batch: block_dtype *
    f32 promotes, the weighted product computes in f32 and rounds once on
    the final store.
    """
    n = d.shape[0]
    rm = pool_flat.shape[0]
    if debias:
        d = d.at[pool_flat, jnp.arange(rm)].set(LARGE)
    dw = d * w.reshape(-1)[None, :]
    if block_dtype is not None:
        dw = dw.astype(block_dtype)
    return jnp.moveaxis(dw.reshape(n, restarts, rm // restarts), 1, 0)


def solve_restarts(
    d_pool: jnp.ndarray,    # (R, n, m) per-restart weighted blocks
    init_idx: jnp.ndarray,  # (R, k) per-restart initial medoids
    *,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
) -> solver.SolveResult:
    """All R fused steepest-descent searches as one vmapped program.

    Each lane is exactly :func:`solver.solve_batched` (same swap-select
    kernel, same incremental repair); the batched ``while_loop`` freezes
    converged lanes until the slowest restart finishes. Returns a
    SolveResult whose fields carry a leading restart axis.
    """
    return jax.vmap(
        lambda d, i: solver.solve_batched(d, i, max_swaps=max_swaps,
                                          eps=eps, backend=backend)
    )(d_pool, init_idx)


def solve_restarts_matrix_free(
    x: jnp.ndarray,          # (n, p) data rows, shared by all lanes
    pool_idx: jnp.ndarray,   # (R, m) per-restart batch columns
    weights: jnp.ndarray,    # (R, m) per-restart batch weights
    init_idx: jnp.ndarray,   # (R, k) per-restart initial medoids
    *,
    variant: str = "nniw",
    metric: str = "l1",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
) -> solver.SolveResult:
    """All R matrix-free searches as one vmapped program (DESIGN.md §2b).

    Each lane is exactly :func:`solver.solve_matrix_free` — the fused
    distance+swap-select sweep batches over the restart axis with X
    unbatched (broadcast), so total resident state is O(np + R·(km + m))
    instead of the pooled engine's O(R·nm) blocks. Per-lane trajectories
    are bit-for-bit the unbatched solver's (tests/test_matrix_free.py).
    """
    return jax.vmap(
        lambda bi, w, ii: solver.solve_matrix_free(
            x, bi, w, ii, metric=metric, debias=(variant == "debias"),
            max_swaps=max_swaps, eps=eps, backend=backend,
            chunk_size=chunk_size)
    )(pool_idx, weights, init_idx)


def solve_restarts_pruned(
    x: jnp.ndarray,          # (n, p) data rows, shared by all lanes
    pool_idx: jnp.ndarray,   # (R, m) per-restart batch columns
    weights: jnp.ndarray,    # (R, m) per-restart batch weights
    init_idx: jnp.ndarray,   # (R, k) per-restart initial medoids
    *,
    variant: str = "nniw",
    metric: str = "l1",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
    prune_m: int | None = None,
    survivor_frac: float = 0.5,
) -> solver.SolveResult:
    """All R bound-pruned searches as one vmapped program (DESIGN.md §2c).

    Each lane is exactly :func:`pruned.solve_pruned`, so per-lane
    trajectories are bit-for-bit the matrix-free (and hence batched)
    solver's. The phase-1 subsample positions are static (strided over
    m), so all lanes share the same m' column-slice of their respective
    batches — one vmapped phase-1 sweep, no per-lane gather patterns.
    Under vmap the dense-fallback ``lax.cond`` lowers to a select (both
    branches execute); that costs speed on mixed lanes, never changes
    any lane's swaps.
    """
    from repro.core import pruned as pruned_mod
    return jax.vmap(
        lambda bi, w, ii: pruned_mod.solve_pruned(
            x, bi, w, ii, metric=metric, debias=(variant == "debias"),
            max_swaps=max_swaps, eps=eps, backend=backend,
            chunk_size=chunk_size, prune_m=prune_m,
            survivor_frac=survivor_frac)
    )(pool_idx, weights, init_idx)


def elect(
    x: jnp.ndarray,
    medoid_idx: jnp.ndarray,  # (R, k) medoid sets, indices into X_n
    eval_idx: jnp.ndarray,    # (eval_m,) held-out evaluation columns
    *,
    metric: str = "l1",
    backend: str = "auto",
    chunk_size: int | None = None,
    block_dtype: str | jnp.dtype | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-restart election on the shared held-out batch.

    Scores restart r as mean_j min_l d(x_eval_j, medoid_rl) — the exact
    objective estimator, uniform over the eval batch — and returns
    ``(argmin restart, (R,) scores)``. The (eval_m, R·k) eval block is
    built through the streaming pipeline (``chunk_size`` bounds peak
    intermediates) and stored in ``block_dtype`` when set (bf16-aware:
    the min/mean reduction always accumulates in f32). Score ties elect
    the lowest restart index, deterministically.
    """
    restarts, k = medoid_idx.shape
    deval = streaming.stream_block(
        x[eval_idx], x[medoid_idx.reshape(-1)], metric=metric,
        backend=backend, chunk_size=chunk_size, block_dtype=block_dtype).d
    return score_restarts(deval, restarts, k)


def score_restarts(d_eval: jnp.ndarray, restarts: int, k: int):
    """The election scoring contract, in one place: per-restart
    mean-of-min over the (eval_m, R·k) eval block, f32 accumulation,
    argmin with lowest-restart tie-break. Shared by the host
    :func:`elect` and the mesh election
    (``distributed.make_distributed_obp_restarts``) so the bit-for-bit
    host == mesh guarantee cannot drift out from under
    ``tests/helpers/dist_restart_check.py``.
    """
    per_restart = d_eval.astype(jnp.float32).reshape(-1, restarts, k)
    evals = per_restart.min(axis=2).mean(axis=0)             # (R,)
    return jnp.argmin(evals).astype(jnp.int32), evals


def _init_draws(key: jax.Array, n: int, k: int, restarts: int) -> jnp.ndarray:
    """(R, k) per-restart initial medoids, one independent draw per lane."""
    keys = jax.random.split(key, restarts)
    return jax.vmap(
        lambda kk: jax.random.choice(kk, n, shape=(k,), replace=False)
    )(keys).astype(jnp.int32)


def one_batch_pam_restarts(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    restarts: int,
    m: int | None = None,
    eval_m: int | None = None,
    variant: str = "nniw",
    metric: str = "l1",
    strategy: str = "batched",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
    block_dtype: str | jnp.dtype | None = None,
    mesh=None,
    prune_m: int | None = None,
    survivor_frac: float = 0.5,
) -> tuple[RestartResult, Pool]:
    """End-to-end multi-restart OneBatchPAM: pool → vmapped solve → elect.

    ``m`` defaults to the paper heuristic clamped to n // R so the pool
    fits (the pooled-sample budget: R disjoint batches must come out of n
    rows — ``solver.one_batch_pam`` warns when a user-passed m is
    clamped); ``eval_m`` defaults to m. With ``mesh=`` the whole pipeline
    runs data-parallel under shard_map — per-shard fused swap-select
    partials per restart and a single-psum election
    (``distributed.make_distributed_obp_restarts``); the returned Pool
    then has ``d=None`` since the blocks only exist shard-wise.
    ``strategy="matrix_free"`` (host-side only) runs the R lanes through
    :func:`solve_restarts_matrix_free` on a block-free pool — ``Pool.d``
    is None because the blocks never exist at all (DESIGN.md §2b).
    ``strategy="pruned"`` (host-side only) is the same block-free pool
    fed to :func:`solve_restarts_pruned` — bitwise the matrix-free
    lanes, most sweeps only exactly rescoring bound-surviving candidates
    (DESIGN.md §2c); ``prune_m``/``survivor_frac`` tune it.
    """
    n = x.shape[0]
    if m is None:
        m = min(sampling.default_batch_size(n, k), max(n // restarts, 1))
    if strategy not in ("batched", "matrix_free", "pruned"):
        raise ValueError(
            "restart lanes support strategy='batched', 'matrix_free' or "
            f"'pruned', got {strategy!r}")
    matrix_free = strategy == "matrix_free"
    block_free = strategy in ("matrix_free", "pruned")
    _check_pool_shape(n, m, restarts)
    key_b, key_i = jax.random.split(key)
    init_idx = _init_draws(key_i, n, k, restarts)

    if mesh is not None and block_free:
        raise ValueError(
            f"restarts x mesh x {strategy} is not composed yet; run "
            f"{strategy} restarts host-side (mesh=None) or use the "
            "single-restart distributed matrix-free path "
            "(distributed.make_distributed_obp_matrix_free)")
    if mesh is not None:
        from repro.core import distributed
        if variant == "lwcs":
            raise ValueError(
                "variant 'lwcs' is not supported in-mesh; run restarts "
                "host-side (mesh=None) or pick unif/debias/nniw")
        eval_m_eff = max(1, min(m if eval_m is None else eval_m, n))
        pool_flat, eval_idx = _pool_draws(key_b, n, m, restarts, eval_m_eff)
        run = distributed.make_distributed_obp_restarts(
            mesh, k=k, restarts=restarts, metric=metric, variant=variant,
            max_swaps=max_swaps, eps=eps, backend=backend,
            chunk_size=chunk_size,
            block_dtype=solver._dtype_name(block_dtype))
        results, best_r, evals, weights = run(
            distributed.shard_over_batch(mesh, x), pool_flat, eval_idx,
            init_idx)
        pool = Pool(idx=pool_flat.reshape(restarts, m), weights=weights,
                    d=None, eval_idx=eval_idx)
    else:
        pool = build_pool(key_b, x, m, restarts, eval_m=eval_m,
                          variant=variant, metric=metric, backend=backend,
                          chunk_size=chunk_size, block_dtype=block_dtype,
                          materialize=not block_free)
        if matrix_free:
            results = solve_restarts_matrix_free(
                x, pool.idx, pool.weights, init_idx, variant=variant,
                metric=metric, max_swaps=max_swaps, eps=eps,
                backend=backend, chunk_size=chunk_size)
        elif strategy == "pruned":
            results = solve_restarts_pruned(
                x, pool.idx, pool.weights, init_idx, variant=variant,
                metric=metric, max_swaps=max_swaps, eps=eps,
                backend=backend, chunk_size=chunk_size, prune_m=prune_m,
                survivor_frac=survivor_frac)
        else:
            results = solve_restarts(pool.d, init_idx, max_swaps=max_swaps,
                                     eps=eps, backend=backend)
        best_r, evals = elect(x, results.medoid_idx, pool.eval_idx,
                              metric=metric, backend=backend,
                              chunk_size=chunk_size, block_dtype=block_dtype)

    best = jax.tree.map(lambda a: a[best_r], results)
    return RestartResult(best=best, best_restart=best_r,
                         eval_objectives=evals, results=results), pool
