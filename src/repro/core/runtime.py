"""Fault-tolerant solve runtime: checkpoint/resume + guard ladder
(DESIGN.md §6).

``one_batch_pam`` runs each local search as one opaque
``lax.while_loop``: fast, but a preempted solve loses everything, and an
invariant violation (poisoned input, corrupted bound cache, a bad bf16
sweep) surfaces — if at all — as a silently wrong answer minutes later.
This module re-hosts the *identical* loop bodies (``solver._fused_step``
/ ``_matrix_free_step`` / ``pruned._pruned_step`` / ``_eager_pass`` —
the same jitted step functions ``core/trace.py`` already proves replay
the while_loop solvers bit for bit) in a host-driven sweep loop that
can, between sweeps:

  * **checkpoint** the full solver state through the ``repro.checkpoint``
    atomic-rename machinery — medoids, the (k, m)/top-2 state, the
    pruned (n, k) bound caches, per-lane restart states, the swap count
    — every ``ckpt_every`` sweeps. The batch/pool is *not* stored: it is
    rebuilt bitwise from the run's PRNG key (``sampling.build_batch`` /
    ``restarts.build_pool`` are deterministic in (key, shape, config)),
    so a checkpoint is O(km + nk·pruned) on disk, and ``resume="auto"``
    continues a SIGKILL'd solve with a bitwise-identical remaining
    trajectory (tests/helpers/kill_resume_check.py kills at every sweep
    and diffs the logs).
  * **guard** the sweep with the ``validate=`` tiers (core/guards.py)
    and, on a violation, walk the degradation ladder instead of
    crashing: ``pruned`` falls back to the matrix-free sweep for the
    offending sweep (bound caches reset — the selection chain *is* the
    exactness oracle, so the trajectory stays bitwise-correct); bf16
    blocks escalate the offending sweep to an f32 re-score on the
    deterministically rebuilt f32 block; anything else re-anchors the
    top-2 state from the medoid set (``_top2`` is value-exact with the
    incremental repair, so re-anchoring preserves the swap trajectory
    bitwise) and redoes the sweep. A violation that survives its
    recovery raises :class:`guards.GuardViolation`.
  * **report** everything in a structured :class:`SolveReport` — sweeps,
    swaps, per-sweep wall times (``monitoring.StepTimer``), every
    checkpoint write, fallback, and violation.

Restart lanes (R > 1) run through the vmapped step functions with the
per-lane freeze/accept semantics of the batched ``while_loop``
(inactive lanes compute and discard, exactly like vmap's select), so
the R-lane trajectory — and its checkpoints — match
``one_batch_pam(restarts=R)`` lane for lane, bit for bit.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guards, sampling, solver
from repro.core import trace as trace_mod
from repro.monitoring import StepTimer
from repro.monitoring import telemetry as telemetry_mod

_CKPT_VERSION = 1


# ----------------------------------------------------------- telemetry --

class _SolveTelemetry:
    """Per-solve scope over a (usually process-wide) metrics registry
    (DESIGN.md §10). Counters are monotonic across solves — Prometheus
    semantics — so this records each counter's value at solve start and
    exposes the solve's own deltas via :meth:`snapshot`, which lands in
    ``SolveReport.metrics``: the report's counts are *views over the
    registry*, not a parallel set of bare ints. Every call is host-side
    bookkeeping around the jitted steps; with ``telemetry="off"`` no
    instance exists and the solve loop is the untouched path (pinned by
    the ``telemetry_overhead_vs_off`` bench gate)."""

    def __init__(self, tel: telemetry_mod.Telemetry, strategy: str):
        self.tel = tel
        self.strategy = strategy
        r = tel.registry
        self.c_sweeps = r.counter("solve_sweeps_total",
                                  "executed solve sweeps")
        self.c_swaps = r.counter("solve_swaps_total", "accepted swaps")
        self.c_fallbacks = r.counter(
            "solve_fallbacks_total",
            "degradation-ladder firings, by recovery kind")
        self.c_violations = r.counter(
            "solve_guard_violations_total",
            "invariant-guard violations, by guard name")
        self.c_ckpt = r.counter("solve_checkpoint_writes_total",
                                "persisted sweep checkpoints")
        self.c_restores = r.counter("solve_checkpoint_restores_total",
                                    "resume restores from checkpoint")
        self.h_sweep = r.histogram("solve_sweep_seconds",
                                   "wall seconds per solve sweep")
        self.h_ckpt_s = r.histogram("solve_checkpoint_write_seconds",
                                    "wall seconds per checkpoint write")
        self.h_ckpt_b = r.histogram(
            "solve_checkpoint_bytes", "leaf bytes per checkpoint write",
            buckets=(1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9))
        self.h_restore = r.histogram("solve_checkpoint_restore_seconds",
                                     "wall seconds per resume restore")
        self._counters = {
            "sweeps": (self.c_sweeps, {"strategy": strategy}),
            "swaps": (self.c_swaps, {"strategy": strategy}),
            "fallbacks": (self.c_fallbacks, None),
            "guard_violations": (self.c_violations, None),
            "checkpoint_writes": (self.c_ckpt, {}),
            "checkpoint_restores": (self.c_restores, {}),
        }
        self._base = {k: self._read(k) for k in self._counters}

    def _read(self, key: str) -> float:
        c, labels = self._counters[key]
        return c.total() if labels is None else c.value(**labels)

    def snapshot(self) -> dict:
        """This solve's registry deltas (JSON-safe)."""
        return {k: self._read(k) - v for k, v in self._base.items()}

    # -- per-event hooks (each mirrors one SolveReport record) ----------
    def sweep(self, sweep: int, t0_ns: int, t1_ns: int, accepted) -> None:
        self.c_sweeps.inc(strategy=self.strategy)
        self.h_sweep.observe((t1_ns - t0_ns) / 1e9,
                             strategy=self.strategy)
        acc = np.asarray(accepted)
        if acc.any():
            self.c_swaps.inc(float(acc.sum()), strategy=self.strategy)
        self.tel.complete("solve/sweep", t0_ns, t1_ns, sweep=sweep,
                          strategy=self.strategy)

    def violation(self, sweep: int, names) -> None:
        for nm in names:
            self.c_violations.inc(strategy=self.strategy, guard=nm)
        self.tel.instant("solve/guard_violation", sweep=sweep,
                         guards=list(names))

    def fallback(self, sweep: int, kind: str) -> None:
        self.c_fallbacks.inc(strategy=self.strategy, kind=kind)
        self.tel.instant("solve/fallback", sweep=sweep, kind=kind)

    def pruned_stats(self, per) -> None:
        """Fold one sweep's PrunedStats scalars (or R-lane vectors) into
        the pruning-effectiveness series (core/pruned.publish_stats)."""
        from repro.core import pruned as pruned_mod
        pruned_mod.publish_stats(self.tel, per)

    def checkpoint_write(self, t0_ns: int, t1_ns: int,
                         nbytes: int) -> None:
        self.c_ckpt.inc()
        self.h_ckpt_s.observe((t1_ns - t0_ns) / 1e9)
        self.h_ckpt_b.observe(nbytes)
        self.tel.complete("solve/checkpoint_write", t0_ns, t1_ns,
                          bytes=nbytes)

    def checkpoint_restore(self, t0_ns: int, t1_ns: int,
                           sweep: int) -> None:
        self.c_restores.inc()
        self.h_restore.observe((t1_ns - t0_ns) / 1e9)
        self.tel.complete("solve/checkpoint_restore", t0_ns, t1_ns,
                          sweep=sweep)


# ----------------------------------------------------------- reporting --

@dataclasses.dataclass
class SolveReport:
    """Structured account of a fault-tolerant solve.

    ``sweep_log`` has one entry per executed sweep: ``{"sweep", "accepted",
    "i", "l", "gain"}`` (per-lane lists when ``restarts > 1``, plus
    ``"active"``) — gains are f32 values (exact through JSON: every f32
    is a double). ``fallbacks``/``violations`` record the degradation
    ladder's firings; ``checkpoint_writes`` the persisted steps;
    ``timer`` the per-sweep wall times (``timer.summary()`` has
    p50/p95/max over the steady steps, with ``count``/``warmup_excluded``
    naming exactly that population, plus the straggler count);
    ``election`` the restart
    winner (None for a single restart). ``resumed_from`` is the sweep a
    ``resume="auto"`` run continued from (None = fresh start).

    ``metrics`` (telemetry on only, else None) is this solve's slice of
    the shared metrics registry — the per-solve deltas of the
    ``solve_*_total`` counters (``_SolveTelemetry.snapshot``). The bare
    counts here are *views over the registry*, not a second source of
    truth: ``metrics["sweeps"] == sweeps``, ``metrics["fallbacks"] ==
    len(fallbacks)``, etc. (tests/test_monitoring.py pins this).
    """
    strategy: str = "batched"
    validate: str = "off"
    restarts: int = 1
    sweeps: int = 0
    swaps: int = 0
    converged: bool = False
    resumed_from: int | None = None
    checkpoint_writes: list = dataclasses.field(default_factory=list)
    fallbacks: list = dataclasses.field(default_factory=list)
    violations: list = dataclasses.field(default_factory=list)
    sweep_log: list = dataclasses.field(default_factory=list)
    timer: StepTimer = dataclasses.field(default_factory=StepTimer)
    election: dict | None = None
    metrics: dict | None = None

    def to_dict(self) -> dict:
        """JSON-safe snapshot (rides checkpoint extras; the timer is
        summarised, not persisted — wall times don't survive a kill)."""
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
             if f.name != "timer"}
        d["timer_summary"] = self.timer.summary()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SolveReport":
        kw = {f.name: d[f.name] for f in dataclasses.fields(cls)
              if f.name != "timer" and f.name in d}
        return cls(**kw)


# ------------------------------------------------- jitted step plumbing --
# Single-restart steps reuse core/trace.py's lru-cached jits (the proof
# that host-replay == while_loop rides on those exact functions); the
# vmapped variants below are their R-lane twins, in_axes matching the
# solve_restarts* wrappers (X/pool broadcast, lane state batched).

@functools.lru_cache(maxsize=64)
def _jit_fused_step_v(eps: float, backend: str):
    return jax.jit(jax.vmap(functools.partial(
        solver._fused_step, eps=eps, backend=backend)))


@functools.lru_cache(maxsize=64)
def _jit_mf_step_v(metric: str, debias: bool, eps: float, backend: str,
                   chunk_size):
    return jax.jit(jax.vmap(functools.partial(
        solver._matrix_free_step, metric=metric, debias=debias, eps=eps,
        backend=backend, chunk_size=chunk_size),
        in_axes=(None, 0, 0, 0, 0)))


@functools.lru_cache(maxsize=64)
def _jit_pruned_step_v(metric: str, debias: bool, eps: float, backend: str,
                       chunk_size, prune_m: int, survivor_frac: float):
    from repro.core import pruned
    return jax.jit(jax.vmap(functools.partial(
        pruned._pruned_step, metric=metric, debias=debias, eps=eps,
        backend=backend, chunk_size=chunk_size, prune_m=prune_m,
        survivor_frac=survivor_frac),
        in_axes=(None, 0, 0, 0, 0, 0, 0)))


@functools.lru_cache(maxsize=8)
def _jit_init_state_v():
    return jax.jit(jax.vmap(solver._init_state))


@functools.lru_cache(maxsize=64)
def _jit_init_state_mf(metric: str, debias: bool, backend: str,
                       vmapped: bool):
    f = functools.partial(solver._init_state_matrix_free, metric=metric,
                          debias=debias, backend=backend)
    if vmapped:
        f = jax.vmap(f, in_axes=(None, 0, 0, 0, 0))
    return jax.jit(f)


@functools.lru_cache(maxsize=8)
def _jit_reanchor_block(vmapped: bool):
    def f(d, state):
        med_rows = d[state.medoid_idx].astype(jnp.float32)
        d1, d2, near, near2 = solver._top2(med_rows)
        return state._replace(med_rows=med_rows, d1=d1, d2=d2,
                              near=near, near2=near2)
    return jax.jit(jax.vmap(f) if vmapped else f)


@functools.lru_cache(maxsize=64)
def _jit_reanchor_mf(metric: str, debias: bool, backend: str,
                     vmapped: bool):
    def f(xp, b, w, bidx, state):
        med_rows = solver._weighted_rows(
            xp[state.medoid_idx], b, w, bidx, state.medoid_idx,
            metric=metric, debias=debias, backend=backend)
        d1, d2, near, near2 = solver._top2(med_rows)
        return state._replace(med_rows=med_rows, d1=d1, d2=d2,
                              near=near, near2=near2)
    if vmapped:
        return jax.jit(jax.vmap(f, in_axes=(None, 0, 0, 0, 0)))
    return jax.jit(f)


@functools.lru_cache(maxsize=8)
def _jit_cheap(vmapped: bool):
    f = guards.cheap_stats
    if vmapped:
        f = jax.vmap(f, in_axes=(0, 0, 0, 0, None, None))
    return jax.jit(f)


@functools.lru_cache(maxsize=8)
def _jit_cheap_eager():
    return jax.jit(guards.cheap_stats_eager)


@functools.lru_cache(maxsize=8)
def _jit_oracle_block(backend: str, vmapped: bool):
    def f(d, state):
        g = guards.exact_gains_block(d, state, backend=backend)
        return guards.exact_select(g, state.medoid_idx)
    return jax.jit(jax.vmap(f) if vmapped else f)


@functools.lru_cache(maxsize=64)
def _jit_oracle_mf(metric: str, debias: bool, backend: str, chunk: int,
                   vmapped: bool):
    def f(xp, b, w, bidx, state):
        g = guards.exact_gains_matrix_free(
            xp, b, w, bidx, state, metric=metric, debias=debias,
            backend=backend, chunk=chunk)
        return (*guards.exact_select(g, state.medoid_idx), g)
    if vmapped:
        return jax.jit(jax.vmap(f, in_axes=(None, 0, 0, 0, 0)))
    return jax.jit(f)


def _lane_where(mask, a, b):
    """Per-lane select with trailing-axis broadcast; a pure bit-move."""
    m = jnp.asarray(mask)
    return jnp.where(m.reshape(m.shape + (1,) * (jnp.ndim(a) - m.ndim)),
                     a, b)


def _sub_lanes(mask, new, old):
    return jax.tree.map(lambda a, b: _lane_where(mask, a, b), new, old)


# ------------------------------------------------------- checkpointing --

def _state_leaves(state, ub=None, lb=None) -> dict:
    d = {"medoid_idx": state.medoid_idx, "med_rows": state.med_rows,
         "d1": state.d1, "d2": state.d2, "near": state.near,
         "near2": state.near2, "t": state.t, "done": state.done}
    if ub is not None:
        d["ub"], d["lb"] = ub, lb
    return d


def _state_from_leaves(leaves: dict):
    state = solver._State(*(jnp.asarray(leaves[f]) for f in
                            solver._State._fields))
    ub = jnp.asarray(leaves["ub"]) if "ub" in leaves else None
    lb = jnp.asarray(leaves["lb"]) if "lb" in leaves else None
    return state, ub, lb


def _key_bits(key) -> list[int]:
    try:
        data = jax.random.key_data(key)
    except TypeError:
        data = key
    return np.asarray(data).astype(np.uint32).reshape(-1).tolist()


def _fingerprint(key, *, n, p, k, m, variant, metric, strategy, max_swaps,
                 eps, backend, chunk_size, block_dtype, restarts, eval_m,
                 prune_m, survivor_frac) -> dict:
    """Everything the remaining trajectory depends on. ``validate`` /
    ``ckpt_every`` / ``keep`` are deliberately absent: they change what
    is checked or written, never the floats, so a resume may tighten or
    relax them."""
    return {"version": _CKPT_VERSION, "key": _key_bits(key), "n": int(n),
            "p": int(p), "k": int(k), "m": int(m), "variant": variant,
            "metric": metric, "strategy": strategy,
            "max_swaps": int(max_swaps), "eps": float(eps),
            "backend": backend, "chunk_size": chunk_size,
            "block_dtype": solver._dtype_name(block_dtype),
            "restarts": int(restarts),
            "eval_m": None if eval_m is None else int(eval_m),
            "prune_m": None if prune_m is None else int(prune_m),
            "survivor_frac": float(survivor_frac)}


def _check_fingerprint(saved: dict, current: dict) -> None:
    keys = sorted(set(saved) | set(current))
    diffs = [f"{kk}: checkpoint has {saved.get(kk)!r}, "
             f"this run has {current.get(kk)!r}"
             for kk in keys if saved.get(kk) != current.get(kk)]
    if diffs:
        raise ValueError(
            "cannot resume: checkpoint was written by a run with a "
            "different configuration —\n  " + "\n  ".join(diffs) +
            "\nPass resume='never' (or a fresh checkpoint_dir) to start "
            "over.")


class _Checkpointer:
    """Sweep-granular persistence through ``repro.checkpoint``."""

    def __init__(self, root: str | None, *, every: int, keep: int,
                 fingerprint: dict, stel: _SolveTelemetry | None = None):
        self.root, self.every, self.keep = root, max(1, every), keep
        self.fingerprint = fingerprint
        self.stel = stel
        self._last = None

    def maybe_save(self, done_sweeps: int, leaves: dict,
                   report: SolveReport, *, final: bool = False) -> None:
        if self.root is None or done_sweeps == self._last:
            return
        if not final and done_sweeps % self.every != 0:
            return
        from repro import checkpoint as ckpt
        extra = {"version": _CKPT_VERSION, "sweep": done_sweeps,
                 "fingerprint": self.fingerprint,
                 "report": report.to_dict()}
        t0 = time.perf_counter_ns()
        ckpt.save(self.root, done_sweeps, leaves, extra=extra,
                  keep=self.keep)
        if self.stel is not None:
            self.stel.checkpoint_write(
                t0, time.perf_counter_ns(),
                sum(np.asarray(v).nbytes for v in leaves.values()))
        self._last = done_sweeps
        report.checkpoint_writes.append(done_sweeps)

    def try_resume(self, template: dict):
        """-> (leaves, sweep, report) from the newest valid checkpoint,
        or None when the directory holds none (or none restores — a
        fully corrupt directory warns and starts fresh rather than
        failing an otherwise-runnable solve)."""
        import os
        import warnings

        from repro import checkpoint as ckpt
        if self.root is None or not os.path.isdir(self.root):
            return None
        steps = ckpt.all_steps(self.root)
        if not steps:
            return None
        # Config mismatch must surface as the clear fingerprint error,
        # not masquerade as corruption: check it from the newest
        # readable manifest BEFORE any leaf touches the shape check.
        for step in reversed(steps):
            try:
                saved = ckpt.manifest(self.root, step).get("extra", {})
            except Exception:
                continue
            _check_fingerprint(saved.get("fingerprint", {}),
                               self.fingerprint)
            break
        t0 = time.perf_counter_ns()
        try:
            leaves, extra, step = ckpt.restore_latest_valid(self.root,
                                                            template)
        except FileNotFoundError as e:
            warnings.warn(
                f"resume='auto': no restorable checkpoint under "
                f"{self.root} ({e}); starting fresh", UserWarning,
                stacklevel=2)
            return None
        if self.stel is not None:
            self.stel.checkpoint_restore(t0, time.perf_counter_ns(),
                                         int(extra["sweep"]))
        report = SolveReport.from_dict(extra.get("report", {}))
        report.timer = StepTimer()
        self._last = step
        return leaves, int(extra["sweep"]), report


# ------------------------------------------------------------ the loop --

def solve_fault_tolerant(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    m: int | None = None,
    variant: str = "nniw",
    metric: str = "l1",
    strategy: str = "batched",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
    block_dtype: str | jnp.dtype | None = None,
    restarts: int = 1,
    eval_m: int | None = None,
    prune_m: int | None = None,
    survivor_frac: float = 0.5,
    validate: str = "off",
    checkpoint_dir: str | None = None,
    ckpt_every: int = 1,
    resume: str = "auto",
    keep: int = 3,
    telemetry="off",
    _fault_hook=None,
) -> tuple[solver.SolveResult, sampling.Batch, SolveReport]:
    """Fault-tolerant OneBatchPAM: ``one_batch_pam``'s trajectory, bit
    for bit, plus checkpoint/resume, invariant guards, and degradation
    (module docstring). Returns ``(result, batch, report)``.

    ``telemetry="off" | "on" | Telemetry`` wires the solve into the
    metrics registry + span tracer (DESIGN.md §10): sweep/checkpoint
    spans, sweep-seconds and checkpoint write/restore histograms,
    fallback/violation counters, pruned survivors/scored histograms,
    and ``report.metrics`` as the per-solve registry deltas. All of it
    is host-side bookkeeping around the same jitted steps — the
    trajectory is bitwise identical either way, and ``"off"`` skips
    every telemetry branch (the untouched path the
    ``telemetry_overhead_vs_off`` bench gate pins).

    ``_fault_hook(run)`` is the test seam: called at the top of every
    sweep with a mutable ``{"sweep", "state", "ub", "lb"}`` dict whose
    (possibly mutated) entries are read back — tests/faults.py injects
    corruption and kills through it. Exceptions it raises propagate
    (completed sweeps are already checkpointed).
    """
    tel = telemetry_mod.resolve(telemetry)
    guards.check_validate(validate)
    if resume not in ("auto", "never"):
        raise ValueError(f"resume must be 'auto' or 'never', got {resume!r}")
    if strategy not in ("batched", "matrix_free", "pruned", "eager"):
        raise ValueError(f"unknown strategy {strategy!r}")
    x = jnp.asarray(x)
    if validate != "off":
        guards.check_inputs(x, k, m=m, restarts=restarts)
    n, p = x.shape
    user_m = m
    m = m if m is not None else sampling.default_batch_size(n, k)
    m = min(m, n)
    if restarts < 1:
        raise ValueError(f"restarts must be >= 1, got {restarts}")
    block_free = strategy in ("matrix_free", "pruned")
    if block_free and block_dtype is not None:
        raise ValueError(
            f"strategy={strategy!r} builds no block; block_dtype does not "
            "apply (tiles are recomputed in f32 on chip, DESIGN.md §2b)")
    if restarts > 1 and strategy == "eager":
        raise ValueError(
            "restarts > 1 supports strategy='batched', 'matrix_free' "
            "or 'pruned'")
    debias = variant == "debias"
    if strategy == "pruned" and prune_m is None:
        from repro.core import pruned as pruned_mod
        prune_m_eff = pruned_mod.default_prune_m(
            solver._clamp_pool_m(n, restarts, m, user_m=None)
            if restarts > 1 else m)
    else:
        prune_m_eff = prune_m

    if restarts > 1:
        return _solve_restarts(
            key, x, k, m=m, user_m=user_m, variant=variant, metric=metric,
            strategy=strategy, max_swaps=max_swaps, eps=eps,
            backend=backend, chunk_size=chunk_size, block_dtype=block_dtype,
            restarts=restarts, eval_m=eval_m, prune_m=prune_m_eff,
            survivor_frac=survivor_frac, validate=validate,
            checkpoint_dir=checkpoint_dir, ckpt_every=ckpt_every,
            resume=resume, keep=keep, tel=tel, fault_hook=_fault_hook)
    return _solve_single(
        key, x, k, m=m, variant=variant, metric=metric, strategy=strategy,
        max_swaps=max_swaps, eps=eps, backend=backend,
        chunk_size=chunk_size, block_dtype=block_dtype, eval_m=eval_m,
        prune_m=prune_m_eff, survivor_frac=survivor_frac,
        validate=validate, checkpoint_dir=checkpoint_dir,
        ckpt_every=ckpt_every, resume=resume, keep=keep, tel=tel,
        fault_hook=_fault_hook)


def _hook(fault_hook, sweep, state, ub, lb):
    if fault_hook is None:
        return state, ub, lb
    run: dict[str, Any] = {"sweep": sweep, "state": state, "ub": ub,
                           "lb": lb}
    fault_hook(run)
    return run["state"], run["ub"], run["lb"]


def _record_violation(report, sweep, names, *, lanes=None, detail="",
                      stel=None):
    entry = {"sweep": int(sweep), "guards": list(names)}
    if lanes is not None:
        entry["lanes"] = [int(r) for r in lanes]
    if detail:
        entry["detail"] = detail
    report.violations.append(entry)
    if stel is not None:
        stel.violation(int(sweep), names)


def _record_fallback(report, sweep, kind, *, lanes=None, stel=None):
    entry = {"sweep": int(sweep), "kind": kind}
    if lanes is not None:
        entry["lanes"] = [int(r) for r in lanes]
    report.fallbacks.append(entry)
    if stel is not None:
        stel.fallback(int(sweep), kind)


# --------------------------------------------------------- one restart --

def _solve_single(key, x, k, *, m, variant, metric, strategy, max_swaps,
                  eps, backend, chunk_size, block_dtype, eval_m, prune_m,
                  survivor_frac, validate, checkpoint_dir, ckpt_every,
                  resume, keep, tel=None, fault_hook=None):
    from repro.core import pruned as pruned_mod
    n, p = x.shape
    stel = (_SolveTelemetry(tel, strategy) if tel is not None else None)
    solve_t0 = time.perf_counter_ns() if stel is not None else 0
    debias = variant == "debias"
    key_b, key_i = jax.random.split(key)
    init_idx = jax.random.choice(key_i, n, shape=(k,), replace=False)
    batch = sampling.build_batch(
        key_b, x, m, variant=variant, metric=metric, backend=backend,
        chunk_size=chunk_size, block_dtype=block_dtype,
        materialize=strategy not in ("matrix_free", "pruned"))

    pruned_caches = strategy == "pruned"
    if strategy in ("matrix_free", "pruned"):
        xp = solver._prepared(x, metric)
        b = xp[batch.idx]
        w = batch.weights.astype(jnp.float32)
        bidx = batch.idx.astype(jnp.int32)
        state = _jit_init_state_mf(metric, debias, backend, False)(
            xp, b, w, bidx, init_idx)
    else:
        d = batch.d
        state = solver._init_state(d, init_idx)
    ub = jnp.full((n, k), pruned_mod.BIG) if pruned_caches else None
    lb = jnp.full((n, k), -pruned_mod.BIG) if pruned_caches else None

    fp = _fingerprint(key, n=n, p=p, k=k, m=m, variant=variant,
                      metric=metric, strategy=strategy, max_swaps=max_swaps,
                      eps=eps, backend=backend, chunk_size=chunk_size,
                      block_dtype=block_dtype, restarts=1, eval_m=eval_m,
                      prune_m=prune_m, survivor_frac=survivor_frac)
    ckpt = _Checkpointer(checkpoint_dir, every=ckpt_every, keep=keep,
                         fingerprint=fp, stel=stel)
    report = SolveReport(strategy=strategy, validate=validate, restarts=1)
    sweep = 0
    if resume == "auto":
        got = ckpt.try_resume(_state_leaves(state, ub, lb))
        if got is not None:
            leaves, sweep, report = got
            state, ub, lb = _state_from_leaves(leaves)
            report.resumed_from = sweep
            report.strategy, report.validate = strategy, validate

    cheap = _jit_cheap(False)
    eps_a = jnp.float32(eps)

    if strategy == "eager":
        _run_eager(d, state, report=report, ckpt=ckpt, sweep=sweep,
                   max_swaps=max_swaps, eps=eps, validate=validate,
                   stel=stel, fault_hook=fault_hook)
        # state was rebound inside; re-fetch the loop's final state
        state = report._eager_final  # set by _run_eager
        del report._eager_final
        res = solver.SolveResult(state.medoid_idx, state.t,
                                 jnp.mean(state.d1), state.done)
        report.sweeps = len(report.sweep_log)
        report.swaps = int(state.t)
        report.converged = bool(state.done)
        _finish_tel(stel, report, solve_t0, n=n, k=k, restarts=1)
        return res, batch, report

    if strategy == "batched":
        step = trace_mod._jit_fused_step(eps, backend)
    elif strategy == "matrix_free":
        step = trace_mod._jit_matrix_free_step(metric, debias, eps,
                                               backend, chunk_size)
    else:
        step = trace_mod._jit_pruned_step(metric, debias, eps, backend,
                                          chunk_size, prune_m,
                                          survivor_frac, 1.0)
    mf_step = (trace_mod._jit_matrix_free_step(metric, debias, eps,
                                               backend, chunk_size)
               if pruned_caches else None)
    d32 = None  # lazily rebuilt f32 block for the bf16 escalation

    per_slot = {}  # pruned per-sweep stats, captured only under telemetry

    def run_step(st, u, lo):
        if strategy == "batched":
            out = step(d, st)
            return (*out, u, lo)
        if strategy == "matrix_free":
            out = step(xp, b, w, bidx, st)
            return (*out, u, lo)
        new_state, ub_n, lb_n, improved, best, i, l, per = step(
            xp, b, w, bidx, st, u, lo)
        if stel is not None:
            per_slot["per"] = per
        return new_state, improved, best, i, l, ub_n, lb_n

    def run_oracle(st):
        if strategy == "batched":
            o_best, o_i, o_l = _jit_oracle_block(backend, False)(d, st)
            return o_best, o_i, o_l, None
        return _jit_oracle_mf(metric, debias, backend,
                              pruned_mod._chunk_q(n), False)(
            xp, b, w, bidx, st)

    while not bool(state.done) and int(state.t) < max_swaps:
        state, ub, lb = _hook(fault_hook, sweep, state, ub, lb)
        t0 = time.perf_counter()
        t0_ns = time.perf_counter_ns() if stel is not None else 0
        new_state, improved, best, i, l, ub_n, lb_n = run_step(state, ub, lb)

        if validate != "off":
            names = guards.cheap_names(cheap(state, new_state, improved,
                                             best, eps_a, 1.0))
            detail = ""
            if validate == "paranoid" and not names:
                o_best, o_i, o_l, g = run_oracle(state)
                if pruned_caches:
                    ok, nbad, row = guards.bound_containment(
                        g, ub, lb, state.medoid_idx)
                    if not bool(ok):
                        names.append("bound_containment")
                        detail = (f"{int(nbad)} row(s) outside the cache "
                                  f"interval, first at row {int(row)}")
                if guards.selection_mismatch(best, i, l, o_best, o_i, o_l):
                    names.append("selection_mismatch")
            if names:
                _record_violation(report, sweep, names, detail=detail,
                                  stel=stel)
                # ---- degradation ladder ----------------------------
                if pruned_caches:
                    # The matrix-free sweep IS the exactness oracle:
                    # same selection floats, no caches to trust.
                    new_state, improved, best, i, l = mf_step(
                        xp, b, w, bidx, state)
                    ub_n = jnp.full((n, k), pruned_mod.BIG)
                    lb_n = jnp.full((n, k), -pruned_mod.BIG)
                    _record_fallback(report, sweep, "pruned->matrix_free",
                                     stel=stel)
                elif (strategy == "batched"
                      and block_dtype is not None):
                    if d32 is None:
                        d32 = sampling.build_batch(
                            key_b, x, m, variant=variant, metric=metric,
                            backend=backend, chunk_size=chunk_size,
                            block_dtype=None).d
                    state = _jit_reanchor_block(False)(d32, state)
                    new_state, improved, best, i, l = \
                        trace_mod._jit_fused_step(eps, backend)(d32, state)
                    _record_fallback(report, sweep, "bf16->f32_rescore",
                                     stel=stel)
                else:
                    if strategy == "batched":
                        state = _jit_reanchor_block(False)(d, state)
                    else:
                        state = _jit_reanchor_mf(metric, debias, backend,
                                                 False)(xp, b, w, bidx,
                                                        state)
                    new_state, improved, best, i, l, ub_n, lb_n = \
                        run_step(state, ub, lb)
                    _record_fallback(report, sweep, "state_reanchor",
                                     stel=stel)
                still = guards.cheap_names(cheap(state, new_state,
                                                 improved, best, eps_a,
                                                 1.0))
                if still:
                    raise guards.GuardViolation(still, sweep=sweep,
                                                detail="after recovery")
        report.timer.record(time.perf_counter() - t0)
        if stel is not None:
            stel.sweep(sweep, t0_ns, time.perf_counter_ns(), improved)
            per = per_slot.pop("per", None)
            if per is not None:
                stel.pruned_stats(per)

        acc = bool(improved)
        report.sweep_log.append({"sweep": sweep, "accepted": acc,
                                 "i": int(i), "l": int(l),
                                 "gain": float(best)})
        if acc:
            state, ub, lb = new_state, ub_n, lb_n
        else:
            state = state._replace(done=jnp.bool_(True))
        sweep += 1
        ckpt.maybe_save(sweep, _state_leaves(state, ub, lb), report)

    ckpt.maybe_save(sweep, _state_leaves(state, ub, lb), report,
                    final=True)
    res = solver.SolveResult(state.medoid_idx, state.t,
                             jnp.mean(state.d1), state.done)
    report.sweeps = len(report.sweep_log)
    report.swaps = int(state.t)
    report.converged = bool(state.done)
    _finish_tel(stel, report, solve_t0, n=n, k=k, restarts=1)
    return res, batch, report


def _finish_tel(stel, report, solve_t0, *, n, k, restarts):
    """Close out a solve's telemetry: snapshot the per-solve registry
    deltas into ``report.metrics`` and emit the root "solve" span."""
    if stel is None:
        return
    report.metrics = stel.snapshot()
    stel.tel.complete("solve", solve_t0, time.perf_counter_ns(),
                      strategy=stel.strategy, n=n, k=k,
                      restarts=restarts, sweeps=report.sweeps,
                      swaps=report.swaps)


def _run_eager(d, state, *, report, ckpt, sweep, max_swaps, eps, validate,
               stel=None, fault_hook=None):
    """Pass-level host loop for the eager strategy (cheap tier only —
    a first-improvement pass has no single selection to oracle)."""
    scan = trace_mod._jit_eager_pass(eps)
    cheap = _jit_cheap_eager()
    reanchor = _jit_reanchor_block(False)
    max_passes = max(2, max_swaps // max(int(state.medoid_idx.shape[0]), 1))
    while not bool(state.done) and sweep < max_passes:
        state, _, _ = _hook(fault_hook, sweep, state, None, None)
        t0 = time.perf_counter()
        t0_ns = time.perf_counter_ns() if stel is not None else 0
        new_state, swapped, flags, slots = scan(d, state)
        if validate != "off":
            names = guards.cheap_names(cheap(state, new_state, swapped))
            if names:
                _record_violation(report, sweep, names, stel=stel)
                state = reanchor(d, state)
                new_state, swapped, flags, slots = scan(d, state)
                _record_fallback(report, sweep, "state_reanchor",
                                 stel=stel)
                still = guards.cheap_names(cheap(state, new_state, swapped))
                if still:
                    raise guards.GuardViolation(still, sweep=sweep,
                                                detail="after recovery")
        report.timer.record(time.perf_counter() - t0)
        if stel is not None:
            stel.sweep(sweep, t0_ns, time.perf_counter_ns(), flags)
        nsw = np.flatnonzero(np.asarray(flags))
        report.sweep_log.append(
            {"sweep": sweep, "accepted": bool(swapped),
             "i": [int(c) for c in nsw],
             "l": [int(np.asarray(slots)[c]) for c in nsw],
             "gain": []})
        state = new_state._replace(done=~swapped)
        sweep += 1
        ckpt.maybe_save(sweep, _state_leaves(state), report)
    ckpt.maybe_save(sweep, _state_leaves(state), report, final=True)
    report._eager_final = state


# ------------------------------------------------------- restart lanes --

def _solve_restarts(key, x, k, *, m, user_m, variant, metric, strategy,
                    max_swaps, eps, backend, chunk_size, block_dtype,
                    restarts, eval_m, prune_m, survivor_frac, validate,
                    checkpoint_dir, ckpt_every, resume, keep, tel=None,
                    fault_hook=None):
    from repro.core import pruned as pruned_mod
    from repro.core import restarts as restarts_mod
    n, p = x.shape
    stel = (_SolveTelemetry(tel, strategy) if tel is not None else None)
    solve_t0 = time.perf_counter_ns() if stel is not None else 0
    debias = variant == "debias"
    block_free = strategy in ("matrix_free", "pruned")
    rm = solver._clamp_pool_m(n, restarts, m, user_m=user_m)
    key_b, key_i = jax.random.split(key)
    init_idx = restarts_mod._init_draws(key_i, n, k, restarts)
    pool = restarts_mod.build_pool(
        key_b, x, rm, restarts, eval_m=eval_m, variant=variant,
        metric=metric, backend=backend, chunk_size=chunk_size,
        block_dtype=block_dtype, materialize=not block_free)

    pruned_caches = strategy == "pruned"
    if block_free:
        xp = solver._prepared(x, metric)
        b = xp[pool.idx]                                   # (R, m, p)
        w = pool.weights.astype(jnp.float32)
        bidx = pool.idx.astype(jnp.int32)
        state = _jit_init_state_mf(metric, debias, backend, True)(
            xp, b, w, bidx, init_idx)
        d_pool = None
    else:
        d_pool = pool.d
        state = _jit_init_state_v()(d_pool, init_idx)
    ub = jnp.full((restarts, n, k), pruned_mod.BIG) if pruned_caches else None
    lb = (jnp.full((restarts, n, k), -pruned_mod.BIG)
          if pruned_caches else None)

    fp = _fingerprint(key, n=n, p=p, k=k, m=rm, variant=variant,
                      metric=metric, strategy=strategy, max_swaps=max_swaps,
                      eps=eps, backend=backend, chunk_size=chunk_size,
                      block_dtype=block_dtype, restarts=restarts,
                      eval_m=eval_m, prune_m=prune_m,
                      survivor_frac=survivor_frac)
    ckpt = _Checkpointer(checkpoint_dir, every=ckpt_every, keep=keep,
                         fingerprint=fp, stel=stel)
    report = SolveReport(strategy=strategy, validate=validate,
                         restarts=restarts)
    sweep = 0
    if resume == "auto":
        got = ckpt.try_resume(_state_leaves(state, ub, lb))
        if got is not None:
            leaves, sweep, report = got
            state, ub, lb = _state_from_leaves(leaves)
            report.resumed_from = sweep
            report.strategy, report.validate = strategy, validate

    if strategy == "batched":
        step_v = _jit_fused_step_v(eps, backend)
    elif strategy == "matrix_free":
        step_v = _jit_mf_step_v(metric, debias, eps, backend, chunk_size)
    else:
        step_v = _jit_pruned_step_v(metric, debias, eps, backend,
                                    chunk_size, prune_m, survivor_frac)
    mf_step_v = (_jit_mf_step_v(metric, debias, eps, backend, chunk_size)
                 if pruned_caches else None)
    cheap_v = _jit_cheap(True)
    eps_a = jnp.float32(eps)
    d32_pool = None

    per_slot = {}  # pruned per-sweep stats (R-lane), telemetry only

    def run_step(st, u, lo):
        if strategy == "batched":
            out = step_v(d_pool, st)
            return (*out, u, lo)
        if strategy == "matrix_free":
            out = step_v(xp, b, w, bidx, st)
            return (*out, u, lo)
        new_state, ub_n, lb_n, improved, best, i, l, per = step_v(
            xp, b, w, bidx, st, u, lo)
        if stel is not None:
            per_slot["per"] = per
        return new_state, improved, best, i, l, ub_n, lb_n

    def lanes_active(st):
        return np.asarray(~st.done & (st.t < max_swaps))

    active = lanes_active(state)
    while active.any():
        state, ub, lb = _hook(fault_hook, sweep, state, ub, lb)
        t0 = time.perf_counter()
        t0_ns = time.perf_counter_ns() if stel is not None else 0
        new_state, improved, best, i, l, ub_n, lb_n = run_step(state, ub, lb)

        if validate != "off":
            flags = cheap_v(state, new_state, improved, best, eps_a, 1.0)
            flags = [np.asarray(f) for f in flags]
            bad = active & ~(flags[0] & flags[1] & flags[2] & flags[3])
            names = sorted({nm for r in np.flatnonzero(bad)
                            for nm in guards.cheap_names(
                                [f[r] for f in flags])})
            if validate == "paranoid" and not bad.any():
                if strategy == "batched":
                    o_best, o_i, o_l = _jit_oracle_block(backend, True)(
                        d_pool, state)
                    g = None
                else:
                    o_best, o_i, o_l, g = _jit_oracle_mf(
                        metric, debias, backend, pruned_mod._chunk_q(n),
                        True)(xp, b, w, bidx, state)
                for r in np.flatnonzero(active):
                    lane_names = []
                    if pruned_caches:
                        ok, nbad, row = guards.bound_containment(
                            g[r], ub[r], lb[r], state.medoid_idx[r])
                        if not bool(ok):
                            lane_names.append("bound_containment")
                    if guards.selection_mismatch(
                            best[r], i[r], l[r], o_best[r], o_i[r], o_l[r]):
                        lane_names.append("selection_mismatch")
                    if lane_names:
                        bad[r] = True
                        names = sorted(set(names) | set(lane_names))
            if bad.any():
                lanes = np.flatnonzero(bad)
                _record_violation(report, sweep, names, lanes=lanes,
                                  stel=stel)
                badm = jnp.asarray(bad)
                if pruned_caches:
                    alt = mf_step_v(xp, b, w, bidx, state)
                    new_state = _sub_lanes(badm, alt[0], new_state)
                    improved, best, i, l = (
                        _lane_where(badm, a, o) for a, o in
                        zip(alt[1:], (improved, best, i, l)))
                    ub_n = _lane_where(
                        badm, jnp.full((restarts, n, k), pruned_mod.BIG),
                        ub_n)
                    lb_n = _lane_where(
                        badm, jnp.full((restarts, n, k), -pruned_mod.BIG),
                        lb_n)
                    _record_fallback(report, sweep, "pruned->matrix_free",
                                     lanes=lanes, stel=stel)
                elif strategy == "batched" and block_dtype is not None:
                    if d32_pool is None:
                        d32_pool = restarts_mod.build_pool(
                            key_b, x, rm, restarts, eval_m=eval_m,
                            variant=variant, metric=metric,
                            backend=backend, chunk_size=chunk_size,
                            block_dtype=None).d
                    re32 = _jit_reanchor_block(True)(d32_pool, state)
                    state = _sub_lanes(badm, re32, state)
                    alt = _jit_fused_step_v(eps, backend)(d32_pool, state)
                    new_state = _sub_lanes(badm, alt[0], new_state)
                    improved, best, i, l = (
                        _lane_where(badm, a, o) for a, o in
                        zip(alt[1:], (improved, best, i, l)))
                    _record_fallback(report, sweep, "bf16->f32_rescore",
                                     lanes=lanes, stel=stel)
                else:
                    if strategy == "batched":
                        re = _jit_reanchor_block(True)(d_pool, state)
                    else:
                        re = _jit_reanchor_mf(metric, debias, backend,
                                              True)(xp, b, w, bidx, state)
                    state = _sub_lanes(badm, re, state)
                    alt = run_step(state, ub, lb)
                    new_state = _sub_lanes(badm, alt[0], new_state)
                    improved, best, i, l = (
                        _lane_where(badm, a, o) for a, o in
                        zip(alt[1:5], (improved, best, i, l)))
                    if pruned_caches:
                        ub_n = _lane_where(badm, alt[5], ub_n)
                        lb_n = _lane_where(badm, alt[6], lb_n)
                    _record_fallback(report, sweep, "state_reanchor",
                                     lanes=lanes, stel=stel)
                flags = cheap_v(state, new_state, improved, best, eps_a,
                                1.0)
                flags = [np.asarray(f) for f in flags]
                still = jnp.asarray(bad) & ~(flags[0] & flags[1]
                                             & flags[2] & flags[3])
                if bool(np.asarray(still).any()):
                    raise guards.GuardViolation(
                        names, sweep=sweep,
                        detail=f"after recovery on lanes "
                               f"{np.flatnonzero(np.asarray(still)).tolist()}")
        report.timer.record(time.perf_counter() - t0)

        improved_h = np.asarray(improved)
        if stel is not None:
            stel.sweep(sweep, t0_ns, time.perf_counter_ns(),
                       active & improved_h)
            per = per_slot.pop("per", None)
            if per is not None:
                stel.pruned_stats(per)
        report.sweep_log.append({
            "sweep": sweep,
            "active": [bool(a) for a in active],
            "accepted": [bool(a and im) for a, im in
                         zip(active, improved_h)],
            "i": [int(v) for v in np.asarray(i)],
            "l": [int(v) for v in np.asarray(l)],
            "gain": [float(v) for v in np.asarray(best)]})
        take = jnp.asarray(active) & jnp.asarray(improved)
        nxt = _sub_lanes(take, new_state, state)
        nxt = nxt._replace(done=jnp.where(
            jnp.asarray(active) & ~jnp.asarray(improved),
            jnp.bool_(True), nxt.done))
        state = nxt
        if pruned_caches:
            ub = _lane_where(take, ub_n, ub)
            lb = _lane_where(take, lb_n, lb)
        sweep += 1
        ckpt.maybe_save(sweep, _state_leaves(state, ub, lb), report)
        active = lanes_active(state)

    ckpt.maybe_save(sweep, _state_leaves(state, ub, lb), report,
                    final=True)
    results = solver.SolveResult(state.medoid_idx, state.t,
                                 jax.vmap(jnp.mean)(state.d1), state.done)
    best_r, evals = restarts_mod.elect(
        x, results.medoid_idx, pool.eval_idx, metric=metric,
        backend=backend, chunk_size=chunk_size, block_dtype=block_dtype)
    res = jax.tree.map(lambda a: a[best_r], results)
    r = int(best_r)
    d_best = None if pool.d is None else pool.d[r]
    batch = sampling.Batch(idx=pool.idx[r], weights=pool.weights[r],
                           d=d_best)
    report.sweeps = len(report.sweep_log)
    report.swaps = int(jnp.sum(results.n_swaps))
    report.converged = bool(jnp.all(results.converged))
    report.election = {"best_restart": r,
                       "eval_objectives": [float(v) for v in
                                           np.asarray(evals)]}
    _finish_tel(stel, report, solve_t0, n=n, k=k, restarts=restarts)
    return res, batch, report
