"""Batch construction for OneBatchPAM (Algorithm 1, lines 3-6).

Four variants from the paper's Experiments section:
  unif   — uniform sample, unit weights.
  debias — uniform sample, then d(x_sigma(j), x_sigma(j)) := LARGE so the
           batch points cannot advertise a zero self-distance and bias the
           medoid choice toward themselves.
  nniw   — uniform sample + nearest-neighbour importance weighting
           (Loog 2012): w_j ∝ #{i : argmin_j' d_ij' = j}, normalised to
           mean 1 so objectives stay comparable across variants.
  lwcs   — lightweight-coreset sampling (Bachem et al. 2018):
           q(x) = 1/2n + d(x, mean)^2 / (2 * sum d^2), weights 1/(m q).

All functions are jit-compatible (static m). The (n, m) block is produced
by the streaming pipeline (streaming.py, DESIGN.md §4): pass ``chunk_size``
to bound peak intermediate memory — the nniw nearest-neighbour histogram
is fused into the same row sweep, so no full-height argmin pass re-reads
the block. ``chunk_size=None`` keeps the one-shot computation; both paths
produce identical numbers whenever they stay on the same evaluation path
(see streaming.py's module docstring for the ref-oracle big-block caveat
that bounds the bitwise form of this claim).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import streaming
from repro.kernels.ref import LARGE

VARIANTS = ("unif", "debias", "nniw", "lwcs")


class Batch(NamedTuple):
    """The single batch of OneBatchPAM."""
    idx: jnp.ndarray      # (m,) int32 indices into X_n
    weights: jnp.ndarray  # (m,) f32 importance weights (mean ~ 1)
    d: jnp.ndarray | None  # (n, m) weighted distance block (f32 or
    #                        block_dtype); None on the matrix-free and
    #                        mesh paths, where the block never exists


def _uniform_idx(key: jax.Array, n: int, m: int) -> jnp.ndarray:
    return jax.random.choice(key, n, shape=(m,), replace=False)


def default_batch_size(n: int, k: int) -> int:
    """The paper's heuristic m = 100 * log(k * n) (Experiments section)."""
    import math
    return max(int(100 * math.log(max(k * n, 2))), 2 * k + 1)


def build_batch(
    key: jax.Array,
    x: jnp.ndarray,
    m: int,
    *,
    variant: str = "nniw",
    metric: str = "l1",
    backend: str = "auto",
    chunk_size: int | None = None,
    block_dtype: str | jnp.dtype | None = None,
    materialize: bool = True,
) -> Batch:
    """Sample the batch, compute the (n, m) block, apply the variant.

    ``chunk_size`` streams the n axis through the distance kernels in row
    chunks (exact; see streaming.py). None computes the block in one shot.
    ``block_dtype`` stores the block in a narrower dtype (e.g.
    ``"bfloat16"``): distances and weights are computed in f32, the weight
    multiply runs in f32 via promotion, and only the stored block rounds —
    so ``Batch.weights`` is identical to the f32 path (DESIGN.md §2).
    ``materialize=False`` is the matrix-free path (DESIGN.md §2b): the
    (n, m) block is never built — nniw weights come from the block-free
    streaming histogram (``stream_nn_counts``, bitwise the materialized
    weights per evaluation path; the count pass defaults to
    ``streaming.MF_DEFAULT_CHUNK`` rather than one-shot so it cannot
    transiently build the block) and ``Batch.d`` is None; the solver
    recomputes distance tiles on chip (``solver.solve_matrix_free``).
    Incompatible with ``block_dtype`` (there is no stored block to
    narrow).
    """
    n = x.shape[0]
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; options {VARIANTS}")
    if not materialize and block_dtype is not None:
        raise ValueError(
            "materialize=False builds no block; block_dtype does not apply "
            "(the matrix-free sweep upcasts tiles to f32 on chip)")

    if variant == "lwcs":
        mean = jnp.mean(x, axis=0, keepdims=True)
        dmean = streaming.stream_block(
            x, mean, metric=metric, backend=backend, chunk_size=chunk_size).d[:, 0]
        q = 0.5 / n + 0.5 * (dmean**2) / jnp.maximum(jnp.sum(dmean**2), 1e-30)
        idx = jax.random.choice(key, n, shape=(m,), replace=False, p=q)
        w = 1.0 / (m * q[idx])
        w = w * (m / jnp.sum(w))  # normalise to mean 1
    else:
        idx = _uniform_idx(key, n, m)
        w = jnp.ones((m,), jnp.float32)

    if not materialize:
        if variant == "nniw":
            # Default to a bounded chunk (not one-shot): the count pass
            # must not transiently build the very block this path exists
            # to avoid (streaming.MF_DEFAULT_CHUNK).
            counts = streaming.stream_nn_counts(
                x, x[idx], metric=metric, backend=backend,
                chunk_size=(streaming.MF_DEFAULT_CHUNK
                            if chunk_size is None else chunk_size))
            w = counts * (m / n)                            # mean 1
        return Batch(idx=idx, weights=w, d=None)

    sb = streaming.stream_block(x, x[idx], metric=metric, backend=backend,
                                chunk_size=chunk_size,
                                count_nn=(variant == "nniw"),
                                block_dtype=block_dtype)
    d = sb.d

    if variant == "nniw":
        w = sb.nn_counts * (m / n)                          # mean 1
    if variant == "debias":
        d = d.at[idx, jnp.arange(m)].set(LARGE)

    # bf16 block x f32 weights promotes to f32, so the weighted product is
    # computed full-precision and rounds once on the final store.
    dw = d * w[None, :]
    if block_dtype is not None:
        dw = dw.astype(block_dtype)
    return Batch(idx=idx, weights=w, d=dw)


def weighted_block(d_raw: jnp.ndarray, batch: Batch) -> jnp.ndarray:
    """Re-apply a batch's weights to a raw distance block (for new points)."""
    return d_raw * batch.weights[None, :]
