"""High-level data-selection API: OneBatchPAM as a framework feature.

This is the interface the rest of the framework consumes (data curation,
active-learning batch picking, prompt clustering in serving). sklearn-like:

    sel = MedoidSelector(k=64, variant="nniw")
    sel = sel.fit(embeddings)          # embeddings: (n, p) array
    sel.medoid_indices_                # (k,) indices into the input
    labels = sel.predict(embeddings)   # nearest-medoid assignment

Fitted selectors are durable: ``sel.save(path)`` writes the medoids +
config through the atomic ``repro.checkpoint`` machinery, and
``MedoidSelector.from_checkpoint(path)`` (or ``sel.load(path)`` onto a
matching config) restores them without refitting — the serving-path
warm-start artifact (ROADMAP). Long fits are themselves restartable:
``checkpoint_dir=`` checkpoints solver state every ``ckpt_every``
sweeps and ``resume="auto"`` continues a killed fit bitwise
(DESIGN.md §6); ``validate=`` turns on runtime invariant guards.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solver, streaming

_SAVE_VERSION = 1

# The config fields a saved selector pins: loading onto an instance whose
# values differ is an error (the fitted arrays would not correspond to
# the config the caller thinks it has). ``mesh`` is excluded (not
# serializable, orthogonal to the fitted result); the robustness knobs
# are excluded (they never change the floats).
CONFIG_FIELDS = ("k", "m", "variant", "metric", "strategy", "max_swaps",
                 "seed", "backend", "chunk_size", "block_dtype",
                 "restarts", "eval_m", "prune_m", "survivor_frac")


@dataclasses.dataclass
class MedoidSelector:
    k: int
    m: int | None = None
    variant: str = "nniw"
    metric: str = "l1"
    # "batched" (fused block sweep), "matrix_free" (same sweep, no (n, m)
    # block ever — DESIGN.md §2b, swap-for-swap identical), "pruned"
    # (matrix-free with bound-based candidate elimination — DESIGN.md
    # §2c, bitwise-identical trajectory), or "eager" (paper-faithful
    # serial scan).
    strategy: str = "batched"
    max_swaps: int = 500
    seed: int = 0
    backend: str = "auto"
    # Streaming / sharding / storage knobs (DESIGN.md §2, §4-§5):
    # chunk_size bounds peak intermediate memory to O(chunk * m); mesh
    # shards the n axis; block_dtype (e.g. "bfloat16") halves the resident
    # block and the sweep's HBM traffic (accumulation stays f32).
    chunk_size: int | None = None
    block_dtype: str | None = None
    mesh: object = None
    # Multi-restart knobs (DESIGN.md §2a): restarts > 1 runs R vmapped
    # local searches on a pooled column sample and elects the winner on a
    # held-out eval batch of eval_m columns (defaults to m). restarts=1
    # is the original single-restart trajectory, bit for bit.
    restarts: int = 1
    eval_m: int | None = None
    # Pruned-sweep knobs (DESIGN.md §2c, strategy="pruned" only):
    # prune_m is the phase-1 subsample width (default m // 8);
    # survivor_frac the dense-fallback threshold on the survivor count.
    prune_m: int | None = None
    survivor_frac: float = 0.5
    # Robustness knobs (DESIGN.md §6): any of validate != "off" /
    # checkpoint_dir routes fit() through the fault-tolerant runtime —
    # same trajectory bit for bit, plus sweep-level checkpoints
    # (resume="auto" continues a killed fit), invariant guards, and a
    # structured report_ after fit.
    validate: str = "off"
    checkpoint_dir: str | None = None
    ckpt_every: int = 1
    resume: str = "auto"

    medoid_indices_: np.ndarray | None = None
    medoids_: np.ndarray | None = None
    est_objective_: float | None = None
    n_swaps_: int | None = None
    best_restart_: int | None = None
    eval_objectives_: np.ndarray | None = None
    report_: object | None = None

    def _robust(self) -> bool:
        return self.validate != "off" or self.checkpoint_dir is not None

    def fit(self, x) -> "MedoidSelector":
        x = jnp.asarray(x)
        if self._robust():
            # The runtime path handles restarts itself and reports the
            # election through SolveReport.
            res, _, report = solver.one_batch_pam(
                jax.random.PRNGKey(self.seed), x, self.k, m=self.m,
                variant=self.variant, metric=self.metric,
                strategy=self.strategy, max_swaps=self.max_swaps,
                backend=self.backend, chunk_size=self.chunk_size,
                block_dtype=self.block_dtype, mesh=self.mesh,
                restarts=self.restarts, eval_m=self.eval_m,
                prune_m=self.prune_m, survivor_frac=self.survivor_frac,
                validate=self.validate,
                checkpoint_dir=self.checkpoint_dir,
                ckpt_every=self.ckpt_every, resume=self.resume,
                return_report=True)
            self.report_ = report
            if report.election is not None:
                self.best_restart_ = int(report.election["best_restart"])
                self.eval_objectives_ = np.asarray(
                    report.election["eval_objectives"], np.float32)
        elif self.restarts > 1:
            if self.strategy not in ("batched", "matrix_free", "pruned"):
                # Same contract as solver.one_batch_pam: restart lanes
                # are the vmapped batched / block-free sweeps only.
                raise ValueError(
                    "restarts > 1 supports strategy='batched', "
                    "'matrix_free' or 'pruned'")
            from repro.core import restarts as restarts_mod
            n = x.shape[0]
            m = self.m
            if m is not None:
                # Warns on shrinkage (the pooled-sample budget R*m <= n;
                # DESIGN.md §2a) instead of the former silent clamp.
                m = solver._clamp_pool_m(n, self.restarts, min(m, n),
                                         user_m=m)
            rr, _ = restarts_mod.one_batch_pam_restarts(
                jax.random.PRNGKey(self.seed), x, self.k,
                restarts=self.restarts, m=m, eval_m=self.eval_m,
                variant=self.variant, metric=self.metric,
                strategy=self.strategy,
                max_swaps=self.max_swaps, backend=self.backend,
                chunk_size=self.chunk_size, block_dtype=self.block_dtype,
                mesh=self.mesh, prune_m=self.prune_m,
                survivor_frac=self.survivor_frac)
            res = rr.best
            self.best_restart_ = int(rr.best_restart)
            self.eval_objectives_ = np.asarray(rr.eval_objectives)
        else:
            res, _ = solver.one_batch_pam(
                jax.random.PRNGKey(self.seed), x, self.k, m=self.m,
                variant=self.variant, metric=self.metric,
                strategy=self.strategy, max_swaps=self.max_swaps,
                backend=self.backend, chunk_size=self.chunk_size,
                block_dtype=self.block_dtype, mesh=self.mesh,
                prune_m=self.prune_m, survivor_frac=self.survivor_frac)
        self.medoid_indices_ = np.asarray(res.medoid_idx)
        self.medoids_ = np.asarray(x[res.medoid_idx])
        self.est_objective_ = float(res.est_objective)
        self.n_swaps_ = int(res.n_swaps)
        return self

    def predict(self, x) -> np.ndarray:
        # block_dtype is threaded, matching fit(): a selector configured
        # for bf16 tiles serves bf16 assignment too (rounded tiles, f32
        # accumulation — DESIGN.md §2; it used to be silently dropped
        # here, so predict() ran f32 regardless of config).
        if self.medoids_ is None:
            raise RuntimeError("call fit() first")
        labels, _ = streaming.stream_assign(
            jnp.asarray(x), jnp.asarray(self.medoids_), metric=self.metric,
            backend=self.backend, chunk_size=self.chunk_size,
            block_dtype=self.block_dtype)
        return np.asarray(labels)

    def objective(self, x) -> float:
        if self.medoid_indices_ is None:
            raise RuntimeError("call fit() first")
        return float(solver.objective(jnp.asarray(x),
                                      jnp.asarray(self.medoid_indices_),
                                      metric=self.metric, backend=self.backend,
                                      chunk_size=self.chunk_size,
                                      block_dtype=self.block_dtype))

    # ------------------------------------------------------- warm start --

    def warm_init(self, x) -> np.ndarray:
        """Map the fitted medoid rows onto *rows of x*: a (k,) index
        vector warm-starting a solve on x from the live medoid set.

        Each medoid snaps to its nearest row of x (one ``stream_assign``
        with the roles reversed — medoids are the queries). Two medoids
        may snap to the same row when x drifted; duplicates are repaired
        greedily in slot order (first slot keeps the row, later slots
        take their nearest *untaken* row), so the result is always k
        distinct indices — the solver's init contract.
        """
        if self.medoids_ is None:
            raise RuntimeError("call fit() first")
        x = np.asarray(x)
        if len(x) < self.k:
            raise ValueError(
                f"warm_init needs at least k={self.k} rows to pick distinct "
                f"indices from; got n={len(x)}")
        xj = jnp.asarray(x)
        med = jnp.asarray(self.medoids_)
        nearest, _ = streaming.stream_assign(
            med, xj, metric=self.metric, backend=self.backend,
            chunk_size=self.chunk_size)
        init = np.asarray(nearest, np.int64).copy()
        taken = set()
        for slot, row in enumerate(init):
            if int(row) not in taken:
                taken.add(int(row))
                continue
            # O(n·p) repair per colliding slot (rare: drift has to fold
            # two medoids onto one row): full distance row, mask taken.
            from repro.kernels import ops
            d = np.array(ops.pairwise_distance(
                med[slot][None, :], xj, metric=self.metric,
                backend=self.backend)[0])
            d[list(taken)] = np.inf
            init[slot] = int(d.argmin())
            taken.add(int(init[slot]))
        return init.astype(np.int32)

    def refit(self, x) -> "MedoidSelector":
        """Re-fit on (drifted) data, warm-starting from the live medoid
        set instead of a random init — the serving engine's background
        refit entry (DESIGN.md §9).

        The fitted medoids snap onto rows of x (:meth:`warm_init`) and
        the solve starts there (``one_batch_pam(init_idx=...)``): near a
        local optimum, steepest descent pays only for the swaps the
        drift actually caused (FasterPAM's warm-start discipline) —
        tests/test_serving.py pins ≤ the cold objective in fewer sweeps.
        Restarts and the robustness knobs are bypassed (warm start *is*
        the init choice; ``solver.one_batch_pam`` rejects composing
        them), everything else (metric, strategy, m, block_dtype, ...)
        comes from this instance's config.
        """
        if self.medoids_ is None:
            raise RuntimeError("call fit() first — refit() warm-starts "
                               "from the fitted medoids")
        xj = jnp.asarray(x)
        res, _ = solver.one_batch_pam(
            jax.random.PRNGKey(self.seed), xj, self.k, m=self.m,
            variant=self.variant, metric=self.metric,
            strategy=self.strategy, max_swaps=self.max_swaps,
            backend=self.backend, chunk_size=self.chunk_size,
            block_dtype=self.block_dtype,
            prune_m=self.prune_m, survivor_frac=self.survivor_frac,
            init_idx=jnp.asarray(self.warm_init(x)))
        self.medoid_indices_ = np.asarray(res.medoid_idx)
        self.medoids_ = np.asarray(xj[res.medoid_idx])
        self.est_objective_ = float(res.est_objective)
        self.n_swaps_ = int(res.n_swaps)
        return self

    # ------------------------------------------------ durable artifact --

    def _config(self) -> dict:
        return {f: getattr(self, f) for f in CONFIG_FIELDS}

    def serving_config(self) -> dict:
        """The *snapshot-defining* subset of the config: every
        :data:`CONFIG_FIELDS` entry except pure execution knobs
        (``backend``, ``chunk_size`` — they change where the floats are
        computed, never which medoid set is the answer). Two engines may
        exchange a medoid generation iff these agree;
        ``repro.serving.guards.snapshot_fingerprint`` hashes this dict
        (plus the feature width) into the fingerprint every durable
        serving snapshot is pinned under (DESIGN.md §9a)."""
        cfg = self._config()
        for f in ("backend", "chunk_size"):
            cfg.pop(f)
        return cfg

    def save(self, path: str) -> str:
        """Persist the fitted selector (medoid indices, medoid rows,
        config, eval objectives) through ``repro.checkpoint`` —
        atomic-rename durable, versioned. Returns the checkpoint dir."""
        if self.medoid_indices_ is None:
            raise RuntimeError("call fit() before save() — there is no "
                               "fitted state to persist")
        from repro import checkpoint as ckpt
        state = {"medoid_indices": np.asarray(self.medoid_indices_),
                 "medoids": np.asarray(self.medoids_)}
        if self.eval_objectives_ is not None:
            state["eval_objectives"] = np.asarray(self.eval_objectives_,
                                                  np.float32)
        extra = {"save_version": _SAVE_VERSION,
                 "config": self._config(),
                 "fitted": {"est_objective": self.est_objective_,
                            "n_swaps": self.n_swaps_,
                            "best_restart": self.best_restart_}}
        return ckpt.save(path, 0, state, extra=extra, keep=1)

    def load(self, path: str) -> "MedoidSelector":
        """Restore fitted state saved by :meth:`save` into *this*
        instance. The saved config must match this instance's
        (:data:`CONFIG_FIELDS`) — a clear error lists every mismatched
        field, because fitted arrays divorced from their config are a
        silent-wrong-answer factory. Use :meth:`from_checkpoint` to
        build the matching instance from the artifact itself."""
        from repro import checkpoint as ckpt
        man = ckpt.manifest(path)
        extra = man.get("extra", {})
        version = extra.get("save_version")
        if version != _SAVE_VERSION:
            raise ValueError(
                f"selector checkpoint at {path} has save_version "
                f"{version!r}; this build reads version {_SAVE_VERSION}")
        saved = extra.get("config", {})
        mine = self._config()
        diffs = [f"{f}: saved {saved.get(f)!r} != this instance "
                 f"{mine.get(f)!r}" for f in CONFIG_FIELDS
                 if saved.get(f) != mine.get(f)]
        if diffs:
            raise ValueError(
                f"selector checkpoint at {path} was fitted under a "
                "different config —\n  " + "\n  ".join(diffs) +
                "\nUse MedoidSelector.from_checkpoint(path) to build the "
                "matching instance.")
        target = {leaf["name"]: jax.ShapeDtypeStruct(tuple(leaf["shape"]),
                                                     leaf["dtype"])
                  for leaf in man["leaves"]}
        state, _ = ckpt.restore(path, target)
        self.medoid_indices_ = np.asarray(state["medoid_indices"])
        self.medoids_ = np.asarray(state["medoids"])
        if "eval_objectives" in state:
            self.eval_objectives_ = np.asarray(state["eval_objectives"])
        fitted = extra.get("fitted", {})
        self.est_objective_ = fitted.get("est_objective")
        self.n_swaps_ = fitted.get("n_swaps")
        self.best_restart_ = fitted.get("best_restart")
        return self

    @classmethod
    def from_checkpoint(cls, path: str) -> "MedoidSelector":
        """Build a selector from a :meth:`save` artifact: config comes
        from the checkpoint, fitted arrays load straight in."""
        from repro import checkpoint as ckpt
        saved = ckpt.manifest(path).get("extra", {}).get("config", {})
        sel = cls(**{f: saved[f] for f in CONFIG_FIELDS if f in saved})
        return sel.load(path)
