"""High-level data-selection API: OneBatchPAM as a framework feature.

This is the interface the rest of the framework consumes (data curation,
active-learning batch picking, prompt clustering in serving). sklearn-like:

    sel = MedoidSelector(k=64, variant="nniw")
    sel = sel.fit(embeddings)          # embeddings: (n, p) array
    sel.medoid_indices_                # (k,) indices into the input
    labels = sel.predict(embeddings)   # nearest-medoid assignment
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solver, streaming


@dataclasses.dataclass
class MedoidSelector:
    k: int
    m: int | None = None
    variant: str = "nniw"
    metric: str = "l1"
    # "batched" (fused block sweep), "matrix_free" (same sweep, no (n, m)
    # block ever — DESIGN.md §2b, swap-for-swap identical), "pruned"
    # (matrix-free with bound-based candidate elimination — DESIGN.md
    # §2c, bitwise-identical trajectory), or "eager" (paper-faithful
    # serial scan).
    strategy: str = "batched"
    max_swaps: int = 500
    seed: int = 0
    backend: str = "auto"
    # Streaming / sharding / storage knobs (DESIGN.md §2, §4-§5):
    # chunk_size bounds peak intermediate memory to O(chunk * m); mesh
    # shards the n axis; block_dtype (e.g. "bfloat16") halves the resident
    # block and the sweep's HBM traffic (accumulation stays f32).
    chunk_size: int | None = None
    block_dtype: str | None = None
    mesh: object = None
    # Multi-restart knobs (DESIGN.md §2a): restarts > 1 runs R vmapped
    # local searches on a pooled column sample and elects the winner on a
    # held-out eval batch of eval_m columns (defaults to m). restarts=1
    # is the original single-restart trajectory, bit for bit.
    restarts: int = 1
    eval_m: int | None = None
    # Pruned-sweep knobs (DESIGN.md §2c, strategy="pruned" only):
    # prune_m is the phase-1 subsample width (default m // 8);
    # survivor_frac the dense-fallback threshold on the survivor count.
    prune_m: int | None = None
    survivor_frac: float = 0.5

    medoid_indices_: np.ndarray | None = None
    medoids_: np.ndarray | None = None
    est_objective_: float | None = None
    n_swaps_: int | None = None
    best_restart_: int | None = None
    eval_objectives_: np.ndarray | None = None

    def fit(self, x) -> "MedoidSelector":
        x = jnp.asarray(x)
        if self.restarts > 1:
            if self.strategy not in ("batched", "matrix_free", "pruned"):
                # Same contract as solver.one_batch_pam: restart lanes
                # are the vmapped batched / block-free sweeps only.
                raise ValueError(
                    "restarts > 1 supports strategy='batched', "
                    "'matrix_free' or 'pruned'")
            from repro.core import restarts as restarts_mod
            n = x.shape[0]
            m = self.m
            if m is not None:
                # Warns on shrinkage (the pooled-sample budget R*m <= n;
                # DESIGN.md §2a) instead of the former silent clamp.
                m = solver._clamp_pool_m(n, self.restarts, min(m, n),
                                         user_m=m)
            rr, _ = restarts_mod.one_batch_pam_restarts(
                jax.random.PRNGKey(self.seed), x, self.k,
                restarts=self.restarts, m=m, eval_m=self.eval_m,
                variant=self.variant, metric=self.metric,
                strategy=self.strategy,
                max_swaps=self.max_swaps, backend=self.backend,
                chunk_size=self.chunk_size, block_dtype=self.block_dtype,
                mesh=self.mesh, prune_m=self.prune_m,
                survivor_frac=self.survivor_frac)
            res = rr.best
            self.best_restart_ = int(rr.best_restart)
            self.eval_objectives_ = np.asarray(rr.eval_objectives)
        else:
            res, _ = solver.one_batch_pam(
                jax.random.PRNGKey(self.seed), x, self.k, m=self.m,
                variant=self.variant, metric=self.metric,
                strategy=self.strategy, max_swaps=self.max_swaps,
                backend=self.backend, chunk_size=self.chunk_size,
                block_dtype=self.block_dtype, mesh=self.mesh,
                prune_m=self.prune_m, survivor_frac=self.survivor_frac)
        self.medoid_indices_ = np.asarray(res.medoid_idx)
        self.medoids_ = np.asarray(x[res.medoid_idx])
        self.est_objective_ = float(res.est_objective)
        self.n_swaps_ = int(res.n_swaps)
        return self

    def predict(self, x) -> np.ndarray:
        if self.medoids_ is None:
            raise RuntimeError("call fit() first")
        labels, _ = streaming.stream_assign(
            jnp.asarray(x), jnp.asarray(self.medoids_), metric=self.metric,
            backend=self.backend, chunk_size=self.chunk_size)
        return np.asarray(labels)

    def objective(self, x) -> float:
        if self.medoid_indices_ is None:
            raise RuntimeError("call fit() first")
        return float(solver.objective(jnp.asarray(x),
                                      jnp.asarray(self.medoid_indices_),
                                      metric=self.metric, backend=self.backend,
                                      chunk_size=self.chunk_size))
