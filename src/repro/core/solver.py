"""OneBatchPAM local-search solver (the paper's core contribution, in JAX).

Four strategies over identical swap math (DESIGN.md §2):

  * ``eager``   — Algorithm 2 of the paper: scan candidates i = 1..n in
      order, swap as soon as the batch-estimated gain is positive
      (first-improvement, FasterPAM semantics). Serial; the faithful
      baseline we validate against the paper's claims. Backend-free: the
      scan evaluates gains in pure jnp, no kernel dispatch.
  * ``batched`` (:func:`solve_batched`) — TPU-native steepest descent with
      the *fused* swap-select sweep: one O(nm) kernel pass per iteration
      reduces the gain tiles on-chip to O(n/TN) partials (``ops.swap_select``),
      so the (n, k) gain matrix never reaches HBM, and the d1/d2/near state
      is repaired incrementally after each accepted swap (FasterPAM-style,
      O(m) expected) instead of recomputed from the full (k, m) view.
  * :func:`solve_batched_naive` — the pre-fusion batched solver
      (materialise (n, k) gains, host argmax, full top-2 recompute). Kept
      as the equivalence oracle for the fused path and as the "naive"
      column of the sweep benchmarks; same swaps, same floats.
  * ``matrix_free`` (:func:`solve_matrix_free`) — the fused sweep with
      the (n, m) block itself fused away (DESIGN.md §2b): per iteration
      ``ops.fused_swap_select`` recomputes each distance tile on chip
      from X and B (O(np + mp) HBM traffic instead of O(nm)), and the
      accepted candidate's single weighted row is recomputed O(mp) for
      the same incremental repair. Swap-for-swap identical to
      :func:`solve_batched` on the f32 ref/interpret paths — same
      floats, different data movement.

The solver is batch-size agnostic: pass the n x m OneBatch block for OBP, or
the full n x n matrix to recover exact (Fast)PAM — tests exploit this
equivalence (m = n  =>  same swaps as FasterPAM, Theorem 1's limit case).
The block may be stored in bf16 (``block_dtype`` in sampling/streaming);
all solver state and gain accumulation stay f32.
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling
from repro.kernels import metrics, ops
from repro.kernels.ref import LARGE
from repro.kernels.ref import NEG  # noqa: F401  (re-exported; distributed.py)

BIG = jnp.float32(1e30)  # sentinel for "no second medoid" / masked entries


class SolveResult(NamedTuple):
    medoid_idx: jnp.ndarray     # (k,) int32 indices into X_n
    n_swaps: jnp.ndarray        # int32, accepted swaps
    est_objective: jnp.ndarray  # f32, batch-estimated mean objective
    converged: jnp.ndarray      # bool, True if a local minimum was reached


def _top2(med_rows: jnp.ndarray):
    """d1/d2/near/near2 from the (k, m) medoid-to-batch distance view."""
    k, m = med_rows.shape
    near = jnp.argmin(med_rows, axis=0)                       # (m,)
    d1 = jnp.take_along_axis(med_rows, near[None, :], axis=0)[0]
    masked = jnp.where(jax.nn.one_hot(near, k, axis=0, dtype=bool), BIG, med_rows)
    near2 = jnp.argmin(masked, axis=0)                        # (m,)
    d2 = jnp.take_along_axis(masked, near2[None, :], axis=0)[0]
    return d1, d2, near, near2


def _repair_top2(med_rows, d1, d2, near, near2, r, l):
    """Incremental top-2 repair after medoid slot ``l`` is replaced by a
    candidate whose (weighted) batch row is ``r`` (FasterPAM-style;
    DESIGN.md §2). Returns ``(med_rows', d1', d2', near', near2')``.

    Value-exact with a full :func:`_top2` recompute: every output is a copy
    or a min of existing floats, so the fused solver's trajectory is
    bit-for-bit the naive solver's. Slot choices (near/near2) may differ
    from argmin's on exact distance ties, but a tie means d1 == d2, which
    zeroes the removal correction r_ij — slot identity never reaches the
    gains (tests/test_fused_solver.py pins the value invariant).

    Cost: O(m) for every column except the *hard* case — the removed slot
    was in the column's top-2 and the new row does not re-enter it — which
    needs the third-nearest distance. Those columns (expected fraction
    ~2/k) fall back to one masked min over the cached (k, m) rows, gated
    behind ``lax.cond`` so swap steps with no hard column skip it.
    """
    k = med_rows.shape[0]
    new_rows = med_rows.at[l].set(r)
    was1 = near == l
    surv = jnp.where(was1, near2, near)        # best surviving old slot
    s = jnp.where(was1, d2, d1)                # its distance
    closer = r < s
    d1n = jnp.minimum(r, s)
    nearn = jnp.where(closer, l, surv)
    hard = was1 | (near2 == l)
    need = hard & (r > d2)
    # Easy path: the removed slot was outside the top-2 (its distance was
    # >= d2, so top-2 of {r, s, d2} is exact), or the new row re-enters.
    d2e = jnp.minimum(jnp.maximum(r, s), d2)
    near2e = jnp.where(closer, surv, l)
    near2e = jnp.where(~hard & (r >= d2), near2, near2e)

    def recompute(_):
        slot = jnp.arange(k, dtype=nearn.dtype)[:, None]
        masked = jnp.where(slot == nearn[None, :], BIG, new_rows)
        n2 = jnp.argmin(masked, axis=0)
        return jnp.take_along_axis(masked, n2[None, :], axis=0)[0], n2

    d2r, near2r = jax.lax.cond(
        jnp.any(need), recompute, lambda _: (d2e, near2e), None)
    return (new_rows, d1n, jnp.where(need, d2r, d2e), nearn,
            jnp.where(need, near2r, near2e))


class _State(NamedTuple):
    medoid_idx: jnp.ndarray  # (k,)
    med_rows: jnp.ndarray    # (k, m) f32 (cast from the block's dtype)
    d1: jnp.ndarray          # (m,)
    d2: jnp.ndarray          # (m,)
    near: jnp.ndarray        # (m,)
    near2: jnp.ndarray       # (m,)
    t: jnp.ndarray           # swaps performed
    done: jnp.ndarray        # bool


def _init_state(d: jnp.ndarray, init_idx: jnp.ndarray) -> _State:
    med_rows = d[init_idx].astype(jnp.float32)
    d1, d2, near, near2 = _top2(med_rows)
    return _State(init_idx.astype(jnp.int32), med_rows, d1, d2, near, near2,
                  jnp.int32(0), jnp.bool_(False))


def _apply_swap(state: _State, d: jnp.ndarray, i: jnp.ndarray, l: jnp.ndarray) -> _State:
    """Full-recompute swap application (naive/eager paths)."""
    med_rows = state.med_rows.at[l].set(d[i].astype(jnp.float32))
    d1, d2, near, near2 = _top2(med_rows)
    return _State(state.medoid_idx.at[l].set(i.astype(jnp.int32)),
                  med_rows, d1, d2, near, near2, state.t + 1, state.done)


def _fused_step(d: jnp.ndarray, state: _State, *, eps: float = 0.0,
                backend: str = "auto"):
    """One fused steepest-descent step: swap-select sweep + incremental
    repair. Returns ``(new_state, improved, best_gain, i, l)`` — the exact
    float sequence of :func:`solve_batched`'s loop body, factored out so
    ``core/trace.py`` can replay the trajectory swap for swap (the caller
    applies ``new_state`` only when ``improved``)."""
    n, _ = d.shape
    k = state.medoid_idx.shape[0]
    nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
    # Current medoids are not swap candidates: O(n) mask instead of the
    # former O(nk) scatter into the materialised gain matrix.
    row_mask = jnp.ones((n,), jnp.float32).at[state.medoid_idx].set(0.0)
    best, i, l = ops.swap_select(d, state.d1, state.d2, nh,
                                 row_mask=row_mask, backend=backend)
    improved = best > eps * jnp.sum(state.d1)
    r = d[i].astype(jnp.float32)
    med_rows, d1, d2, near, near2 = _repair_top2(
        state.med_rows, state.d1, state.d2, state.near, state.near2, r, l)
    new_state = _State(state.medoid_idx.at[l].set(i.astype(jnp.int32)),
                       med_rows, d1, d2, near, near2,
                       state.t + 1, state.done)
    return new_state, improved, best, i, l


def _mf_chunk(chunk_size: int | None) -> int:
    """The matrix-free default row chunk (streaming.MF_DEFAULT_CHUNK)
    when the caller left chunk_size unset; see that constant's note."""
    from repro.core.streaming import MF_DEFAULT_CHUNK
    return MF_DEFAULT_CHUNK if chunk_size is None else chunk_size


def _prepared(x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """The metric's row transform, applied exactly once (DESIGN.md §2b:
    prepare is row-local, so ``prepare(x)[idx] == prepare(x[idx])``
    bitwise — the matrix-free chain matches the batch builder's)."""
    spec = metrics.get(metric)
    return spec.prepare(x) if spec.prepare is not None else x


def _weighted_rows(rows, b, w, batch_idx, row_ids, *, metric, debias,
                   backend):
    """Weighted batch-distance rows for candidates ``row_ids`` — the
    O(q·m·p) on-the-fly recompute of ``d[row_ids]`` from the block path,
    same float chain: metric on prepared rows -> finalize -> debias
    owner set -> weight multiply. ``rows`` must already be prepared."""
    spec = metrics.get(metric)
    d = spec.finalize(ops.pairwise_raw(rows, b, metric=metric,
                                       backend=backend, skip_prepare=True))
    if debias:
        d = jnp.where(batch_idx[None, :] == row_ids[:, None], LARGE, d)
    return d * w[None, :]


def _matrix_free_step(xp, b, w, batch_idx, state: _State, *, metric: str,
                      debias: bool = False, eps: float = 0.0,
                      backend: str = "auto", chunk_size: int | None = None):
    """One matrix-free steepest-descent step: fused distance+swap-select
    sweep over X/B plus the incremental repair fed by an O(mp) recompute
    of the accepted candidate's weighted row. The exact float sequence of
    :func:`solve_matrix_free`'s loop body — and of :func:`_fused_step` on
    the materialised block (same gains, same selection, same repair) —
    factored out so ``core/trace.py`` replays it swap for swap. ``xp``
    and ``b`` must already carry the metric's ``prepare`` transform."""
    n = xp.shape[0]
    k = state.medoid_idx.shape[0]
    nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
    row_mask = jnp.ones((n,), jnp.float32).at[state.medoid_idx].set(0.0)
    owner = batch_idx if debias else None
    best, i, l = ops.fused_swap_select(
        xp, b, w, state.d1, state.d2, nh, metric=metric, row_mask=row_mask,
        owner=owner, backend=backend, skip_prepare=True,
        row_chunk=_mf_chunk(chunk_size))
    improved = best > eps * jnp.sum(state.d1)
    r = _weighted_rows(xp[i][None, :], b, w, batch_idx, i[None],
                       metric=metric, debias=debias, backend=backend)[0]
    med_rows, d1, d2, near, near2 = _repair_top2(
        state.med_rows, state.d1, state.d2, state.near, state.near2, r, l)
    new_state = _State(state.medoid_idx.at[l].set(i.astype(jnp.int32)),
                       med_rows, d1, d2, near, near2,
                       state.t + 1, state.done)
    return new_state, improved, best, i, l


def _init_state_matrix_free(xp, b, w, batch_idx, init_idx, *, metric,
                            debias, backend) -> _State:
    med_rows = _weighted_rows(xp[init_idx], b, w, batch_idx, init_idx,
                              metric=metric, debias=debias, backend=backend)
    d1, d2, near, near2 = _top2(med_rows)
    return _State(init_idx.astype(jnp.int32), med_rows, d1, d2, near, near2,
                  jnp.int32(0), jnp.bool_(False))


@functools.partial(jax.jit, static_argnames=(
    "metric", "debias", "max_swaps", "backend", "chunk_size"))
def solve_matrix_free(
    x: jnp.ndarray,            # (n, p) data rows (f32 or bf16)
    batch_idx: jnp.ndarray,    # (m,) batch column indices into x
    weights: jnp.ndarray,      # (m,) f32 batch weights
    init_idx: jnp.ndarray,     # (k,) initial medoids
    *,
    metric: str = "l1",
    debias: bool = False,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
) -> SolveResult:
    """Matrix-free steepest descent: :func:`solve_batched` without the
    (n, m) block ever existing (DESIGN.md §2b).

    Per iteration one ``ops.fused_swap_select`` pass recomputes every
    distance tile on chip from X (n, p) and B (m, p) — O(np + mp) HBM
    read, O(n/TN) partials written — and the accepted swap repairs the
    O(km) top-2 state incrementally from one O(mp) recomputed row.
    Resident memory is O(np + km + m), so n is no longer capped by the
    O(nm) block. Swap-for-swap identical to :func:`solve_batched` fed
    ``sampling.build_batch``'s f32 block on the same backend (ref and
    interpret; tests/test_matrix_free.py + the golden fixtures pin it).

    ``debias`` applies the debias variant's LARGE owner diagonal
    in-flight (``batch_idx`` names each column's source row).
    ``chunk_size`` bounds the ref backend's sweep to O(chunk · m)
    intermediates (the kernel paths are tiled already); it defaults to
    ``MF_DEFAULT_CHUNK`` rather than one-shot, so the no-block guarantee
    holds without the caller remembering to chunk.
    """
    xp = _prepared(x, metric)
    b = xp[batch_idx]
    w = weights.astype(jnp.float32)
    batch_idx = batch_idx.astype(jnp.int32)
    state = _init_state_matrix_free(xp, b, w, batch_idx, init_idx,
                                    metric=metric, debias=debias,
                                    backend=backend)

    def cond(state):
        return jnp.logical_and(~state.done, state.t < max_swaps)

    def body(state):
        new_state, improved, _, _, _ = _matrix_free_step(
            xp, b, w, batch_idx, state, metric=metric, debias=debias,
            eps=eps, backend=backend, chunk_size=chunk_size)
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state,
            state._replace(done=jnp.bool_(True)))

    state = jax.lax.while_loop(cond, body, state)
    return SolveResult(state.medoid_idx, state.t,
                       jnp.mean(state.d1), state.done)


def _eager_pass(d: jnp.ndarray, state: _State, *, eps: float = 0.0):
    """One full first-improvement pass over all n candidates (Algorithm 2).

    Returns ``(state, swapped, do_swap (n,), slots (n,))`` — the last two
    record, per candidate index, whether it was swapped in and into which
    slot, so ``core/trace.py`` recovers the swap sequence from the same
    scan :func:`solve_eager` runs (identical floats by construction).

    The eps acceptance threshold needs ``sum(d1)``, which only changes on
    an accepted swap — so the sum rides the scan carry and is recomputed
    (one O(m) ``jnp.sum``, behind ``lax.cond``) only then, instead of the
    former unconditional O(m) reduction per candidate: same array summed
    at the same points, so the floats — and the swap trajectory — are
    bit-for-bit the per-candidate recompute's (tests/test_core.py pins
    it against a fresh-sum reference at eps > 0)."""
    n, _ = d.shape
    k = state.medoid_idx.shape[0]

    def candidate_step(carry, i):
        state, swapped, sum_d1 = carry
        row = d[i].astype(jnp.float32)                        # (m,)
        g = jnp.sum(jnp.maximum(state.d1 - row, 0.0))
        r = state.d1 - jnp.minimum(jnp.maximum(row, state.d1), state.d2)
        big_r = jnp.zeros((k,), jnp.float32).at[state.near].add(r)
        l = jnp.argmax(big_r)
        gain = g + big_r[l]
        is_medoid = jnp.any(state.medoid_idx == i)
        do_swap = jnp.logical_and(gain > eps * sum_d1, ~is_medoid)
        new_state = _apply_swap(state, d, i.astype(jnp.int32), l)
        state = jax.tree.map(lambda a, b: jnp.where(do_swap, a, b),
                             new_state, state)
        sum_d1 = jax.lax.cond(do_swap, lambda s: jnp.sum(s.d1),
                              lambda _: sum_d1, state)
        return (state, jnp.logical_or(swapped, do_swap), sum_d1), (do_swap, l)

    (state, swapped, _), (flags, slots) = jax.lax.scan(
        candidate_step, (state, jnp.bool_(False), jnp.sum(state.d1)),
        jnp.arange(n))
    return state, swapped, flags, slots


@functools.partial(jax.jit, static_argnames=("max_swaps", "backend"))
def solve_batched(
    d: jnp.ndarray,            # (n, m) weighted distance block (f32 or bf16)
    init_idx: jnp.ndarray,     # (k,) initial medoids
    *,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
) -> SolveResult:
    """Steepest-descent local search with the fused swap-select sweep.

    Per iteration: one ``ops.swap_select`` pass (O(nm) block read, O(n/TN)
    partials written — the (n, k) gain matrix never materialises), then an
    incremental ``_repair_top2`` state update for the accepted swap.
    Bit-for-bit the same swaps as :func:`solve_batched_naive`.
    """
    state = _init_state(d, init_idx)

    def cond(state):
        return jnp.logical_and(~state.done, state.t < max_swaps)

    def body(state):
        new_state, improved, _, _, _ = _fused_step(d, state, eps=eps,
                                                   backend=backend)
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state,
            state._replace(done=jnp.bool_(True)))

    state = jax.lax.while_loop(cond, body, state)
    return SolveResult(state.medoid_idx, state.t,
                       jnp.mean(state.d1), state.done)


@functools.partial(jax.jit, static_argnames=("max_swaps", "backend"))
def solve_batched_naive(
    d: jnp.ndarray,
    init_idx: jnp.ndarray,
    *,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
) -> SolveResult:
    """Pre-fusion batched solver: materialise the (n, k) gain matrix, argmax
    it, recompute the full top-2 state after every swap.

    Kept as the equivalence oracle (`tests/test_fused_solver.py` pins
    identical trajectories against :func:`solve_batched`) and as the
    "naive" column of the sweep benchmarks. O(nk) HBM write + read per
    iteration that the fused path avoids.
    """
    n, m = d.shape
    k = init_idx.shape[0]
    state = _init_state(d, init_idx)

    def cond(state):
        return jnp.logical_and(~state.done, state.t < max_swaps)

    def body(state):
        nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
        gain = ops.swap_gain(d, state.d1, state.d2, nh, backend=backend)
        gain = gain.at[state.medoid_idx].set(NEG)
        flat = jnp.argmax(gain)
        i, l = flat // k, flat % k
        best = gain.reshape(-1)[flat]
        improved = best > eps * jnp.sum(state.d1)
        new_state = _apply_swap(state, d, i, l)
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state,
            state._replace(done=jnp.bool_(True)))

    state = jax.lax.while_loop(cond, body, state)
    return SolveResult(state.medoid_idx, state.t,
                       jnp.mean(state.d1), state.done)


@functools.partial(jax.jit, static_argnames=("max_passes",))
def solve_eager(
    d: jnp.ndarray,
    init_idx: jnp.ndarray,
    *,
    max_passes: int = 8,
    eps: float = 0.0,
) -> SolveResult:
    """Paper-faithful Algorithm 2: first-improvement scan over candidates.

    One "pass" visits all n candidates in index order, swapping eagerly.
    Terminates when a full pass performs no swap (local minimum) or after
    max_passes. Serial by construction — this is the CPU algorithm the
    paper ships; kept as the validation baseline. Backend-free: gains are
    evaluated in pure jnp, so there is no ``backend=`` knob here.
    """
    state0 = _init_state(d, init_idx)

    def pass_body(carry):
        state, p = carry
        state, swapped, _, _ = _eager_pass(d, state, eps=eps)
        return state._replace(done=~swapped), p + 1

    def pass_cond(carry):
        state, p = carry
        return jnp.logical_and(~state.done, p < max_passes)

    state, _ = jax.lax.while_loop(
        pass_cond, pass_body, (state0, jnp.int32(0)))
    return SolveResult(state.medoid_idx, state.t, jnp.mean(state.d1), state.done)


def objective(x: jnp.ndarray, medoid_idx: jnp.ndarray, *, metric: str = "l1",
              backend: str = "auto", chunk_size: int | None = None,
              block_dtype: str | jnp.dtype | None = None) -> jnp.ndarray:
    """Exact k-medoids objective L(M) on the full dataset (Eq. 1 / n).

    ``chunk_size`` streams the evaluation in O(chunk * k) memory without
    materialising the (n, k) block (streaming.py, DESIGN.md §4).
    ``block_dtype`` rounds each distance tile to the narrow dtype before
    the per-row min, with the mean accumulated in f32 — the stored-block
    convention in the assignment direction (DESIGN.md §2).
    """
    from repro.core import streaming
    _, dmin = streaming.stream_assign(x, x[medoid_idx], metric=metric,
                                      backend=backend, chunk_size=chunk_size,
                                      block_dtype=block_dtype)
    return jnp.mean(dmin)


def one_batch_pam(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    m: int | None = None,
    variant: str = "nniw",
    metric: str = "l1",
    strategy: str = "batched",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
    block_dtype: str | jnp.dtype | None = None,
    mesh=None,
    restarts: int = 1,
    eval_m: int | None = None,
    prune_m: int | None = None,
    survivor_frac: float = 0.5,
    validate: str = "off",
    checkpoint_dir: str | None = None,
    ckpt_every: int = 1,
    resume: str = "auto",
    return_report: bool = False,
    telemetry="off",
    init_idx: jnp.ndarray | None = None,
) -> tuple[SolveResult, sampling.Batch]:
    """End-to-end OneBatchPAM (Algorithm 1).

    Returns the solve result plus the batch (for inspection / reuse).

    ``chunk_size`` streams the distance build in row chunks (DESIGN.md §4).
    ``block_dtype`` stores the (n, m) block in a narrower dtype (e.g.
    ``"bfloat16"``) — gain accumulation stays f32, HBM traffic on the
    memory-bound sweep halves (DESIGN.md §2).
    ``mesh`` (a ``jax.sharding.Mesh``) shards the n axis across its batch
    axes and runs the whole batch build + swap sweep data-parallel under
    shard_map (DESIGN.md §5); the returned batch then has ``d=None`` since
    the block only ever exists shard-wise on the devices.
    ``init_idx`` (k,) warm-starts the local search from a caller-chosen
    medoid set instead of the random draw — the serving path's refit
    entry (``MedoidSelector.refit``, DESIGN.md §9): starting near a
    local optimum, steepest descent reaches it in the few swaps the
    drift actually moved, instead of re-climbing from scratch (the
    FasterPAM warm-start discipline, Schubert & Rousseeuw). The batch
    draw is unchanged (same ``key_b`` split), so a warm and a cold solve
    on the same key see the identical batch. Not composed with
    ``restarts > 1`` (the election exists to pick an init) or the
    robustness knobs (the runtime owns its init for bitwise resume).
    ``restarts=R > 1`` runs R independent local searches as one vmapped
    program over a pooled R·m column sample and elects the winner on a
    held-out evaluation batch of ``eval_m`` columns (core/restarts.py,
    DESIGN.md §2a); the returned batch is the *winning* restart's slice of
    the pool. ``restarts=1`` (the default) is the original single-restart
    trajectory, bit for bit — same key splits, same draws, same sweep —
    and ``eval_m`` is ignored (there is nothing to elect).

    **Pooled-sample budget**: with restarts the R per-restart batches are
    drawn *disjointly* from one pool, so R·m cannot exceed n — a
    user-passed ``m`` above ``n // restarts`` is clamped down to fit and
    a ``UserWarning`` names the effective size (the estimator quality
    m buys is per restart, so silent shrinkage would silently change the
    quality/compute trade; see the README perf-knob table).

    ``strategy="matrix_free"`` (DESIGN.md §2b) never materialises the
    (n, m) block: the batch is built block-free (``Batch.d is None``,
    nniw weights from the streaming histogram) and
    :func:`solve_matrix_free` recomputes distance tiles on chip. Same
    swaps as ``"batched"`` on the f32 ref/interpret paths; resident
    memory drops from O(nm) to O(np + km). ``block_dtype`` does not
    apply (no stored block).

    ``strategy="pruned"`` (DESIGN.md §2c) is the matrix-free sweep with
    bound-based candidate elimination: most sweeps exactly rescore only
    the rows whose confidence upper bound reaches the best lower bound,
    with a guaranteed-identical trajectory (bitwise the
    ``"matrix_free"`` swaps — core/pruned.py). ``prune_m`` is the
    phase-1 subsample width (default m // 8) and ``survivor_frac`` the
    dense-fallback threshold; both are ignored by other strategies.

    **Robustness knobs** (DESIGN.md §6; ``core/runtime.py``): setting
    ``validate`` ("off" | "cheap" | "paranoid"), ``checkpoint_dir``, or
    ``return_report=True`` routes the solve through the fault-tolerant
    runtime — the identical trajectory, bit for bit, driven sweep by
    sweep from the host so it can checkpoint solver state every
    ``ckpt_every`` sweeps (``resume="auto"`` continues a killed solve;
    "never" starts over), check runtime invariants, and degrade
    gracefully on violations. With ``return_report=True`` the return
    becomes ``(result, batch, report)`` with a
    :class:`runtime.SolveReport` third. Not composed with ``mesh=`` yet.

    ``telemetry`` ("off" | "on" | a ``monitoring.Telemetry``) also
    routes through the runtime and wires the solve into the metrics
    registry and span tracer (DESIGN.md §10) — same trajectory, bit for
    bit; "off" is the untouched jitted path.
    """
    if init_idx is not None:
        if restarts > 1:
            raise ValueError(
                "init_idx warm start and restarts > 1 are mutually "
                "exclusive: the restart election exists to *choose* an "
                "init — warm-start a single trajectory instead")
        if (validate != "off" or checkpoint_dir is not None
                or return_report or telemetry not in ("off", None, False)):
            raise ValueError(
                "init_idx is not composed with the fault-tolerant runtime "
                "yet (the runtime owns its init draw for bitwise resume); "
                "drop the robustness knobs to warm-start")
        init_idx = jnp.asarray(init_idx, jnp.int32)
        if init_idx.shape != (k,):
            raise ValueError(
                f"init_idx must have shape ({k},), got {init_idx.shape}")

    robust = (validate != "off" or checkpoint_dir is not None
              or return_report or telemetry not in ("off", None, False))
    if robust:
        if mesh is not None:
            raise ValueError(
                "the fault-tolerant runtime (validate/checkpoint_dir/"
                "return_report/telemetry) is host-side only; mesh= is not "
                "composed yet — drop mesh or the robustness knobs")
        from repro.core import runtime
        res, batch, report = runtime.solve_fault_tolerant(
            key, x, k, m=m, variant=variant, metric=metric,
            strategy=strategy, max_swaps=max_swaps, eps=eps,
            backend=backend, chunk_size=chunk_size,
            block_dtype=block_dtype, restarts=restarts, eval_m=eval_m,
            prune_m=prune_m, survivor_frac=survivor_frac,
            validate=validate, checkpoint_dir=checkpoint_dir,
            ckpt_every=ckpt_every, resume=resume, telemetry=telemetry)
        return (res, batch, report) if return_report else (res, batch)

    n = x.shape[0]
    user_m = m
    m = m if m is not None else sampling.default_batch_size(n, k)
    m = min(m, n)
    if restarts < 1:
        raise ValueError(f"restarts must be >= 1, got {restarts}")
    matrix_free = strategy == "matrix_free"
    block_free = strategy in ("matrix_free", "pruned")
    if block_free and block_dtype is not None:
        raise ValueError(
            f"strategy={strategy!r} builds no block; block_dtype does not "
            "apply (tiles are recomputed in f32 on chip, DESIGN.md §2b)")
    if restarts > 1:
        from repro.core import restarts as restarts_mod
        if strategy not in ("batched", "matrix_free", "pruned"):
            raise ValueError(
                "restarts > 1 supports strategy='batched', 'matrix_free' "
                "or 'pruned'")
        rm = _clamp_pool_m(n, restarts, m, user_m=user_m)
        rr, pool = restarts_mod.one_batch_pam_restarts(
            key, x, k, restarts=restarts, m=rm,
            eval_m=eval_m, variant=variant, metric=metric, strategy=strategy,
            max_swaps=max_swaps, eps=eps, backend=backend,
            chunk_size=chunk_size, block_dtype=block_dtype, mesh=mesh,
            prune_m=prune_m, survivor_frac=survivor_frac)
        r = rr.best_restart
        d_best = None if pool.d is None else pool.d[r]
        return rr.best, sampling.Batch(idx=pool.idx[r],
                                       weights=pool.weights[r], d=d_best)

    key_b, key_i = jax.random.split(key)
    if init_idx is None:
        init_idx = jax.random.choice(key_i, n, shape=(k,), replace=False)

    if mesh is not None:
        from repro.core import distributed
        if strategy not in ("batched", "matrix_free"):
            raise ValueError(
                "mesh mode supports strategy='batched' or 'matrix_free' only")
        # Same draw as build_batch so mesh and host runs see the same batch.
        batch_idx = sampling._uniform_idx(key_b, n, m)
        if matrix_free:
            run = distributed.make_distributed_obp_matrix_free(
                mesh, k=k, metric=metric, variant=variant,
                chunk_size=chunk_size, max_swaps=max_swaps, eps=eps,
                backend=backend)
        else:
            run = distributed.make_distributed_obp_e2e(
                mesh, k=k, metric=metric, variant=variant,
                chunk_size=chunk_size, max_swaps=max_swaps, eps=eps,
                backend=backend, block_dtype=_dtype_name(block_dtype))
        res, weights = run(distributed.shard_over_batch(mesh, x), batch_idx,
                           init_idx)
        return res, sampling.Batch(idx=batch_idx, weights=weights, d=None)

    batch = sampling.build_batch(key_b, x, m, variant=variant, metric=metric,
                                 backend=backend, chunk_size=chunk_size,
                                 block_dtype=block_dtype,
                                 materialize=not block_free)
    if strategy == "batched":
        res = solve_batched(batch.d, init_idx, max_swaps=max_swaps, eps=eps,
                            backend=backend)
    elif matrix_free:
        res = solve_matrix_free(x, batch.idx, batch.weights, init_idx,
                                metric=metric, debias=(variant == "debias"),
                                max_swaps=max_swaps, eps=eps, backend=backend,
                                chunk_size=chunk_size)
    elif strategy == "pruned":
        from repro.core import pruned as pruned_mod
        res = pruned_mod.solve_pruned(
            x, batch.idx, batch.weights, init_idx,
            metric=metric, debias=(variant == "debias"),
            max_swaps=max_swaps, eps=eps, backend=backend,
            chunk_size=chunk_size, prune_m=prune_m,
            survivor_frac=survivor_frac)
    elif strategy == "eager":
        res = solve_eager(batch.d, init_idx,
                          max_passes=max(2, max_swaps // max(k, 1)), eps=eps)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return res, batch


def _clamp_pool_m(n: int, restarts: int, m: int, *, user_m=None) -> int:
    """Clamp a per-restart batch size to the disjoint-pool budget
    ``n // restarts``, warning (instead of the former silent shrink) when
    a caller-requested m had to give."""
    fit = max(n // restarts, 1)
    if m > fit:
        if user_m is not None:
            warnings.warn(
                f"restarts={restarts} draws disjoint batches from one pool "
                f"of n={n} rows, so the requested m={user_m} is clamped to "
                f"{fit} per restart (R*m <= n). Lower restarts or m to "
                "silence this.", UserWarning, stacklevel=3)
        return fit
    return m


def _dtype_name(block_dtype) -> str | None:
    """Normalise a block dtype to a hashable name for the lru_cached
    distributed factories (None stays None)."""
    return None if block_dtype is None else jnp.dtype(block_dtype).name


def fasterpam(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    metric: str = "l1",
    strategy: str = "eager",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
) -> SolveResult:
    """Exact FasterPAM baseline: the same solver fed the full n x n matrix
    with random init (Schubert & Rousseeuw 2021 recommend random init).

    ``eps`` is the relative acceptance threshold and reaches both
    strategies (the eager path used to drop it). ``backend`` selects the
    distance-build and batched-sweep kernels only — :func:`solve_eager` is
    backend-free by construction (pure-jnp candidate scan), so it is *not*
    forwarded there. ``chunk_size`` streams the n x n build in row chunks
    through the §4 pipeline (``stream_block``) so the baseline's build-time
    intermediates are O(chunk · n) instead of chunk-free — the resident
    matrix itself is inherently O(n²); the *batch* solvers are what remove
    that (this used to be the one distance build that ignored chunking,
    making the exact baseline the memory hog of the benchmark suite).
    """
    from repro.core import streaming

    n = x.shape[0]
    d = streaming.stream_block(x, x, metric=metric, backend=backend,
                               chunk_size=chunk_size).d
    init_idx = jax.random.choice(key, n, shape=(k,), replace=False)
    if strategy == "eager":
        return solve_eager(d, init_idx,
                           max_passes=max(2, max_swaps // max(k, 1)), eps=eps)
    return solve_batched(d, init_idx, max_swaps=max_swaps, eps=eps,
                         backend=backend)
