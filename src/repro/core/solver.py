"""OneBatchPAM local-search solver (the paper's core contribution, in JAX).

Two strategies over identical swap math (DESIGN.md §2):

  * ``eager``   — Algorithm 2 of the paper: scan candidates i = 1..n in
      order, swap as soon as the batch-estimated gain is positive
      (first-improvement, FasterPAM semantics). Serial; the faithful
      baseline we validate against the paper's claims.
  * ``batched`` — TPU-native steepest descent: evaluate the full (n, k)
      gain matrix with one fused kernel pass (relu row-sum + clipped
      correction matmul on the MXU), take the globally best swap, repeat
      inside a single ``lax.while_loop``. Beyond-paper optimisation; same
      local-search family, one compiled XLA program, no host round trips.

The solver is batch-size agnostic: pass the n x m OneBatch block for OBP, or
the full n x n matrix to recover exact (Fast)PAM — tests exploit this
equivalence (m = n  =>  same swaps as FasterPAM, Theorem 1's limit case).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling
from repro.kernels import ops

BIG = jnp.float32(1e30)  # sentinel for "no second medoid" / masked entries
NEG = jnp.float32(-1e30)


class SolveResult(NamedTuple):
    medoid_idx: jnp.ndarray     # (k,) int32 indices into X_n
    n_swaps: jnp.ndarray        # int32, accepted swaps
    est_objective: jnp.ndarray  # f32, batch-estimated mean objective
    converged: jnp.ndarray      # bool, True if a local minimum was reached


def _top2(med_rows: jnp.ndarray):
    """d1/d2/near from the (k, m) medoid-to-batch distance view."""
    k, m = med_rows.shape
    near = jnp.argmin(med_rows, axis=0)                       # (m,)
    d1 = jnp.take_along_axis(med_rows, near[None, :], axis=0)[0]
    masked = jnp.where(jax.nn.one_hot(near, k, axis=0, dtype=bool), BIG, med_rows)
    d2 = jnp.min(masked, axis=0)
    return d1, d2, near


class _State(NamedTuple):
    medoid_idx: jnp.ndarray  # (k,)
    med_rows: jnp.ndarray    # (k, m)
    d1: jnp.ndarray          # (m,)
    d2: jnp.ndarray          # (m,)
    near: jnp.ndarray        # (m,)
    t: jnp.ndarray           # swaps performed
    done: jnp.ndarray        # bool


def _init_state(d: jnp.ndarray, init_idx: jnp.ndarray) -> _State:
    med_rows = d[init_idx]
    d1, d2, near = _top2(med_rows)
    return _State(init_idx.astype(jnp.int32), med_rows, d1, d2, near,
                  jnp.int32(0), jnp.bool_(False))


def _apply_swap(state: _State, d: jnp.ndarray, i: jnp.ndarray, l: jnp.ndarray) -> _State:
    med_rows = state.med_rows.at[l].set(d[i])
    d1, d2, near = _top2(med_rows)
    return _State(state.medoid_idx.at[l].set(i.astype(jnp.int32)),
                  med_rows, d1, d2, near, state.t + 1, state.done)


@functools.partial(jax.jit, static_argnames=("max_swaps", "backend"))
def solve_batched(
    d: jnp.ndarray,            # (n, m) weighted distance block
    init_idx: jnp.ndarray,     # (k,) initial medoids
    *,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
) -> SolveResult:
    """Steepest-descent local search on the batch objective."""
    n, m = d.shape
    k = init_idx.shape[0]
    state = _init_state(d, init_idx)

    def cond(state):
        return jnp.logical_and(~state.done, state.t < max_swaps)

    def body(state):
        nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
        gain = ops.swap_gain(d, state.d1, state.d2, nh, backend=backend)  # (n, k)
        # Current medoids are not swap candidates.
        gain = gain.at[state.medoid_idx].set(NEG)
        flat = jnp.argmax(gain)
        i, l = flat // k, flat % k
        best = gain.reshape(-1)[flat]
        improved = best > eps * jnp.sum(state.d1)
        new_state = _apply_swap(state, d, i, l)
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state,
            state._replace(done=jnp.bool_(True)))

    state = jax.lax.while_loop(cond, body, state)
    return SolveResult(state.medoid_idx, state.t,
                       jnp.mean(state.d1), state.done)


@functools.partial(jax.jit, static_argnames=("max_passes",))
def solve_eager(
    d: jnp.ndarray,
    init_idx: jnp.ndarray,
    *,
    max_passes: int = 8,
    eps: float = 0.0,
) -> SolveResult:
    """Paper-faithful Algorithm 2: first-improvement scan over candidates.

    One "pass" visits all n candidates in index order, swapping eagerly.
    Terminates when a full pass performs no swap (local minimum) or after
    max_passes. Serial by construction — this is the CPU algorithm the
    paper ships; kept as the validation baseline.
    """
    n, m = d.shape
    k = init_idx.shape[0]
    state0 = _init_state(d, init_idx)

    def candidate_step(i, carry):
        state, swapped = carry
        row = d[i]                                            # (m,)
        g = jnp.sum(jnp.maximum(state.d1 - row, 0.0))
        r = state.d1 - jnp.minimum(jnp.maximum(row, state.d1), state.d2)
        big_r = jnp.zeros((k,), jnp.float32).at[state.near].add(r)
        l = jnp.argmax(big_r)
        gain = g + big_r[l]
        is_medoid = jnp.any(state.medoid_idx == i)
        do_swap = jnp.logical_and(gain > eps * jnp.sum(state.d1), ~is_medoid)
        new_state = _apply_swap(state, d, jnp.int32(i), l)
        state = jax.tree.map(lambda a, b: jnp.where(do_swap, a, b), new_state, state)
        return state, jnp.logical_or(swapped, do_swap)

    def pass_body(carry):
        state, p = carry
        state, swapped = jax.lax.fori_loop(
            0, n, candidate_step, (state, jnp.bool_(False)))
        return state._replace(done=~swapped), p + 1

    def pass_cond(carry):
        state, p = carry
        return jnp.logical_and(~state.done, p < max_passes)

    state, _ = jax.lax.while_loop(
        pass_cond, pass_body, (state0, jnp.int32(0)))
    return SolveResult(state.medoid_idx, state.t, jnp.mean(state.d1), state.done)


def objective(x: jnp.ndarray, medoid_idx: jnp.ndarray, *, metric: str = "l1",
              backend: str = "auto",
              chunk_size: int | None = None) -> jnp.ndarray:
    """Exact k-medoids objective L(M) on the full dataset (Eq. 1 / n).

    ``chunk_size`` streams the evaluation in O(chunk * k) memory without
    materialising the (n, k) block (streaming.py, DESIGN.md §4).
    """
    from repro.core import streaming
    _, dmin = streaming.stream_assign(x, x[medoid_idx], metric=metric,
                                      backend=backend, chunk_size=chunk_size)
    return jnp.mean(dmin)


def one_batch_pam(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    m: int | None = None,
    variant: str = "nniw",
    metric: str = "l1",
    strategy: str = "batched",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
    mesh=None,
) -> tuple[SolveResult, sampling.Batch]:
    """End-to-end OneBatchPAM (Algorithm 1).

    Returns the solve result plus the batch (for inspection / reuse).

    ``chunk_size`` streams the distance build in row chunks (DESIGN.md §4).
    ``mesh`` (a ``jax.sharding.Mesh``) shards the n axis across its batch
    axes and runs the whole batch build + swap sweep data-parallel under
    shard_map (DESIGN.md §5); the returned batch then has ``d=None`` since
    the block only ever exists shard-wise on the devices.
    """
    n = x.shape[0]
    m = m if m is not None else sampling.default_batch_size(n, k)
    m = min(m, n)
    key_b, key_i = jax.random.split(key)
    init_idx = jax.random.choice(key_i, n, shape=(k,), replace=False)

    if mesh is not None:
        from repro.core import distributed
        if strategy != "batched":
            raise ValueError("mesh mode supports strategy='batched' only")
        # Same draw as build_batch so mesh and host runs see the same batch.
        batch_idx = sampling._uniform_idx(key_b, n, m)
        run = distributed.make_distributed_obp_e2e(
            mesh, k=k, metric=metric, variant=variant, chunk_size=chunk_size,
            max_swaps=max_swaps, eps=eps, backend=backend)
        res, weights = run(distributed.shard_over_batch(mesh, x), batch_idx,
                           init_idx)
        return res, sampling.Batch(idx=batch_idx, weights=weights, d=None)

    batch = sampling.build_batch(key_b, x, m, variant=variant, metric=metric,
                                 backend=backend, chunk_size=chunk_size)
    if strategy == "batched":
        res = solve_batched(batch.d, init_idx, max_swaps=max_swaps, eps=eps,
                            backend=backend)
    elif strategy == "eager":
        res = solve_eager(batch.d, init_idx,
                          max_passes=max(2, max_swaps // max(k, 1)), eps=eps)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return res, batch


def fasterpam(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    metric: str = "l1",
    strategy: str = "eager",
    max_swaps: int = 500,
    backend: str = "auto",
) -> SolveResult:
    """Exact FasterPAM baseline: the same solver fed the full n x n matrix
    with random init (Schubert & Rousseeuw 2021 recommend random init)."""
    n = x.shape[0]
    d = ops.pairwise_distance(x, x, metric=metric, backend=backend)
    init_idx = jax.random.choice(key, n, shape=(k,), replace=False)
    if strategy == "eager":
        return solve_eager(d, init_idx, max_passes=max(2, max_swaps // max(k, 1)))
    return solve_batched(d, init_idx, max_swaps=max_swaps, backend=backend)
