"""OneBatchPAM local-search solver (the paper's core contribution, in JAX).

Three strategies over identical swap math (DESIGN.md §2):

  * ``eager``   — Algorithm 2 of the paper: scan candidates i = 1..n in
      order, swap as soon as the batch-estimated gain is positive
      (first-improvement, FasterPAM semantics). Serial; the faithful
      baseline we validate against the paper's claims. Backend-free: the
      scan evaluates gains in pure jnp, no kernel dispatch.
  * ``batched`` (:func:`solve_batched`) — TPU-native steepest descent with
      the *fused* swap-select sweep: one O(nm) kernel pass per iteration
      reduces the gain tiles on-chip to O(n/TN) partials (``ops.swap_select``),
      so the (n, k) gain matrix never reaches HBM, and the d1/d2/near state
      is repaired incrementally after each accepted swap (FasterPAM-style,
      O(m) expected) instead of recomputed from the full (k, m) view.
  * :func:`solve_batched_naive` — the pre-fusion batched solver
      (materialise (n, k) gains, host argmax, full top-2 recompute). Kept
      as the equivalence oracle for the fused path and as the "naive"
      column of the sweep benchmarks; same swaps, same floats.

The solver is batch-size agnostic: pass the n x m OneBatch block for OBP, or
the full n x n matrix to recover exact (Fast)PAM — tests exploit this
equivalence (m = n  =>  same swaps as FasterPAM, Theorem 1's limit case).
The block may be stored in bf16 (``block_dtype`` in sampling/streaming);
all solver state and gain accumulation stay f32.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sampling
from repro.kernels import ops
from repro.kernels.ref import NEG  # noqa: F401  (re-exported; distributed.py)

BIG = jnp.float32(1e30)  # sentinel for "no second medoid" / masked entries


class SolveResult(NamedTuple):
    medoid_idx: jnp.ndarray     # (k,) int32 indices into X_n
    n_swaps: jnp.ndarray        # int32, accepted swaps
    est_objective: jnp.ndarray  # f32, batch-estimated mean objective
    converged: jnp.ndarray      # bool, True if a local minimum was reached


def _top2(med_rows: jnp.ndarray):
    """d1/d2/near/near2 from the (k, m) medoid-to-batch distance view."""
    k, m = med_rows.shape
    near = jnp.argmin(med_rows, axis=0)                       # (m,)
    d1 = jnp.take_along_axis(med_rows, near[None, :], axis=0)[0]
    masked = jnp.where(jax.nn.one_hot(near, k, axis=0, dtype=bool), BIG, med_rows)
    near2 = jnp.argmin(masked, axis=0)                        # (m,)
    d2 = jnp.take_along_axis(masked, near2[None, :], axis=0)[0]
    return d1, d2, near, near2


def _repair_top2(med_rows, d1, d2, near, near2, r, l):
    """Incremental top-2 repair after medoid slot ``l`` is replaced by a
    candidate whose (weighted) batch row is ``r`` (FasterPAM-style;
    DESIGN.md §2). Returns ``(med_rows', d1', d2', near', near2')``.

    Value-exact with a full :func:`_top2` recompute: every output is a copy
    or a min of existing floats, so the fused solver's trajectory is
    bit-for-bit the naive solver's. Slot choices (near/near2) may differ
    from argmin's on exact distance ties, but a tie means d1 == d2, which
    zeroes the removal correction r_ij — slot identity never reaches the
    gains (tests/test_fused_solver.py pins the value invariant).

    Cost: O(m) for every column except the *hard* case — the removed slot
    was in the column's top-2 and the new row does not re-enter it — which
    needs the third-nearest distance. Those columns (expected fraction
    ~2/k) fall back to one masked min over the cached (k, m) rows, gated
    behind ``lax.cond`` so swap steps with no hard column skip it.
    """
    k = med_rows.shape[0]
    new_rows = med_rows.at[l].set(r)
    was1 = near == l
    surv = jnp.where(was1, near2, near)        # best surviving old slot
    s = jnp.where(was1, d2, d1)                # its distance
    closer = r < s
    d1n = jnp.minimum(r, s)
    nearn = jnp.where(closer, l, surv)
    hard = was1 | (near2 == l)
    need = hard & (r > d2)
    # Easy path: the removed slot was outside the top-2 (its distance was
    # >= d2, so top-2 of {r, s, d2} is exact), or the new row re-enters.
    d2e = jnp.minimum(jnp.maximum(r, s), d2)
    near2e = jnp.where(closer, surv, l)
    near2e = jnp.where(~hard & (r >= d2), near2, near2e)

    def recompute(_):
        slot = jnp.arange(k, dtype=nearn.dtype)[:, None]
        masked = jnp.where(slot == nearn[None, :], BIG, new_rows)
        n2 = jnp.argmin(masked, axis=0)
        return jnp.take_along_axis(masked, n2[None, :], axis=0)[0], n2

    d2r, near2r = jax.lax.cond(
        jnp.any(need), recompute, lambda _: (d2e, near2e), None)
    return (new_rows, d1n, jnp.where(need, d2r, d2e), nearn,
            jnp.where(need, near2r, near2e))


class _State(NamedTuple):
    medoid_idx: jnp.ndarray  # (k,)
    med_rows: jnp.ndarray    # (k, m) f32 (cast from the block's dtype)
    d1: jnp.ndarray          # (m,)
    d2: jnp.ndarray          # (m,)
    near: jnp.ndarray        # (m,)
    near2: jnp.ndarray       # (m,)
    t: jnp.ndarray           # swaps performed
    done: jnp.ndarray        # bool


def _init_state(d: jnp.ndarray, init_idx: jnp.ndarray) -> _State:
    med_rows = d[init_idx].astype(jnp.float32)
    d1, d2, near, near2 = _top2(med_rows)
    return _State(init_idx.astype(jnp.int32), med_rows, d1, d2, near, near2,
                  jnp.int32(0), jnp.bool_(False))


def _apply_swap(state: _State, d: jnp.ndarray, i: jnp.ndarray, l: jnp.ndarray) -> _State:
    """Full-recompute swap application (naive/eager paths)."""
    med_rows = state.med_rows.at[l].set(d[i].astype(jnp.float32))
    d1, d2, near, near2 = _top2(med_rows)
    return _State(state.medoid_idx.at[l].set(i.astype(jnp.int32)),
                  med_rows, d1, d2, near, near2, state.t + 1, state.done)


def _fused_step(d: jnp.ndarray, state: _State, *, eps: float = 0.0,
                backend: str = "auto"):
    """One fused steepest-descent step: swap-select sweep + incremental
    repair. Returns ``(new_state, improved, best_gain, i, l)`` — the exact
    float sequence of :func:`solve_batched`'s loop body, factored out so
    ``core/trace.py`` can replay the trajectory swap for swap (the caller
    applies ``new_state`` only when ``improved``)."""
    n, _ = d.shape
    k = state.medoid_idx.shape[0]
    nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
    # Current medoids are not swap candidates: O(n) mask instead of the
    # former O(nk) scatter into the materialised gain matrix.
    row_mask = jnp.ones((n,), jnp.float32).at[state.medoid_idx].set(0.0)
    best, i, l = ops.swap_select(d, state.d1, state.d2, nh,
                                 row_mask=row_mask, backend=backend)
    improved = best > eps * jnp.sum(state.d1)
    r = d[i].astype(jnp.float32)
    med_rows, d1, d2, near, near2 = _repair_top2(
        state.med_rows, state.d1, state.d2, state.near, state.near2, r, l)
    new_state = _State(state.medoid_idx.at[l].set(i.astype(jnp.int32)),
                       med_rows, d1, d2, near, near2,
                       state.t + 1, state.done)
    return new_state, improved, best, i, l


def _eager_pass(d: jnp.ndarray, state: _State, *, eps: float = 0.0):
    """One full first-improvement pass over all n candidates (Algorithm 2).

    Returns ``(state, swapped, do_swap (n,), slots (n,))`` — the last two
    record, per candidate index, whether it was swapped in and into which
    slot, so ``core/trace.py`` recovers the swap sequence from the same
    scan :func:`solve_eager` runs (identical floats by construction)."""
    n, _ = d.shape
    k = state.medoid_idx.shape[0]

    def candidate_step(carry, i):
        state, swapped = carry
        row = d[i].astype(jnp.float32)                        # (m,)
        g = jnp.sum(jnp.maximum(state.d1 - row, 0.0))
        r = state.d1 - jnp.minimum(jnp.maximum(row, state.d1), state.d2)
        big_r = jnp.zeros((k,), jnp.float32).at[state.near].add(r)
        l = jnp.argmax(big_r)
        gain = g + big_r[l]
        is_medoid = jnp.any(state.medoid_idx == i)
        do_swap = jnp.logical_and(gain > eps * jnp.sum(state.d1), ~is_medoid)
        new_state = _apply_swap(state, d, i.astype(jnp.int32), l)
        state = jax.tree.map(lambda a, b: jnp.where(do_swap, a, b),
                             new_state, state)
        return (state, jnp.logical_or(swapped, do_swap)), (do_swap, l)

    (state, swapped), (flags, slots) = jax.lax.scan(
        candidate_step, (state, jnp.bool_(False)), jnp.arange(n))
    return state, swapped, flags, slots


@functools.partial(jax.jit, static_argnames=("max_swaps", "backend"))
def solve_batched(
    d: jnp.ndarray,            # (n, m) weighted distance block (f32 or bf16)
    init_idx: jnp.ndarray,     # (k,) initial medoids
    *,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
) -> SolveResult:
    """Steepest-descent local search with the fused swap-select sweep.

    Per iteration: one ``ops.swap_select`` pass (O(nm) block read, O(n/TN)
    partials written — the (n, k) gain matrix never materialises), then an
    incremental ``_repair_top2`` state update for the accepted swap.
    Bit-for-bit the same swaps as :func:`solve_batched_naive`.
    """
    state = _init_state(d, init_idx)

    def cond(state):
        return jnp.logical_and(~state.done, state.t < max_swaps)

    def body(state):
        new_state, improved, _, _, _ = _fused_step(d, state, eps=eps,
                                                   backend=backend)
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state,
            state._replace(done=jnp.bool_(True)))

    state = jax.lax.while_loop(cond, body, state)
    return SolveResult(state.medoid_idx, state.t,
                       jnp.mean(state.d1), state.done)


@functools.partial(jax.jit, static_argnames=("max_swaps", "backend"))
def solve_batched_naive(
    d: jnp.ndarray,
    init_idx: jnp.ndarray,
    *,
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
) -> SolveResult:
    """Pre-fusion batched solver: materialise the (n, k) gain matrix, argmax
    it, recompute the full top-2 state after every swap.

    Kept as the equivalence oracle (`tests/test_fused_solver.py` pins
    identical trajectories against :func:`solve_batched`) and as the
    "naive" column of the sweep benchmarks. O(nk) HBM write + read per
    iteration that the fused path avoids.
    """
    n, m = d.shape
    k = init_idx.shape[0]
    state = _init_state(d, init_idx)

    def cond(state):
        return jnp.logical_and(~state.done, state.t < max_swaps)

    def body(state):
        nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
        gain = ops.swap_gain(d, state.d1, state.d2, nh, backend=backend)
        gain = gain.at[state.medoid_idx].set(NEG)
        flat = jnp.argmax(gain)
        i, l = flat // k, flat % k
        best = gain.reshape(-1)[flat]
        improved = best > eps * jnp.sum(state.d1)
        new_state = _apply_swap(state, d, i, l)
        return jax.tree.map(
            lambda a, b: jnp.where(improved, a, b), new_state,
            state._replace(done=jnp.bool_(True)))

    state = jax.lax.while_loop(cond, body, state)
    return SolveResult(state.medoid_idx, state.t,
                       jnp.mean(state.d1), state.done)


@functools.partial(jax.jit, static_argnames=("max_passes",))
def solve_eager(
    d: jnp.ndarray,
    init_idx: jnp.ndarray,
    *,
    max_passes: int = 8,
    eps: float = 0.0,
) -> SolveResult:
    """Paper-faithful Algorithm 2: first-improvement scan over candidates.

    One "pass" visits all n candidates in index order, swapping eagerly.
    Terminates when a full pass performs no swap (local minimum) or after
    max_passes. Serial by construction — this is the CPU algorithm the
    paper ships; kept as the validation baseline. Backend-free: gains are
    evaluated in pure jnp, so there is no ``backend=`` knob here.
    """
    state0 = _init_state(d, init_idx)

    def pass_body(carry):
        state, p = carry
        state, swapped, _, _ = _eager_pass(d, state, eps=eps)
        return state._replace(done=~swapped), p + 1

    def pass_cond(carry):
        state, p = carry
        return jnp.logical_and(~state.done, p < max_passes)

    state, _ = jax.lax.while_loop(
        pass_cond, pass_body, (state0, jnp.int32(0)))
    return SolveResult(state.medoid_idx, state.t, jnp.mean(state.d1), state.done)


def objective(x: jnp.ndarray, medoid_idx: jnp.ndarray, *, metric: str = "l1",
              backend: str = "auto",
              chunk_size: int | None = None) -> jnp.ndarray:
    """Exact k-medoids objective L(M) on the full dataset (Eq. 1 / n).

    ``chunk_size`` streams the evaluation in O(chunk * k) memory without
    materialising the (n, k) block (streaming.py, DESIGN.md §4).
    """
    from repro.core import streaming
    _, dmin = streaming.stream_assign(x, x[medoid_idx], metric=metric,
                                      backend=backend, chunk_size=chunk_size)
    return jnp.mean(dmin)


def one_batch_pam(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    m: int | None = None,
    variant: str = "nniw",
    metric: str = "l1",
    strategy: str = "batched",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
    chunk_size: int | None = None,
    block_dtype: str | jnp.dtype | None = None,
    mesh=None,
    restarts: int = 1,
    eval_m: int | None = None,
) -> tuple[SolveResult, sampling.Batch]:
    """End-to-end OneBatchPAM (Algorithm 1).

    Returns the solve result plus the batch (for inspection / reuse).

    ``chunk_size`` streams the distance build in row chunks (DESIGN.md §4).
    ``block_dtype`` stores the (n, m) block in a narrower dtype (e.g.
    ``"bfloat16"``) — gain accumulation stays f32, HBM traffic on the
    memory-bound sweep halves (DESIGN.md §2).
    ``mesh`` (a ``jax.sharding.Mesh``) shards the n axis across its batch
    axes and runs the whole batch build + swap sweep data-parallel under
    shard_map (DESIGN.md §5); the returned batch then has ``d=None`` since
    the block only ever exists shard-wise on the devices.
    ``restarts=R > 1`` runs R independent local searches as one vmapped
    program over a pooled R·m column sample and elects the winner on a
    held-out evaluation batch of ``eval_m`` columns (core/restarts.py,
    DESIGN.md §2a); the returned batch is the *winning* restart's slice of
    the pool. ``restarts=1`` (the default) is the original single-restart
    trajectory, bit for bit — same key splits, same draws, same sweep —
    and ``eval_m`` is ignored (there is nothing to elect).
    """
    n = x.shape[0]
    m = m if m is not None else sampling.default_batch_size(n, k)
    m = min(m, n)
    if restarts < 1:
        raise ValueError(f"restarts must be >= 1, got {restarts}")
    if restarts > 1:
        from repro.core import restarts as restarts_mod
        if strategy != "batched":
            raise ValueError("restarts > 1 supports strategy='batched' only")
        rr, pool = restarts_mod.one_batch_pam_restarts(
            key, x, k, restarts=restarts, m=min(m, max(n // restarts, 1)),
            eval_m=eval_m, variant=variant, metric=metric,
            max_swaps=max_swaps, eps=eps, backend=backend,
            chunk_size=chunk_size, block_dtype=block_dtype, mesh=mesh)
        r = rr.best_restart
        d_best = None if pool.d is None else pool.d[r]
        return rr.best, sampling.Batch(idx=pool.idx[r],
                                       weights=pool.weights[r], d=d_best)

    key_b, key_i = jax.random.split(key)
    init_idx = jax.random.choice(key_i, n, shape=(k,), replace=False)

    if mesh is not None:
        from repro.core import distributed
        if strategy != "batched":
            raise ValueError("mesh mode supports strategy='batched' only")
        # Same draw as build_batch so mesh and host runs see the same batch.
        batch_idx = sampling._uniform_idx(key_b, n, m)
        run = distributed.make_distributed_obp_e2e(
            mesh, k=k, metric=metric, variant=variant, chunk_size=chunk_size,
            max_swaps=max_swaps, eps=eps, backend=backend,
            block_dtype=_dtype_name(block_dtype))
        res, weights = run(distributed.shard_over_batch(mesh, x), batch_idx,
                           init_idx)
        return res, sampling.Batch(idx=batch_idx, weights=weights, d=None)

    batch = sampling.build_batch(key_b, x, m, variant=variant, metric=metric,
                                 backend=backend, chunk_size=chunk_size,
                                 block_dtype=block_dtype)
    if strategy == "batched":
        res = solve_batched(batch.d, init_idx, max_swaps=max_swaps, eps=eps,
                            backend=backend)
    elif strategy == "eager":
        res = solve_eager(batch.d, init_idx,
                          max_passes=max(2, max_swaps // max(k, 1)), eps=eps)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return res, batch


def _dtype_name(block_dtype) -> str | None:
    """Normalise a block dtype to a hashable name for the lru_cached
    distributed factories (None stays None)."""
    return None if block_dtype is None else jnp.dtype(block_dtype).name


def fasterpam(
    key: jax.Array,
    x: jnp.ndarray,
    k: int,
    *,
    metric: str = "l1",
    strategy: str = "eager",
    max_swaps: int = 500,
    eps: float = 0.0,
    backend: str = "auto",
) -> SolveResult:
    """Exact FasterPAM baseline: the same solver fed the full n x n matrix
    with random init (Schubert & Rousseeuw 2021 recommend random init).

    ``eps`` is the relative acceptance threshold and reaches both
    strategies (the eager path used to drop it). ``backend`` selects the
    distance-build and batched-sweep kernels only — :func:`solve_eager` is
    backend-free by construction (pure-jnp candidate scan), so it is *not*
    forwarded there.
    """
    n = x.shape[0]
    d = ops.pairwise_distance(x, x, metric=metric, backend=backend)
    init_idx = jax.random.choice(key, n, shape=(k,), replace=False)
    if strategy == "eager":
        return solve_eager(d, init_idx,
                           max_passes=max(2, max_swaps // max(k, 1)), eps=eps)
    return solve_batched(d, init_idx, max_swaps=max_swaps, eps=eps,
                         backend=backend)
