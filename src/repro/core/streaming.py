"""Row-streaming distance pipeline (DESIGN.md §4).

OneBatchPAM's memory story is O(nm) instead of O(n^2), but the seed
pipeline still materialised the full x array *and* the whole (n, m) block
through one device allocation — the intermediate broadcast/padding of the
distance computation peaked at O(chunk-free) HBM and capped n far below
the ROADMAP's millions-of-points target. This module sweeps the n axis in
fixed-size row chunks with ``lax.map`` (a sequential scan, so only one
chunk's intermediates are ever live):

  * :func:`stream_block` — the (n, m) block, chunk by chunk. Only the
    (n, m) f32 output is materialised; per-chunk intermediates are
    O(chunk * m) on the Pallas kernel path (plus fixed VMEM tiles), and
    up to O(chunk * m * p_tile) on the ref-oracle path, whose broadcast
    metrics (l1/chebyshev) hold a (chunk, m, p_tile) slab — p_tile = p
    below ``ref._BCAST_BUDGET``, <= 32 above it. Size chunks from the
    backend you run on. The nniw nearest-neighbour count is fused into
    the same sweep (``count_nn=True``) so the batch builder never
    re-reads the block for a full-height argmin pass.
  * :func:`stream_assign` — nearest-batch labels + distances without
    materialising (n, m) at all: O(chunk * m) total for predict /
    objective at any n.

Chunking is exact, not approximate: every per-row quantity (distance row,
argmin, min) is row-local, so the chunked sweep computes the identical
numbers as the one-shot path — tests/test_streaming.py pins this for
every registered metric x batch variant. ``chunk_size=None`` (the
default everywhere) falls through to the one-shot computation. One
caveat bounds the bitwise form of the claim: equality is per evaluation
path, and the ref oracles for the broadcast metrics (l1/chebyshev)
switch to p-tiled summation above ``ref._BCAST_BUDGET`` — a one-shot
block big enough to trip that escape while its chunks stay under it can
differ from the chunked sweep in the last ulp (different f32 summation
order; the same applies between ref and pallas backends). The values
are equally valid roundings; exact equality is guaranteed whenever both
paths stay on the same oracle, which the tests pin.

The same chunk loop runs unchanged inside ``shard_map`` on each device's
local rows, which is how core/distributed.py bounds per-device HBM
(DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import metrics, ops


# Default row chunk for the matrix-free path's streamed evaluations (the
# ref-backend sweep in solver.solve_matrix_free and the block-free nniw
# count pass): without it, a chunk_size=None caller would transiently
# materialize the full (n, m) block inside those passes — exactly what
# strategy="matrix_free" promises never to do (DESIGN.md §2b). 2048 rows
# bound the per-chunk footprint to O(2048·m) (plus the ref broadcast
# slab, §7) while staying a no-op (chunk >= n => one-shot, bit-identical)
# on test-scale inputs. Callers can pass chunk_size= explicitly to tune.
MF_DEFAULT_CHUNK = 2048


class StreamedBlock(NamedTuple):
    """Result of one streaming sweep over the n axis."""
    d: jnp.ndarray          # (n, m) distance block (post-transformed)
    nn_counts: jnp.ndarray  # (m,) f32 count of rows whose (within-group)
    #                         argmin is column j (see count_groups)


def _check_chunk(chunk_size: int | None) -> None:
    if chunk_size is not None and chunk_size < 1:
        raise ValueError(
            f"chunk_size must be a positive row count or None, "
            f"got {chunk_size}")


def _nn_hist(di: jnp.ndarray, vi: jnp.ndarray, m: int,
             count_groups: int) -> jnp.ndarray:
    """Per-group argmin scatter-add for one chunk's f32 distances.

    Grouped argmin over the (rows, R, m/R) view — identical indices to
    the whole-row argmin when count_groups == 1 — then one flat
    scatter-add; padded-tail rows are masked by ``vi``. The single home
    for the nniw histogram math: ``stream_block``'s fused counts and the
    block-free ``stream_nn_counts`` share it, so the two paths cannot
    drift apart (matrix-free weights == materialized weights, bitwise).
    """
    rows = di.shape[0]
    mg = m // count_groups
    win = jnp.argmin(di.reshape(rows, count_groups, mg), axis=2)
    flat = win + (jnp.arange(count_groups) * mg)[None, :]
    vals = jnp.broadcast_to(vi.astype(jnp.float32)[:, None], win.shape)
    return jnp.zeros((m,), jnp.float32).at[flat.reshape(-1)].add(
        vals.reshape(-1))


def _chunk_rows(x: jnp.ndarray, chunk_size: int):
    """Pad the n axis to a chunk multiple and reshape to (c, chunk, p).

    Returns the chunked rows plus a (c, chunk) validity mask for the
    padded tail (padded rows still produce distance rows — sliced off by
    the caller — but must not contribute to fused statistics).
    """
    n, p = x.shape
    pad = (-n) % chunk_size
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    c = (n + pad) // chunk_size
    valid = (jnp.arange(c * chunk_size) < n).reshape(c, chunk_size)
    return x.reshape(c, chunk_size, p), valid


def stream_block(
    x: jnp.ndarray,
    b: jnp.ndarray,
    *,
    metric: str = "l1",
    backend: str = "auto",
    chunk_size: int | None = None,
    count_nn: bool = False,
    count_groups: int = 1,
    raw: bool = False,
    block_dtype: str | jnp.dtype | None = None,
) -> StreamedBlock:
    """The (n, m) distance block, swept in row chunks.

    With ``count_nn`` the per-chunk argmin feeds a scatter-add into the
    (m,) nearest-neighbour histogram inside the same sweep — the nniw
    weights come out of the sweep for free (DESIGN.md §4).

    ``count_groups=R`` treats the m columns as R contiguous groups of
    m/R (the multi-restart pooled batch, DESIGN.md §2a): the argmin is
    taken *within each group*, so one sweep over x produces all R
    per-restart nearest-neighbour histograms at once. The output keeps
    the (m,) layout — counts for group r live in ``nn_counts[r*mg:(r+1)*mg]``
    — and ``count_groups=1`` is exactly the old whole-row argmin.

    ``raw=True`` returns the metric's pre-``post`` accumulator instead of
    distances (see ops.pairwise_raw): the distributed path reduces raw
    partials across feature shards before finalizing. ``count_nn`` is not
    meaningful on raw partials, so the two flags are mutually exclusive.

    ``block_dtype`` (e.g. ``"bfloat16"``) narrows the *stored* block: each
    chunk's distances are computed in f32 and cast before they join the
    output, so the resident block halves while every per-row statistic
    (nniw argmin included) is still taken on the f32 values — weights are
    block_dtype-independent (DESIGN.md §2). Raw partials stay f32 because
    they still have a feature-shard reduction ahead of them, so the two
    flags are mutually exclusive; the distributed path casts after its
    ``reduce`` collective instead.
    """
    if raw and count_nn:
        raise ValueError("count_nn requires finalized distances (raw=False)")
    if raw and block_dtype is not None:
        raise ValueError(
            "block_dtype applies to finalized distances; raw partials must "
            "stay f32 until after the feature-shard reduce (DESIGN.md §5)")
    _check_chunk(chunk_size)
    n = x.shape[0]
    m = b.shape[0]
    if count_groups < 1 or m % count_groups:
        raise ValueError(
            f"count_groups={count_groups} must be >= 1 and divide m={m}")
    spec = metrics.get(metric)

    def pair(xi, bi):
        r = ops.pairwise_raw(xi, bi, metric=metric, backend=backend,
                             skip_prepare=True)
        return r if raw else spec.finalize(r)

    def cast(di):
        return di if block_dtype is None else di.astype(block_dtype)

    def nn_hist(di, vi):
        return _nn_hist(di, vi, m, count_groups)

    # Apply the metric's row transform once, outside the chunk loop: it is
    # row-local (chunking cannot change it) and b is loop-invariant, so
    # re-preparing per chunk would redo m*p work every iteration.
    if spec.prepare is not None:
        x = spec.prepare(x)
        b = spec.prepare(b)

    if chunk_size is None or chunk_size >= n:
        d = pair(x, b)
        if count_nn:
            counts = nn_hist(d, jnp.ones((n,), jnp.float32))
        else:
            counts = jnp.zeros((m,), jnp.float32)
        return StreamedBlock(d=cast(d), nn_counts=counts)

    xc, valid = _chunk_rows(x, chunk_size)

    def sweep(args):
        xi, vi = args
        di = pair(xi, b)
        if count_nn:
            ci = nn_hist(di, vi)
        else:
            ci = jnp.zeros((m,), jnp.float32)
        # Cast inside the sweep so the stacked output (the resident block)
        # is narrow from the start, not materialised f32 then converted.
        return cast(di), ci

    d, counts = jax.lax.map(sweep, (xc, valid))
    return StreamedBlock(d=d.reshape(-1, m)[:n], nn_counts=counts.sum(axis=0))


def stream_nn_counts(
    x: jnp.ndarray,
    b: jnp.ndarray,
    *,
    metric: str = "l1",
    backend: str = "auto",
    chunk_size: int | None = None,
    count_groups: int = 1,
    skip_prepare: bool = False,
) -> jnp.ndarray:
    """The nniw nearest-neighbour histogram WITHOUT materialising the
    block: O(chunk · m) total, the count-only sibling of ``stream_block
    (count_nn=True)`` for the matrix-free path (DESIGN.md §2b), sharing
    its argmin/scatter math (:func:`_nn_hist`) chunk for chunk — the
    counts are bitwise the materialized path's. ``count_groups=R`` gives
    the multi-restart per-group histograms, as in ``stream_block``.
    ``skip_prepare`` is for callers that already hold prepared rows
    (the distributed matrix-free factory prepares each shard once and
    reuses the rows for both the count pass and the solve).
    """
    _check_chunk(chunk_size)
    n = x.shape[0]
    m = b.shape[0]
    if count_groups < 1 or m % count_groups:
        raise ValueError(
            f"count_groups={count_groups} must be >= 1 and divide m={m}")
    spec = metrics.get(metric)
    if spec.prepare is not None and not skip_prepare:
        # once, outside the loop (see stream_block)
        x = spec.prepare(x)
        b = spec.prepare(b)

    def pair(xi):
        return spec.finalize(ops.pairwise_raw(
            xi, b, metric=metric, backend=backend, skip_prepare=True))

    if chunk_size is None or chunk_size >= n:
        return _nn_hist(pair(x), jnp.ones((n,), jnp.float32), m,
                        count_groups)

    xc, valid = _chunk_rows(x, chunk_size)

    def sweep(args):
        xi, vi = args
        return _nn_hist(pair(xi), vi, m, count_groups)

    return jax.lax.map(sweep, (xc, valid)).sum(axis=0)


def stream_assign(
    x: jnp.ndarray,
    b: jnp.ndarray,
    *,
    metric: str = "l1",
    backend: str = "auto",
    chunk_size: int | None = None,
    block_dtype: str | jnp.dtype | None = None,
    skip_prepare: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Nearest-batch assignment without materialising the (n, m) block.

    Returns ``(labels, dmin)``: per-row argmin index into b and the
    corresponding distance. This is the O(chunk * m) predict/objective
    path (DESIGN.md §7's memory budget table).

    ``block_dtype`` mirrors ``stream_block``'s stored-block convention
    for the assignment direction: each chunk's distances are computed in
    f32, rounded to the narrow dtype, and the argmin/min is taken on the
    rounded values (the tiles a narrow block would have held), with
    ``dmin`` returned as the exact f32 upcast — narrow tiles, f32
    accumulation downstream (DESIGN.md §2). The assign kernel path
    (ops.assign) applies the identical rounding in-VMEM, so the two stay
    bitwise-pinned per backend. ``skip_prepare`` is for callers that
    already hold metric-prepared rows (the serving engine prepares its
    medoid buffer once per swap, not per query batch).
    """
    _check_chunk(chunk_size)
    n = x.shape[0]
    spec = metrics.get(metric)
    if spec.prepare is not None and not skip_prepare:
        # once, outside the loop (see stream_block)
        x = spec.prepare(x)
        b = spec.prepare(b)

    def pair(xi):
        d = spec.finalize(ops.pairwise_raw(
            xi, b, metric=metric, backend=backend, skip_prepare=True))
        if block_dtype is not None:
            # Round then compare in f32: the upcast is exact, so the
            # argmin/min on the upcasts equals the argmin/min on the
            # narrow values while dmin comes out f32 for free.
            d = d.astype(block_dtype).astype(jnp.float32)
        return d

    if chunk_size is None or chunk_size >= n:
        d = pair(x)
        return jnp.argmin(d, axis=1), jnp.min(d, axis=1)

    xc, _ = _chunk_rows(x, chunk_size)

    def sweep(xi):
        di = pair(xi)
        return jnp.argmin(di, axis=1), jnp.min(di, axis=1)

    labels, dmin = jax.lax.map(sweep, xc)
    return labels.reshape(-1)[:n], dmin.reshape(-1)[:n]
