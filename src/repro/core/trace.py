"""Swap-trajectory tracing: the solvers' decisions, one swap at a time.

The solvers run whole local searches inside one ``lax.while_loop`` — fast,
but opaque: only the final state comes back. The differential and
golden-trajectory suites (tests/test_differential.py,
tests/test_golden_trajectory.py) need the *sequence* of swap decisions to
pin cross-implementation equivalence swap for swap. This module replays
the exact loop bodies step by step from the host:

  * :func:`trace_batched` drives ``solver._fused_step`` — the literal
    body of ``solve_batched`` (same swap-select kernel call, same
    incremental repair, same acceptance comparison evaluated inside the
    jitted step) — so the recorded trajectory is bit-for-bit the
    while_loop's.
  * :func:`trace_eager` drives ``solver._eager_pass`` — the literal
    per-pass candidate scan of ``solve_eager`` — and reads the recorded
    (do_swap, slot) lanes back.
  * :func:`trace_matrix_free` drives ``solver._matrix_free_step`` — the
    literal body of ``solve_matrix_free`` (same fused distance+select
    sweep, same O(mp) row recompute, same repair) — pinning the
    matrix-free trajectory swap for swap against the block path's.
  * :func:`trace_pruned` drives ``pruned._pruned_step`` — the literal
    body of ``solve_pruned`` (same phase-1 bounds, same survivor
    rescore, same fallback predicate) — threading the (ub, lb) bound
    caches through the host loop, pinning the pruned trajectory (and
    its per-sweep pruning decisions) swap for swap.

Tracing is a test/debug tool: O(1 jit dispatch per swap) host overhead
makes it slower than the fused loops; production callers want
``solve_batched``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solver


class Trajectory(NamedTuple):
    """A traced local search: the swap sequence plus the final result."""
    swaps: tuple[tuple[int, int], ...]  # ((candidate i, slot l), ...)
    gains: tuple[float, ...]            # accepted gain per swap (batched only)
    result: solver.SolveResult          # identical to the solver's return


@functools.lru_cache(maxsize=64)
def _jit_fused_step(eps: float, backend: str):
    return jax.jit(functools.partial(solver._fused_step, eps=eps,
                                     backend=backend))


@functools.lru_cache(maxsize=64)
def _jit_eager_pass(eps: float):
    return jax.jit(functools.partial(solver._eager_pass, eps=eps))


def trace_batched(d, init_idx, *, max_swaps: int = 500, eps: float = 0.0,
                  backend: str = "auto") -> Trajectory:
    """Replay ``solve_batched`` recording every accepted (i, l, gain).

    Matches :func:`solver.solve_batched` exactly — medoids, swap count,
    objective, convergence flag — because each step *is* the solver's
    loop body (``_fused_step``), acceptance decided inside the jitted
    step on the same floats.
    """
    d = jnp.asarray(d)
    state = solver._init_state(d, jnp.asarray(init_idx))
    step = _jit_fused_step(eps, backend)
    swaps: list[tuple[int, int]] = []
    gains: list[float] = []
    converged = False
    while len(swaps) < max_swaps:
        new_state, improved, best, i, l = step(d, state)
        if not bool(improved):
            converged = True
            break
        swaps.append((int(i), int(l)))
        gains.append(float(best))
        state = new_state
    result = solver.SolveResult(state.medoid_idx, jnp.int32(len(swaps)),
                                jnp.mean(state.d1), jnp.bool_(converged))
    return Trajectory(tuple(swaps), tuple(gains), result)


@functools.lru_cache(maxsize=64)
def _jit_matrix_free_step(metric: str, debias: bool, eps: float,
                          backend: str, chunk_size):
    return jax.jit(functools.partial(
        solver._matrix_free_step, metric=metric, debias=debias, eps=eps,
        backend=backend, chunk_size=chunk_size))


def trace_matrix_free(x, batch_idx, weights, init_idx, *,
                      metric: str = "l1", debias: bool = False,
                      max_swaps: int = 500, eps: float = 0.0,
                      backend: str = "auto",
                      chunk_size: int | None = None) -> Trajectory:
    """Replay ``solve_matrix_free`` recording every accepted (i, l, gain).

    Matches :func:`solver.solve_matrix_free` exactly for the same reason
    :func:`trace_batched` matches ``solve_batched``: each step *is* the
    solver's loop body (``_matrix_free_step``), same floats throughout.
    """
    x = jnp.asarray(x)
    batch_idx = jnp.asarray(batch_idx).astype(jnp.int32)
    xp = solver._prepared(x, metric)
    b = xp[batch_idx]
    w = jnp.asarray(weights).astype(jnp.float32)
    state = solver._init_state_matrix_free(
        xp, b, w, batch_idx, jnp.asarray(init_idx), metric=metric,
        debias=debias, backend=backend)
    step = _jit_matrix_free_step(metric, debias, eps, backend, chunk_size)
    swaps: list[tuple[int, int]] = []
    gains: list[float] = []
    converged = False
    while len(swaps) < max_swaps:
        new_state, improved, best, i, l = step(xp, b, w, batch_idx, state)
        if not bool(improved):
            converged = True
            break
        swaps.append((int(i), int(l)))
        gains.append(float(best))
        state = new_state
    result = solver.SolveResult(state.medoid_idx, jnp.int32(len(swaps)),
                                jnp.mean(state.d1), jnp.bool_(converged))
    return Trajectory(tuple(swaps), tuple(gains), result)


@functools.lru_cache(maxsize=64)
def _jit_pruned_step(metric: str, debias: bool, eps: float, backend: str,
                     chunk_size, prune_m: int, survivor_frac: float,
                     bound_scale: float):
    from repro.core import pruned
    return jax.jit(functools.partial(
        pruned._pruned_step, metric=metric, debias=debias, eps=eps,
        backend=backend, chunk_size=chunk_size, prune_m=prune_m,
        survivor_frac=survivor_frac, bound_scale=bound_scale))


def trace_pruned(x, batch_idx, weights, init_idx, *,
                 metric: str = "l1", debias: bool = False,
                 max_swaps: int = 500, eps: float = 0.0,
                 backend: str = "auto", chunk_size: int | None = None,
                 prune_m: int | None = None, survivor_frac: float = 0.5,
                 bound_scale: float = 1.0) -> Trajectory:
    """Replay ``solve_pruned`` recording every accepted (i, l, gain).

    Matches :func:`pruned.solve_pruned` exactly — each step *is* the
    solver's loop body (``_pruned_step``), with the (ub, lb) bound
    caches threaded through the host loop from the same ``+/-BIG``
    initialisation the ``while_loop`` uses, so every sweep sees the same
    survivor sets and the same selection floats.
    """
    from repro.core import pruned
    x = jnp.asarray(x)
    batch_idx = jnp.asarray(batch_idx).astype(jnp.int32)
    if prune_m is None:
        prune_m = pruned.default_prune_m(batch_idx.shape[0])
    xp = solver._prepared(x, metric)
    b = xp[batch_idx]
    w = jnp.asarray(weights).astype(jnp.float32)
    state = solver._init_state_matrix_free(
        xp, b, w, batch_idx, jnp.asarray(init_idx), metric=metric,
        debias=debias, backend=backend)
    n = x.shape[0]
    k = jnp.asarray(init_idx).shape[0]
    ub = jnp.full((n, k), pruned.BIG)
    lb = jnp.full((n, k), -pruned.BIG)
    step = _jit_pruned_step(metric, debias, eps, backend, chunk_size,
                            prune_m, survivor_frac, bound_scale)
    swaps: list[tuple[int, int]] = []
    gains: list[float] = []
    converged = False
    while len(swaps) < max_swaps:
        new_state, ub_n, lb_n, improved, best, i, l, _ = step(
            xp, b, w, batch_idx, state, ub, lb)
        if not bool(improved):
            converged = True
            break
        swaps.append((int(i), int(l)))
        gains.append(float(best))
        state, ub, lb = new_state, ub_n, lb_n
    result = solver.SolveResult(state.medoid_idx, jnp.int32(len(swaps)),
                                jnp.mean(state.d1), jnp.bool_(converged))
    return Trajectory(tuple(swaps), tuple(gains), result)


def trace_eager(d, init_idx, *, max_passes: int = 8,
                eps: float = 0.0) -> Trajectory:
    """Replay ``solve_eager`` recording every first-improvement swap.

    Each pass is :func:`solver._eager_pass` — the identical candidate
    scan ``solve_eager`` runs — with the per-candidate (do_swap, slot)
    lanes read back and compacted into the swap sequence.
    """
    d = jnp.asarray(d)
    state = solver._init_state(d, jnp.asarray(init_idx))
    scan = _jit_eager_pass(eps)
    swaps: list[tuple[int, int]] = []
    converged = False
    for _ in range(max_passes):
        state, swapped, flags, slots = scan(d, state)
        flags = np.asarray(flags)
        slots = np.asarray(slots)
        for i in np.nonzero(flags)[0]:
            swaps.append((int(i), int(slots[i])))
        if not bool(swapped):
            converged = True
            break
    result = solver.SolveResult(state.medoid_idx, state.t,
                                jnp.mean(state.d1), jnp.bool_(converged))
    return Trajectory(tuple(swaps), (), result)
