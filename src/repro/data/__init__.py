from .tokens import TokenIterator, TokenStore, build_synthetic  # noqa: F401
from .embeddings import embedding_stream, gaussian_mixture, heavy_tail  # noqa: F401
