"""Synthetic embedding streams for the OneBatchPAM pipelines and the
paper-reproduction benchmarks (the container is offline: no MNIST/UCI).

``gaussian_mixture`` mimics the clustered geometry of embedding spaces;
``heavy_tail`` adds the imbalanced far-out points the paper's
"Overfitting for highly imbalanced datasets" section discusses.
"""
from __future__ import annotations

import numpy as np


def gaussian_mixture(n: int, p: int, centers: int = 20, spread: float = 0.25,
                     seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(centers, p)).astype(np.float32) * 3.0
    weights = rng.dirichlet(np.ones(centers) * 2.0)
    assign = rng.choice(centers, size=n, p=weights)
    x = c[assign] + rng.normal(size=(n, p)).astype(np.float32) * spread
    return x.astype(np.float32)


def heavy_tail(n: int, p: int, seed: int = 0, outlier_frac: float = 0.01
               ) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = gaussian_mixture(n, p, seed=seed)
    n_out = max(int(n * outlier_frac), 1)
    idx = rng.choice(n, size=n_out, replace=False)
    x[idx] = rng.normal(size=(n_out, p)).astype(np.float32) * 25.0
    return x


def embedding_stream(total: int, chunk: int, p: int, seed: int = 0):
    """Yields (chunk, p) blocks — the shape of a curation pipeline input."""
    done = 0
    while done < total:
        size = min(chunk, total - done)
        yield gaussian_mixture(size, p, seed=seed + done)
        done += size
