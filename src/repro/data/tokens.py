"""Token data pipeline: memory-mapped store + deterministic, host-sharded,
checkpoint-resumable iterator.

At fleet scale the invariants that matter are:
  * determinism: batch content is a pure function of (seed, step, shard) —
    any host can be replaced and replays identical data;
  * resumability: iterator state is one integer (step), checkpointed in
    the "extra" blob;
  * host sharding: each host reads only its 1/num_shards of the batch.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np


class TokenStore:
    """Memory-mapped flat token array (.bin, uint16 or uint32)."""

    def __init__(self, path: str, vocab_size: int):
        self.path = path
        self.vocab_size = vocab_size
        dtype = np.uint16 if vocab_size <= 65_535 else np.uint32
        self.tokens = np.memmap(path, dtype=dtype, mode="r")

    def __len__(self):
        return len(self.tokens)


def build_synthetic(path: str, n_tokens: int, vocab_size: int,
                    seed: int = 0) -> TokenStore:
    """Zipf-distributed synthetic corpus with local structure (runs of
    repeated n-grams) so small models have something to learn."""
    rng = np.random.default_rng(seed)
    dtype = np.uint16 if vocab_size <= 65_535 else np.uint32
    base = rng.zipf(1.3, size=n_tokens).astype(np.int64) % vocab_size
    # inject learnable bigram structure: token follows (prev * 31) % vocab
    follow = (np.roll(base, 1) * 31 + 7) % vocab_size
    mask = rng.random(n_tokens) < 0.5
    toks = np.where(mask, follow, base).astype(dtype)
    with open(path, "wb") as f:
        toks.tofile(f)
    return TokenStore(path, vocab_size)


@dataclasses.dataclass
class TokenIterator:
    store: TokenStore
    batch_size: int           # per-host batch
    seq_len: int
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    step: int = 0             # the resumable state

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> "TokenIterator":
        self.step = int(state["step"])
        self.seed = int(state["seed"])
        return self

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        n = len(self.store) - self.seq_len - 1
        rng = np.random.default_rng(
            (self.seed, self.step, self.shard_id))
        offsets = rng.integers(0, n, size=self.batch_size)
        toks = np.stack([np.asarray(self.store.tokens[o:o + self.seq_len])
                         for o in offsets]).astype(np.int32)
        self.step += 1
        return {"tokens": toks}
