"""Pallas TPU kernels for OneBatchPAM's compute hot spots.

Layout (per repo convention):
  pairwise.py / swap_gain.py — pl.pallas_call kernels with explicit
      BlockSpec VMEM tiling (TPU target; interpret=True on CPU).
  metrics.py — the metric registry: name -> (ref oracle, Pallas kernel,
      tiles, prepare/post transforms, cross-shard reduce). DESIGN.md §3.
  ops.py — jit'd, padding, backend-dispatching public wrappers.
  ref.py — pure-jnp oracles (ground truth for tests).
"""
from . import metrics  # noqa: F401
from .metrics import MetricSpec  # noqa: F401
from .ops import pairwise_distance, pairwise_raw, swap_gain  # noqa: F401
from .ref import LARGE  # noqa: F401
