"""Pallas TPU kernels for OneBatchPAM's compute hot spots.

Layout (per repo convention):
  pairwise.py / swap_gain.py / fused_sweep.py / assign.py —
      pl.pallas_call kernels with explicit BlockSpec VMEM tiling (TPU
      target; interpret=True on CPU). fused_sweep composes pairwise tile
      math with the swap_gain selection so the (n, m) block never exists
      (DESIGN.md §2b); assign is its query-side sibling for the serving
      path (DESIGN.md §9).
  metrics.py — the metric registry: name -> (ref oracle, Pallas kernel,
      tiles + in-kernel tile math, prepare/post transforms, cross-shard
      reduce). DESIGN.md §3.
  ops.py — jit'd, padding, backend-dispatching public wrappers.
  ref.py — pure-jnp oracles (ground truth for tests).
"""
from . import metrics  # noqa: F401
from .metrics import MetricSpec  # noqa: F401
from .ops import (  # noqa: F401
    assign,
    fused_swap_select,
    pairwise_distance,
    pairwise_raw,
    swap_gain,
)
from .ref import LARGE  # noqa: F401
