"""Pallas TPU kernels for OneBatchPAM's compute hot spots.

Layout (per repo convention):
  pairwise.py / swap_gain.py — pl.pallas_call kernels with explicit
      BlockSpec VMEM tiling (TPU target; interpret=True on CPU).
  ops.py — jit'd, padding, backend-dispatching public wrappers.
  ref.py — pure-jnp oracles (ground truth for tests).
"""
from .ops import pairwise_distance, swap_gain  # noqa: F401
from .ref import LARGE  # noqa: F401
