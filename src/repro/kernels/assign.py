"""Batched nearest-medoid top-1 Pallas kernel — the serving hot path
(DESIGN.md §9).

Assignment is the query-side mirror of the solve-side fused sweep: for a
query tile X (TN, p) and the medoid rows B (k, p), compute the distance
tile in VMEM via the metric registry's in-kernel tile math
(``MetricSpec.tile`` — the exact p-chunk accumulation order of the
standalone pairwise kernels, DESIGN.md §2b) and reduce each row to its
top-1 ``(label, d1)``. The (n, k) distance block never reaches HBM: per
query row only 8 bytes (one i32 label + one f32 distance) are written,
so the sweep reads O(n·p + k·p) and writes O(n) — the memory profile a
high-QPS assignment engine needs.

Residency: B uses a constant-index BlockSpec, so the medoid rows are
DMA'd from HBM once per call and stay VMEM-resident across the whole
query grid (k·p floats — tiny in the k-medoids regime). k is swept in
AS_TK-column tiles with a running (min, label) pair accumulated in the
output refs, so arbitrary k works; the strictly-less update keeps the
global tie-break at the lowest medoid index, exactly ``jnp.argmin``.

``block_dtype`` (e.g. ``"bfloat16"``) rounds each distance tile to the
narrow dtype *before* the min/label reduction — the serving analog of
the PR 2 stored-block convention (tiles narrow, accumulation f32): the
reduction then sees exactly the values a bf16 block would have held, so
the kernel stays bitwise ``streaming.stream_assign(block_dtype=...)``.
The returned d1 is the f32 upcast (exact) of that rounded minimum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import metrics

AS_TN = 128   # query rows per grid step
AS_TK = 128   # medoid columns per k-tile (lane-aligned)

# Finite +inf stand-in for masked/padded medoid columns and the running
# minimum's init, as a python float: jnp constants cannot be closed over
# by a Pallas kernel body. Far above any finite distance, so padded
# columns never win the min.
_BIG = 1e30


def _assign_kernel(x_ref, b_ref, d_ref, l_ref, *, k_true, metric,
                   block_dtype):
    """One (TN, TK) grid step: distance tile from the query row tile and
    a slice of the VMEM-resident B -> per-row running (min, label).

    The output refs ignore the k grid index, so the same (TN, 1) tiles
    are revisited across the k sweep and accumulated in place: init at
    k-step 0 with +BIG, then a strictly-less merge per step. Labels
    ascend with the k sweep, and within a tile the first minimal column
    wins (min over an index where-mask), so the composition equals the
    global lowest-index argmin — ``jnp.argmin``'s tie-break, which the
    differential suite pins against ``stream_assign`` ties included.
    """
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        d_ref[...] = jnp.full_like(d_ref, _BIG)
        l_ref[...] = jnp.zeros_like(l_ref)

    spec = metrics.get(metric)
    cols = pl.ds(jk * AS_TK, AS_TK)
    x = x_ref[...].astype(jnp.float32)                   # (TN, P)
    bt = b_ref[cols, :].astype(jnp.float32)              # (TK, P) slice
    d = spec.finalize(spec.tile(x, bt))                  # (TN, TK) distances
    if block_dtype is not None:
        # Round to the narrow tile dtype, compare in f32 (the upcast is
        # exact, so min/equality on the upcasts == min on the narrow
        # values) — see the module docstring.
        d = d.astype(block_dtype).astype(jnp.float32)
    col = jk * AS_TK + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(col < k_true, d, _BIG)
    tmin = jnp.min(d, axis=1, keepdims=True)             # (TN, 1)
    tlab = jnp.min(jnp.where(d == tmin, col, jnp.int32(2**30)),
                   axis=1, keepdims=True)                # first minimal col
    better = tmin < d_ref[...]
    l_ref[...] = jnp.where(better, tlab, l_ref[...])
    d_ref[...] = jnp.where(better, tmin, d_ref[...])


@functools.partial(jax.jit, static_argnames=("k_true", "metric",
                                             "block_dtype", "interpret"))
def assign_top1(
    x: jnp.ndarray,            # (n, p) query rows (prepared, padded)
    b: jnp.ndarray,            # (k_pad, p) medoid rows (prepared, padded)
    *,
    k_true: int,
    metric: str = "l1",
    block_dtype: str | None = None,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Nearest-medoid labels + distances: ``(labels, d1)`` of shapes
    (n, 1) i32 / (n, 1) f32, lowest-index tie-break.

    n must be an AS_TN multiple, k padded to AS_TK, p to the metric
    tile's ``p_mult`` (ops.assign pads and slices). Padded medoid rows
    are masked in-kernel (col >= k_true -> +BIG), padded query rows
    produce garbage rows the caller slices off, and padded p features
    are zeros — the same operand convention as the pairwise kernels, so
    the tile values are bit-for-bit the stored block's.
    """
    n, p = x.shape
    kp = b.shape[0]
    spec = metrics.get(metric)
    if spec.tile is None:  # pragma: no cover — ops.assign guards first
        raise ValueError(f"metric {metric!r} has no in-kernel tile math")
    if p % spec.tile.p_mult:
        raise ValueError(
            f"p={p} must be padded to a {spec.tile.p_mult} multiple")
    grid = (n // AS_TN, kp // AS_TK)
    d1, labels = pl.pallas_call(
        functools.partial(_assign_kernel, k_true=k_true, metric=metric,
                          block_dtype=block_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((AS_TN, p), lambda i, jk: (i, 0)),
            # Constant index map: one DMA per call, then VMEM-resident
            # across the whole query grid (the serving engine's medoid
            # buffer is k·p floats — small by construction).
            pl.BlockSpec((kp, p), lambda i, jk: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((AS_TN, 1), lambda i, jk: (i, 0)),
            pl.BlockSpec((AS_TN, 1), lambda i, jk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, b)
    return labels, d1
