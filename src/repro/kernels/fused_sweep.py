"""Matrix-free fused sweep: distance tiles fused into swap selection
(DESIGN.md §2b) — the (n, m) block never exists.

``swap_select`` (swap_gain.py) removed the (n, k) gain matrix from the
sweep; its remaining HBM cost is the O(nm) block read, which also caps
resident memory at O(nm). But the block is pure *derived* state: every
(TN, TM) tile of it is a metric computation over an (TN, p) X row tile
and a (TM, p) batch tile — O((TN + TM)·p) bytes of inputs for a
TN·TM·p-FLOP tile. With m ≈ 100·log(kn) typically far above p, the
blocked sweep is memory-bound while the MXU idles; recomputing the tile
on-chip turns the sweep compute-bound and drops per-sweep HBM traffic
from O(nm) to O(np + mp).

``fused_sweep`` composes, per (TN, TM) grid step, entirely in VMEM:

  1. the metric registry's tile math (``MetricSpec.tile`` — the exact
     p-chunk accumulation order of the standalone pairwise kernels, so
     the on-the-fly distances are bit-for-bit the stored block's),
  2. the registry ``post`` transform (finalize),
  3. the debias owner mask (column j owned by this global row -> LARGE,
     pre-weight, mirroring ``build_batch``'s diagonal set),
  4. the per-column batch-weight multiply (the weight-application
     invariant: finalize first, weights after — §2b),
  5. the swap-gain accumulation into the same (TN, K) VMEM scratch
     ``swap_select`` uses (``swap_gain._accumulate_gain``), and
  6. at the last m step, the shared on-chip argmax reduction
     (``swap_gain._select_reduce``).

Only the O(n/TN) ``(best_gain, best_flat)`` partials ever reach HBM.
Inputs X/B may be f32 or bf16 (tiles upcast on load; accumulation is
always f32). k is padded to a 128 lane multiple and kept whole per tile;
m is swept by the grid; p is resident per tile (padded to the metric's
TP multiple), which targets the paper's regime p ≲ 2k features.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import metrics
from .swap_gain import SG_TM, SG_TN, _NEG, _accumulate_gain, _select_reduce

# Finite debias sentinel, as a python float: jnp constants cannot be
# closed over by a Pallas kernel body (== float(ref.LARGE)).
_LARGE = 1e15


def _fused_sweep_kernel(x_ref, b_ref, w_ref, d1_ref, d2_ref, nh_ref,
                        own_ref, mask_ref, g_ref, f_ref, acc_ref, *,
                        k_true, m_steps, metric):
    """One (TN, TM) grid step: distance tile from the X row tile and a
    slice of the VMEM-resident B -> weighted gain accumulation -> (at
    the last m step) on-chip argmax partial.

    B and the m-vectors (w/d1/d2/owner/one-hot) use constant-index
    BlockSpecs, so they are DMA'd from HBM ONCE per sweep and stay
    resident in VMEM across the whole grid — the jk-th tile is an
    in-VMEM slice here, not a per-step re-fetch. That residency is what
    makes the per-sweep HBM traffic truly O(np + mp): with per-jk tiled
    specs the B re-fetch per n-row-tile revisit would be O(n·m·p/TN) —
    back to an O(nm) sweep. The premise m·(p + k) ≪ VMEM is the paper's
    own (m ≈ 100·log kn with small p, k); fused_sweep checks the bound.
    """
    i = pl.program_id(0)
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    spec = metrics.get(metric)
    cols = pl.ds(jk * SG_TM, SG_TM)
    x = x_ref[...].astype(jnp.float32)                   # (TN, P)
    bt = b_ref[cols, :].astype(jnp.float32)              # (TM, P) slice
    d = spec.finalize(spec.tile(x, bt))                  # (TN, TM) distances
    # Debias owner mask: batch column j whose source row is this global
    # row gets d = LARGE *before* the weight multiply, exactly where
    # build_batch sets the diagonal. own = -1 disables (never matches).
    rows = i * SG_TN + jax.lax.broadcasted_iota(
        jnp.int32, (SG_TN, SG_TM), 0)
    d = jnp.where(own_ref[:, cols] == rows, _LARGE, d)
    d = d * w_ref[:, cols].astype(jnp.float32)           # (1, TM) weights

    d1 = d1_ref[:, cols].astype(jnp.float32)             # (1, TM)
    d2 = d2_ref[:, cols].astype(jnp.float32)             # (1, TM)
    nh = nh_ref[cols, :].astype(jnp.float32)             # (TM, K)
    _accumulate_gain(d, d1, d2, nh, acc_ref)

    @pl.when(jk == m_steps - 1)
    def _reduce():
        _select_reduce(acc_ref, mask_ref, g_ref, f_ref, k_true=k_true)


def _rowmax_reduce(acc_ref, off_ref, g_ref, l_ref, *, k_true):
    """Per-row reduction of the accumulated (TN, K) gain tile: each row's
    maximum gain and the first slot attaining it (jnp.argmax(axis=1)
    tie-break — the per-row half of ``_select_reduce``). ``off_ref`` is a
    (1, K) per-slot additive offset folded in before the reduce (0 for
    exact sweeps; the pruned sweep's phase-1 interval endpoints
    otherwise). No row mask: the pruned sweep caches *unmasked* row
    maxima so bounds stay sound when a row leaves the medoid set."""
    tn, kp = acc_ref.shape
    gain = acc_ref[...] + off_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, (tn, kp), 1)
    gain = jnp.where(col < k_true, gain, _NEG)
    rmax = jnp.max(gain, axis=1, keepdims=True)            # (TN, 1)
    l_row = jnp.min(jnp.where(gain == rmax, col, kp),
                    axis=1, keepdims=True)                 # (TN, 1)
    g_ref[...] = rmax
    l_ref[...] = l_row


def _fused_sweep_rowmax_kernel(x_ref, b_ref, w_ref, d1_ref, d2_ref, nh_ref,
                               own_ref, off_ref, g_ref, l_ref, acc_ref, *,
                               k_true, m_steps, metric):
    """The fused-sweep grid step with a per-row reduction instead of the
    per-tile argmax: same VMEM-resident B / m-vectors, same
    ``_accumulate_gain``, but the output is the full (n, 1) row-max gain
    and slot vectors — what the pruned sweep (core/pruned.py) caches and
    bounds per candidate."""
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(0)
    spec = metrics.get(metric)
    cols = pl.ds(jk * SG_TM, SG_TM)
    x = x_ref[...].astype(jnp.float32)                   # (TN, P)
    bt = b_ref[cols, :].astype(jnp.float32)              # (TM, P) slice
    d = spec.finalize(spec.tile(x, bt))                  # (TN, TM) distances
    rows = i * SG_TN + jax.lax.broadcasted_iota(
        jnp.int32, (SG_TN, SG_TM), 0)
    d = jnp.where(own_ref[:, cols] == rows, _LARGE, d)
    d = d * w_ref[:, cols].astype(jnp.float32)           # (1, TM) weights

    d1 = d1_ref[:, cols].astype(jnp.float32)             # (1, TM)
    d2 = d2_ref[:, cols].astype(jnp.float32)             # (1, TM)
    nh = nh_ref[cols, :].astype(jnp.float32)             # (TM, K)
    _accumulate_gain(d, d1, d2, nh, acc_ref)

    @pl.when(jk == m_steps - 1)
    def _reduce():
        _rowmax_reduce(acc_ref, off_ref, g_ref, l_ref, k_true=k_true)


@functools.partial(jax.jit, static_argnames=("k_true", "metric", "interpret"))
def fused_sweep_rowmax(
    x: jnp.ndarray,            # (n, p) candidate rows (prepared, padded)
    b: jnp.ndarray,            # (m, p) batch rows (prepared, padded)
    w: jnp.ndarray,            # (m,) f32 batch weights (0 on padded cols)
    d1: jnp.ndarray,           # (m,)
    d2: jnp.ndarray,           # (m,)
    near_onehot: jnp.ndarray,  # (m, k_pad)
    owner: jnp.ndarray,        # (m,) i32 global row owning column j, -1 = none
    offset: jnp.ndarray,       # (k_pad,) f32 per-slot additive offset
    *,
    k_true: int,
    metric: str = "l1",
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Matrix-free per-row swap-gain maxima.

    Same fused dataflow as :func:`fused_sweep` (VMEM-resident B and
    m-vectors, one DMA per sweep, O(np + mp) HBM traffic), but instead
    of collapsing each row tile to one argmax partial it writes, per
    row, ``max_l (G(i, l) + offset_l)`` and the first slot attaining it
    — shapes (n, 1) f32 / (n, 1) i32. The per-slot ``offset`` lets the
    pruned sweep turn one kernel into both interval endpoints of its
    phase-1 bounds (DESIGN.md §2c); exact callers pass zeros (x + 0.0
    is the identity, so the maxima are bit-for-bit the offset-free
    gains). No row masking — see ``_rowmax_reduce``.
    """
    n, p = x.shape
    m = b.shape[0]
    kp = near_onehot.shape[1]
    spec = metrics.get(metric)
    if spec.tile is None:  # pragma: no cover — ops guards before calling
        raise ValueError(f"metric {metric!r} has no in-kernel tile math")
    if p % spec.tile.p_mult:
        raise ValueError(
            f"p={p} must be padded to a {spec.tile.p_mult} multiple")
    resident = (m * p + m * kp) * 4 + 4 * m * 4
    if resident > 8 * 2**20:
        raise ValueError(
            f"matrix-free needs B (m x p) + one-hot (m x k) resident in "
            f"VMEM; m={m}, p={p}, k_pad={kp} needs {resident / 2**20:.1f} "
            "MiB > 8 MiB — shrink m (the paper regime is m ~ 100 log kn) "
            "or fall back to the block path")
    grid = (n // SG_TN, m // SG_TM)
    return pl.pallas_call(
        functools.partial(_fused_sweep_rowmax_kernel, k_true=k_true,
                          m_steps=grid[1], metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((SG_TN, p), lambda i, jk: (i, 0)),
            # Constant index maps: one DMA per sweep, then VMEM-resident.
            pl.BlockSpec((m, p), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, m), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, m), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, m), lambda i, jk: (0, 0)),
            pl.BlockSpec((m, kp), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, m), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, kp), lambda i, jk: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SG_TN, 1), lambda i, jk: (i, 0)),
            pl.BlockSpec((SG_TN, 1), lambda i, jk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((SG_TN, kp), jnp.float32)],
        interpret=interpret,
    )(x, b, w.reshape(1, m), d1.reshape(1, m), d2.reshape(1, m),
      near_onehot, owner.reshape(1, m).astype(jnp.int32),
      offset.reshape(1, kp).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("k_true", "metric", "interpret"))
def fused_sweep(
    x: jnp.ndarray,            # (n, p) candidate rows (prepared, padded)
    b: jnp.ndarray,            # (m, p) batch rows (prepared, padded)
    w: jnp.ndarray,            # (m,) f32 batch weights (0 on padded cols)
    d1: jnp.ndarray,           # (m,)
    d2: jnp.ndarray,           # (m,)
    near_onehot: jnp.ndarray,  # (m, k_pad)
    owner: jnp.ndarray,        # (m,) i32 global row owning column j, -1 = none
    row_mask: jnp.ndarray,     # (n,) f32, 0 = row excluded (medoid / padding)
    *,
    k_true: int,
    metric: str = "l1",
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Matrix-free per-row-tile swap-selection partials.

    Same contract as ``swap_gain.swap_select`` — ``(best_gain,
    best_flat)`` of shape (n // SG_TN, 1) each, first-flat-index
    tie-break — but fed X and B instead of the (n, m) block. n, m must
    be (SG_TN, SG_TM)-aligned, p a multiple of the metric tile's
    ``p_mult``, and the one-hot width a 128 multiple; ops.py pads and
    tree-reduces.
    """
    n, p = x.shape
    m = b.shape[0]
    kp = near_onehot.shape[1]
    spec = metrics.get(metric)
    if spec.tile is None:  # pragma: no cover — ops.fused_swap_select guards
        raise ValueError(f"metric {metric!r} has no in-kernel tile math")
    if p % spec.tile.p_mult:
        raise ValueError(
            f"p={p} must be padded to a {spec.tile.p_mult} multiple")
    # B + one-hot + m-vectors stay fully VMEM-resident across the grid
    # (see the kernel docstring); bound their footprint well under the
    # ~16 MB/core budget, leaving room for the X tile, the broadcast
    # slab, and the (TN, kp) scratch (DESIGN.md §2b / §7).
    resident = (m * p + m * kp) * 4 + 4 * m * 4
    if resident > 8 * 2**20:
        raise ValueError(
            f"matrix-free needs B (m x p) + one-hot (m x k) resident in "
            f"VMEM; m={m}, p={p}, k_pad={kp} needs {resident / 2**20:.1f} "
            "MiB > 8 MiB — shrink m (the paper regime is m ~ 100 log kn) "
            "or fall back to the block path")
    grid = (n // SG_TN, m // SG_TM)
    return pl.pallas_call(
        functools.partial(_fused_sweep_kernel, k_true=k_true,
                          m_steps=grid[1], metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((SG_TN, p), lambda i, jk: (i, 0)),
            # Constant index maps: one DMA per sweep, then VMEM-resident.
            pl.BlockSpec((m, p), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, m), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, m), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, m), lambda i, jk: (0, 0)),
            pl.BlockSpec((m, kp), lambda i, jk: (0, 0)),
            pl.BlockSpec((1, m), lambda i, jk: (0, 0)),
            # (n, 1) column layout, as in swap_select: a (TN, 1) tile
            # reads directly without a lane->sublane relayout.
            pl.BlockSpec((SG_TN, 1), lambda i, jk: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, jk: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, jk: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n // SG_TN, 1), jnp.float32),
            jax.ShapeDtypeStruct((n // SG_TN, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((SG_TN, kp), jnp.float32)],
        interpret=interpret,
    )(x, b, w.reshape(1, m), d1.reshape(1, m), d2.reshape(1, m),
      near_onehot, owner.reshape(1, m).astype(jnp.int32),
      row_mask.reshape(n, 1))
