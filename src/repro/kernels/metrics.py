"""Metric registry: the single place a dissimilarity is defined (DESIGN.md §3).

Every metric bundles everything the rest of the pipeline needs to stay
metric-agnostic:

  * ``ref``     — pure-jnp oracle (ground truth, CPU fallback),
  * ``kernel``  — Pallas kernel over tile-padded inputs,
  * ``tiles``   — (TN, TM, TP) padding multiples for that kernel,
  * ``prepare`` — optional row-space transform applied to both operands
                  before either backend (e.g. L2 row-normalisation turns
                  the dot kernel into cosine similarity),
  * ``post``    — monotone transform from the kernel's raw accumulator to
                  the distance (e.g. sqrt for l2, ``1 - s`` for cosine),
  * ``reduce``  — how raw partials from feature (p-axis) shards combine
                  across a model mesh axis: "sum" (psum), "max" (pmax), or
                  None when the metric cannot be feature-sharded (cosine:
                  ``prepare`` needs full rows). See DESIGN.md §5.
  * ``tile``    — in-kernel tile math for the matrix-free fused sweep
                  (DESIGN.md §2b): ``tile(x_tile, b_tile) -> raw`` usable
                  inside a Pallas kernel body, with p padded to a
                  ``tiles[2]`` multiple. Replays the standalone kernel's
                  p-chunk accumulation order exactly, so an on-the-fly
                  distance tile is bit-for-bit the stored block's.

``ops.pairwise_distance`` dispatches through this table, so adding a metric
is one ``register()`` call — no solver, sampling, streaming, or distributed
code changes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from . import pairwise, ref


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One registered dissimilarity; see the module docstring for fields."""
    name: str
    ref: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    kernel: Callable[..., jnp.ndarray]
    tiles: tuple[int, int, int]
    prepare: Callable[[jnp.ndarray], jnp.ndarray] | None = None
    post: Callable[[jnp.ndarray], jnp.ndarray] | None = None
    reduce: str | None = "sum"
    tile: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray] | None = None

    def finalize(self, raw: jnp.ndarray) -> jnp.ndarray:
        """Raw kernel accumulator -> distance (identity when post is None)."""
        return self.post(raw) if self.post is not None else raw


_REGISTRY: dict[str, MetricSpec] = {}


def register(spec: MetricSpec) -> MetricSpec:
    """Add a metric to the registry (last registration wins)."""
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> MetricSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown metric {name!r}; registered: {names()}") from None


def names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def _normalize_rows(a: jnp.ndarray) -> jnp.ndarray:
    a = a.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(a * a, axis=-1, keepdims=True))
    return a / jnp.maximum(norm, 1e-12)


_L1_TILES = (pairwise.L1_TN, pairwise.L1_TM, pairwise.L1_TP)
_L2_TILES = (pairwise.L2_TN, pairwise.L2_TM, pairwise.L2_TP)

register(MetricSpec(
    name="l1",
    ref=ref.pairwise_l1_auto,
    kernel=pairwise.l1_distance,
    tiles=_L1_TILES,
    tile=pairwise.l1_tile,
))

register(MetricSpec(
    name="sqeuclidean",
    ref=lambda x, b: ref.pairwise_l2(x, b, squared=True),
    kernel=pairwise.l2_distance,
    tiles=_L2_TILES,
    post=lambda raw: jnp.maximum(raw, 0.0),
    tile=pairwise.l2_tile,
))

register(MetricSpec(
    name="l2",
    ref=lambda x, b: ref.pairwise_l2(x, b, squared=True),
    kernel=pairwise.l2_distance,
    tiles=_L2_TILES,
    post=lambda raw: jnp.sqrt(jnp.maximum(raw, 0.0)),
    tile=pairwise.l2_tile,
))

register(MetricSpec(
    name="cosine",
    ref=ref.pairwise_dot,
    kernel=pairwise.dot_product,
    tiles=_L2_TILES,
    prepare=_normalize_rows,
    post=lambda raw: jnp.maximum(1.0 - raw, 0.0),
    reduce=None,
    tile=pairwise.dot_tile,
))

register(MetricSpec(
    name="chebyshev",
    ref=ref.pairwise_chebyshev_auto,
    kernel=pairwise.chebyshev_distance,
    tiles=_L1_TILES,
    reduce="max",
    tile=pairwise.chebyshev_tile,
))
