"""Public, shape-agnostic entry points for the Pallas kernels.

Dispatch policy (``backend=`` argument, default "auto"):
  * "pallas"    — compiled Pallas kernel (TPU target).
  * "interpret" — Pallas kernel body executed in interpret mode (CPU
                  correctness path; used by the test suite).
  * "ref"       — pure-jnp oracle from ref.py.
  * "auto"      — pallas on TPU, ref elsewhere (interpret mode is far too
                  slow for real CPU workloads).

Distances dispatch through the metric registry (metrics.py, DESIGN.md §3):
each metric's prepare / kernel / tile / post pipeline lives there, so this
module stays metric-agnostic. These wrappers pad inputs to the kernels'
tile multiples and slice the result back, so callers never see alignment
constraints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import (assign as assign_mod, fused_sweep as fused_sweep_mod, metrics,
               swap_gain as swap_gain_mod)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


def _pad_to(a: jnp.ndarray, axis: int, mult: int, value: float = 0.0) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def pairwise_raw(
    x: jnp.ndarray,
    b: jnp.ndarray,
    *,
    metric: str = "l1",
    backend: str = "auto",
    skip_prepare: bool = False,
) -> jnp.ndarray:
    """Raw (pre-``post``) metric accumulator between x (n, p) and b (m, p).

    This is the distributed building block: raw partials from feature
    shards combine with the metric's ``reduce`` collective (psum / pmax)
    before ``MetricSpec.finalize`` produces actual distances (DESIGN.md §5).
    Single-host callers want :func:`pairwise_distance` instead.

    ``skip_prepare`` is for loop callers (streaming.py) that have already
    applied the metric's row transform once, outside their chunk loop —
    re-preparing the loop-invariant b operand every iteration would
    otherwise redo m*p work per chunk.
    """
    backend = _resolve(backend)
    spec = metrics.get(metric)
    if spec.prepare is not None and not skip_prepare:
        x = spec.prepare(x)
        b = spec.prepare(b)
    if backend == "ref":
        return spec.ref(x, b)
    interpret = backend == "interpret"
    n, m = x.shape[0], b.shape[0]
    tn, tm, tp = spec.tiles
    xp = _pad_to(_pad_to(x, 0, tn), 1, tp)
    bp = _pad_to(_pad_to(b, 0, tm), 1, tp)
    return spec.kernel(xp, bp, interpret=interpret)[:n, :m]


def pairwise_distance(
    x: jnp.ndarray,
    b: jnp.ndarray,
    *,
    metric: str = "l1",
    backend: str = "auto",
) -> jnp.ndarray:
    """Distance block between rows of x (n, p) and b (m, p) -> (n, m) f32."""
    spec = metrics.get(metric)
    return spec.finalize(pairwise_raw(x, b, metric=metric, backend=backend))


def assign(
    x: jnp.ndarray,            # (n, p) query rows
    b: jnp.ndarray,            # (k, p) medoid rows
    *,
    metric: str = "l1",
    backend: str = "auto",
    block_dtype: str | jnp.dtype | None = None,
    skip_prepare: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Nearest-medoid top-1: ``(labels, d1)`` of shapes (n,) i32 / (n,)
    f32 — for every query row, the lowest-index nearest medoid and its
    distance. The serving hot path (DESIGN.md §9).

    On the kernel path (kernels/assign.py) the (n, k) distance block
    never reaches HBM: the medoid rows stay VMEM-resident across the
    whole query grid and each (TN, TK) tile is recomputed from the
    metric registry's tile math and reduced on-chip to a running
    (min, label) pair — O(n·p + k·p) read, O(n) written. Labels and d1
    are bitwise ``ref.assign`` / ``streaming.stream_assign`` on the same
    backend, ties included (tests/test_assign.py pins it across
    metrics × dtypes × backends).

    ``block_dtype`` rounds each distance tile to the narrow dtype before
    the min (f32 accumulation preserved, DESIGN.md §2). ``skip_prepare``
    is for loop callers (the serving engine) that applied the metric's
    row transform once, outside the per-batch jit.
    """
    from . import ref

    backend = _resolve(backend)
    spec = metrics.get(metric)
    if spec.prepare is not None and not skip_prepare:
        x = spec.prepare(x)
        b = spec.prepare(b)
    if block_dtype is not None:
        block_dtype = jnp.dtype(block_dtype).name   # hashable static arg
    if backend == "ref":
        return ref.assign(x, b, metric=metric, block_dtype=block_dtype)

    interpret = backend == "interpret"
    if spec.tile is None:
        raise ValueError(
            f"metric {metric!r} has no in-kernel tile math; register a "
            "MetricSpec.tile to use the assign kernel path, or run "
            "with backend='ref'")
    n = x.shape[0]
    k = b.shape[0]
    tn, tk = assign_mod.AS_TN, assign_mod.AS_TK
    tp = spec.tile.p_mult
    xp = _pad_to(_pad_to(x, 0, tn), 1, tp)
    bp = _pad_to(_pad_to(b, 0, tk), 1, tp)
    # Padded medoid rows are masked in-kernel (col >= k_true -> +BIG, so
    # a row of zeros can never win the min); padded query rows produce
    # garbage outputs sliced off here; padded p features are zeros on
    # both operands (distance contribution 0 for every registered tile).
    labels, d1 = assign_mod.assign_top1(
        xp, bp, k_true=k, metric=metric, block_dtype=block_dtype,
        interpret=interpret)
    return labels[:n, 0], d1[:n, 0]


def swap_gain(
    d: jnp.ndarray,
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    near_onehot: jnp.ndarray,
    *,
    backend: str = "auto",
) -> jnp.ndarray:
    """Swap-gain matrix (n, k); see swap_gain.py / ref.swap_gain."""
    from . import ref

    backend = _resolve(backend)
    if backend == "ref":
        return ref.swap_gain(d, d1, d2, near_onehot)

    interpret = backend == "interpret"
    n, m = d.shape
    k = near_onehot.shape[1]
    tn, tm = swap_gain_mod.SG_TN, swap_gain_mod.SG_TM
    dp = _pad_to(_pad_to(d, 0, tn), 1, tm)
    # Padded batch columns have d1 = d2 = 0 and D = 0 => relu term 0 and
    # r = 0, so they contribute nothing; padded k columns are sliced off.
    d1p = _pad_to(d1, 0, tm)
    d2p = _pad_to(d2, 0, tm)
    nhp = _pad_to(_pad_to(near_onehot, 0, tm), 1, 128)
    out = swap_gain_mod.swap_gain(dp, d1p, d2p, nhp, interpret=interpret)
    return out[:n, :k]


def swap_select(
    d: jnp.ndarray,
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    near_onehot: jnp.ndarray,
    *,
    row_mask: jnp.ndarray | None = None,
    backend: str = "auto",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused swap selection: ``(best_gain, i, l)`` without the (n, k) matrix.

    Semantically ``argmax(swap_gain(...))`` with rows where ``row_mask``
    is 0 excluded (first-flat-index tie-break, exactly ``jnp.argmax``).
    On the kernel path the (n, k) gain matrix never reaches HBM: the
    Pallas kernel reduces each (SG_TN, k) tile on-chip to a
    ``(best_gain, best_flat)`` partial and only the O(n/SG_TN) partials
    are written, then tree-reduced here (``jnp.argmax`` over the tile
    maxima keeps the first-tile tie-break, so the composition equals the
    global first-flat-index argmax). ``d`` may be bf16 (DESIGN.md §2);
    accumulation is always f32.

    vmap-safe on every backend: the multi-restart engine
    (core/restarts.py, DESIGN.md §2a) maps the whole fused sweep over a
    leading restart axis — the ref oracle batches as plain jnp, the
    Pallas kernel through ``pallas_call``'s batching rule (one extra
    grid dimension) — and each lane's selection stays bit-for-bit the
    unbatched call's (tests/test_restarts.py pins it on ref and
    interpret).
    """
    from . import ref

    backend = _resolve(backend)
    if backend == "ref":
        return ref.swap_select(d, d1, d2, near_onehot, row_mask)

    interpret = backend == "interpret"
    n, m = d.shape
    k = near_onehot.shape[1]
    tn, tm = swap_gain_mod.SG_TN, swap_gain_mod.SG_TM
    if row_mask is None:
        row_mask = jnp.ones((n,), jnp.float32)
    dp = _pad_to(_pad_to(d, 0, tn), 1, tm)
    d1p = _pad_to(d1, 0, tm)
    d2p = _pad_to(d2, 0, tm)
    nhp = _pad_to(_pad_to(near_onehot, 0, tm), 1, 128)
    # Padded rows get mask 0 => NEG inside the kernel, so they never win;
    # padded k columns are masked by the kernel's col < k_true check.
    maskp = _pad_to(row_mask.astype(jnp.float32), 0, tn)
    gains, flats = swap_gain_mod.swap_select(dp, d1p, d2p, nhp, maskp,
                                             k_true=k, interpret=interpret)
    return _reduce_partials(gains, flats, tn, k)


def fused_swap_select_rowmax(
    x: jnp.ndarray,            # (n, p) candidate rows (f32 or bf16)
    b: jnp.ndarray,            # (m, p) batch rows
    weights: jnp.ndarray,      # (m,) f32 batch weights
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    near_onehot: jnp.ndarray,
    *,
    metric: str = "l1",
    owner: jnp.ndarray | None = None,
    offset: jnp.ndarray | None = None,
    backend: str = "auto",
    skip_prepare: bool = False,
    row_chunk: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Matrix-free per-row swap-gain maxima: ``(row_gain, row_slot)`` of
    shapes (n,) f32 / (n,) i32 — for every candidate row,
    ``max_l (G(i, l) + offset_l)`` and the first slot attaining it.

    The per-row sibling of :func:`fused_swap_select`, built on the same
    fused dataflow (kernels/fused_sweep.py): the (n, m) weighted block
    never exists, and per-row gains go through the identical float chain
    as the block path, so a host-side ``jnp.argmax`` over ``row_gain``
    (first-row tie-break) recovers exactly the fused selection. The
    per-slot ``offset`` (k,) f32, default zeros, is how the pruned sweep
    (core/pruned.py) evaluates both confidence-interval endpoints of its
    subsample bounds with this one primitive; it is added before the
    row reduce and does not perturb exact callers (x + 0 is exact).

    No row masking, by design: the pruned sweep caches *unmasked* row
    maxima so its bounds survive rows entering/leaving the medoid set;
    callers mask at selection time. vmap-safe on every backend, like
    :func:`fused_swap_select`.
    """
    from . import ref

    backend = _resolve(backend)
    spec = metrics.get(metric)
    if spec.prepare is not None and not skip_prepare:
        x = spec.prepare(x)
        b = spec.prepare(b)
    n, p = x.shape
    m = b.shape[0]
    k = near_onehot.shape[1]
    if owner is None:
        owner = jnp.full((m,), -1, jnp.int32)
    if offset is None:
        offset = jnp.zeros((k,), jnp.float32)

    if backend == "ref":
        if row_chunk is None or row_chunk >= n:
            return ref.fused_swap_select_rowmax(
                x, b, weights, d1, d2, near_onehot, owner, offset,
                metric=metric)
        # Stream in row chunks — row-local math, identical floats per row
        # (same floor-of-8 rationale as fused_swap_select).
        row_chunk = max(row_chunk, 8)
        pad = (-n) % row_chunk
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        c = (n + pad) // row_chunk
        offs = jnp.arange(c, dtype=jnp.int32) * row_chunk

        def chunk(args):
            xi, off = args
            return ref.fused_swap_select_rowmax(
                xi, b, weights, d1, d2, near_onehot, owner, offset,
                metric=metric, row_offset=off)
        gains, slots = jax.lax.map(chunk, (xp.reshape(c, row_chunk, p), offs))
        return gains.reshape(-1)[:n], slots.reshape(-1)[:n]

    interpret = backend == "interpret"
    if spec.tile is None:
        raise ValueError(
            f"metric {metric!r} has no in-kernel tile math; register a "
            "MetricSpec.tile to use the matrix-free kernel path, or run "
            "with backend='ref'")
    tn, tm = swap_gain_mod.SG_TN, swap_gain_mod.SG_TM
    tp = spec.tile.p_mult
    xp = _pad_to(_pad_to(x, 0, tn), 1, tp)
    bp = _pad_to(_pad_to(b, 0, tm), 1, tp)
    # Same padding contract as fused_swap_select; padded k columns are
    # masked in-kernel (col < k_true), so the padded offset slots are
    # inert; padded rows produce garbage maxima that are sliced off.
    wp = _pad_to(weights.astype(jnp.float32), 0, tm)
    d1p = _pad_to(d1, 0, tm)
    d2p = _pad_to(d2, 0, tm)
    nhp = _pad_to(_pad_to(near_onehot, 0, tm), 1, 128)
    ownp = _pad_to(owner.astype(jnp.int32), 0, tm, value=-1)
    offp = _pad_to(offset.astype(jnp.float32), 0, 128)
    gains, slots = fused_sweep_mod.fused_sweep_rowmax(
        xp, bp, wp, d1p, d2p, nhp, ownp, offp, k_true=k, metric=metric,
        interpret=interpret)
    return gains[:n, 0], slots[:n, 0]


def _reduce_partials(gains, flats, tn, k):
    """Tree-reduce per-row-tile (best_gain, best_flat) partials to the
    global ``(best, i, l)``: ``jnp.argmax`` over the tile maxima keeps
    the first-tile tie-break, so the composition equals the global
    first-flat-index argmax. Shared by swap_select and the matrix-free
    fused sweep (identical partial contract)."""
    t = jnp.argmax(gains[:, 0])          # first maximal tile = minimal i
    flat = flats[t, 0]
    return (gains[t, 0], (t * tn + flat // k).astype(jnp.int32),
            (flat % k).astype(jnp.int32))


def fused_swap_select(
    x: jnp.ndarray,            # (n, p) candidate rows (f32 or bf16)
    b: jnp.ndarray,            # (m, p) batch rows
    weights: jnp.ndarray,      # (m,) f32 batch weights
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    near_onehot: jnp.ndarray,
    *,
    metric: str = "l1",
    row_mask: jnp.ndarray | None = None,
    owner: jnp.ndarray | None = None,
    backend: str = "auto",
    skip_prepare: bool = False,
    row_chunk: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Matrix-free fused swap selection: ``(best_gain, i, l)`` from X and
    B directly — the (n, m) weighted block never exists (DESIGN.md §2b).

    Semantically ``swap_select(weighted_block(x, b), ...)`` with the
    block's exact float chain (metric tile math -> ``finalize`` -> debias
    ``owner`` set -> per-column weight multiply) evaluated on the fly:
    the Pallas kernel (kernels/fused_sweep.py) recomputes each (TN, TM)
    distance tile in VMEM from an O((TN+TM)·p) read instead of an
    O(TN·TM) block read, and only the O(n/TN) selection partials reach
    HBM. Selection is bit-for-bit ``ops.swap_select`` on the materialised
    block of the same backend (tests/test_matrix_free.py pins it, ties
    included).

    ``owner`` (global row index per batch column, -1 = none) applies the
    debias variant's LARGE diagonal in-flight. ``skip_prepare`` is for
    loop callers (solver.solve_matrix_free) that applied the metric's
    row transform once outside the swap loop. ``row_chunk`` bounds the
    *ref* backend's evaluation to O(row_chunk · m) memory by streaming
    row chunks through the oracle (row-local math — identical floats);
    the Pallas/interpret paths are already tiled and ignore it.

    vmap-safe on every backend, like :func:`swap_select`: the restart
    engine maps it over a leading lane axis with X unbatched.
    """
    from . import ref

    backend = _resolve(backend)
    spec = metrics.get(metric)
    if spec.prepare is not None and not skip_prepare:
        x = spec.prepare(x)
        b = spec.prepare(b)
    n, p = x.shape
    m = b.shape[0]
    k = near_onehot.shape[1]
    if row_mask is None:
        row_mask = jnp.ones((n,), jnp.float32)
    if owner is None:
        owner = jnp.full((m,), -1, jnp.int32)

    if backend == "ref":
        if row_chunk is None or row_chunk >= n:
            return ref.fused_swap_select(x, b, weights, d1, d2, near_onehot,
                                         row_mask, owner, metric=metric)
        # Stream the oracle in row chunks: every gain is row-local, so the
        # chunked evaluation computes identical floats per row, and the
        # chunk-major tree reduce equals the global first-flat argmax.
        # Floor of 8 rows: XLA strength-reduces a degenerate (1, m) @
        # (m, k) matmul into a context-blocked reduce, which would void
        # the oracle's fixed-accumulation-order guarantee (ref.swap_gain).
        row_chunk = max(row_chunk, 8)
        pad = (-n) % row_chunk
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        maskp = jnp.pad(row_mask.astype(jnp.float32), (0, pad))
        c = (n + pad) // row_chunk
        offs = jnp.arange(c, dtype=jnp.int32) * row_chunk

        def chunk(args):
            xi, mi, off = args
            g, i, l = ref.fused_swap_select(xi, b, weights, d1, d2,
                                            near_onehot, mi, owner,
                                            metric=metric, row_offset=off)
            return g, i * k + l
        gains, flats = jax.lax.map(
            chunk, (xp.reshape(c, row_chunk, p),
                    maskp.reshape(c, row_chunk), offs))
        return _reduce_partials(gains[:, None], flats[:, None], row_chunk, k)

    interpret = backend == "interpret"
    if spec.tile is None:
        raise ValueError(
            f"metric {metric!r} has no in-kernel tile math; register a "
            "MetricSpec.tile to use the matrix-free kernel path, or run "
            "with backend='ref'")
    tn, tm = swap_gain_mod.SG_TN, swap_gain_mod.SG_TM
    tp = spec.tile.p_mult
    xp = _pad_to(_pad_to(x, 0, tn), 1, tp)
    bp = _pad_to(_pad_to(b, 0, tm), 1, tp)
    # Padded batch columns carry weight 0 => weighted distance 0, with
    # d1 = d2 = 0 their relu and removal terms vanish; padded rows get
    # mask 0 => NEG at the reduce; padded owners (-1) never match a row.
    wp = _pad_to(weights.astype(jnp.float32), 0, tm)
    d1p = _pad_to(d1, 0, tm)
    d2p = _pad_to(d2, 0, tm)
    nhp = _pad_to(_pad_to(near_onehot, 0, tm), 1, 128)
    ownp = _pad_to(owner.astype(jnp.int32), 0, tm, value=-1)
    maskp = _pad_to(row_mask.astype(jnp.float32), 0, tn)
    gains, flats = fused_sweep_mod.fused_sweep(
        xp, bp, wp, d1p, d2p, nhp, ownp, maskp, k_true=k, metric=metric,
        interpret=interpret)
    return _reduce_partials(gains, flats, tn, k)
