"""Pallas TPU kernels for the n x m pairwise-dissimilarity block.

This is OneBatchPAM's dominant compute: O(n * m * p) FLOPs producing the
(n, m) block that the whole local search then re-reads. Four kernels, all
registered in metrics.py (DESIGN.md §3):

  * ``l1_distance`` — the paper's metric. |x - b| has no matmul form, so it
    is a VPU kernel: blocked abs-diff-accumulate with an (TN, TM) f32
    accumulator resident in VMEM across the p-grid.
  * ``l2_distance`` — MXU formulation: ||x||^2 + ||b||^2 - 2 x b^T with the
    cross term as a (TN, TP) @ (TP, TM) dot per grid step.
  * ``chebyshev_distance`` — the L_inf norm: same blocked VPU sweep as l1
    but the p-grid accumulation is max instead of sum.
  * ``dot_product`` — plain blocked x b^T on the MXU; with row-normalised
    inputs (the cosine ``prepare`` in metrics.py) this is cosine similarity,
    and the registry's post-transform turns it into cosine distance.

Tiling: grid = (n/TN, m/TM, p/TP). The output BlockSpec ignores the p index,
so the same VMEM tile is revisited across the p sweep and accumulated
in-place (initialised at p-step 0). Tile sizes keep the MXU/VPU shapes
128-aligned and the working set << 16 MB VMEM:

  l1/chebyshev: X tile (128, 512) + B tile (128, 512) + out (128, 128) +
      the (128, 128, 8) broadcast slab ~ 1.5 MB.
  l2/dot: X (256, 256) + B^T view (256, 256) + out (256, 256) f32 ~ 1 MB.

Inputs of any f32/bf16 dtype; accumulation always f32. Callers must pad
shapes to tile multiples (ops.py does this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# L1 tiles: p is blocked twice — TP per grid step, and an inner unrolled
# TP_INNER loop keeping the (TN, TM, TP_INNER) broadcast slab small.
L1_TN, L1_TM, L1_TP, L1_TP_INNER = 128, 128, 512, 8
L2_TN, L2_TM, L2_TP = 256, 256, 256


def _l1_kernel(x_ref, b_ref, o_ref):
    """One (TN, TM) output tile; accumulates |x - b| sums over the p grid."""
    pk = pl.program_id(2)

    @pl.when(pk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (TN, TP)
    b = b_ref[...].astype(jnp.float32)          # (TM, TP)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    # Unrolled inner loop over TP in TP_INNER chunks: bounds the broadcast
    # intermediate to (TN, TM, TP_INNER) f32 (= 512 KB) in VREG/VMEM.
    for s in range(L1_TP // L1_TP_INNER):
        xs = x[:, s * L1_TP_INNER:(s + 1) * L1_TP_INNER]
        bs = b[:, s * L1_TP_INNER:(s + 1) * L1_TP_INNER]
        acc += jnp.abs(xs[:, None, :] - bs[None, :, :]).sum(-1)
    o_ref[...] += acc


def _l2_kernel(x_ref, b_ref, o_ref):
    """One (TN, TM) tile of ||x||^2 + ||b||^2 - 2 x.b^T, p-accumulated."""
    pk = pl.program_id(2)

    @pl.when(pk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (TN, TP)
    b = b_ref[...].astype(jnp.float32)          # (TM, TP)
    # Partial sums over this p chunk all add linearly across the grid.
    xsq = jnp.sum(x * x, axis=1)                # (TN,)
    bsq = jnp.sum(b * b, axis=1)                # (TM,)
    cross = jax.lax.dot_general(
        x, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (TN, TM) on the MXU
    o_ref[...] += xsq[:, None] + bsq[None, :] - 2.0 * cross


@functools.partial(jax.jit, static_argnames=("interpret",))
def l1_distance(x: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Blocked L1 distance matrix. x (n, p), b (m, p) -> (n, m) f32.

    Shapes must be multiples of (L1_TN, L1_TM, L1_TP); see ops.pairwise for
    the padded public entry point.
    """
    n, p = x.shape
    m, _ = b.shape
    grid = (n // L1_TN, m // L1_TM, p // L1_TP)
    return pl.pallas_call(
        _l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L1_TN, L1_TP), lambda i, j, pk: (i, pk)),
            pl.BlockSpec((L1_TM, L1_TP), lambda i, j, pk: (j, pk)),
        ],
        out_specs=pl.BlockSpec((L1_TN, L1_TM), lambda i, j, pk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, b)


def _chebyshev_kernel(x_ref, b_ref, o_ref):
    """One (TN, TM) tile of max_p |x - b|, max-accumulated over the p grid.

    |x - b| >= 0, so a zero init (and zero p-padding upstream) is absorbed
    by the running max.
    """
    pk = pl.program_id(2)

    @pl.when(pk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (TN, TP)
    b = b_ref[...].astype(jnp.float32)          # (TM, TP)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for s in range(L1_TP // L1_TP_INNER):
        xs = x[:, s * L1_TP_INNER:(s + 1) * L1_TP_INNER]
        bs = b[:, s * L1_TP_INNER:(s + 1) * L1_TP_INNER]
        acc = jnp.maximum(acc, jnp.abs(xs[:, None, :] - bs[None, :, :]).max(-1))
    o_ref[...] = jnp.maximum(o_ref[...], acc)


def _dot_kernel(x_ref, b_ref, o_ref):
    """One (TN, TM) tile of x.b^T, p-accumulated on the MXU."""
    pk = pl.program_id(2)

    @pl.when(pk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (TN, TP)
    b = b_ref[...].astype(jnp.float32)          # (TM, TP)
    o_ref[...] += jax.lax.dot_general(
        x, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def l2_distance(x: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Blocked squared-L2 distance matrix. x (n, p), b (m, p) -> (n, m) f32."""
    n, p = x.shape
    m, _ = b.shape
    grid = (n // L2_TN, m // L2_TM, p // L2_TP)
    out = pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L2_TN, L2_TP), lambda i, j, pk: (i, pk)),
            pl.BlockSpec((L2_TM, L2_TP), lambda i, j, pk: (j, pk)),
        ],
        out_specs=pl.BlockSpec((L2_TN, L2_TM), lambda i, j, pk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, b)
    return jnp.maximum(out, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def chebyshev_distance(x: jnp.ndarray, b: jnp.ndarray, *,
                       interpret: bool = False) -> jnp.ndarray:
    """Blocked L_inf distance matrix. x (n, p), b (m, p) -> (n, m) f32."""
    n, p = x.shape
    m, _ = b.shape
    grid = (n // L1_TN, m // L1_TM, p // L1_TP)
    return pl.pallas_call(
        _chebyshev_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L1_TN, L1_TP), lambda i, j, pk: (i, pk)),
            pl.BlockSpec((L1_TM, L1_TP), lambda i, j, pk: (j, pk)),
        ],
        out_specs=pl.BlockSpec((L1_TN, L1_TM), lambda i, j, pk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dot_product(x: jnp.ndarray, b: jnp.ndarray, *,
                interpret: bool = False) -> jnp.ndarray:
    """Blocked row dot products. x (n, p), b (m, p) -> (n, m) f32 x.b^T."""
    n, p = x.shape
    m, _ = b.shape
    grid = (n // L2_TN, m // L2_TM, p // L2_TP)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L2_TN, L2_TP), lambda i, j, pk: (i, pk)),
            pl.BlockSpec((L2_TM, L2_TP), lambda i, j, pk: (j, pk)),
        ],
        out_specs=pl.BlockSpec((L2_TN, L2_TM), lambda i, j, pk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, b)
