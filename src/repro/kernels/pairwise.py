"""Pallas TPU kernels for the n x m pairwise-dissimilarity block.

This is OneBatchPAM's dominant compute: O(n * m * p) FLOPs producing the
(n, m) block that the whole local search then re-reads. Four kernels, all
registered in metrics.py (DESIGN.md §3):

  * ``l1_distance`` — the paper's metric. |x - b| has no matmul form, so it
    is a VPU kernel: blocked abs-diff-accumulate with an (TN, TM) f32
    accumulator resident in VMEM across the p-grid.
  * ``l2_distance`` — MXU formulation: ||x||^2 + ||b||^2 - 2 x b^T with the
    cross term as a (TN, TP) @ (TP, TM) dot per grid step.
  * ``chebyshev_distance`` — the L_inf norm: same blocked VPU sweep as l1
    but the p-grid accumulation is max instead of sum.
  * ``dot_product`` — plain blocked x b^T on the MXU; with row-normalised
    inputs (the cosine ``prepare`` in metrics.py) this is cosine similarity,
    and the registry's post-transform turns it into cosine distance.

Tiling: grid = (n/TN, m/TM, p/TP). The output BlockSpec ignores the p index,
so the same VMEM tile is revisited across the p sweep and accumulated
in-place (initialised at p-step 0). Tile sizes keep the MXU/VPU shapes
128-aligned and the working set << 16 MB VMEM:

  l1/chebyshev: X tile (128, 512) + B tile (128, 512) + out (128, 128) +
      the (128, 128, 8) broadcast slab ~ 1.5 MB.
  l2/dot: X (256, 256) + B^T view (256, 256) + out (256, 256) f32 ~ 1 MB.

Inputs of any f32/bf16 dtype; accumulation always f32. Callers must pad
shapes to tile multiples (ops.py does this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# L1 tiles: p is blocked twice — TP per grid step, and an inner unrolled
# TP_INNER loop keeping the (TN, TM, TP_INNER) broadcast slab small.
L1_TN, L1_TM, L1_TP, L1_TP_INNER = 128, 128, 512, 8
L2_TN, L2_TM, L2_TP = 256, 256, 256


# --- Tile math (shared with the matrix-free fused sweep, DESIGN.md §2b) ---
#
# The per-(TP)-chunk accumulation of each metric, factored out of the
# kernel bodies so kernels/fused_sweep.py composes the *identical* float
# sequence in-kernel: a distance tile computed on the fly must be
# bit-for-bit the one the standalone pairwise kernels would have stored.
# All chunk fns take f32 (TN, TP_chunk) x / (TM, TP_chunk) b tiles and
# return the (TN, TM) partial for that chunk.

def _l1_chunk(x, b):
    """Sum_p |x - b| over one TP chunk, TP_INNER-blocked: bounds the
    broadcast intermediate to (TN, TM, TP_INNER) f32 in VREG/VMEM."""
    acc = jnp.zeros((x.shape[0], b.shape[0]), jnp.float32)
    for s in range(x.shape[1] // L1_TP_INNER):
        xs = x[:, s * L1_TP_INNER:(s + 1) * L1_TP_INNER]
        bs = b[:, s * L1_TP_INNER:(s + 1) * L1_TP_INNER]
        acc += jnp.abs(xs[:, None, :] - bs[None, :, :]).sum(-1)
    return acc


def _cheb_chunk(x, b):
    """Max_p |x - b| over one TP chunk, TP_INNER-blocked like _l1_chunk."""
    acc = jnp.zeros((x.shape[0], b.shape[0]), jnp.float32)
    for s in range(x.shape[1] // L1_TP_INNER):
        xs = x[:, s * L1_TP_INNER:(s + 1) * L1_TP_INNER]
        bs = b[:, s * L1_TP_INNER:(s + 1) * L1_TP_INNER]
        acc = jnp.maximum(acc, jnp.abs(xs[:, None, :] - bs[None, :, :]).max(-1))
    return acc


def _l2_chunk(x, b):
    """||x||^2 + ||b||^2 - 2 x.b^T partial over one TP chunk (MXU)."""
    xsq = jnp.sum(x * x, axis=1)                # (TN,)
    bsq = jnp.sum(b * b, axis=1)                # (TM,)
    cross = jax.lax.dot_general(
        x, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (TN, TM) on the MXU
    return xsq[:, None] + bsq[None, :] - 2.0 * cross


def _dot_chunk(x, b):
    """x.b^T partial over one TP chunk (MXU)."""
    return jax.lax.dot_general(
        x, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _p_folded(chunk_fn, fold, tp, p_mult):
    """Full-p tile fn: loop TP-boundary chunks in grid order, folding
    partials the way the corresponding kernel's o_ref accumulation does
    (add / max), each chunk keeping its own local sub-accumulation — the
    exact association of the standalone kernel's p-grid sweep, so the
    floats match chunk for chunk.

    Callers pad p to a ``p_mult`` multiple (exposed as ``tile.p_mult``):
    the full tp for the MXU metrics (the contraction length must match
    the standalone kernel's for identical dot/sum reductions), but only
    TP_INNER for the broadcast metrics — their accumulation is a
    *sequential* chain of 8-wide partials, and dropping the standalone
    kernel's zero-padding chunks only removes exact ``+0.0`` /
    ``max(., 0)`` identity steps (both operands are >= 0), so the
    cheaper padding is still bit-for-bit."""
    def tile(x, b):
        p = x.shape[1]
        assert p % p_mult == 0, f"p={p} must be a {p_mult} multiple"
        acc = chunk_fn(x[:, :tp], b[:, :tp])
        for c in range(tp, p, tp):
            acc = fold(acc, chunk_fn(x[:, c:c + tp], b[:, c:c + tp]))
        return acc
    tile.p_mult = p_mult
    return tile


l1_tile = _p_folded(_l1_chunk, jnp.add, L1_TP, L1_TP_INNER)
chebyshev_tile = _p_folded(_cheb_chunk, jnp.maximum, L1_TP, L1_TP_INNER)
dot_tile = _p_folded(_dot_chunk, jnp.add, L2_TP, L2_TP)
_l2_tile_raw = _p_folded(_l2_chunk, jnp.add, L2_TP, L2_TP)


def l2_tile(x, b):
    """Full-p squared-L2 tile, including the wrapper-level clamp of
    :func:`l2_distance` (max with 0 is idempotent under the registry's
    post-transforms, so applying it here keeps the chains identical)."""
    return jnp.maximum(_l2_tile_raw(x, b), 0.0)


l2_tile.p_mult = L2_TP


def _l1_kernel(x_ref, b_ref, o_ref):
    """One (TN, TM) output tile; accumulates |x - b| sums over the p grid."""
    pk = pl.program_id(2)

    @pl.when(pk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (TN, TP)
    b = b_ref[...].astype(jnp.float32)          # (TM, TP)
    o_ref[...] += _l1_chunk(x, b)


def _l2_kernel(x_ref, b_ref, o_ref):
    """One (TN, TM) tile of ||x||^2 + ||b||^2 - 2 x.b^T, p-accumulated."""
    pk = pl.program_id(2)

    @pl.when(pk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (TN, TP)
    b = b_ref[...].astype(jnp.float32)          # (TM, TP)
    # Partial sums over this p chunk all add linearly across the grid.
    o_ref[...] += _l2_chunk(x, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def l1_distance(x: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Blocked L1 distance matrix. x (n, p), b (m, p) -> (n, m) f32.

    Shapes must be multiples of (L1_TN, L1_TM, L1_TP); see ops.pairwise for
    the padded public entry point.
    """
    n, p = x.shape
    m, _ = b.shape
    grid = (n // L1_TN, m // L1_TM, p // L1_TP)
    return pl.pallas_call(
        _l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L1_TN, L1_TP), lambda i, j, pk: (i, pk)),
            pl.BlockSpec((L1_TM, L1_TP), lambda i, j, pk: (j, pk)),
        ],
        out_specs=pl.BlockSpec((L1_TN, L1_TM), lambda i, j, pk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, b)


def _chebyshev_kernel(x_ref, b_ref, o_ref):
    """One (TN, TM) tile of max_p |x - b|, max-accumulated over the p grid.

    |x - b| >= 0, so a zero init (and zero p-padding upstream) is absorbed
    by the running max.
    """
    pk = pl.program_id(2)

    @pl.when(pk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (TN, TP)
    b = b_ref[...].astype(jnp.float32)          # (TM, TP)
    o_ref[...] = jnp.maximum(o_ref[...], _cheb_chunk(x, b))


def _dot_kernel(x_ref, b_ref, o_ref):
    """One (TN, TM) tile of x.b^T, p-accumulated on the MXU."""
    pk = pl.program_id(2)

    @pl.when(pk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (TN, TP)
    b = b_ref[...].astype(jnp.float32)          # (TM, TP)
    o_ref[...] += _dot_chunk(x, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def l2_distance(x: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Blocked squared-L2 distance matrix. x (n, p), b (m, p) -> (n, m) f32."""
    n, p = x.shape
    m, _ = b.shape
    grid = (n // L2_TN, m // L2_TM, p // L2_TP)
    out = pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L2_TN, L2_TP), lambda i, j, pk: (i, pk)),
            pl.BlockSpec((L2_TM, L2_TP), lambda i, j, pk: (j, pk)),
        ],
        out_specs=pl.BlockSpec((L2_TN, L2_TM), lambda i, j, pk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, b)
    return jnp.maximum(out, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def chebyshev_distance(x: jnp.ndarray, b: jnp.ndarray, *,
                       interpret: bool = False) -> jnp.ndarray:
    """Blocked L_inf distance matrix. x (n, p), b (m, p) -> (n, m) f32."""
    n, p = x.shape
    m, _ = b.shape
    grid = (n // L1_TN, m // L1_TM, p // L1_TP)
    return pl.pallas_call(
        _chebyshev_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L1_TN, L1_TP), lambda i, j, pk: (i, pk)),
            pl.BlockSpec((L1_TM, L1_TP), lambda i, j, pk: (j, pk)),
        ],
        out_specs=pl.BlockSpec((L1_TN, L1_TM), lambda i, j, pk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dot_product(x: jnp.ndarray, b: jnp.ndarray, *,
                interpret: bool = False) -> jnp.ndarray:
    """Blocked row dot products. x (n, p), b (m, p) -> (n, m) f32 x.b^T."""
    n, p = x.shape
    m, _ = b.shape
    grid = (n // L2_TN, m // L2_TM, p // L2_TP)
    return pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L2_TN, L2_TP), lambda i, j, pk: (i, pk)),
            pl.BlockSpec((L2_TM, L2_TP), lambda i, j, pk: (j, pk)),
        ],
        out_specs=pl.BlockSpec((L2_TN, L2_TM), lambda i, j, pk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, b)
