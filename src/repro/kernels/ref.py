"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations used by:
  * tests/ (assert_allclose against the Pallas kernels in interpret mode),
  * ops.py as the CPU fallback path for small problems.

All math here mirrors the paper exactly:
  * pairwise_l1 / pairwise_l2 / pairwise_chebyshev / pairwise_dot: the
    n x m dissimilarity block of OneBatchPAM (Algorithm 1, line 4), one
    oracle per registered metric (DESIGN.md §3).
  * swap_gain: the vectorised form of Algorithm 2 lines 6-18 (see
    DESIGN.md §2 for the derivation).
  * swap_select: the fused selection contract — argmax over swap_gain with
    row masking — that the on-chip Pallas reduction must match exactly.

The ``*_auto`` variants switch to the lax.scan-tiled implementation when
the naive (n, m, p) broadcast would exceed ~1 GiB of intermediate memory —
the pure-jnp mirror of the Pallas tiling (DESIGN.md §7).
"""
from __future__ import annotations

import jax.numpy as jnp

# Finite stand-in for the paper's ``d_jj = +inf`` debias trick: +inf would
# produce inf - inf = nan inside the gain computation.
LARGE = jnp.float32(1e15)

# Sentinel for masked swap candidates (current medoids, padded rows): far
# below any real gain, so masked entries can never win the argmax.
NEG = jnp.float32(-1e30)


def pairwise_l1(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """L1 distances between rows of x (n, p) and rows of b (m, p) -> (n, m)."""
    x = x.astype(jnp.float32)
    b = b.astype(jnp.float32)
    return jnp.abs(x[:, None, :] - b[None, :, :]).sum(-1)


def _pairwise_bcast_chunked(x: jnp.ndarray, b: jnp.ndarray, *,
                            combine: str, n_chunk: int = 4096,
                            p_chunk: int = 32) -> jnp.ndarray:
    """Memory-bounded broadcast metrics: the pure-jnp mirror of the Pallas
    kernel's (TN, TM, TP) tiling — lax.scan over row/feature tiles keeps
    the live broadcast at (n_chunk, m, p_chunk) instead of (n, m, p). Used
    for large blocks (distributed OBP, dry-run) where the naive broadcast
    would claim hundreds of GB. ``combine`` is how per-feature-tile
    partials fold together: "sum" (L1) or "max" (Chebyshev)."""
    import jax

    n, p = x.shape
    m = b.shape[0]
    n_chunk = min(n_chunk, n)
    while n % n_chunk:
        n_chunk -= 1
    p_chunk = min(p_chunk, p)
    while p % p_chunk:
        p_chunk -= 1
    xb = x.astype(jnp.float32).reshape(n // n_chunk, n_chunk,
                                       p // p_chunk, p_chunk)
    bb = b.astype(jnp.float32).reshape(m, p // p_chunk, p_chunk)
    fold = jnp.add if combine == "sum" else jnp.maximum

    def row_tile(_, xc):                       # xc: (n_chunk, P/pc, pc)
        def p_tile(acc, idx):
            xs = xc[:, idx]                    # (n_chunk, pc)
            bs = bb[:, idx]                    # (m, pc)
            diff = jnp.abs(xs[:, None, :] - bs[None, :, :])
            part = diff.sum(-1) if combine == "sum" else diff.max(-1)
            return fold(acc, part), None
        acc0 = jnp.zeros((n_chunk, m), jnp.float32)
        acc, _ = jax.lax.scan(p_tile, acc0, jnp.arange(p // p_chunk))
        return None, acc

    _, tiles = jax.lax.scan(row_tile, None, xb)
    return tiles.reshape(n, m)


def pairwise_l1_chunked(x: jnp.ndarray, b: jnp.ndarray, *,
                        n_chunk: int = 4096, p_chunk: int = 32) -> jnp.ndarray:
    """Memory-bounded L1; see _pairwise_bcast_chunked."""
    return _pairwise_bcast_chunked(x, b, combine="sum", n_chunk=n_chunk,
                                   p_chunk=p_chunk)


# Naive-broadcast intermediates above this many f32 elements (~1 GiB) take
# the scan-tiled path instead.
_BCAST_BUDGET = 1 << 28


def pairwise_l1_auto(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """L1 oracle with the big-block escape hatch (registry entry point)."""
    if x.shape[0] * b.shape[0] * x.shape[1] > _BCAST_BUDGET:
        return pairwise_l1_chunked(x, b)
    return pairwise_l1(x, b)


def pairwise_chebyshev(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """L_inf distances between rows of x (n, p) and b (m, p) -> (n, m)."""
    x = x.astype(jnp.float32)
    b = b.astype(jnp.float32)
    return jnp.abs(x[:, None, :] - b[None, :, :]).max(-1)


def pairwise_chebyshev_auto(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Chebyshev oracle with the big-block escape hatch."""
    if x.shape[0] * b.shape[0] * x.shape[1] > _BCAST_BUDGET:
        return _pairwise_bcast_chunked(x, b, combine="max")
    return pairwise_chebyshev(x, b)


def pairwise_dot(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Row dot products x.b^T (n, m). With row-normalised inputs this is
    cosine similarity; metrics.py's post-transform maps it to distance."""
    return x.astype(jnp.float32) @ b.astype(jnp.float32).T


def pairwise_l2(x: jnp.ndarray, b: jnp.ndarray, *, squared: bool = True) -> jnp.ndarray:
    """(Squared) L2 distances between rows of x (n, p) and b (m, p) -> (n, m)."""
    x = x.astype(jnp.float32)
    b = b.astype(jnp.float32)
    xsq = jnp.sum(x * x, axis=-1)
    bsq = jnp.sum(b * b, axis=-1)
    d = xsq[:, None] + bsq[None, :] - 2.0 * (x @ b.T)
    d = jnp.maximum(d, 0.0)
    return d if squared else jnp.sqrt(d)


def assign(
    x: jnp.ndarray,      # (n, p) query rows (already prepared)
    b: jnp.ndarray,      # (k, p) medoid rows (already prepared)
    *,
    metric: str = "l1",
    block_dtype: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Nearest-medoid top-1 oracle: ``(labels, d1)`` of shapes (n,) i32 /
    (n,) f32, ``jnp.argmin`` lowest-index tie-break.

    The identical float chain as one ``stream_assign`` chunk — metric
    ``ref`` on prepared rows, ``finalize``, optional round-trip through
    the narrow ``block_dtype`` (compare the rounded values, return the
    exact f32 upcast of the minimum) — so it is the ground truth the
    assign kernel (kernels/assign.py) must match bitwise per backend.
    Inputs must already carry the metric's ``prepare`` transform
    (ops.assign applies it once, outside any loop).
    """
    from . import metrics  # deferred: metrics.py imports this module

    spec = metrics.get(metric)
    d = spec.finalize(spec.ref(x, b))
    if block_dtype is not None:
        d = d.astype(block_dtype).astype(jnp.float32)
    return (jnp.argmin(d, axis=1).astype(jnp.int32),
            jnp.min(d, axis=1).astype(jnp.float32))


def swap_gain(
    d: jnp.ndarray,      # (n, m) weighted distances to the batch
    d1: jnp.ndarray,     # (m,)  distance of batch point j to its nearest medoid
    d2: jnp.ndarray,     # (m,)  ... to its second-nearest medoid
    near_onehot: jnp.ndarray,  # (m, k) one-hot of the nearest medoid slot
) -> jnp.ndarray:
    """Gain matrix G (n, k): batch-estimated objective reduction of the swap
    (add candidate i as a medoid, drop medoid slot l).

    G(i, l) = g_i + R(i, l) with
      g_i     = sum_j max(0, d1_j - d_ij)                    (add gain)
      r_ij    = d1_j - min(max(d_ij, d1_j), d2_j)            (removal corr.)
      R(i, l) = sum_{j: near(j) = l} r_ij = (r @ near_onehot)(i, l)

    Positive G = the swap reduces the estimated objective. Identical numbers
    to Algorithm 2 of the paper, evaluated for all (i, l) at once.

    Implementation notes, mirroring the kernels' codegen-stability rules
    (swap_gain._accumulate_gain, DESIGN.md §2b) so the oracle computes
    the same bits no matter the surrounding program (eager op-by-op, a
    jitted solver loop, or a row-chunked lax.map sweep): the add-gain
    term is the value-identical ``d1 - min(d, d1)`` (no mul+sub chain
    for the backend to contract into an FMA when d was just computed),
    and both m-contractions are matmuls with shape-fixed accumulation
    order, never ``jnp.sum`` (whose blocking follows the fusion context).
    """
    d = d.astype(jnp.float32)
    d1 = d1.astype(jnp.float32)[None, :]
    d2 = d2.astype(jnp.float32)[None, :]
    gterm = d1 - jnp.minimum(d, d1)                 # (n, m) == relu(d1 - d)
    g = gterm @ jnp.ones((d.shape[1], 1), jnp.float32)          # (n, 1)
    r = d1 - jnp.minimum(jnp.maximum(d, d1), d2)                # (n, m)
    big_r = r @ near_onehot.astype(jnp.float32)                 # (n, k)
    return g + big_r


def apply_debias(d: jnp.ndarray, owner: jnp.ndarray,
                 row_offset: int | jnp.ndarray = 0) -> jnp.ndarray:
    """Set d[owner_j - row_offset, j] = LARGE wherever that local row
    exists: the matrix-free mirror of ``build_batch``'s debias diagonal
    set (``d.at[idx, arange(m)].set(LARGE)``). ``owner`` holds global row
    indices (-1 = no owner); ``row_offset`` maps them into this block's
    local rows (row-chunked / sharded callers)."""
    n, m = d.shape
    local = owner - row_offset
    valid = (local >= 0) & (local < n)
    safe = jnp.clip(local, 0, n - 1)
    cols = jnp.arange(m)
    return d.at[safe, cols].set(jnp.where(valid, LARGE, d[safe, cols]))


def fused_swap_select(
    x: jnp.ndarray,            # (n, p) candidate rows (already prepared)
    b: jnp.ndarray,            # (m, p) batch rows (already prepared)
    w: jnp.ndarray,            # (m,) batch weights
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    near_onehot: jnp.ndarray,
    row_mask: jnp.ndarray | None = None,
    owner: jnp.ndarray | None = None,
    *,
    metric: str = "l1",
    row_offset: int | jnp.ndarray = 0,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Matrix-free swap-selection oracle (DESIGN.md §2b).

    Materialises the weighted distance block through the *identical*
    float chain the batch builder uses — metric ``ref`` on prepared rows,
    ``finalize``, debias owner set, weight multiply — then defers to
    :func:`swap_select`. Ground truth for ``ops.fused_swap_select``; the
    Pallas kernel (kernels/fused_sweep.py) must match it exactly, ties
    included. Inputs must already carry the metric's ``prepare``
    transform (ops.py applies it once, outside any loop).
    """
    from . import metrics  # deferred: metrics.py imports this module

    spec = metrics.get(metric)
    d = spec.finalize(spec.ref(x, b))
    if owner is not None:
        d = apply_debias(d, owner, row_offset)
    return swap_select(d * w[None, :].astype(jnp.float32),
                       d1, d2, near_onehot, row_mask)


def swap_gain_rowmax(
    d: jnp.ndarray,
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    near_onehot: jnp.ndarray,
    offset: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row swap-gain maxima: ``(max_l (G + offset_l), argmax_l)`` of
    shapes (n,) f32 / (n,) i32, first-slot tie-break (``jnp.argmax`` along
    axis 1). The rowmax contract the fused kernel's ``_rowmax_reduce``
    must match exactly; no row masking (the pruned sweep caches unmasked
    maxima — see core/pruned.py)."""
    gain = swap_gain(d, d1, d2, near_onehot)
    if offset is not None:
        gain = gain + offset[None, :].astype(jnp.float32)
    return (jnp.max(gain, axis=1),
            jnp.argmax(gain, axis=1).astype(jnp.int32))


def fused_swap_select_rowmax(
    x: jnp.ndarray,            # (n, p) candidate rows (already prepared)
    b: jnp.ndarray,            # (m, p) batch rows (already prepared)
    w: jnp.ndarray,            # (m,) batch weights
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    near_onehot: jnp.ndarray,
    owner: jnp.ndarray | None = None,
    offset: jnp.ndarray | None = None,
    *,
    metric: str = "l1",
    row_offset: int | jnp.ndarray = 0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Matrix-free per-row maxima oracle: the weighted block through the
    identical float chain as :func:`fused_swap_select`, reduced per row
    by :func:`swap_gain_rowmax`. Ground truth for
    ``ops.fused_swap_select_rowmax``."""
    from . import metrics  # deferred: metrics.py imports this module

    spec = metrics.get(metric)
    d = spec.finalize(spec.ref(x, b))
    if owner is not None:
        d = apply_debias(d, owner, row_offset)
    return swap_gain_rowmax(d * w[None, :].astype(jnp.float32),
                            d1, d2, near_onehot, offset)


def swap_select(
    d: jnp.ndarray,
    d1: jnp.ndarray,
    d2: jnp.ndarray,
    near_onehot: jnp.ndarray,
    row_mask: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused swap selection oracle: ``argmax`` over :func:`swap_gain`.

    Returns ``(best_gain, i, l)`` scalars — the best masked swap and its
    (candidate, slot) coordinates, with ``jnp.argmax`` first-flat-index
    tie-break. ``row_mask`` (n,) zeroes out rows that must not be selected
    (current medoids; the Pallas path also uses it for tile padding). This
    is the semantic contract the on-chip kernel reduction must match
    exactly, ties included (tests/test_kernels.py pins it).
    """
    gain = swap_gain(d, d1, d2, near_onehot)
    if row_mask is not None:
        gain = jnp.where(row_mask[:, None] > 0, gain, NEG)
    k = near_onehot.shape[1]
    flat = jnp.argmax(gain)
    return (gain.reshape(-1)[flat],
            (flat // k).astype(jnp.int32), (flat % k).astype(jnp.int32))
