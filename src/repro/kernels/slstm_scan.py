"""Pallas TPU kernel: fused sLSTM sequence scan.

Why: sLSTM is inherently sequential (hidden-to-hidden recurrence), so the
XLA while-loop implementation re-reads the recurrent matrices and streams
per-step tensors through HBM every timestep — the dominant remaining
memory term of the xlstm-1.3b train cell (EXPERIMENTS.md §Perf cell A).
This kernel keeps the recurrent weights AND the (c, n, h, m) state in
VMEM for the whole sequence: the grid iterates time sequentially
(TPU grid order is sequential), per step reading one gx slice from HBM
and writing one h slice back.

VMEM budget (full xlstm-1.3b, per core): r (4, 4, 512, 512) f32 = 16.8 MB
+ state 4 x (B, 4, 512) + one gx/ys slice << 128 MB VMEM. Per-step HBM
traffic drops from ~MBs (weights + stacked buffers) to the 2 x 16 KB
gx/ys slices — the ~100 s memory term becomes ~0.4 s (kernel-corrected
§Roofline entry).

Stabilised gating matches models/ssm._slstm_core exactly (oracle for the
interpret-mode tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(gx_ref, r_ref, c0_ref, n0_ref, h0_ref, m0_ref,
            ys_ref, cf_ref, nf_ref, hf_ref, mf_ref,
            c_s, n_s, h_s, m_s):
    t = pl.program_id(0)
    steps = pl.num_programs(0)

    @pl.when(t == 0)
    def _init():
        c_s[...] = c0_ref[...].astype(jnp.float32)
        n_s[...] = n0_ref[...].astype(jnp.float32)
        h_s[...] = h0_ref[...].astype(jnp.float32)
        m_s[...] = m0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)            # (NH, 4, hd, hd) VMEM
    g_t = gx_ref[...][:, 0].astype(jnp.float32)   # (B, 4, NH, hd)
    c, n, h, m = c_s[...], n_s[...], h_s[...], m_s[...]

    rec = jnp.einsum("bhk,hgkl->bghl", h, r)      # (B, 4, NH, hd)
    pre = g_t + rec
    z_p, i_p, f_p, o_p = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    i_red = i_p.max(-1)
    f_red = f_p.max(-1)
    m_new = jnp.maximum(f_red + m, i_red)
    i_s = jnp.exp(i_p - m_new[..., None])
    f_s = jnp.exp(f_p + (m - m_new)[..., None])
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h = o * c / jnp.maximum(n, 1e-6)

    c_s[...], n_s[...], h_s[...], m_s[...] = c, n, h, m_new
    ys_ref[...] = h[:, None].astype(ys_ref.dtype)

    @pl.when(t == steps - 1)
    def _fin():
        cf_ref[...] = c
        nf_ref[...] = n
        hf_ref[...] = h
        mf_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def slstm_scan(gx, r, c0, n0, h0, m0, *, interpret: bool = False):
    """gx: (B, S, 4, NH, hd); r: (NH, 4, hd, hd); state: c/n/h (B, NH, hd),
    m (B, NH). Returns (ys (B, S, NH, hd), (c, n, h, m))."""
    B, S, _, NH, hd = gx.shape
    grid = (S,)
    out_shape = (
        jax.ShapeDtypeStruct((B, S, NH, hd), gx.dtype),
        jax.ShapeDtypeStruct((B, NH, hd), jnp.float32),
        jax.ShapeDtypeStruct((B, NH, hd), jnp.float32),
        jax.ShapeDtypeStruct((B, NH, hd), jnp.float32),
        jax.ShapeDtypeStruct((B, NH), jnp.float32),
    )
    whole = lambda *shape: pl.BlockSpec(shape, lambda t: (0,) * len(shape))
    ys, c, n, h, m = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, 1, 4, NH, hd), lambda t: (0, t, 0, 0, 0)),
            whole(NH, 4, hd, hd),
            whole(B, NH, hd), whole(B, NH, hd), whole(B, NH, hd),
            whole(B, NH),
        ],
        out_specs=(
            pl.BlockSpec((B, 1, NH, hd), lambda t: (0, t, 0, 0)),
            whole(B, NH, hd), whole(B, NH, hd), whole(B, NH, hd),
            whole(B, NH),
        ),
        out_shape=out_shape,
        scratch_shapes=[
            _scratch((B, NH, hd)), _scratch((B, NH, hd)),
            _scratch((B, NH, hd)), _scratch((B, NH)),
        ],
        interpret=interpret,
    )(gx, r, c0, n0, h0, m0)
    return ys, (c, n, h, m)


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
