"""Fused Pallas kernel for the OneBatchPAM swap-gain matrix.

Evaluates Algorithm 2 (lines 6-18) of the paper for all n candidates and all
k medoid slots in one pass over the (n, m) distance block:

    G(i, l) = g_i + (r @ N)(i, l)
    g_i  = sum_j relu(d1_j - D_ij)
    r_ij = d1_j - min(max(D_ij, d1_j), d2_j)

The naive jnp version reads D three times from HBM (relu term, clip term,
matmul operand). The kernel reads each (TN, TM) tile of D once from VMEM and
produces both the VPU row-sum and the MXU matmul contribution, accumulating
the (TN, K) output tile across the m grid. This is the memory-bound hot loop
of the solver (O(nm) bytes per sweep), so the single-read fusion is the win.

k is padded to a 128 lane multiple and kept whole per tile (k <= ~1024 in
all paper settings); m is swept by the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SG_TN = 256   # candidate rows per tile
SG_TM = 256   # batch columns per grid step


def _swap_gain_kernel(d_ref, d1_ref, d2_ref, nh_ref, o_ref):
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = d_ref[...].astype(jnp.float32)            # (TN, TM)
    d1 = d1_ref[...].astype(jnp.float32)          # (1, TM)
    d2 = d2_ref[...].astype(jnp.float32)          # (1, TM)
    nh = nh_ref[...].astype(jnp.float32)          # (TM, K)

    g = jnp.maximum(d1 - d, 0.0).sum(axis=1)      # (TN,)  VPU
    r = d1 - jnp.minimum(jnp.maximum(d, d1), d2)  # (TN, TM) VPU
    big_r = jax.lax.dot_general(                  # (TN, K) MXU
        r, nh, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] += big_r + g[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def swap_gain(
    d: jnp.ndarray,           # (n, m)
    d1: jnp.ndarray,          # (m,)
    d2: jnp.ndarray,          # (m,)
    near_onehot: jnp.ndarray,  # (m, k)
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Swap-gain matrix (n, k) f32. n, m must be (SG_TN, SG_TM)-aligned and
    k a 128 multiple; ops.py pads and unpads."""
    n, m = d.shape
    k = near_onehot.shape[1]
    grid = (n // SG_TN, m // SG_TM)
    return pl.pallas_call(
        _swap_gain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((SG_TN, SG_TM), lambda i, jk: (i, jk)),
            pl.BlockSpec((1, SG_TM), lambda i, jk: (0, jk)),
            pl.BlockSpec((1, SG_TM), lambda i, jk: (0, jk)),
            pl.BlockSpec((SG_TM, k), lambda i, jk: (jk, 0)),
        ],
        out_specs=pl.BlockSpec((SG_TN, k), lambda i, jk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(d, d1.reshape(1, m), d2.reshape(1, m), near_onehot)
