"""Fused Pallas kernels for the OneBatchPAM swap sweep.

Evaluates Algorithm 2 (lines 6-18) of the paper for all n candidates and all
k medoid slots in one pass over the (n, m) distance block:

    G(i, l) = g_i + (r @ N)(i, l)
    g_i  = sum_j relu(d1_j - D_ij)
    r_ij = d1_j - min(max(D_ij, d1_j), d2_j)

Two kernels share the gain math (DESIGN.md §2):

  * ``swap_gain`` — materialises the full (n, k) gain matrix. The naive jnp
    version reads D three times from HBM (relu term, clip term, matmul
    operand); this kernel reads each (TN, TM) tile of D once from VMEM and
    produces both the VPU row-sum and the MXU matmul contribution,
    accumulating the (TN, K) output tile across the m grid.
  * ``swap_select`` — the fused swap-*selection* sweep: the same gain
    accumulation runs into a VMEM scratch tile that never leaves the chip,
    and at the last m grid step the (TN, K) tile is reduced on-chip to one
    ``(best_gain, best_flat)`` partial per row tile (first-flat-index
    tie-break, matching ``jnp.argmax`` on the full matrix). Per sweep the
    kernel writes O(n/TN) scalars to HBM instead of the O(nk) gain matrix —
    selection costs one read of D and nothing else. D tiles may be bf16
    (accumulation is always f32), halving sweep HBM traffic.

k is padded to a 128 lane multiple and kept whole per tile (k <= ~1024 in
all paper settings); m is swept by the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SG_TN = 256   # candidate rows per tile
SG_TM = 256   # batch columns per grid step

# Masked-entry sentinel inside the select kernel. A python float on purpose:
# jnp constants cannot be closed over by a Pallas kernel body.
_NEG = -1e30


def _swap_gain_kernel(d_ref, d1_ref, d2_ref, nh_ref, o_ref):
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = d_ref[...].astype(jnp.float32)            # (TN, TM)
    d1 = d1_ref[...].astype(jnp.float32)          # (1, TM)
    d2 = d2_ref[...].astype(jnp.float32)          # (1, TM)
    nh = nh_ref[...].astype(jnp.float32)          # (TM, K)
    _accumulate_gain(d, d1, d2, nh, o_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def swap_gain(
    d: jnp.ndarray,           # (n, m)
    d1: jnp.ndarray,          # (m,)
    d2: jnp.ndarray,          # (m,)
    near_onehot: jnp.ndarray,  # (m, k)
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Swap-gain matrix (n, k) f32. n, m must be (SG_TN, SG_TM)-aligned and
    k a 128 multiple; ops.py pads and unpads."""
    n, m = d.shape
    k = near_onehot.shape[1]
    grid = (n // SG_TN, m // SG_TM)
    return pl.pallas_call(
        _swap_gain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((SG_TN, SG_TM), lambda i, jk: (i, jk)),
            pl.BlockSpec((1, SG_TM), lambda i, jk: (0, jk)),
            pl.BlockSpec((1, SG_TM), lambda i, jk: (0, jk)),
            pl.BlockSpec((SG_TM, k), lambda i, jk: (jk, 0)),
        ],
        out_specs=pl.BlockSpec((SG_TN, k), lambda i, jk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(d, d1.reshape(1, m), d2.reshape(1, m), near_onehot)


def _accumulate_gain(d, d1, d2, nh, acc_ref):
    """One (TN, TM) gain-accumulation step into the VMEM scratch — the
    shared swap math of swap_select and the matrix-free fused sweep
    (kernels/fused_sweep.py): identical ops, so the accumulated floats
    cannot depend on where the distance tile came from.

    Two codegen-stability rules keep that true even when ``d`` is an
    on-chip computation rather than a loaded block (DESIGN.md §2b):

      * both m-contractions run as dot_generals — the add-gain row sum
        against a ones column, not ``jnp.sum`` — because a gemm's
        accumulation order is fixed by its shapes, while XLA re-blocks a
        ``reduce`` with the fusion context;
      * the add-gain term is ``d1 - min(d, d1)``, value-identical to
        ``relu(d1 - d)`` (either exact 0 or the exact difference) but
        with a ``min`` between the producer of ``d`` (a weight multiply
        in the fused sweep) and the subtract, so the backend cannot
        contract mul+sub into one fused-multiply-sub and skip the
        product's rounding. The removal term already has this shape.
    """
    gterm = d1 - jnp.minimum(d, d1)               # (TN, TM) == relu(d1 - d)
    ones = jnp.ones((d.shape[1], 1), jnp.float32)
    g = jax.lax.dot_general(                      # (TN, 1) m row-sum
        gterm, ones, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    r = d1 - jnp.minimum(jnp.maximum(d, d1), d2)  # (TN, TM) VPU
    big_r = jax.lax.dot_general(                  # (TN, K) MXU
        r, nh, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] += big_r + g


def _select_reduce(acc_ref, mask_ref, g_ref, f_ref, *, k_true):
    """On-chip reduction of the accumulated (TN, K) gain tile to one
    (best_gain, best_flat) partial — first-flat-index tie-break, exactly
    jnp.argmax semantics: the first row attaining the tile max, then the
    first column within that row attaining the row max. Shared by
    swap_select and the matrix-free fused sweep."""
    tn, kp = acc_ref.shape
    gain = acc_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, (tn, kp), 1)
    rmask = mask_ref[...]                         # (TN, 1), no relayout
    gain = jnp.where((col < k_true) & (rmask > 0), gain, _NEG)
    rmax = jnp.max(gain, axis=1, keepdims=True)            # (TN, 1)
    l_row = jnp.min(jnp.where(gain == rmax, col, kp),
                    axis=1, keepdims=True)                 # (TN, 1)
    tmax = jnp.max(gain)
    rows = jax.lax.broadcasted_iota(jnp.int32, (tn, 1), 0)
    brow = jnp.min(jnp.where(rmax == tmax, rows, tn))
    bl = jnp.min(jnp.where(rows == brow, l_row, kp))
    g_ref[0, 0] = tmax
    f_ref[0, 0] = brow * k_true + bl


def _swap_select_kernel(d_ref, d1_ref, d2_ref, nh_ref, mask_ref,
                        g_ref, f_ref, acc_ref, *, k_true, m_steps):
    """Gain accumulation fused with on-chip per-tile argmax.

    The (TN, K) gain tile lives in the ``acc_ref`` VMEM scratch across the
    m grid; at the last m step it is reduced to (best_gain, best_flat) and
    only those two scalars reach HBM. ``flat = row * k_true + l`` uses the
    *unpadded* k so the host-side reduce recovers global (i, l) directly.
    """
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = d_ref[...].astype(jnp.float32)            # (TN, TM)
    d1 = d1_ref[...].astype(jnp.float32)          # (1, TM)
    d2 = d2_ref[...].astype(jnp.float32)          # (1, TM)
    nh = nh_ref[...].astype(jnp.float32)          # (TM, K)
    _accumulate_gain(d, d1, d2, nh, acc_ref)

    @pl.when(jk == m_steps - 1)
    def _reduce():
        _select_reduce(acc_ref, mask_ref, g_ref, f_ref, k_true=k_true)


@functools.partial(jax.jit, static_argnames=("k_true", "interpret"))
def swap_select(
    d: jnp.ndarray,            # (n, m) f32 or bf16
    d1: jnp.ndarray,           # (m,)
    d2: jnp.ndarray,           # (m,)
    near_onehot: jnp.ndarray,  # (m, k_pad)
    row_mask: jnp.ndarray,     # (n,) f32, 0 = row excluded (medoid / padding)
    *,
    k_true: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row-tile swap-selection partials.

    Returns ``(best_gain, best_flat)`` of shape (n // SG_TN, 1) each: the
    maximum masked gain within each (SG_TN, k_true) row tile and its local
    flat index ``row * k_true + l``. n, m must be (SG_TN, SG_TM)-aligned
    and the one-hot width a 128 multiple; ops.py pads, masks the padded
    rows via ``row_mask``, and tree-reduces the partials.
    """
    n, m = d.shape
    kp = near_onehot.shape[1]
    grid = (n // SG_TN, m // SG_TM)
    return pl.pallas_call(
        functools.partial(_swap_select_kernel, k_true=k_true,
                          m_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((SG_TN, SG_TM), lambda i, jk: (i, jk)),
            pl.BlockSpec((1, SG_TM), lambda i, jk: (0, jk)),
            pl.BlockSpec((1, SG_TM), lambda i, jk: (0, jk)),
            pl.BlockSpec((SG_TM, kp), lambda i, jk: (jk, 0)),
            # (n, 1) column layout: the kernel reads a (TN, 1) tile
            # directly — a (1, TN) row would need a lane->sublane reshape
            # in-kernel, a relayout class Mosaic often refuses to lower.
            pl.BlockSpec((SG_TN, 1), lambda i, jk: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, jk: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, jk: (i, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n // SG_TN, 1), jnp.float32),
            jax.ShapeDtypeStruct((n // SG_TN, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((SG_TN, kp), jnp.float32)],
        interpret=interpret,
    )(d, d1.reshape(1, m), d2.reshape(1, m), near_onehot,
      row_mask.reshape(n, 1))
