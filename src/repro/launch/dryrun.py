import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the host
# device count on first init). Everything below is ordinary code.
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis import hlo as hlo_analysis  # noqa: E402
from repro.analysis import roofline  # noqa: E402
from repro.configs import SHAPES, cells, get, registry  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.models.sharding import MeshPlan, specs_for_tree  # noqa: E402
from repro.training import OptConfig, make_train_step  # noqa: E402
from repro.training.trainer import cast_for_compute  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

# Cache sharding preferences (see models/sharding.py for the mechanics).
_CACHE_RULES = {
    "k":    [(1, "batch"), (3, "model"), (4, "model"), (2, "batch")],
    "v":    [(1, "batch"), (3, "model"), (4, "model"), (2, "batch")],
    "conv": [(1, "batch"), (3, "model")],
    "h":    [(1, "batch"), (2, "model")],
    "c":    [(1, "batch"), (3, "model")],
    "n":    [(1, "batch"), (3, "model")],
    "m":    [(1, "batch")],
    "enc_out": [(0, "batch")],
}


def make_serve_step(cfg):
    """decode one token: (params, cache, token (B,), t) -> (logits, cache).

    Lives here (with its only consumer, the dry-run cells) since PR 8:
    repro.serving now serves the paper's workload — k-medoids assignment
    (serving.AssignmentEngine) — not LLM decode."""

    def serve_step(params, cache, token, t):
        pc = cast_for_compute(params, cfg.compute_dtype)
        return transformer.decode_step(pc, cfg, token, cache, t)

    return serve_step


def make_prefill(cfg, max_len: int):
    def prefill_step(params, tokens, frames=None):
        pc = cast_for_compute(params, cfg.compute_dtype)
        return transformer.prefill(pc, cfg, tokens, max_len,
                                   enc_frames=frames)

    return prefill_step


def _cache_specs(cache_shapes, plan):
    from repro.models import sharding as sh
    old = sh._RULES
    try:
        sh._RULES = {**old, **_CACHE_RULES}
        # cache leaves are NOT stacked-shifted: dims in rules already
        # include the leading period dim, so disable the shift.
        return sh.specs_for_tree(cache_shapes, plan, stacked_root="\x00none")
    finally:
        sh._RULES = old


def pick_grad_accum(cfg, shape, plan, target_tokens=8192):
    dp = plan.size(plan.batch_axes)
    per_dev_seqs = max(shape.global_batch // dp, 1)
    per_dev_tokens = per_dev_seqs * shape.seq_len
    return max(1, min(per_dev_tokens // target_tokens, per_dev_seqs))


def _shard_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def _maybe_batch(plan, size):
    axes = plan.batch_axes
    return P(axes) if size % plan.size(axes) == 0 and size >= plan.size(axes) \
        else P()


def _whisper_frames(cfg, batch):
    return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model),
                                jnp.float32)


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell
    (weak-type-correct, shardable, no device allocation)."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.encoder_layers:
            spec["frames"] = _whisper_frames(cfg, B)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.encoder_layers:
            spec["frames"] = _whisper_frames(cfg, B)
        return spec
    # decode: one new token against a seq_len cache
    return {"token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "t": jax.ShapeDtypeStruct((), jnp.int32)}


def build_cell(arch: str, shape_name: str, mesh, plan):
    """-> (fn, arg_shapes tuple, in_shardings tuple, meta dict)."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    meta = {"params": cfg.param_count(),
            "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        oc = OptConfig(moment_dtype=cfg.moment_dtype)
        accum = pick_grad_accum(cfg, shape, plan)
        meta["grad_accum"] = accum
        # 100B+ models: bf16 accumulation buffer (same tradeoff as their
        # bf16 Adam moments; DESIGN.md §7)
        accum_dtype = ("bfloat16" if cfg.param_count() > 1e11 and accum > 1
                       else "float32")
        meta["accum_dtype"] = accum_dtype
        step = make_train_step(cfg, oc, grad_accum=accum,
                               accum_dtype=accum_dtype)
        from repro.training import init_train_state
        state_shapes = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg, oc))
        state_specs = specs_for_tree(state_shapes, plan)
        batch_shapes = input_specs(arch, shape_name)
        batch_specs = {"tokens": P(plan.batch_axes, None)}
        if "frames" in batch_shapes:
            batch_specs["frames"] = P(plan.batch_axes, None, None)
        return (step, (state_shapes, batch_shapes),
                (_shard_tree(mesh, state_specs),
                 _shard_tree(mesh, batch_specs)), meta)

    # serving cells store params in the compute dtype (deployment layout)
    serve_cfg = dataclasses.replace(cfg, param_dtype=cfg.compute_dtype)
    params_shapes = jax.eval_shape(
        lambda: transformer.init_lm(jax.random.PRNGKey(0), serve_cfg))
    params_specs = specs_for_tree(params_shapes, plan)

    if shape.kind == "prefill":
        fn = make_prefill(serve_cfg, max_len=S)
        batch_shapes = input_specs(arch, shape_name)
        args = (params_shapes, batch_shapes["tokens"])
        shards = (_shard_tree(mesh, params_specs),
                  NamedSharding(mesh, _maybe_batch(plan, B)))
        if "frames" in batch_shapes:
            args = args + (batch_shapes["frames"],)
            shards = shards + (NamedSharding(
                mesh, P(plan.batch_axes, None, None)
                if B % plan.size(plan.batch_axes) == 0 else P()),)
        return fn, args, shards, meta

    # decode
    fn = make_serve_step(serve_cfg)
    cache_shapes = jax.eval_shape(
        lambda: transformer.init_cache(serve_cfg, B, S))
    cache_specs = _cache_specs(cache_shapes, plan)
    io = input_specs(arch, shape_name)
    args = (params_shapes, cache_shapes, io["token"], io["t"])
    shards = (_shard_tree(mesh, params_specs),
              _shard_tree(mesh, cache_specs),
              NamedSharding(mesh, _maybe_batch(plan, B)),
              NamedSharding(mesh, P()))
    return fn, args, shards, meta


def build_obp_cell(mesh, plan, *, n=1 << 22, p=4096, m=1024, k=256):
    """The paper-technique cell: distributed OneBatchPAM solve on the mesh
    (embedding-scale curation workload)."""
    from repro.core.distributed import make_distributed_obp
    run = make_distributed_obp(mesh, k=k, metric="l1", max_swaps=64)
    x = jax.ShapeDtypeStruct((n, p), jnp.float32)
    bi = jax.ShapeDtypeStruct((m,), jnp.int32)
    w = jax.ShapeDtypeStruct((m,), jnp.float32)
    init = jax.ShapeDtypeStruct((k,), jnp.int32)
    meta = {"params": 0, "active_params": 0, "n": n, "p": p, "m": m, "k": k}
    return run, (x, bi, w, init), None, meta


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = ARTIFACT_DIR, save_hlo: bool = False) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    plan = MeshPlan.from_mesh(mesh)
    chips = mesh.devices.size

    if arch == "obp-selection":
        fn, args, shards, meta = build_obp_cell(mesh, plan)
        shape_kind = "obp"
        mf = 0.0
    else:
        fn, args, shards, meta = build_cell(arch, shape_name, mesh, plan)
        shape_kind = SHAPES[shape_name].kind
        mf = roofline.model_flops(get(arch), SHAPES[shape_name])

    t0 = time.perf_counter()
    with compat.set_mesh(mesh):
        jfn = jax.jit(fn, in_shardings=shards) if shards is not None \
            else fn  # obp cell is already jitted with shard_map specs
        lowered = jfn.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    pod_size = 256 if multi else None
    costs = hlo_analysis.analyze(hlo_text, pod_size=pod_size)
    rl = roofline.compute(costs, chips=chips, model_flops_global=mf)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape_kind, "chips": chips, "meta": meta,
        "times": {"lower_s": t_lower, "compile_s": t_compile},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "xla_cost_analysis": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "hlo_per_device": costs,
        "roofline": rl.as_dict(),
        "hlo_chars": len(hlo_text),
    }
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_kind}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, name + ".hlo.txt"), "w") as f:
            f.write(hlo_text)
    return result


def _cell_list(mesh_kinds):
    out = []
    for arch, shape_name, skip in cells():
        for mk in mesh_kinds:
            out.append((arch, shape_name, mk, skip))
    for mk in mesh_kinds:
        out.append(("obp-selection", "selection", mk, None))
    return out


def run_all(mesh_kinds, jobs: int, out_dir: str, force: bool) -> None:
    todo = []
    skipped = []
    for arch, shape_name, mk, skip in _cell_list(mesh_kinds):
        name = f"{arch}__{shape_name}__{mk}"
        path = os.path.join(out_dir, name + ".json")
        if skip:
            skipped.append({"arch": arch, "shape": shape_name, "mesh": mk,
                            "skip": skip})
            continue
        if not force and os.path.exists(path):
            print(f"[cached] {name}")
            continue
        todo.append((arch, shape_name, mk, name))

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "skips.json"), "w") as f:
        json.dump(skipped, f, indent=1)

    procs: list = []
    results = {"ok": 0, "fail": []}

    def reap(block=False):
        for pr, name, logf in procs[:]:
            if pr.poll() is None and not block:
                continue
            pr.wait()
            procs.remove((pr, name, logf))
            if pr.returncode == 0:
                results["ok"] += 1
                print(f"[ok] {name}")
            else:
                results["fail"].append(name)
                print(f"[FAIL] {name} (log: {logf})")

    for arch, shape_name, mk, name in todo:
        while len(procs) >= jobs:
            reap()
            time.sleep(2)
        logf = os.path.join(out_dir, name + ".log")
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape_name, "--mesh", mk, "--out", out_dir]
        env = dict(os.environ)
        with open(logf, "w") as lf:
            pr = subprocess.Popen(cmd, stdout=lf, stderr=lf, env=env)
        procs.append((pr, name, logf))
        print(f"[start] {name}")
    while procs:
        reap(block=True)
        time.sleep(1)
    print(f"done: {results['ok']} ok, {len(results['fail'])} failed, "
          f"{len(skipped)} skipped")
    if results["fail"]:
        print("failed:", results["fail"])
        sys.exit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS + ("obp-selection",))
    ap.add_argument("--shape", default="train_4k",
                    choices=tuple(SHAPES) + ("selection",))
    ap.add_argument("--mesh", default="single", choices=("single", "multi",
                                                         "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    kinds = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        run_all(kinds, args.jobs, args.out, args.force)
        return
    for mk in kinds:
        res = run_cell(args.arch, args.shape, mk, args.out, args.save_hlo)
        mem = res["memory"]
        rl = res["roofline"]
        print(json.dumps({
            "cell": f'{args.arch}/{args.shape}/{mk}',
            "compile_s": round(res["times"]["compile_s"], 1),
            "arg_gb": round((mem["argument_bytes"] or 0) / 2**30, 3),
            "temp_gb": round((mem["temp_bytes"] or 0) / 2**30, 3),
            "bottleneck": rl["bottleneck"],
            "mfu": round(rl["mfu"], 4),
        }))


if __name__ == "__main__":
    main()
