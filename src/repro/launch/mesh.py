"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py sets
the 512-device XLA flag).

Topology (v5e): one pod = 256 chips as (data=16, model=16); multi-pod adds
a leading DCN-connected "pod" axis — (pod=2, data=16, model=16) for the
2-pod dry-run. The same function scales the pod axis for larger fleets
(elastic: the checkpoint layer is topology-independent).
"""
from __future__ import annotations

from repro.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False, num_pods: int = 2):
    if multi_pod:
        shape = (num_pods, 16, 16)
        axes = ("pod", "data", "model")
    else:
        shape = (16, 16)
        axes = ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over forced host devices (tests / examples)."""
    return _make_mesh(shape, axes)
