"""Training driver: checkpointed, preemption-safe, straggler-aware.

CPU-scale usage (end-to-end example driver):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1 \
      --resume auto

On a real fleet the same entry point runs per host under
launch/scripts/tpu_pod.sh (jax.distributed initialises from the
coordinator env), with the production mesh from launch/mesh.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get, reduced as make_reduced
from repro.data import TokenIterator, TokenStore, build_synthetic
from repro.monitoring import CSVLogger, StepTimer
from repro.training import OptConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-tokens", type=int, default=2_000_000)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="no", choices=("no", "auto"))
    ap.add_argument("--log", default=None)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    oc = OptConfig(lr=args.lr, warmup_steps=args.warmup,
                   total_steps=args.steps, moment_dtype=cfg.moment_dtype)
    step_fn = jax.jit(make_train_step(cfg, oc, grad_accum=args.grad_accum))

    if args.data == "synthetic":
        path = os.path.join(args.ckpt_dir or "/tmp", f"{args.arch}.tokens.bin")
        if not os.path.exists(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
            build_synthetic(path, args.data_tokens, cfg.vocab_size,
                            seed=args.seed)
        store = TokenStore(path, cfg.vocab_size)
    else:
        store = TokenStore(args.data, cfg.vocab_size)

    host_id = jax.process_index() if jax.process_count() > 1 else 0
    it = TokenIterator(store, args.batch, args.seq, seed=args.seed,
                       shard_id=host_id, num_shards=max(jax.process_count(), 1))

    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, oc)
    start_step = 0
    if args.resume == "auto" and args.ckpt_dir \
            and ckpt.latest_step(args.ckpt_dir) is not None:
        target = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), state)
        state, extra = ckpt.restore(args.ckpt_dir, target)
        it.restore(extra["data"])
        start_step = int(extra.get("step", 0))
        print(f"[resume] from step {start_step}")

    logger = CSVLogger(args.log, ["step", "loss", "grad_norm", "lr",
                                  "sec_per_step", "straggler"]) \
        if args.log else None
    timer = StepTimer()

    stop = {"now": False}

    def on_term(signum, frame):
        print("[signal] SIGTERM: checkpointing and exiting")
        stop["now"] = True

    signal.signal(signal.SIGTERM, on_term)

    def save(step):
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, step, state,
                      extra={"data": it.state(), "step": step})

    frames = None
    if cfg.encoder_layers:
        frames = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                           jnp.float32)

    loss = float("nan")
    for step in range(start_step, args.steps):
        batch = it.__next__()
        feed = {"tokens": jnp.asarray(batch["tokens"])}
        if frames is not None:
            feed["frames"] = frames
        t0 = time.perf_counter()
        state, metrics = step_fn(state, feed)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        flagged = timer.record(dt)
        if flagged:
            print(f"[straggler] step {step}: {dt:.2f}s vs ema "
                  f"{timer.ema:.2f}s")
        if logger:
            logger.log(step=step, loss=loss,
                       grad_norm=float(metrics["grad_norm"]),
                       lr=float(metrics["lr"]),
                       sec_per_step=round(dt, 4), straggler=int(flagged))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(step + 1)
        if stop["now"]:
            save(step + 1)
            sys.exit(0)
    save(args.steps)
    print(f"done: final loss {loss:.4f}, stragglers {timer.stragglers}")
    return loss


if __name__ == "__main__":
    main()
