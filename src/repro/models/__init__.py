"""Model zoo: composable blocks + periodic LayerProgram assembly."""
from .transformer import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_lm,
    prefill,
)
from .sharding import MeshPlan, constrain, specs_for_tree  # noqa: F401
