"""GQA attention: causal / sliding-window / bidirectional / cross, with
optional attention-logit softcap (gemma2) and QKV bias (qwen1.5), plus the
KV-cache decode path.

GQA is computed in grouped form — queries reshaped to (B, S, KV, G, hd) so
K/V are never materialised H/KV times. The (pod, data) axes shard batch;
the model axis shards heads (or head_dim for small archs, per
sharding._RULES).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.sharding import constrain

NEG_INF = jnp.float32(-1e30)
BATCH = ("pod", "data")


def init_attention(key, cfg, *, d_in=None, heads=None, kv_heads=None,
                   dtype=jnp.float32):
    d = d_in or cfg.d_model
    H = heads or cfg.num_heads
    KV = kv_heads or cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, H, hd), fan_in=d, dtype=dtype),
        "wk": layers.dense_init(ks[1], (d, KV, hd), fan_in=d, dtype=dtype),
        "wv": layers.dense_init(ks[2], (d, KV, hd), fan_in=d, dtype=dtype),
        "wo": layers.dense_init(ks[3], (H, hd, d), fan_in=H * hd, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def _project_qkv(params, x, kv_x):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _attend(q, k, v, mask, attn_softcap):
    """q: (B,S,KV,G,hd); k,v: (B,T,KV,hd); mask: broadcastable (B,1,1,S,T)."""
    hd = q.shape[-1]
    logits = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    logits = layers.softcap(logits, attn_softcap)
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgst,btkh->bskgh", probs, v)


# ------------------------------------------------------- flash attention --

FLASH_Q_CHUNK = 512
FLASH_KV_CHUNK = 1024


def _flash_q_chunk(q_c, k, v, q_start, *, causal, window, attn_softcap,
                   kv_chunk):
    """Online-softmax over kv chunks for one query chunk.
    q_c: (B, qc, KV, G, hd); k/v: (B, T, KV, hd). Static kv range: causal
    chunks above the diagonal are never visited (triangular schedule)."""
    B, qc, KV, G, hd = q_c.shape
    T = k.shape[1]
    q_end = q_start + qc
    kv_hi = min(T, q_end) if causal else T
    kv_lo = 0
    if window is not None:
        kv_lo = max(0, q_start - window + 1)
        kv_lo = (kv_lo // kv_chunk) * kv_chunk
    n_kv = -(-(kv_hi - kv_lo) // kv_chunk)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    row_idx = q_start + jnp.arange(qc)

    def step(carry, i):
        m, l, acc = carry
        start = kv_lo + i * kv_chunk
        k_blk = jax.lax.dynamic_slice(k, (0, start, 0, 0),
                                      (B, kv_chunk, KV, hd))
        v_blk = jax.lax.dynamic_slice(v, (0, start, 0, 0),
                                      (B, kv_chunk, KV, hd))
        logits = jnp.einsum("bskgh,btkh->bkgst", q_c, k_blk
                            ).astype(jnp.float32) * scale
        logits = layers.softcap(logits, attn_softcap)
        col_idx = start + jnp.arange(kv_chunk)
        mask = col_idx[None, :] < T  # guard the ragged tail chunk
        if causal:
            mask &= col_idx[None, :] <= row_idx[:, None]
        if window is not None:
            mask &= (row_idx[:, None] - col_idx[None, :]) < window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bkgst,btkh->bkgsh", p.astype(v.dtype), v_blk)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
    acc0 = jnp.zeros((B, KV, G, qc, hd), q_c.dtype)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), jnp.arange(n_kv))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4)  # (B, qc, KV, G, hd)


def _attend_flash(q, k, v, *, causal, window, attn_softcap,
                  q_chunk=FLASH_Q_CHUNK, kv_chunk=FLASH_KV_CHUNK):
    """Chunked attention with O(q_chunk * kv_chunk) live logits. The python
    loop over query chunks is static, so causal scheduling skips all blocks
    above the diagonal (no masked-flops waste beyond the diagonal chunk)."""
    B, S, KV, G, hd = q.shape
    q_chunk = min(q_chunk, S)
    while S % q_chunk:
        q_chunk -= 1
    chunk_fn = jax.checkpoint(
        functools.partial(_flash_q_chunk, causal=causal, window=window,
                          attn_softcap=attn_softcap, kv_chunk=kv_chunk),
        static_argnums=(3,))
    outs = []
    for qi in range(S // q_chunk):
        q_c = jax.lax.slice_in_dim(q, qi * q_chunk, (qi + 1) * q_chunk, axis=1)
        outs.append(chunk_fn(q_c, k, v, qi * q_chunk))
    return jnp.concatenate(outs, axis=1)


def _use_flash(S, T, kv_chunk=FLASH_KV_CHUNK):
    return S >= 2 * FLASH_Q_CHUNK and T >= 4 * kv_chunk


def _train_mask(S, T, *, causal, window, offset=0):
    if not causal and window is None:
        return None
    s_idx = jnp.arange(S)[:, None] + offset
    t_idx = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= t_idx <= s_idx
    if window is not None:
        mask &= (s_idx - t_idx) < window
    return mask[None, None, None, :, :]


def attention(params, cfg, x, *, kv_x=None, causal=True, window=None,
              rope=True, positions=None, attn_softcap=None):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    B, S, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    T = kv_x.shape[1]
    q, k, v = _project_qkv(params, x, kv_x)
    # Megatron layout inside attention: gather the sequence dim, shard
    # heads over the model axis (constrain() drops the axis when heads do
    # not divide — small archs fall back to GSPMD's choice).
    q = constrain(q, P(BATCH, None, "model", None))
    k = constrain(k, P(BATCH, None, "model", None))
    v = constrain(v, P(BATCH, None, "model", None))
    H, hd = q.shape[2], q.shape[3]
    KV = k.shape[2]
    if rope and cfg.rope_theta:
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = layers.rope_angles(pos, hd, cfg.rope_theta)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
    qg = q.reshape(B, S, KV, H // KV, hd)
    qg = constrain(qg, P(BATCH, None, "model", None, None))
    if _use_flash(S, T):
        out = _attend_flash(qg, k, v, causal=causal, window=window,
                            attn_softcap=attn_softcap)
    else:
        mask = _train_mask(S, T, causal=causal, window=window)
        out = _attend(qg, k, v, mask, attn_softcap)
    out = out.reshape(B, S, H, hd)
    out = constrain(out, P(BATCH, None, "model", None))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# ---------------------------------------------------------------- decode --

def init_kv_cache(cfg, batch, max_len, dtype, *, heads=None):
    KV = heads or cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, KV, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(params, cfg, x, cache, pos, *, window=None,
                     attn_softcap=None):
    """One-token decode: x (B, 1, D), cache k/v (B, L, KV, hd), pos:
    scalar int32 — position being written.

    Local-attention layers use a RING buffer of length L = min(window,
    max_len): token t lives at slot t % L, so the cache never grows past
    the window (gemma2 decode_32k: 4096 slots instead of 32768). The slot
    validity mask `slot_token >= 0` with slot_token = pos - ((pos - i) %
    L) degenerates to the plain causal mask when L = max_len, so one code
    path serves both."""
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, x)
    H, hd = q.shape[2], q.shape[3]
    KV = k_new.shape[2]
    if cfg.rope_theta:
        posv = jnp.full((1,), pos)
        cos, sin = layers.rope_angles(posv, hd, cfg.rope_theta)
        q = layers.apply_rope(q, cos, sin)
        k_new = layers.apply_rope(k_new, cos, sin)
    L = cache["k"].shape[1]
    ring = window is not None
    write_pos = (pos % L) if ring else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, write_pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, write_pos, 0, 0))
    slot = jnp.arange(L)
    if ring:
        slot_token = pos - ((pos - slot) % L)
        mask = (slot_token >= 0)[None, None, None, None, :]
        if window < L:  # pragma: no cover - L == min(window, max_len)
            mask &= ((pos - slot_token) < window)[None, None, None, None, :]
    else:
        mask = (slot <= pos)[None, None, None, None, :]
    qg = q.reshape(B, 1, KV, H // KV, hd)
    out = _attend(qg, k, v, mask, attn_softcap)
    out = out.reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k, "v": v}
