"""Shared building blocks: norms, RoPE, dense FFNs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            / jnp.sqrt(jnp.float32(max(fan_in, 1)))).astype(dtype)


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ------------------------------------------------------------------ RoPE --

def rope_angles(positions, head_dim, theta):
    """cos/sin tables for `positions` (any shape) -> (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (S, hd/2) or (B, S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch & heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:              # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq, d, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


# ------------------------------------------------------------------- FFN --

def init_mlp(key, d, f, ffn_type, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    params = {"wi": dense_init(ks[0], (d, f), dtype=dtype),
              "wd": dense_init(ks[1], (f, d), dtype=dtype)}
    if ffn_type == "swiglu":
        params["wg"] = dense_init(ks[2], (d, f), dtype=dtype)
    return params


def mlp(params, x, ffn_type):
    h = x @ params["wi"]
    if ffn_type == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ params["wd"]


# ------------------------------------------------------------- embedding --

def init_embed(key, vocab, d, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(params, tokens, *, scale=False):
    h = params["table"][tokens]
    if scale:
        h = h * jnp.sqrt(jnp.float32(params["table"].shape[1])).astype(h.dtype)
    return h
