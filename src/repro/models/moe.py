"""Mixture-of-Experts FFN: top-k routing with sort-based, capacity-bounded
dispatch (static shapes, SPMD-friendly).

Dispatch pipeline (per MoE layer):
  1. router scores (T, E) in f32, top-k per token;
  2. flatten the T*k assignments, stable-sort by expert id;
  3. position-within-expert via searchsorted; tokens beyond the per-expert
     capacity C are dropped (their residual path still carries them);
  4. scatter to (E, C, D) slots, expert matmuls as one (E, C, D)x(E, D, F)
     einsum (MXU-friendly, experts sharded over the model axis when
     E % model == 0 — EP; otherwise d_ff is sharded — TP-inside-expert);
  5. combine back with routing weights via scatter-add.

Capacity C = ceil(T * k / E * capacity_factor) keeps the dispatched
activation at O(T * k * D * cf) regardless of routing skew. The auxiliary
load-balance loss is the standard switch-style E * sum_e f_e * p_e.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import layers
from repro.models.sharding import constrain


def init_moe(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": layers.dense_init(ks[0], (d, e), dtype=jnp.float32),
        "moe_wi": layers.dense_init(ks[1], (e, d, f), fan_in=d, dtype=dtype),
        "moe_wd": layers.dense_init(ks[2], (e, f, d), fan_in=f, dtype=dtype),
    }
    if cfg.ffn_type == "swiglu":
        p["moe_wg"] = layers.dense_init(ks[3], (e, d, f), fan_in=d, dtype=dtype)
    return p


def capacity(tokens: int, cfg) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.num_experts
                  * cfg.capacity_factor)
    # keep C divisible by a model axis up to 16 so the dispatch buffer can
    # shard on capacity when E does not divide the model axis (grok: 8e)
    return max(16, min(c + (-c) % 16, tokens))


def _dispatch_spec(E: int, C: int):
    """EP when experts divide the model axis; otherwise shard CAPACITY
    over the batch axes (C@model would conflict with the experts' F@model
    TP layout and force an 8 GB xg all-gather — grok iteration 1/2).
    Without any sharding the (E, C, D) dispatch buffer replicates and its
    combine becomes a full all-reduce — 96% of grok-1's v1 collective
    bytes."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return P(None, None, None)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    if E % sizes["model"] == 0:
        return P("model", None, None)
    batch = tuple(n for n in mesh.axis_names if n != "model")
    bs = 1
    for b in batch:
        bs *= sizes[b]
    if bs > 1 and C % bs == 0:
        return P(None, batch, None)
    return P(None, None, None)


def moe_ffn(params, cfg, x):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar f32)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity(T, cfg)
    xf = x.reshape(T, D)

    scores = (xf.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(scores, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                        # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (switch-style)
    frac = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(frac * probs.mean(0))

    flat_e = top_i.reshape(-1)                                    # (T*K,)
    flat_w = top_w.reshape(-1).astype(x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(E))
    pos = jnp.arange(T * K) - starts[se]
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)                   # overflow -> E*C

    tok_for_slot = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        st.astype(jnp.int32))
    w_for_slot = jnp.zeros((E * C + 1,), x.dtype).at[slot].set(
        jnp.where(keep, sw, 0).astype(x.dtype))

    spec = _dispatch_spec(E, C)
    xg = xf[tok_for_slot[:E * C]].reshape(E, C, D)
    xg = constrain(xg, spec)

    h = jnp.einsum("ecd,edf->ecf", xg, params["moe_wi"])
    if cfg.ffn_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, params["moe_wg"])) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h, params["moe_wd"])
    y = constrain(y, spec)

    # combine: scatter back to token layout. The accumulator is pinned to
    # the token sharding up front — an unsharded target makes GSPMD
    # replicate the scatter and all-reduce the full (T, D) buffer.
    batch = tuple(a for a in ("pod", "data"))
    zeros = constrain(jnp.zeros((T, D), x.dtype), P(batch, None))
    out = zeros.at[tok_for_slot[:E * C]].add(
        y.reshape(E * C, D) * w_for_slot[:E * C, None])
    out = constrain(out, P(batch, None))
    return out.reshape(B, S, D), aux
