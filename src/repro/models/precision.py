"""Mixed-precision casting with explicit ZeRO-3 gather points.

Masters live f32, sharded (model x data) per sharding._RULES. Compute
copies are cast to bf16 and re-constrained to MODEL-ONLY sharding — the
constraint pins GSPMD to gather-weights-over-data (ZeRO-3) instead of
all-reducing full activations against data-sharded weights.

Placement matters: the block stack is cast INSIDE the layer scan
(per-period slice), so only one period's gathered bf16 weights are live
at a time — casting the whole stack up front materialises params/16
per device (grok: +39 GB, §Perf iteration 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import sharding as sh

# f32-sensitive leaves never downcast to the compute dtype.
KEEP_F32 = ("router", "a_log", "b_gates", "dt_bias", "w_gates")


def cast_tree(params, compute_dtype, *, constrain_model_only: bool = False,
              stacked: bool = True):
    dt = jnp.dtype(compute_dtype)

    def cast(path, p):
        name = str(getattr(path[-1], "key", ""))
        if name in KEEP_F32 or not jnp.issubdtype(p.dtype, jnp.floating):
            return p
        return p.astype(dt)

    out = jax.tree_util.tree_map_with_path(cast, params)
    if constrain_model_only:
        plan = sh.compute_plan_from_context()
        if plan is not None:
            out = sh.constrain_tree(
                out, plan, stacked_root="blocks" if stacked else "\x00none")
    return out
