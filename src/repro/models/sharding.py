"""Parameter/activation sharding rules (GSPMD, path-based).

Single-pod mesh: ("data", "model"); multi-pod adds a leading "pod" axis.
Roles:
  * model — Megatron TP: heads / d_ff / vocab / experts.
  * fsdp  — parameter + optimizer-state sharding over the in-pod "data"
            axis (ZeRO-3-like); the pod axis replicates params (pure DP
            over DCN) unless fsdp_over_pod is set.
  * batch — activation batch dims over ("pod", "data").

Divisibility rule (DESIGN.md §5): each preference (dim, role) is
applied only if the dim size divides by the axis size and the axis is not
already used — small archs (whisper's 8 heads on a 16-wide model axis)
fall through to their next preference (head_dim) automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro import compat


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Static description of the mesh used to resolve sharding roles."""
    axis_sizes: dict          # name -> size
    model_axis: str = "model"
    fsdp_axes: tuple = ("data",)
    batch_axes: tuple = ("data",)   # ("pod", "data") multi-pod
    seq_shard: bool = True          # sequence-parallel residual stream

    @staticmethod
    def from_mesh(mesh, *, fsdp_over_pod: bool = False, seq_shard: bool = True):
        names = tuple(mesh.axis_names)
        sizes = dict(zip(names, mesh.devices.shape)) if hasattr(mesh, "devices") \
            else {n: s for n, s in zip(names, mesh.axis_sizes)}
        batch = tuple(n for n in names if n != "model")
        fsdp = batch if fsdp_over_pod else tuple(n for n in batch if n != "pod")
        return MeshPlan(axis_sizes=sizes, batch_axes=batch, fsdp_axes=fsdp)

    def size(self, axes) -> int:
        if isinstance(axes, str):
            axes = (axes,)
        out = 1
        for a in axes:
            out *= self.axis_sizes.get(a, 1)
        return out

    def has(self, axis: str) -> bool:
        return self.axis_sizes.get(axis, 1) > 1


# Preference tables: leaf name -> ordered (dim, role) assignments.
# role: "model" | "fsdp". Dims are indices into the UNSTACKED leaf shape.
_RULES = {
    # embeddings / head
    "table":    [(0, "model"), (1, "fsdp")],
    "head_w":   [(1, "model"), (0, "fsdp")],
    "pos_table": [(1, "fsdp")],
    # attention (d, H, hd) / (H, hd, d). These are the MASTER layouts
    # (f32 + optimizer states, ZeRO-3 sharded over model x data); the
    # bf16 compute copies are re-constrained to model-only sharding at
    # the cast (trainer.cast_for_compute), which pins GSPMD to the
    # gather-weights schedule instead of all-reducing activations over
    # the data axis — see EXPERIMENTS.md §Perf (grok iterations).
    "wq":       [(1, "model"), (2, "model"), (0, "fsdp")],
    "wk":       [(1, "model"), (2, "model"), (0, "fsdp")],
    "wv":       [(1, "model"), (2, "model"), (0, "fsdp")],
    "wo":       [(0, "model"), (1, "model"), (2, "fsdp")],
    "bq":       [], "bk": [], "bv": [],
    # dense mlp (d, F) / (F, d)
    "wi":       [(1, "model"), (0, "fsdp")],
    "wg":       [(1, "model"), (0, "fsdp")],
    "wd":       [(0, "model"), (1, "fsdp")],
    # moe (E, d, F) / (E, F, d); EP on experts when divisible, else TP on F
    "moe_wi":   [(0, "model"), (2, "model"), (1, "fsdp")],
    "moe_wg":   [(0, "model"), (2, "model"), (1, "fsdp")],
    "moe_wd":   [(0, "model"), (1, "model"), (2, "fsdp")],
    "router":   [(0, "fsdp")],
    # mamba
    "in_proj":  [(1, "model"), (0, "fsdp")],
    "conv_w":   [(1, "model")],
    "conv_b":   [(0, "model")],
    "x_proj":   [(0, "model")],
    "dt_proj":  [(1, "model")],
    "dt_bias":  [(0, "model")],
    "a_log":    [(0, "model")],
    "skip_d":   [(0, "model")],
    "out_proj": [(0, "model"), (1, "fsdp")],
    # mlstm / slstm
    "w_up":     [(1, "model"), (0, "fsdp")],
    "w_down":   [(0, "model"), (1, "fsdp")],
    "w_gates":  [(0, "fsdp")],
    # (NH, 4, hd, hd): shard recurrent mats over model on hd — keeps the
    # per-step gradient accumulation carry sharded (otherwise GSPMD
    # all-reduces a replicated 16.8MB grad every timestep of the scan)
    "r_gates":  [(2, "model")],
    # norms
    "scale":    [],
    "bias":     [],
}


def _leaf_name(path) -> str:
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    return str(keys[-1])


def specs_for_tree(tree, plan: MeshPlan, *, stacked_root: str = "blocks"):
    """PartitionSpec pytree mirroring `tree` (arrays or ShapeDtypeStructs)."""

    def one(path, leaf):
        name = _leaf_name(path)
        keys = [str(getattr(k, "key", "")) for k in path]
        stacked = any(k.startswith(stacked_root) for k in keys)
        rules = _RULES.get(name, [])
        shape = leaf.shape
        shift = 1 if stacked else 0
        spec = [None] * len(shape)
        used = set()
        for dim, role in rules:
            d = dim + shift
            if d >= len(shape) or spec[d] is not None:
                continue
            if role == "model":
                axes = (plan.model_axis,)
            elif role == "model_fsdp":
                axes = (plan.model_axis,) + tuple(plan.fsdp_axes)
            else:
                axes = plan.fsdp_axes
            axes = tuple(a for a in axes if plan.has(a) and a not in used)
            if not axes:
                continue
            if shape[d] % plan.size(axes) == 0 and shape[d] >= plan.size(axes):
                spec[d] = axes[0] if len(axes) == 1 else axes
                used.update(axes)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, tree)


def constrain(x, spec: P):
    """with_sharding_constraint that (a) no-ops outside a mesh context,
    (b) drops axes absent from the current mesh, (c) drops axes whose size
    does not divide the dim (e.g. seq-sharding a length-1 decode step)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    fixed = []
    for i, entry in enumerate(spec):
        dim = x.shape[i] if i < x.ndim else 1
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a in sizes)
        prod = 1
        for a in kept:
            prod *= sizes[a]
        if not kept or prod == 0 or dim % prod != 0:
            fixed.append(None)
        else:
            fixed.append(kept if len(kept) > 1 else kept[0])
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def batch_spec(plan_or_axes) -> tuple:
    if isinstance(plan_or_axes, MeshPlan):
        return plan_or_axes.batch_axes
    return tuple(plan_or_axes)


def compute_plan_from_context() -> "MeshPlan | None":
    """MeshPlan for the bf16 COMPUTE copies: model-only sharding (fsdp
    axes empty). Derived from the abstract mesh at trace time; None when
    tracing outside a mesh (smoke tests)."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    batch = tuple(n for n in mesh.axis_names if n != "model")
    return MeshPlan(axis_sizes=sizes, batch_axes=batch, fsdp_axes=())


def constrain_tree(tree, plan: "MeshPlan", *, stacked_root="blocks"):
    """Apply specs_for_tree layouts as sharding constraints (ZeRO-3
    gather point for the compute-cast weights)."""
    specs = specs_for_tree(tree, plan, stacked_root=stacked_root)
    return jax.tree.map(
        lambda x, s: constrain(x, s), tree, specs,
        is_leaf=lambda x: not isinstance(x, dict))
