"""Recurrent mixers: Mamba (jamba), mLSTM / sLSTM (xLSTM).

TPU adaptation notes (DESIGN.md §8):
  * Mamba's selective scan is evaluated in CHUNKED form: within a chunk the
    linear recurrence h_t = a_t h_{t-1} + b_t runs as an associative scan
    (parallel, VPU/MXU friendly); across chunks a short lax.scan carries the
    state. Chunk length bounds the (B, L, d_inner, state) working set.
  * mLSTM keeps the stabilised exponential-gating recurrence of the xLSTM
    paper as a sequential scan (matrix memory C per head); the chunked
    linear-attention formulation is a §Perf hillclimb lever.
  * sLSTM is inherently sequential (hidden-to-hidden recurrence) — scan.

Each mixer has a full-sequence form (train/prefill) and a single-step form
with explicit state (decode); the state replaces the KV cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


# ------------------------------------------------------------------ mamba --

def mamba_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    rank = max(cfg.d_model // 16, 1)
    return di, rank


def init_mamba(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di, rank = mamba_dims(cfg)
    st, cv = cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": layers.dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": layers.dense_init(ks[1], (cv, di), fan_in=cv, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": layers.dense_init(ks[2], (di, rank + 2 * st), dtype=dtype),
        "dt_proj": layers.dense_init(ks[3], (rank, di), dtype=dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "a_log": jnp.log(a).astype(dtype),
        "skip_d": jnp.ones((di,), dtype),
        "out_proj": layers.dense_init(ks[4], (di, d), dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B, S, C), w (K, C)."""
    k, c = w.shape
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :], window_strides=(1,), padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=c)
    return out + b


def _ssm_scan_chunked(a, b, c_mat, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t;  y_t = sum_s h_t[.., s] * c_t[.., s].

    a, b: (B, S, di, st); c_mat: (B, S, st). Associative within chunks,
    sequential across chunks. Returns y (B, S, di) and final h.
    """
    B, S, di, st = a.shape
    nc = S // chunk

    def one_chunk(h, args):
        ac, bc, cc = args                          # (B, L, di, st), (B, L, st)
        cum_a, cum_b = jax.lax.associative_scan(
            lambda x, y: (y[0] * x[0], y[0] * x[1] + y[1]),
            (ac, bc), axis=1)
        h_t = cum_a * h[:, None] + cum_b           # (B, L, di, st)
        y = jnp.einsum("blds,bls->bld", h_t, cc)
        return h_t[:, -1], y

    a_c = a.reshape(B, nc, chunk, di, st).swapaxes(0, 1)
    b_c = b.reshape(B, nc, chunk, di, st).swapaxes(0, 1)
    c_c = c_mat.reshape(B, nc, chunk, st).swapaxes(0, 1)
    h_fin, ys = jax.lax.scan(one_chunk, h0, (a_c, b_c, c_c))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    return y, h_fin


def mamba(params, cfg, x, *, chunk: int = 128):
    """Full-sequence Mamba. x (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, rank = mamba_dims(cfg)
    st = cfg.ssm_state
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1

    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_in, params["conv_w"], params["conv_b"]))

    proj = x_c @ params["x_proj"]
    dt_low, b_mat, c_mat = jnp.split(proj, [rank, rank + st], axis=-1)
    dt = jax.nn.softplus(dt_low @ params["dt_proj"] + params["dt_bias"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))          # (di, st)
    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)     # (B,S,di,st)
    drive = (dt * x_c).astype(jnp.float32)[..., None] \
        * b_mat.astype(jnp.float32)[:, :, None, :]
    h0 = jnp.zeros((B, di, st), jnp.float32)
    y, _ = _ssm_scan_chunked(decay, drive, c_mat.astype(jnp.float32), h0, chunk)
    y = y.astype(x.dtype) + params["skip_d"] * x_c
    return (y * jax.nn.silu(z)) @ params["out_proj"]


def init_mamba_state(cfg, batch, dtype):
    di, _ = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_step(params, cfg, x, state):
    """One decode step. x (B, 1, d) -> ((B, 1, d), new state)."""
    di, rank = mamba_dims(cfg)
    st = cfg.ssm_state
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                        # (B,1,di)
    window = jnp.concatenate([state["conv"], x_in], axis=1)    # (B, cv, di)
    conv = jnp.einsum("bkc,kc->bc", window, params["conv_w"]) \
        + params["conv_b"]
    x_c = jax.nn.silu(conv)[:, None, :]                        # (B,1,di)
    proj = x_c @ params["x_proj"]
    dt_low, b_mat, c_mat = jnp.split(proj, [rank, rank + st], axis=-1)
    dt = jax.nn.softplus(dt_low @ params["dt_proj"] + params["dt_bias"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32)[:, 0, :, None] * a)  # (B,di,st)
    drive = (dt * x_c).astype(jnp.float32)[:, 0, :, None] \
        * b_mat.astype(jnp.float32)[:, 0, None, :]
    h = decay * state["h"] + drive
    y = jnp.einsum("bds,bs->bd", h, c_mat.astype(jnp.float32)[:, 0])
    y = y[:, None, :].astype(x.dtype) + params["skip_d"] * x_c
    out = (y * jax.nn.silu(z)) @ params["out_proj"]
    return out, {"conv": window[:, 1:], "h": h}


# ------------------------------------------------------------------ mLSTM --

def mlstm_dims(cfg):
    di = 2 * cfg.d_model
    nh = cfg.num_heads
    return di, nh, di // nh


def init_mlstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di, nh, hd = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    # q/k/v are block-diagonal per head (xLSTM paper's BlockDiagonal
    # projections): (NH, hd, hd) instead of (di, di) — 4x fewer params.
    return {
        "w_up": layers.dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "wq": layers.dense_init(ks[1], (nh, hd, hd), fan_in=hd, dtype=dtype),
        "wk": layers.dense_init(ks[2], (nh, hd, hd), fan_in=hd, dtype=dtype),
        "wv": layers.dense_init(ks[3], (nh, hd, hd), fan_in=hd, dtype=dtype),
        "w_gates": layers.dense_init(ks[4], (di, 2 * nh), dtype=jnp.float32),
        "b_gates": jnp.concatenate(
            [jnp.zeros((nh,)), jnp.full((nh,), 3.0)]).astype(jnp.float32),
        "w_down": layers.dense_init(ks[5], (di, d), dtype=dtype),
    }


def _mlstm_core(q, k, v, i_pre, f_pre, state):
    """Stabilised mLSTM scan. q/k/v: (B,S,NH,hd); gates: (B,S,NH).
    state: (C (B,NH,hd,hd), n (B,NH,hd), m (B,NH))."""
    hd = q.shape[-1]
    q = q.astype(jnp.float32) / math.sqrt(hd)
    k = k.astype(jnp.float32) / math.sqrt(hd)
    v = v.astype(jnp.float32)

    def step(carry, xs):
        c, n, m = carry
        qt, kt, vt, it, ft = xs                   # (B,NH,hd) / (B,NH)
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        c = f_s[..., None, None] * c + i_s[..., None, None] \
            * (vt[..., :, None] * kt[..., None, :])      # (B,NH,hd_v,hd_k)
        n = f_s[..., None] * n + i_s[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", c, qt)
        # stabilised denominator floor: exp(-m) (the unscaled "1" of the
        # xLSTM paper, in the e^{-m}-scaled state representation)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))
        y = num / den[..., None]
        return (c, n, m_new), y

    xs = tuple(a.swapaxes(0, 1) for a in (q, k, v, i_pre, f_pre))
    (c, n, m), ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1), (c, n, m)           # (B,S,NH,hd)


def _mlstm_qkv(params, x):
    up = x @ params["w_up"]
    di = up.shape[-1] // 2
    x_m, z = up[..., :di], up[..., di:]
    nh, hd, _ = params["wq"].shape
    xh = x_m.reshape(*x_m.shape[:-1], nh, hd)
    q = jnp.einsum("bshk,hkl->bshl", xh, params["wq"])
    k = jnp.einsum("bshk,hkl->bshl", xh, params["wk"])
    v = jnp.einsum("bshk,hkl->bshl", xh, params["wv"])
    gates = x_m.astype(jnp.float32) @ params["w_gates"] + params["b_gates"]
    i_pre, f_pre = gates[..., :nh], jax.nn.log_sigmoid(gates[..., nh:])
    return q, k, v, i_pre, f_pre, z


def init_mlstm_state(cfg, batch):
    _, nh, hd = mlstm_dims(cfg)
    return {"c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, nh, hd), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def _mlstm_core_chunked(q, k, v, i_pre, f_pre, state, *, chunk: int = 128):
    """Chunkwise-parallel mLSTM (stabilised): identical math to
    _mlstm_core, evaluated with intra-chunk masked matmuls + an
    inter-chunk state recurrence. Backward saves only O(S/chunk) chunk
    boundary states instead of one (hd x hd) matrix per timestep —
    this is the §Perf fix for the xlstm train cells (memory term was
    ~2e6 s on the sequential scan).

    Derivation (per head; t, s inside a chunk of length L):
      F_t   = sum_{u<=t} log f_u           (in-chunk cumulative decay)
      D[t,s]= F_t - F_s + log i_s  (t>=s)  (decay matrix)
      m~_t  = max(max_s D[t,s], F_t + m_prev)
      out_t = e^{F_t+m_prev-m~_t} q_t S^_prev
              + sum_s e^{D[t,s]-m~_t} (q_t.k_s) v_s
      n_t   = e^{F_t+m_prev-m~_t} q_t n^_prev + sum_s e^{D[t,s]-m~_t} (q_t.k_s)
      h_t   = out_t / max(|n_t|, e^{-m~_t})
      state carry (scaled by e^{-m}):
        m_new = F_L + max(m_prev, max_s (log i_s - F_s))
        S^_new = e^{m_prev+F_L-m_new} S^_prev
                 + sum_s e^{log i_s+F_L-F_s-m_new} k_s v_s^T
    """
    B, S, NH, hd = q.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32) * scale
    vf = v.astype(jnp.float32)

    def resh(a):  # (B,S,NH,...) -> (nc, B, L, NH, ...)
        return a.reshape(B, nc, chunk, *a.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = resh(qf), resh(kf), resh(vf)
    is_, fs_ = resh(i_pre), resh(f_pre)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def one_chunk(carry, xs):
        c_prev, n_prev, m_prev = carry          # scaled state
        qc, kc, vc, ic, fc = xs                 # (B,L,NH,..)/(B,L,NH)
        F = jnp.cumsum(fc, axis=1)              # (B,L,NH)
        a = ic - F                              # log i_s - F_s
        # D[t,s] = F_t + a_s  (masked to t>=s)
        d_mat = F[:, :, None, :] + a[:, None, :, :]        # (B,t,s,NH)
        d_mat = jnp.where(tri[None, :, :, None], d_mat, -jnp.inf)
        m_intra = d_mat.max(axis=2)                        # (B,L,NH)
        m_tilde = jnp.maximum(m_intra, F + m_prev[:, None, :])
        # intra-chunk attention-style term
        w = jnp.exp(d_mat - m_tilde[:, :, None, :])        # (B,t,s,NH)
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc)         # (B,t,s,NH)
        out_intra = jnp.einsum("btsh,btsh,bshd->bthd", w, qk, vc)
        n_intra = jnp.einsum("btsh,btsh->bth", w, qk)
        # inter-chunk term from carried state
        g = jnp.exp(F + m_prev[:, None, :] - m_tilde)      # (B,L,NH)
        out_inter = jnp.einsum("bthk,bhvk->bthv", qc, c_prev) * g[..., None]
        n_inter = jnp.einsum("bthk,bhk->bth", qc, n_prev) * g
        num = out_intra + out_inter
        den = jnp.maximum(jnp.abs(n_intra + n_inter),
                          jnp.exp(-m_tilde))
        y = num / den[..., None]
        # state update
        f_last = F[:, -1, :]                               # (B,NH)
        a_max = a.max(axis=1)                              # (B,NH)
        m_new = f_last + jnp.maximum(m_prev, a_max)
        decay_state = jnp.exp(m_prev + f_last - m_new)     # (B,NH)
        kv_w = jnp.exp(ic + f_last[:, None, :] - F - m_new[:, None, :])
        # state layout (B, NH, hd_v, hd_k) — matches the sequential core
        c_new = decay_state[..., None, None] * c_prev + jnp.einsum(
            "bshk,bsh,bshv->bhvk", kc, kv_w, vc)
        n_new = decay_state[..., None] * n_prev + jnp.einsum(
            "bshd,bsh->bhd", kc, kv_w)
        return (c_new, n_new, m_new), y

    one_chunk = jax.checkpoint(one_chunk)
    (c, n, m), ys = jax.lax.scan(
        one_chunk, state, (qs, ks, vs, is_, fs_))
    y = ys.swapaxes(0, 1).reshape(B, S, NH, hd)
    return y, (c, n, m)


def mlstm(params, cfg, x, state=None, *, chunked: bool = True):
    """Full-sequence mLSTM block body. x (B, S, d)."""
    B = x.shape[0]
    q, k, v, i_pre, f_pre, z = _mlstm_qkv(params, x)
    st = state or init_mlstm_state(cfg, B)
    core = _mlstm_core_chunked if (chunked and x.shape[1] > 1) \
        else _mlstm_core
    ys, _ = core(q, k, v, i_pre, f_pre, (st["c"], st["n"], st["m"]))
    di = z.shape[-1]
    y = ys.reshape(B, x.shape[1], di).astype(x.dtype)
    return (y * jax.nn.silu(z)) @ params["w_down"]


def mlstm_step(params, cfg, x, state):
    B = x.shape[0]
    q, k, v, i_pre, f_pre, z = _mlstm_qkv(params, x)
    ys, (c, n, m) = _mlstm_core(q, k, v, i_pre, f_pre,
                                (state["c"], state["n"], state["m"]))
    di = z.shape[-1]
    y = ys.reshape(B, 1, di).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ params["w_down"]
    return out, {"c": c, "n": n, "m": m}


# ------------------------------------------------------------------ sLSTM --

def slstm_dims(cfg):
    nh = cfg.num_heads
    return nh, cfg.d_model // nh


def init_slstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    nh, hd = slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    f_ffn = max((4 * d) // 3, 8)
    return {
        "w_gates": layers.dense_init(ks[0], (d, 4 * d), dtype=dtype),
        "b_gates": jnp.concatenate(
            [jnp.zeros((3 * d,)), jnp.full((d,), 1.0)]).astype(jnp.float32),
        "r_gates": layers.dense_init(ks[1], (nh, 4, hd, hd), fan_in=hd,
                                     dtype=dtype) * 0.5,
        "wi": layers.dense_init(ks[2], (d, f_ffn), dtype=dtype),
        "wd": layers.dense_init(ks[3], (f_ffn, d), dtype=dtype),
    }


def init_slstm_state(cfg, batch):
    nh, hd = slstm_dims(cfg)
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z,
            "m": jnp.zeros((batch, nh), jnp.float32)}


def _slstm_core(params, gx, state):
    """gx: (B, S, 4, NH, hd) input-driven gate pre-activations (z,i,f,o).

    On TPU this dispatches to the fused Pallas kernel
    (kernels/slstm_scan.py): state + recurrent weights stay in VMEM for
    the whole sequence instead of round-tripping HBM per timestep. The
    jnp scan below is the oracle/CPU path.
    """
    if jax.default_backend() == "tpu" and gx.shape[1] > 1:
        from repro.kernels.slstm_scan import slstm_scan
        ys, (c, n, h, m) = slstm_scan(
            gx, params["r_gates"].astype(jnp.float32),
            state["c"], state["n"], state["h"], state["m"])
        return ys, {"c": c, "n": n, "h": h, "m": m}
    r = params["r_gates"].astype(jnp.float32)     # (NH, 4, hd, hd)

    def step(carry, g_t):
        c, n, h, m = carry                        # (B,NH,hd) / (B,NH)
        rec = jnp.einsum("bhk,hgkl->bghl", h, r)  # (B,4,NH,hd)
        pre = g_t.astype(jnp.float32) + rec
        z_p, i_p, f_p, o_p = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        i_red = i_p.max(-1)                       # stabilise per head
        f_red = f_p.max(-1)
        m_new = jnp.maximum(f_red + m, i_red)
        i_s = jnp.exp(i_p - m_new[..., None])
        f_s = jnp.exp(f_p + (m - m_new)[..., None])
        z = jnp.tanh(z_p)
        o = jax.nn.sigmoid(o_p)
        c = f_s * c + i_s * z
        n = f_s * n + i_s
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    carry = (state["c"], state["n"], state["h"], state["m"])
    (c, n, h, m), ys = jax.lax.scan(step, carry, gx.swapaxes(0, 1))
    return ys.swapaxes(0, 1), {"c": c, "n": n, "h": h, "m": m}


def _slstm_gx(params, cfg, x):
    B, S, d = x.shape
    nh, hd = slstm_dims(cfg)
    g = x @ params["w_gates"] + params["b_gates"].astype(x.dtype)
    return g.reshape(B, S, 4, nh, hd)


def slstm(params, cfg, x, state=None):
    B, S, d = x.shape
    st = state or init_slstm_state(cfg, B)
    ys, _ = _slstm_core(params, _slstm_gx(params, cfg, x), st)
    y = ys.reshape(B, S, d).astype(x.dtype)
    h = jax.nn.gelu(y @ params["wi"])
    return h @ params["wd"]


def slstm_step(params, cfg, x, state):
    B = x.shape[0]
    ys, new_state = _slstm_core(params, _slstm_gx(params, cfg, x), state)
    y = ys.reshape(B, 1, -1).astype(x.dtype)
    h = jax.nn.gelu(y @ params["wi"])
    return h @ params["wd"], new_state
