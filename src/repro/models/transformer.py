"""Model assembly: periodic LayerProgram scan over heterogeneous blocks.

A config's ``layer_pattern`` defines one *period* (e.g. gemma2 =
("attn_local", "attn"), jamba = 7 mamba + 1 attn). Parameters for each
pattern position are stacked over ``n_periods`` and the whole stack runs as
one ``lax.scan`` (fast compiles for 94-layer models, natural FSDP prefetch
overlap), with ``jax.checkpoint`` (remat) around the period body.

Entry points:
  init_lm / forward                  — training/scoring path.
  init_cache / prefill / decode_step — serving path (KV cache or SSM state).
Whisper (encoder-decoder) adds an encoder stack + cross-attention; its
audio frontend is a stub: callers pass precomputed frame embeddings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe, ssm
from repro.models.sharding import constrain

MAX_WHISPER_POS = 32_768
BATCH = ("pod", "data")  # activation batch axes; constrain() drops absent ones


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _block_has_ffn(cfg, pos):
    return (cfg.ffn_type != "none" and cfg.d_ff > 0
            and cfg.mixer(pos) in ("attn", "attn_local", "mamba"))


def init_block(key, cfg: ModelConfig, pos: int, *, cross=False):
    dtype = _dt(cfg)
    mixer = cfg.mixer(pos)
    ks = jax.random.split(key, 8)
    p = {"ln1": layers.rmsnorm_init(cfg.d_model, dtype)}
    if mixer in ("attn", "attn_local"):
        p["mixer"] = attention.init_attention(ks[0], cfg, dtype=dtype)
    elif mixer == "mamba":
        p["mixer"] = ssm.init_mamba(ks[0], cfg, dtype=dtype)
    elif mixer == "mlstm":
        p["mixer"] = ssm.init_mlstm(ks[0], cfg, dtype=dtype)
    elif mixer == "slstm":
        p["mixer"] = ssm.init_slstm(ks[0], cfg, dtype=dtype)
    else:
        raise ValueError(mixer)
    if cfg.post_norm:
        p["post_ln1"] = layers.rmsnorm_init(cfg.d_model, dtype)
    if cross:
        p["ln_x"] = layers.rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attention.init_attention(ks[1], cfg, dtype=dtype)
    if _block_has_ffn(cfg, pos):
        p["ln2"] = layers.rmsnorm_init(cfg.d_model, dtype)
        if cfg.is_moe_layer(pos):
            p["ffn"] = moe.init_moe(ks[2], cfg, dtype=dtype)
        else:
            p["ffn"] = layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                       cfg.ffn_type, dtype=dtype)
        if cfg.post_norm:
            p["post_ln2"] = layers.rmsnorm_init(cfg.d_model, dtype)
    return p


def _seq_constrain(h):
    """Sequence-parallel residual stream between blocks."""
    return constrain(h, P(BATCH, "model", None))


def apply_block(params, cfg: ModelConfig, pos: int, h, *, causal=True,
                enc_out=None):
    """Full-sequence block application. Returns (h, moe_aux)."""
    mixer = cfg.mixer(pos)
    aux = jnp.float32(0.0)
    x = layers.rmsnorm(params["ln1"], h)
    if mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if mixer == "attn_local" else None
        y = attention.attention(params["mixer"], cfg, x, causal=causal,
                                window=window, attn_softcap=cfg.attn_softcap)
    elif mixer == "mamba":
        y = ssm.mamba(params["mixer"], cfg, x)
    elif mixer == "mlstm":
        y = ssm.mlstm(params["mixer"], cfg, x)
    else:
        y = ssm.slstm(params["mixer"], cfg, x)
    if cfg.post_norm:
        y = layers.rmsnorm(params["post_ln1"], y)
    h = h + y
    if "cross" in params and enc_out is not None:
        x = layers.rmsnorm(params["ln_x"], h)
        y = attention.attention(params["cross"], cfg, x, kv_x=enc_out,
                                causal=False, rope=False)
        h = h + y
    if _block_has_ffn(cfg, pos):
        x = layers.rmsnorm(params["ln2"], h)
        if cfg.is_moe_layer(pos):
            y, aux = moe.moe_ffn(params["ffn"], cfg, x)
        else:
            y = layers.mlp(params["ffn"], x, cfg.ffn_type)
        if cfg.post_norm:
            y = layers.rmsnorm(params["post_ln2"], y)
        h = h + y
    return _seq_constrain(h), aux


def _stack_init(key, cfg: ModelConfig, *, cross=False):
    blocks = {}
    for pos in range(cfg.period):
        kpos = jax.random.fold_in(key, pos)
        pks = jax.random.split(kpos, cfg.n_periods)
        blocks[f"pos{pos}"] = jax.vmap(
            lambda k: init_block(k, cfg, pos, cross=cross))(pks)
    return blocks


def _stack_apply(blocks, cfg: ModelConfig, h, *, causal=True, enc_out=None,
                 remat=True, block_cast=None):
    def period_fn(carry, period_params):
        hh, aux = carry
        if block_cast is not None:
            # ZeRO-3 gather point: cast this period's master slice to the
            # compute dtype and re-constrain to model-only sharding. Done
            # INSIDE the scan so only one period's gathered weights live.
            from repro.models import precision
            period_params = precision.cast_tree(
                period_params, block_cast, constrain_model_only=True,
                stacked=False)
        for pos in range(cfg.period):
            hh, a = apply_block(period_params[f"pos{pos}"], cfg, pos, hh,
                                causal=causal, enc_out=enc_out)
            aux = aux + a
        return (hh, aux), None

    if remat:
        period_fn = jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(period_fn, (h, jnp.float32(0.0)), blocks)
    return h, aux


# -------------------------------------------------------------- top level --

def init_lm(key, cfg: ModelConfig):
    dtype = _dt(cfg)
    ks = jax.random.split(key, 6)
    params = {
        "embed": layers.init_embed(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": _stack_init(ks[1], cfg, cross=cfg.cross_attention),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"head_w": layers.dense_init(
            ks[2], (cfg.d_model, cfg.vocab_size), dtype=dtype)}
    if cfg.encoder_layers:
        enc_cfg = cfg  # same dims; encoder is bidirectional, non-cross
        params["enc_blocks"] = _stack_init_encoder(ks[3], enc_cfg)
        params["enc_norm"] = layers.rmsnorm_init(cfg.d_model, dtype)
    if cfg.rope_theta == 0.0:
        params["pos"] = {"pos_table": (jax.random.normal(
            ks[4], (MAX_WHISPER_POS, cfg.d_model), jnp.float32) * 0.01
        ).astype(dtype)}
    return params


def _stack_init_encoder(key, cfg):
    import dataclasses
    enc = dataclasses.replace(cfg, layer_pattern=("attn",),
                              num_layers=cfg.encoder_layers,
                              cross_attention=False, num_experts=0)
    return _stack_init(key, enc, cross=False)


def _enc_cfg(cfg):
    import dataclasses
    return dataclasses.replace(cfg, layer_pattern=("attn",),
                               num_layers=cfg.encoder_layers,
                               cross_attention=False, num_experts=0)


def encode(params, cfg: ModelConfig, frames):
    """Whisper-style encoder over stub frame embeddings (B, S_enc, D)."""
    # match the live parameter dtype (params may be bf16-cast for compute)
    h = frames.astype(params["enc_norm"]["scale"].dtype)
    h = h + layers.sinusoidal_positions(h.shape[1], cfg.d_model, h.dtype)
    h, _ = _stack_apply(params["enc_blocks"], _enc_cfg(cfg), h, causal=False)
    return layers.rmsnorm(params["enc_norm"], h)


def _embed_tokens(params, cfg, tokens, pos_offset=0):
    h = layers.embed(params["embed"], tokens, scale=cfg.scale_embed)
    if cfg.rope_theta == 0.0:
        S = tokens.shape[1]
        h = h + jax.lax.dynamic_slice_in_dim(
            params["pos"]["pos_table"], pos_offset, S, 0)
    return h


def _logits(params, cfg, h):
    h = layers.rmsnorm(params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["table"])
    else:
        logits = h @ params["head"]["head_w"]
    logits = layers.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return constrain(logits, P(BATCH, None, "model"))


def forward(params, cfg: ModelConfig, tokens, *, enc_frames=None,
            remat=True, features=False, block_cast=None):
    """Training/scoring forward. tokens (B, S) -> logits (B, S, V) f32.

    Returns (logits, moe_aux). With features=True returns the final hidden
    states instead of logits (the OBP embedding hook). block_cast: cast
    block weights to this dtype per-period inside the scan (training
    mixed-precision path)."""
    h = _embed_tokens(params, cfg, tokens)
    h = _seq_constrain(h)
    enc_out = None
    if cfg.encoder_layers and enc_frames is not None:
        enc_params = params
        if block_cast is not None:
            from repro.models import precision
            enc_params = dict(params)
            enc_params["enc_blocks"] = precision.cast_tree(
                params["enc_blocks"], block_cast)
            enc_params["enc_norm"] = precision.cast_tree(
                params["enc_norm"], block_cast)
        enc_out = encode(enc_params, cfg, enc_frames)
    h, aux = _stack_apply(params["blocks"], cfg, h, causal=True,
                          enc_out=enc_out, remat=remat,
                          block_cast=block_cast)
    if features:
        return layers.rmsnorm(params["final_norm"], h), aux
    return _logits(params, cfg, h), aux


# ----------------------------------------------------------------- serving --

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache: per pattern position, stacked over periods."""
    dtype = jnp.dtype(cfg.compute_dtype)
    cache = {}

    def stacked(make_one):
        one = make_one()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape).copy(), one)

    for pos in range(cfg.period):
        mixer = cfg.mixer(pos)
        if mixer in ("attn", "attn_local"):
            # local layers ring-buffer the window: L = min(window, max_len)
            L = max_len
            if mixer == "attn_local" and cfg.sliding_window:
                L = min(cfg.sliding_window, max_len)
            cache[f"pos{pos}"] = stacked(
                lambda L=L: attention.init_kv_cache(cfg, batch, L, dtype))
        elif mixer == "mamba":
            cache[f"pos{pos}"] = stacked(
                lambda: ssm.init_mamba_state(cfg, batch, dtype))
        elif mixer == "mlstm":
            cache[f"pos{pos}"] = stacked(
                lambda: ssm.init_mlstm_state(cfg, batch))
        else:
            cache[f"pos{pos}"] = stacked(
                lambda: ssm.init_slstm_state(cfg, batch))
    if cfg.encoder_layers:
        cache["enc_out"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                     dtype)
    return cache


def decode_block(params, cfg, pos, h, cache, t, *, enc_out=None):
    """One-token decode through one block. cache: this block's slice."""
    mixer = cfg.mixer(pos)
    x = layers.rmsnorm(params["ln1"], h)
    if mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if mixer == "attn_local" else None
        y, cache = attention.decode_attention(
            params["mixer"], cfg, x, cache, t, window=window,
            attn_softcap=cfg.attn_softcap)
    elif mixer == "mamba":
        y, cache = ssm.mamba_step(params["mixer"], cfg, x, cache)
    elif mixer == "mlstm":
        y, cache = ssm.mlstm_step(params["mixer"], cfg, x, cache)
    else:
        y, cache = ssm.slstm_step(params["mixer"], cfg, x, cache)
    if cfg.post_norm:
        y = layers.rmsnorm(params["post_ln1"], y)
    h = h + y
    if "cross" in params and enc_out is not None:
        x = layers.rmsnorm(params["ln_x"], h)
        y = attention.attention(params["cross"], cfg, x, kv_x=enc_out,
                                causal=False, rope=False)
        h = h + y
    if _block_has_ffn(cfg, pos):
        x = layers.rmsnorm(params["ln2"], h)
        if cfg.is_moe_layer(pos):
            y, _ = moe.moe_ffn(params["ffn"], cfg, x)
        else:
            y = layers.mlp(params["ffn"], x, cfg.ffn_type)
        if cfg.post_norm:
            y = layers.rmsnorm(params["post_ln2"], y)
        h = h + y
    return h, cache


def decode_step(params, cfg: ModelConfig, token, cache, t):
    """token (B,) int32, t scalar int32 (current position). Returns
    (logits (B, V) f32, new cache)."""
    h = _embed_tokens_decode(params, cfg, token, t)
    enc_out = cache.get("enc_out") if cfg.encoder_layers else None

    def period_fn(h, xs):
        period_params, period_cache = xs
        new_cache = {}
        for pos in range(cfg.period):
            h, new_cache[f"pos{pos}"] = decode_block(
                period_params[f"pos{pos}"], cfg, pos, h,
                period_cache[f"pos{pos}"], t, enc_out=enc_out)
        return h, new_cache

    block_cache = {k: v for k, v in cache.items() if k.startswith("pos")}
    h, new_block_cache = jax.lax.scan(
        period_fn, h, (params["blocks"], block_cache))
    logits = _logits(params, cfg, h)[:, 0]
    out_cache = dict(new_block_cache)
    if cfg.encoder_layers:
        out_cache["enc_out"] = cache["enc_out"]
    return logits, out_cache


def _embed_tokens_decode(params, cfg, token, t):
    h = layers.embed(params["embed"], token[:, None], scale=cfg.scale_embed)
    if cfg.rope_theta == 0.0:
        h = h + jax.lax.dynamic_slice_in_dim(
            params["pos"]["pos_table"], t, 1, 0)[None]
    return h


def prefill(params, cfg: ModelConfig, tokens, max_len: int, *,
            enc_frames=None):
    """Full-sequence prefill: returns (last-token logits, filled cache).

    Attention k/v are projected once for the whole prompt and written into
    the cache; SSM/xLSTM blocks return their final recurrent state.
    """
    B, S = tokens.shape
    dtype = jnp.dtype(cfg.compute_dtype)
    h = _embed_tokens(params, cfg, tokens)
    h = _seq_constrain(h)
    enc_out = None
    if cfg.encoder_layers and enc_frames is not None:
        enc_out = encode(params, cfg, enc_frames)

    def period_fn(h, period_params):
        new_cache = {}
        for pos in range(cfg.period):
            p = period_params[f"pos{pos}"]
            mixer = cfg.mixer(pos)
            x = layers.rmsnorm(p["ln1"], h)
            if mixer in ("attn", "attn_local"):
                window = cfg.sliding_window if mixer == "attn_local" else None
                y = attention.attention(p["mixer"], cfg, x, causal=True,
                                        window=window,
                                        attn_softcap=cfg.attn_softcap)
                # re-project k/v for the cache (cheap vs attention itself)
                _, k, v = attention._project_qkv(p["mixer"], x, x)
                if cfg.rope_theta:
                    cos, sin = layers.rope_angles(
                        jnp.arange(S), cfg.resolved_head_dim, cfg.rope_theta)
                    k = layers.apply_rope(k, cos, sin)
                L = max_len
                if window is not None:
                    L = min(window, max_len)
                kv = {"k": jnp.zeros((B, L) + k.shape[2:], dtype),
                      "v": jnp.zeros((B, L) + v.shape[2:], dtype)}
                if L >= S:
                    kv["k"] = jax.lax.dynamic_update_slice(
                        kv["k"], k.astype(dtype), (0, 0, 0, 0))
                    kv["v"] = jax.lax.dynamic_update_slice(
                        kv["v"], v.astype(dtype), (0, 0, 0, 0))
                else:
                    # ring fill: keep the last L tokens at slots t % L
                    t0 = S - L
                    idx = (t0 + jnp.arange(L)) % L
                    kv["k"] = kv["k"].at[:, idx].set(
                        k[:, t0:].astype(dtype))
                    kv["v"] = kv["v"].at[:, idx].set(
                        v[:, t0:].astype(dtype))
                new_cache[f"pos{pos}"] = kv
            elif mixer == "mamba":
                y, st = _mamba_with_state(p["mixer"], cfg, x)
                new_cache[f"pos{pos}"] = st
            elif mixer == "mlstm":
                y, st = _mlstm_with_state(p["mixer"], cfg, x)
                new_cache[f"pos{pos}"] = st
            else:
                y, st = _slstm_with_state(p["mixer"], cfg, x)
                new_cache[f"pos{pos}"] = st
            if cfg.post_norm:
                y = layers.rmsnorm(p["post_ln1"], y)
            h = h + y
            if "cross" in p and enc_out is not None:
                x = layers.rmsnorm(p["ln_x"], h)
                h = h + attention.attention(p["cross"], cfg, x, kv_x=enc_out,
                                            causal=False, rope=False)
            if _block_has_ffn(cfg, pos):
                x = layers.rmsnorm(p["ln2"], h)
                if cfg.is_moe_layer(pos):
                    y, _ = moe.moe_ffn(p["ffn"], cfg, x)
                else:
                    y = layers.mlp(p["ffn"], x, cfg.ffn_type)
                if cfg.post_norm:
                    y = layers.rmsnorm(p["post_ln2"], y)
                h = h + y
            h = _seq_constrain(h)
        return h, new_cache

    h, cache = jax.lax.scan(period_fn, h, params["blocks"])
    if cfg.encoder_layers:
        cache["enc_out"] = (enc_out if enc_out is not None else
                            jnp.zeros((B, cfg.encoder_seq, cfg.d_model), dtype))
    logits = _logits(params, cfg, h[:, -1:])[:, 0]
    return logits, cache


def _mamba_with_state(p, cfg, x):
    """Mamba full-seq + final decode state (conv tail + ssm h)."""
    B, S, _ = x.shape
    di, rank = ssm.mamba_dims(cfg)
    st = cfg.ssm_state
    chunk = min(128, S)
    while S % chunk:
        chunk -= 1
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(ssm._causal_conv(x_in, p["conv_w"], p["conv_b"]))
    proj = x_c @ p["x_proj"]
    dt_low, b_mat, c_mat = jnp.split(proj, [rank, rank + st], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)
    drive = (dt * x_c).astype(jnp.float32)[..., None] \
        * b_mat.astype(jnp.float32)[:, :, None, :]
    h0 = jnp.zeros((B, di, st), jnp.float32)
    y, h_fin = ssm._ssm_scan_chunked(decay, drive, c_mat.astype(jnp.float32),
                                     h0, chunk)
    y = y.astype(x.dtype) + p["skip_d"] * x_c
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    tail = x_in[:, -(cfg.ssm_conv - 1):, :]
    return out, {"conv": tail.astype(jnp.dtype(cfg.compute_dtype)), "h": h_fin}


def _mlstm_with_state(p, cfg, x):
    B = x.shape[0]
    q, k, v, i_pre, f_pre, z = ssm._mlstm_qkv(p, x)
    st0 = ssm.init_mlstm_state(cfg, B)
    ys, (c, n, m) = ssm._mlstm_core_chunked(
        q, k, v, i_pre, f_pre, (st0["c"], st0["n"], st0["m"]))
    di = z.shape[-1]
    y = ys.reshape(B, x.shape[1], di).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["w_down"]
    return out, {"c": c, "n": n, "m": m}


def _slstm_with_state(p, cfg, x):
    B, S, d = x.shape
    st0 = ssm.init_slstm_state(cfg, B)
    ys, new_state = ssm._slstm_core(p, ssm._slstm_gx(p, cfg, x), st0)
    y = ys.reshape(B, S, d).astype(x.dtype)
    h = jax.nn.gelu(y @ p["wi"])
    return h @ p["wd"], new_state
