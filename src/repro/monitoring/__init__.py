"""Observability package: step timing + CSV logs (``metrics``) and the
unified telemetry substrate (``telemetry``: metrics registry, span
tracing, profiler hooks, Prometheus exposition — DESIGN.md §10)."""
from .metrics import CSVLogger, StepTimer  # noqa: F401
from .telemetry import (  # noqa: F401
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    MetricsServer,
    SpanTracer,
    Telemetry,
    default_telemetry,
    registry,
    resolve,
    start_metrics_server,
)
