from .metrics import CSVLogger, StepTimer  # noqa: F401
