"""Run monitoring: step timing, EMA-based straggler detection, CSV logs.

Straggler mitigation at fleet scale is (1) detect — per-step wall time vs
an EMA envelope, (2) report — flagged steps land in the log for the
scheduler/operator, (3) recover — checkpoint/restart excludes the slow
host (launch scripts). This module implements (1) and (2); (3) is the
checkpoint + launcher path.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StepTimer:
    ema_decay: float = 0.95
    threshold: float = 2.0          # x EMA => straggler
    warmup: int = 3                 # ignore compile steps

    count: int = 0
    ema: float = 0.0
    stragglers: int = 0
    _t0: float = 0.0
    history: list = dataclasses.field(default_factory=list)

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.record(time.perf_counter() - self._t0)

    def record(self, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self.count += 1
        self.history.append(dt)
        if self.count <= self.warmup:
            self.ema = dt
            return False
        flagged = dt > self.threshold * self.ema
        if flagged:
            self.stragglers += 1
        self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return flagged


class CSVLogger:
    def __init__(self, path: str, fields):
        self.path = path
        self.fields = list(fields)
        with open(path, "w") as f:
            f.write(",".join(self.fields) + "\n")

    def log(self, **kw):
        with open(self.path, "a") as f:
            f.write(",".join(str(kw.get(k, "")) for k in self.fields) + "\n")
