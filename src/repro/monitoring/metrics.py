"""Run monitoring: step timing, EMA-based straggler detection, CSV logs.

Straggler mitigation at fleet scale is (1) detect — per-step wall time vs
an EMA envelope, (2) report — flagged steps land in the log for the
scheduler/operator, (3) recover — checkpoint/restart excludes the slow
host (launch scripts). This module implements (1) and (2); (3) is the
checkpoint + launcher path.

The telemetry substrate (registry / spans / exposition) lives in
``monitoring/telemetry.py`` (DESIGN.md §10); this module keeps the two
small host-side utilities the solver report and serving engine embed.
"""
from __future__ import annotations

import collections
import csv
import dataclasses
import os
import time


@dataclasses.dataclass
class StepTimer:
    """Per-step wall-time tracker: EMA envelope, straggler flags,
    percentile summary (``warmup`` steps excluded — compiles).

    ``window`` bounds the retained history to a ring of the most recent
    steps (default 4096). A long-running serving process records one
    step per micro-batch forever; the unbounded list this used to keep
    was a slow leak — and its percentiles averaged the whole process
    lifetime, so yesterday's latencies diluted today's regression.
    **Semantics change:** ``summary()`` percentiles now describe the
    last ``window`` steps (warmup still excluded while it remains in
    the ring), and ``count`` is the number of steps *in that window*,
    not since birth — ``total_recorded`` keeps the lifetime count.
    ``window=None`` restores the unbounded history.

    Not thread-safe: ``record()`` mutates count/ema/history and the
    ``with timer:`` form shares one ``_t0`` slot. Multi-threaded callers
    must serialise — the serving engine times each kernel call with a
    local ``perf_counter`` pair and calls ``record(dt)`` under its
    bookkeeping lock (DESIGN.md §9a)."""
    ema_decay: float = 0.95
    threshold: float = 2.0          # x EMA => straggler
    warmup: int = 3                 # ignore compile steps
    window: int | None = 4096       # history ring size (None = unbounded)

    count: int = 0
    ema: float = 0.0
    stragglers: int = 0
    _t0: float = 0.0
    history: collections.deque = None  # built in __post_init__

    def __post_init__(self):
        if self.window is not None and self.window < 1:
            raise ValueError(f"window must be >= 1 (or None), got "
                             f"{self.window}")
        self.history = collections.deque(self.history or (),
                                         maxlen=self.window)

    @property
    def total_recorded(self) -> int:
        """Lifetime number of recorded steps (``count`` mirrors it; the
        windowed population size lives in ``summary()['count']``)."""
        return self.count

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.record(time.perf_counter() - self._t0)

    def record(self, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler.

        The first post-warmup step *seeds* the steady-state EMA instead
        of being compared against it: during warmup the EMA holds a
        compile-step time, so comparing the first real step against it
        could never flag (compile dwarfs steady steps) *and* the compile
        value would bleed into the EMA through the decay — every later
        threshold would be inflated until the decay washed it out.
        """
        self.count += 1
        self.history.append(dt)
        if self.count <= self.warmup:
            self.ema = dt
            return False
        if self.count == self.warmup + 1:
            self.ema = dt          # seed from the first steady step
            return False
        flagged = dt > self.threshold * self.ema
        if flagged:
            self.stragglers += 1
        self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return flagged

    def summary(self) -> dict:
        """Wall-time percentiles over the retained (windowed) steps,
        warmup excluded when enough post-warmup samples exist (the
        warmup steps are compile time, which would dominate every
        percentile; once the ring has rotated past them they are gone
        anyway). ``count`` is the number of steps the statistics are
        actually over; ``warmup_excluded`` says how many leading steps
        were dropped *from the current window*. Keys ``{"count",
        "warmup_excluded", "p50", "p95", "max", "mean", "stragglers"}``
        — consumed by ``runtime.SolveReport`` and the serving engine's
        stats()."""
        hist = list(self.history)
        # Warmup samples still in the ring: the first `warmup` records
        # ever made, minus however many the ring has already evicted.
        in_window = max(0, self.warmup - (self.count - len(hist)))
        steady = hist[in_window:] or hist
        excluded = len(hist) - len(steady)
        if not steady:
            return {"count": 0, "warmup_excluded": 0, "p50": 0.0,
                    "p95": 0.0, "max": 0.0, "mean": 0.0,
                    "stragglers": self.stragglers}
        xs = sorted(steady)

        def pct(q: float) -> float:
            return xs[min(len(xs) - 1, int(q * len(xs)))]

        return {"count": len(steady), "warmup_excluded": excluded,
                "p50": pct(0.50), "p95": pct(0.95),
                "max": xs[-1], "mean": sum(xs) / len(xs),
                "stragglers": self.stragglers}


class CSVLogger:
    """Append-only CSV with real quoting, durable writes, and rotation.

    The former implementation joined raw ``str(value)`` with commas — a
    logged value containing a comma or newline silently sheared every
    later column — and reopened the file per row with no flush, so a
    SIGKILL could lose the tail of the log. Now: the ``csv`` module
    quotes per RFC 4180, one handle stays open (``newline=""`` so the
    writer controls line endings), and every row is flushed to the OS on
    write. Usable as a context manager; ``close()`` is idempotent.

    **Append semantics** (``mode="a"``, the default): an existing log
    whose header line matches ``fields`` is continued, not truncated —
    the old ``mode="w"`` behaviour meant a snapshot-resumed serving
    process (``snapshot_resume="auto"``, DESIGN.md §9a) wiped its own
    pre-kill log on reboot. A header mismatch (schema drift) rotates
    the old file aside rather than interleaving two schemas; an empty
    or fresh file gets the header written. ``mode="w"`` keeps the
    explicit truncate-on-open for run-scoped logs.

    **Rotation** (``max_bytes``): when the file exceeds ``max_bytes``
    after a write, it is closed, renamed to ``path.1`` (existing
    backups shift up to ``path.{backups}``; the oldest falls off), and
    a fresh file with the header takes its place — a serving process
    can log forever on bounded disk.
    """

    def __init__(self, path: str, fields, *, mode: str = "a",
                 max_bytes: int | None = None, backups: int = 1):
        if mode not in ("a", "w"):
            raise ValueError(f"mode must be 'a' or 'w', got {mode!r}")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        if backups < 1:
            raise ValueError(f"backups must be >= 1, got {backups}")
        self.path = path
        self.fields = list(fields)
        self.max_bytes = max_bytes
        self.backups = int(backups)
        self.rotations = 0
        if mode == "a" and self._existing_header_mismatch():
            self._rotate_files()          # schema drift: old log aside
        self._open(mode)

    def _existing_header_mismatch(self) -> bool:
        try:
            with open(self.path, newline="") as f:
                head = next(csv.reader(f), None)
        except OSError:
            return False
        return head is not None and head != self.fields

    def _open(self, mode: str) -> None:
        self._f = open(self.path, mode, newline="")
        self._w = csv.writer(self._f)
        if mode == "w" or self._f.tell() == 0:
            self._w.writerow(self.fields)
            self._f.flush()

    def _rotate_files(self) -> None:
        for i in range(self.backups, 1, -1):
            src = f"{self.path}.{i - 1}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i}")
        if os.path.exists(self.path):
            os.replace(self.path, f"{self.path}.1")

    def log(self, **kw):
        self._w.writerow([kw.get(k, "") for k in self.fields])
        self._f.flush()
        if (self.max_bytes is not None
                and self._f.tell() > self.max_bytes):
            self._f.close()
            self._rotate_files()
            self.rotations += 1
            self._open("w")

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
