"""Run monitoring: step timing, EMA-based straggler detection, CSV logs.

Straggler mitigation at fleet scale is (1) detect — per-step wall time vs
an EMA envelope, (2) report — flagged steps land in the log for the
scheduler/operator, (3) recover — checkpoint/restart excludes the slow
host (launch scripts). This module implements (1) and (2); (3) is the
checkpoint + launcher path.
"""
from __future__ import annotations

import csv
import dataclasses
import time


@dataclasses.dataclass
class StepTimer:
    """Per-step wall-time tracker: EMA envelope, straggler flags,
    percentile summary (``warmup`` steps excluded — compiles).

    Not thread-safe: ``record()`` mutates count/ema/history and the
    ``with timer:`` form shares one ``_t0`` slot. Multi-threaded callers
    must serialise — the serving engine times each kernel call with a
    local ``perf_counter`` pair and calls ``record(dt)`` under its
    bookkeeping lock (DESIGN.md §9a)."""
    ema_decay: float = 0.95
    threshold: float = 2.0          # x EMA => straggler
    warmup: int = 3                 # ignore compile steps

    count: int = 0
    ema: float = 0.0
    stragglers: int = 0
    _t0: float = 0.0
    history: list = dataclasses.field(default_factory=list)

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.record(time.perf_counter() - self._t0)

    def record(self, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler.

        The first post-warmup step *seeds* the steady-state EMA instead
        of being compared against it: during warmup the EMA holds a
        compile-step time, so comparing the first real step against it
        could never flag (compile dwarfs steady steps) *and* the compile
        value would bleed into the EMA through the decay — every later
        threshold would be inflated until the decay washed it out.
        """
        self.count += 1
        self.history.append(dt)
        if self.count <= self.warmup:
            self.ema = dt
            return False
        if self.count == self.warmup + 1:
            self.ema = dt          # seed from the first steady step
            return False
        flagged = dt > self.threshold * self.ema
        if flagged:
            self.stragglers += 1
        self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return flagged

    def summary(self) -> dict:
        """Wall-time percentiles over the recorded steps, warmup
        excluded when enough post-warmup samples exist (the warmup steps
        are compile time, which would dominate every percentile).
        ``count`` is the number of steps the statistics are actually
        over (it used to report ``self.count`` — warmup included — while
        p50/p95/mean excluded warmup, so count and percentiles described
        different populations); ``warmup_excluded`` says how many
        leading steps were dropped. Keys ``{"count", "warmup_excluded",
        "p50", "p95", "max", "mean", "stragglers"}`` — consumed by
        ``runtime.SolveReport`` and the serving engine's stats()."""
        steady = self.history[self.warmup:] or self.history
        excluded = len(self.history) - len(steady)
        if not steady:
            return {"count": 0, "warmup_excluded": 0, "p50": 0.0,
                    "p95": 0.0, "max": 0.0, "mean": 0.0,
                    "stragglers": self.stragglers}
        xs = sorted(steady)

        def pct(q: float) -> float:
            return xs[min(len(xs) - 1, int(q * len(xs)))]

        return {"count": len(steady), "warmup_excluded": excluded,
                "p50": pct(0.50), "p95": pct(0.95),
                "max": xs[-1], "mean": sum(xs) / len(xs),
                "stragglers": self.stragglers}


class CSVLogger:
    """Append-only CSV with real quoting and durable writes.

    The former implementation joined raw ``str(value)`` with commas — a
    logged value containing a comma or newline silently sheared every
    later column — and reopened the file per row with no flush, so a
    SIGKILL could lose the tail of the log. Now: the ``csv`` module
    quotes per RFC 4180, one handle stays open (``newline=""`` so the
    writer controls line endings), and every row is flushed to the OS on
    write. Usable as a context manager; ``close()`` is idempotent.
    """

    def __init__(self, path: str, fields):
        self.path = path
        self.fields = list(fields)
        self._f = open(path, "w", newline="")
        self._w = csv.writer(self._f)
        self._w.writerow(self.fields)
        self._f.flush()

    def log(self, **kw):
        self._w.writerow([kw.get(k, "") for k in self.fields])
        self._f.flush()

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
