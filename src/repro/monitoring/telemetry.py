"""Unified telemetry: metrics registry, span tracing, profiler hooks,
Prometheus exposition (DESIGN.md §10).

One instrumentation substrate answers "where did the microseconds go"
across the solve runtime (sweeps, checkpoints, guard firings), the
pruned sweep's pruning effectiveness, and the serving engine (per-micro-
batch latency, quarantines, drift, refits, breaker transitions) —
without perturbing the bitwise-pinned jitted hot paths. Four pieces,
zero dependencies beyond the stdlib (``jax`` is imported lazily and only
for the opt-in profiler hooks):

  * **Metrics registry** — named :class:`Counter` / :class:`Gauge` /
    :class:`Histogram` primitives with label support, get-or-create
    through a :class:`MetricsRegistry` (process-wide default:
    :func:`registry`). Every mutation is lock-protected; concurrent
    ``inc()`` from serving threads cannot lose updates
    (tests/test_monitoring.py races it).
  * **Span tracing** — :class:`SpanTracer` nestable context-manager
    spans on the monotonic clock, buffered as Chrome trace events
    (:meth:`SpanTracer.write_chrome_trace` loads in Perfetto /
    chrome://tracing) and optionally streamed to a durable JSONL event
    log. The trace export reuses the ``checkpoint/`` discipline: write
    to ``path.tmp``, fsync, atomic rename, fsync the directory — a
    killed exporter can never leave a torn trace where a valid one
    stood. The event buffer is a bounded ring (``max_events``) with a
    drop counter, so a long-running serving process cannot leak.
  * **Profiler hooks** — an opt-in ``profile_dir=`` on
    :class:`Telemetry` wraps hot calls in ``jax.profiler``
    trace annotations (:meth:`Telemetry.annotate`) and fences with
    ``block_until_ready`` (:meth:`Telemetry.fence`) *in profile mode
    only* — with profiling off both are free no-ops, so the pinned
    paths never gain a device sync they didn't have.
  * **Prometheus exposition** — :meth:`MetricsRegistry.render_prometheus`
    emits the text format (``# HELP`` / ``# TYPE`` / samples;
    histograms as cumulative ``_bucket{le=}`` + ``_sum`` + ``_count``),
    and :func:`start_metrics_server` serves it from a stdlib
    ``ThreadingHTTPServer`` on ``GET /metrics``.

The overhead contract: ``telemetry="off"`` resolves to ``None``
(:func:`resolve`), so instrumented call sites guard with one ``is not
None`` check and the off path stays the untouched jitted path —
pinned absolutely by the ``telemetry_overhead_vs_off <= 1.5x`` bench
gate (benchmarks/kernel_bench.py, benchmarks/serving_bench.py,
tools/bench_compare.py vs BENCH_PR10.json).
"""
from __future__ import annotations

import bisect
import collections
import contextlib
import http.server
import io
import json
import math
import os
import re
import threading
import time

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(
            f"invalid metric name {name!r} (prometheus names match "
            "[a-zA-Z_:][a-zA-Z0-9_:]*)")
    return name


def _labelkey(labels: dict) -> tuple:
    for k in labels:
        if not _LABEL_RE.match(k):
            raise ValueError(f"invalid label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(key: tuple, extra: tuple = ()) -> str:
    items = list(key) + list(extra)
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{_escape(v)}"' for k, v in items) + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


class _Metric:
    """Shared label-series plumbing. Subclasses define the per-series
    state and the exposition lines."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = _check_name(name)
        self.help = help
        self._lock = threading.Lock()
        self._series: dict[tuple, object] = {}

    def labelsets(self) -> list[dict]:
        with self._lock:
            return [dict(k) for k in self._series]


class Counter(_Metric):
    """Monotonic counter. ``inc(amount, **labels)``; negative increments
    raise (that is what a Gauge is for)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(
                f"counter {self.name} cannot decrease (inc {amount})")
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_labelkey(labels), 0.0))

    def total(self) -> float:
        """Sum over every label series."""
        with self._lock:
            return float(sum(self._series.values()))

    def _render(self, out: io.StringIO) -> None:
        with self._lock:
            for key, v in sorted(self._series.items()):
                out.write(f"{self.name}{_fmt_labels(key)} {_fmt_value(v)}\n")


class Gauge(_Metric):
    """Last-write-wins instantaneous value; ``set``/``add``."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = float(value)

    def add(self, amount: float, **labels) -> None:
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_labelkey(labels), 0.0))

    def _render(self, out: io.StringIO) -> None:
        with self._lock:
            for key, v in sorted(self._series.items()):
                out.write(f"{self.name}{_fmt_labels(key)} {_fmt_value(v)}\n")


#: Default histogram buckets: wall-clock seconds from 10 us to 60 s —
#: covers a micro-batch kernel call through a whole checkpointed solve.
DEFAULT_BUCKETS = (1e-5, 1e-4, 1e-3, 5e-3, 0.01, 0.05, 0.1, 0.5, 1.0,
                   5.0, 10.0, 60.0)


class _HistState:
    __slots__ = ("buckets", "counts", "sum", "count", "min", "max")

    def __init__(self, buckets: tuple):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf


class Histogram(_Metric):
    """Fixed-bucket histogram (cumulative ``le`` exposition). The bucket
    layout is per-metric, set at creation; ``observe`` is O(log B)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: tuple | None = None):
        super().__init__(name, help)
        bk = tuple(sorted(float(b) for b in (buckets or DEFAULT_BUCKETS)))
        if not bk:
            raise ValueError(f"histogram {name} needs >= 1 bucket bound")
        self.buckets = bk

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        key = _labelkey(labels)
        with self._lock:
            st = self._series.get(key)
            if st is None:
                st = self._series[key] = _HistState(self.buckets)
            st.counts[bisect.bisect_left(st.buckets, v)] += 1
            st.sum += v
            st.count += 1
            st.min = min(st.min, v)
            st.max = max(st.max, v)

    def summary(self, **labels) -> dict:
        """{"count", "sum", "mean", "min", "max"} for one label set
        (zeros when nothing was observed)."""
        with self._lock:
            st = self._series.get(_labelkey(labels))
            if st is None or st.count == 0:
                return {"count": 0, "sum": 0.0, "mean": 0.0,
                        "min": 0.0, "max": 0.0}
            return {"count": st.count, "sum": st.sum,
                    "mean": st.sum / st.count, "min": st.min,
                    "max": st.max}

    def _render(self, out: io.StringIO) -> None:
        with self._lock:
            for key, st in sorted(self._series.items()):
                cum = 0
                for bound, c in zip(st.buckets, st.counts):
                    cum += c
                    le = (("le", _fmt_value(bound)),)
                    out.write(f"{self.name}_bucket{_fmt_labels(key, le)} "
                              f"{cum}\n")
                out.write(f"{self.name}_bucket"
                          f"{_fmt_labels(key, (('le', '+Inf'),))} "
                          f"{st.count}\n")
                out.write(f"{self.name}_sum{_fmt_labels(key)} "
                          f"{_fmt_value(st.sum)}\n")
                out.write(f"{self.name}_count{_fmt_labels(key)} "
                          f"{st.count}\n")


class MetricsRegistry:
    """Get-or-create home for named metrics. Re-requesting a name
    returns the existing instance; re-requesting it as a different kind
    raises (a counter silently shadowing a histogram is the classic
    split-brain dashboard bug)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested as {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple | None = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def metrics(self) -> dict[str, _Metric]:
        with self._lock:
            return dict(self._metrics)

    def reset(self) -> None:
        """Drop every metric (tests; a process-wide registry accretes)."""
        with self._lock:
            self._metrics.clear()

    def render_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4 of every metric."""
        out = io.StringIO()
        for name, m in sorted(self.metrics().items()):
            if m.help:
                out.write(f"# HELP {name} {_escape(m.help)}\n")
            out.write(f"# TYPE {name} {m.kind}\n")
            m._render(out)
        return out.getvalue()


#: Process-wide default registry (what "telemetry='on'" call sites and
#: the scrape endpoint read unless handed their own).
REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return REGISTRY


# ------------------------------------------------------------- tracing --

def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class _Span:
    __slots__ = ("tracer", "name", "args", "t0")

    def __init__(self, tracer, name, args):
        self.tracer, self.name, self.args = tracer, name, args

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        self.tracer._finish(self.name, self.t0,
                            time.perf_counter_ns(), self.args)


class SpanTracer:
    """Nestable wall-time spans -> Chrome trace events + durable JSONL.

    ``span(name, **attrs)`` is a context manager; nesting is implicit in
    the Chrome "X" (complete) event model — the viewer stacks events by
    (tid, ts, dur) containment, so no explicit parent ids are needed and
    spans from concurrent threads land on separate tracks. Timestamps
    come from ``perf_counter_ns`` (monotonic, ns) rebased to the tracer's
    birth so traces start near t=0.

    The in-memory buffer is a ``max_events`` ring: a long-running
    serving process keeps the *newest* events and counts what it
    dropped (``dropped``), surfaced in the trace metadata. With
    ``jsonl_path=`` every completed span is ALSO appended as one JSON
    line, flushed per event and fsync'd every ``fsync_every`` events
    and on ``close()`` — the durable log survives a SIGKILL mid-run
    (the last un-fsync'd tail is the only exposure, exactly the
    checkpoint machinery's contract for non-fsync saves).
    """

    def __init__(self, *, max_events: int = 100_000,
                 jsonl_path: str | None = None, fsync_every: int = 256):
        if max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {max_events}")
        self._lock = threading.Lock()
        self._events: collections.deque = collections.deque(
            maxlen=int(max_events))
        self.dropped = 0
        self._epoch_ns = time.perf_counter_ns()
        self.jsonl_path = jsonl_path
        self._fsync_every = max(1, int(fsync_every))
        self._jsonl_f = None
        self._since_fsync = 0
        if jsonl_path is not None:
            d = os.path.dirname(jsonl_path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._jsonl_f = open(jsonl_path, "a")

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """A zero-duration marker event (guard firings, installs)."""
        now = time.perf_counter_ns()
        self._emit({"name": name, "ph": "i", "s": "t",
                    "ts": (now - self._epoch_ns) / 1e3,
                    "pid": os.getpid(),
                    "tid": threading.get_ident(),
                    "args": attrs})

    def complete(self, name: str, t0_ns: int, t1_ns: int,
                 **attrs) -> None:
        """Record an already-measured span from a ``perf_counter_ns``
        pair — for host loops that already time their own sections and
        must not restructure into ``with`` blocks."""
        self._finish(name, t0_ns, t1_ns, attrs)

    def _finish(self, name: str, t0_ns: int, t1_ns: int,
                args: dict) -> None:
        self._emit({"name": name, "ph": "X",
                    "ts": (t0_ns - self._epoch_ns) / 1e3,
                    "dur": (t1_ns - t0_ns) / 1e3,
                    "pid": os.getpid(),
                    "tid": threading.get_ident(),
                    "args": args})

    def _emit(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)
            if self._jsonl_f is not None:
                self._jsonl_f.write(json.dumps(ev) + "\n")
                self._jsonl_f.flush()
                self._since_fsync += 1
                if self._since_fsync >= self._fsync_every:
                    os.fsync(self._jsonl_f.fileno())
                    self._since_fsync = 0

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def write_chrome_trace(self, path: str) -> str:
        """Export the buffer as Chrome trace-event JSON, atomically:
        tmp write + file fsync + rename + dir fsync (the ``checkpoint/``
        discipline) — a concurrent kill leaves either the old complete
        trace or the new one, never a torn file. Returns ``path``."""
        doc = {"traceEvents": self.events(),
               "displayTimeUnit": "ms",
               "otherData": {"dropped_events": self.dropped}}
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(d)
        return path

    def close(self) -> None:
        with self._lock:
            if self._jsonl_f is not None:
                try:
                    self._jsonl_f.flush()
                    os.fsync(self._jsonl_f.fileno())
                finally:
                    self._jsonl_f.close()
                    self._jsonl_f = None


# ------------------------------------------------------------- facade --

class Telemetry:
    """One handle bundling a registry, a tracer, and the profiler mode.

    Instrumented subsystems (``core/runtime.py``,
    ``serving/engine.py``) accept ``telemetry="off" | "on" | Telemetry``
    and resolve it through :func:`resolve`: ``"off"`` -> ``None`` (the
    untouched hot path, one ``is not None`` guard), ``"on"`` -> the
    process-wide :func:`default_telemetry`, an instance -> itself (tests
    and benches isolate with their own registry/tracer).

    ``profile_dir=`` is the opt-in profiler mode: :meth:`annotate` wraps
    hot calls in ``jax.profiler.TraceAnnotation`` so kernel launches are
    attributed to spans in the device profile, and :meth:`fence` inserts
    the ``block_until_ready`` that makes host span timings mean device
    work — both are no-ops when ``profile_dir`` is None, so profiling
    cost is strictly opt-in. :meth:`start_profile` / :meth:`stop_profile`
    bracket a ``jax.profiler`` trace into ``profile_dir``.
    """

    def __init__(self, registry: MetricsRegistry | None = None,
                 tracer: SpanTracer | None = None, *,
                 profile_dir: str | None = None):
        self.registry = registry if registry is not None else REGISTRY
        self.tracer = tracer if tracer is not None else SpanTracer()
        self.profile_dir = profile_dir
        self._profiling = False

    # metrics passthrough -------------------------------------------------
    def counter(self, name: str, help: str = "") -> Counter:
        return self.registry.counter(name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self.registry.gauge(name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple | None = None) -> Histogram:
        return self.registry.histogram(name, help, buckets=buckets)

    # tracing passthrough -------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        return self.tracer.span(name, **attrs)

    def instant(self, name: str, **attrs) -> None:
        self.tracer.instant(name, **attrs)

    def complete(self, name: str, t0_ns: int, t1_ns: int,
                 **attrs) -> None:
        self.tracer.complete(name, t0_ns, t1_ns, **attrs)

    def write_chrome_trace(self, path: str) -> str:
        return self.tracer.write_chrome_trace(path)

    def render_prometheus(self) -> str:
        return self.registry.render_prometheus()

    # profiler hooks ------------------------------------------------------
    def annotate(self, name: str):
        """``jax.profiler.TraceAnnotation`` in profile mode, else a free
        nullcontext — hot paths call this unconditionally."""
        if self.profile_dir is None:
            return contextlib.nullcontext()
        import jax
        return jax.profiler.TraceAnnotation(name)

    def fence(self, value):
        """``block_until_ready`` in profile mode ONLY (so span wall
        times bound device work); identity otherwise — never a sync the
        unprofiled path didn't have. Returns ``value``."""
        if self.profile_dir is not None:
            import jax
            jax.block_until_ready(value)
        return value

    def start_profile(self) -> None:
        if self.profile_dir is None:
            raise ValueError("pass profile_dir= to enable profiling")
        if not self._profiling:
            import jax
            os.makedirs(self.profile_dir, exist_ok=True)
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True

    def stop_profile(self) -> None:
        if self._profiling:
            import jax
            jax.profiler.stop_trace()
            self._profiling = False

    def close(self) -> None:
        self.stop_profile()
        self.tracer.close()


_DEFAULT_LOCK = threading.Lock()
_DEFAULT: Telemetry | None = None


def default_telemetry() -> Telemetry:
    """The process-wide handle ``telemetry="on"`` resolves to: the
    global REGISTRY plus one shared tracer."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = Telemetry(REGISTRY)
        return _DEFAULT


def resolve(telemetry) -> Telemetry | None:
    """Normalise a ``telemetry=`` knob: ``"off"``/``None``/``False`` ->
    None (call sites skip every telemetry branch — the untouched path),
    ``"on"``/``True`` -> :func:`default_telemetry`, a :class:`Telemetry`
    -> itself."""
    if telemetry is None or telemetry is False or telemetry == "off":
        return None
    if telemetry is True or telemetry == "on":
        return default_telemetry()
    if isinstance(telemetry, Telemetry):
        return telemetry
    raise ValueError(
        f"telemetry must be 'off', 'on', or a Telemetry instance; got "
        f"{telemetry!r}")


# ----------------------------------------------------------- exposition --

class _ScrapeHandler(http.server.BaseHTTPRequestHandler):
    def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
        if self.path.split("?")[0] not in ("/metrics", "/"):
            self.send_error(404, "scrape /metrics")
            return
        body = self.server._registry.render_prometheus().encode()
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # silence per-scrape stderr noise
        pass


class MetricsServer:
    """Stdlib Prometheus scrape endpoint; ``port=0`` binds an ephemeral
    port (read it back from ``.port``). Runs on a daemon thread; call
    :meth:`close` to release the socket deterministically."""

    def __init__(self, registry: MetricsRegistry | None = None, *,
                 host: str = "127.0.0.1", port: int = 0):
        self._httpd = http.server.ThreadingHTTPServer(
            (host, port), _ScrapeHandler)
        self._httpd._registry = (registry if registry is not None
                                 else REGISTRY)
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="telemetry-scrape",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


def start_metrics_server(registry: MetricsRegistry | None = None, *,
                         host: str = "127.0.0.1",
                         port: int = 0) -> MetricsServer:
    return MetricsServer(registry, host=host, port=port)
