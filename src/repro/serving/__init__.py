"""Serving layer: high-QPS nearest-medoid assignment (DESIGN.md §9/§9a).

:class:`AssignmentEngine` is the host loop (micro-batching, drift-
triggered supervised refit, durable versioned snapshots);
:mod:`repro.serving.guards` holds the robustness primitives it composes
(query admission, :class:`RefitBreaker`, :class:`ReservoirWindow`).
"""
from .engine import AssignmentEngine  # noqa: F401
from .guards import (  # noqa: F401
    QUARANTINE_LABEL,
    RefitBreaker,
    ReservoirWindow,
    snapshot_fingerprint,
)
