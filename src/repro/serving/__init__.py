from .engine import AssignmentEngine  # noqa: F401
