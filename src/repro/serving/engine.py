"""High-QPS k-medoids assignment serving (DESIGN.md §9, hardened §9a).

The serving workload for this repo is the paper's own: given a fitted
medoid set, answer "which medoid, how far" for streams of query rows —
prompt/embedding clustering, data curation routing, active-learning
picks. :class:`AssignmentEngine` is the host-side loop around the
batched nearest-medoid top-1 kernel (``ops.assign``, kernels/assign.py):

  * **Micro-batching** — queries are served in fixed-shape micro-batches
    (pad the tail, slice the result), so the jitted assign function
    compiles exactly once per (micro_batch, p) and every call reuses it.
    The query buffer is *donated* to the jit: the device reuses it
    in place instead of holding a second (micro_batch, p) allocation.
  * **Medoid residency** — the metric-prepared (k, p) medoid rows are
    device-resident across calls and VMEM-resident across each kernel
    sweep (constant-index BlockSpec — one DMA per call).
  * **Admission guards** — ``validate="cheap"`` (default) scans each
    batch and quarantines non-finite rows (label −1, NaN distance, or
    ``on_invalid="raise"``) so one poisoned batch can't contaminate the
    drift EMA or the refit window; ``validate="off"`` is the untouched
    PR 8 jitted fast path (serving/guards.py, DESIGN.md §9a).
  * **Drift monitor** — an EMA of the per-batch assignment objective
    (mean d1 over admitted rows) is compared against the fit-time
    ``est_objective_``; past ``drift_threshold`` the engine arms ONE
    supervised background refit warm-started from the live medoid set
    (``MedoidSelector.refit`` -> ``solver.one_batch_pam(init_idx=...)``,
    the FasterPAM warm-start discipline) on an objective-weighted
    reservoir of query rows (``guards.ReservoirWindow``).
  * **Refit supervision** — each attempt runs under a join deadline
    (``refit_timeout``; the cancel flag fences a hung worker off the
    install), failures back off on a deterministic exponential schedule
    (``refit_backoff``), and ``breaker_threshold`` consecutive failures
    open a circuit breaker: serve-only from the last good generation,
    one half-open probe per ``breaker_cooldown`` (guards.RefitBreaker;
    all surfaced in :meth:`stats`).
  * **Atomic swap, durably versioned** — a refit builds its complete
    :class:`_Medoids` snapshot off to the side and installs it with a
    single reference assignment. Serving threads read ``self._model``
    exactly once per call, so they see either the old snapshot or the
    new one, never a torn mix; a refit cancelled (or crashed) mid-flight
    leaves the old snapshot serving (tests/test_serving.py pins it).
    With ``snapshot_dir=`` every installed generation is persisted
    through the ``repro.checkpoint`` atomic-rename machinery (fsync'd)
    under a config fingerprint; :meth:`load_snapshot` /
    :meth:`install_snapshot` resume or receive generations with
    stale-version rejection — the groundwork for the multi-process
    medoid-version broadcast protocol (ROADMAP).

Labels are bitwise ``streaming.stream_assign`` / the numpy mirror in
``core/baselines.py`` per backend (tests/test_assign.py) — for every
admitted (finite) query row, through every fault mode in
tests/test_serving_faults.py — so swapping the host predict loop for
this engine changes throughput, not answers.
"""
from __future__ import annotations

import copy
import functools
import threading
import time
import warnings

import jax.numpy as jnp
import numpy as np

# CPU cannot honor buffer donation (XLA:CPU aliasing); the donation is a
# TPU-path optimisation and the fallback — a copy, exactly what an
# undonated call does — is correct, so the once-per-compile nag is noise.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from repro.core.selector import MedoidSelector
from repro.kernels import metrics, ops
from repro.monitoring import telemetry as telemetry_mod
from repro.monitoring.metrics import StepTimer
from repro.serving import guards


class _ServingTelemetry:
    """Engine-side telemetry bundle (DESIGN.md §10): the serving series
    the acceptance scrape must contain — per-micro-batch latency,
    quarantine counts, the drift EMA gauge, refit attempt/outcome
    events, breaker state transitions, snapshot persistence. Every hook
    is host bookkeeping the engine calls only when ``telemetry`` is on;
    with ``"off"`` no instance exists and the serve path is the
    untouched PR 8/9 code (``telemetry_overhead_vs_off`` bench gate).
    Metric mutations take the metric's own lock, never the engine lock —
    hooks may be called from serving and refit threads concurrently."""

    _BREAKER_STATE = {"closed": 0.0, "half_open": 1.0, "open": 2.0}

    def __init__(self, tel: telemetry_mod.Telemetry):
        self.tel = tel
        r = tel.registry
        self.h_batch = r.histogram(
            "serving_micro_batch_seconds",
            "wall seconds per jitted micro-batch (submit+compute+readback)")
        self.c_queries = r.counter("serving_queries_total",
                                   "admitted query rows served")
        self.c_quarantined = r.counter(
            "serving_quarantined_rows_total",
            "query rows quarantined at admission (non-finite)")
        self.c_refits = r.counter("serving_refit_attempts_total",
                                  "refit attempts, by outcome")
        self.c_breaker = r.counter(
            "serving_breaker_transitions_total",
            "refit circuit-breaker state transitions")
        self.c_persisted = r.counter("serving_snapshots_persisted_total",
                                     "medoid generations persisted to disk")
        self.c_recoveries = r.counter(
            "serving_snapshot_recoveries_total",
            "poisoned-snapshot recoveries (rebuild or disk reload)")
        self.g_drift = r.gauge("serving_drift_ema",
                               "EMA of per-batch assignment objective")
        self.g_ratio = r.gauge(
            "serving_drift_ratio",
            "drift EMA / fit-time objective (>threshold arms a refit)")
        self.g_version = r.gauge("serving_medoid_version",
                                 "installed medoid generation")
        self.g_breaker = r.gauge("serving_breaker_state",
                                 "refit breaker: 0=closed 1=half_open 2=open")

    def micro_batch(self, t0_ns: int, t1_ns: int, rows: int) -> None:
        self.h_batch.observe((t1_ns - t0_ns) / 1e9)
        self.tel.complete("serve/micro_batch", t0_ns, t1_ns, rows=rows)

    def served(self, n: int, drift_ema, drift_ratio: float,
               version: int) -> None:
        self.c_queries.inc(n)
        if drift_ema is not None:
            self.g_drift.set(drift_ema)
        self.g_ratio.set(drift_ratio)
        self.g_version.set(version)

    def quarantined(self, n: int) -> None:
        self.c_quarantined.inc(n)
        self.tel.instant("serve/quarantine", rows=n)

    def refit_outcome(self, outcome: str, t0_ns: int,
                      version: int | None = None) -> None:
        self.c_refits.inc(outcome=outcome)
        self.tel.complete("serve/refit", t0_ns, time.perf_counter_ns(),
                          outcome=outcome,
                          **({} if version is None else
                             {"version": version}))
        if version is not None:
            self.g_version.set(version)

    def breaker(self, old: str, new: str) -> None:
        self.c_breaker.inc(from_state=old, to_state=new)
        self.g_breaker.set(self._BREAKER_STATE.get(new, -1.0))
        self.tel.instant("serve/breaker_transition", old=old, new=new)

    def snapshot_persisted(self) -> None:
        self.c_persisted.inc()

    def snapshot_recovery(self) -> None:
        self.c_recoveries.inc()
        self.tel.instant("serve/snapshot_recovery")


class _Medoids:
    """Immutable snapshot of one medoid generation. Built fully before
    it is installed; the engine swaps whole snapshots, never fields."""

    __slots__ = ("rows", "prepared", "indices", "est_objective", "version")

    def __init__(self, rows, prepared, indices, est_objective, version):
        self.rows = rows                    # (k, p) f32 numpy
        self.prepared = prepared            # (k, p) device array, prepared
        self.indices = indices              # (k,) i32 numpy (into fit data)
        self.est_objective = est_objective  # float, fit-time estimate
        self.version = version              # int, bumps per refit/install


@functools.lru_cache(maxsize=None)
def _assign_fn(metric: str, backend: str, block_dtype: str | None,
               micro_batch: int, p: int):
    """The jitted fixed-shape assign step, one compile per signature.

    Prepare runs on the query tile *inside* the jit (row-local, fuses
    with the kernel launch); the medoid operand arrives pre-prepared
    (once per snapshot, not per batch). ``donate_argnums=0`` donates the
    query buffer — it is a fresh host upload every call, so the device
    may overwrite it freely.
    """
    import jax

    spec = metrics.get(metric)

    def fn(queries, med_prepared):
        q = spec.prepare(queries) if spec.prepare is not None else queries
        return ops.assign(q, med_prepared, metric=metric, backend=backend,
                          block_dtype=block_dtype, skip_prepare=True)

    return jax.jit(fn, donate_argnums=0)


class AssignmentEngine:
    """Serve nearest-medoid assignment at high throughput, with admission
    guards, drift detection, supervised background warm-start refit, and
    durable versioned snapshots.

    Build one with :meth:`from_selector` (a fitted
    :class:`MedoidSelector`) or :meth:`from_checkpoint` (a selector
    ``save()`` artifact). Then::

        labels, d1 = engine.assign(queries)   # (q,) i32, (q,) f32
        engine.stats()                        # latency + drift + refits

    Serving knobs: ``micro_batch`` (rows per jitted step), ``validate``
    (``"cheap"`` quarantines non-finite query rows — sentinel label −1,
    NaN distance; ``"off"`` is the unguarded PR 8 fast path),
    ``on_invalid`` (``"quarantine"`` or ``"raise"``).

    Drift/refit knobs: ``drift_threshold`` (EMA objective / fit
    objective ratio that arms a refit), ``drift_decay`` (EMA smoothing),
    ``refit_window`` (reservoir capacity the refit trains on; 0 disables
    buffering and auto-refit), ``window_mode`` (``"reservoir"`` =
    objective-weighted A-Res sample of the stream, seeded from the
    selector's PRNG seed; ``"ring"`` = PR 8's recency window),
    ``auto_refit`` (arm the background refit at all).

    Refit supervision: ``refit_timeout`` (seconds per attempt; the
    supervisor cancels and abandons a hung worker — the cancel flag
    fences its install), ``refit_backoff``/``refit_backoff_cap``
    (deterministic exponential backoff after failures),
    ``breaker_threshold``/``breaker_cooldown`` (circuit breaker: after N
    consecutive failures, serve-only with one half-open probe per
    cooldown).

    Durability: ``snapshot_dir`` persists every installed medoid
    generation (atomic rename + fsync, ``snapshot_keep`` newest kept,
    config-fingerprinted); ``snapshot_resume="auto"`` re-installs the
    newest on-disk generation at boot.

    Observability: ``telemetry="on"`` (or a ``monitoring.Telemetry``)
    wires serving into the metrics registry + span tracer (DESIGN.md
    §10) — micro-batch latency histogram, quarantine / refit-outcome /
    breaker-transition counters, drift and medoid-version gauges —
    with :meth:`serve_metrics` exposing a Prometheus scrape endpoint
    and :meth:`write_trace` an atomic Chrome trace export. The default
    ``"off"`` resolves to no telemetry object at all: the serve path is
    the untouched PR 8/9 code, pinned by the
    ``telemetry_overhead_vs_off`` bench gate.
    """

    def __init__(self, selector: MedoidSelector, *, micro_batch: int = 4096,
                 drift_threshold: float = 1.25, drift_decay: float = 0.9,
                 refit_window: int = 65536, auto_refit: bool = True,
                 warmup: int = 1,
                 validate: str = "cheap", on_invalid: str = "quarantine",
                 window_mode: str = "reservoir",
                 refit_timeout: float | None = None,
                 refit_backoff: float = 1.0,
                 refit_backoff_cap: float = 60.0,
                 breaker_threshold: int = 3,
                 breaker_cooldown: float = 30.0,
                 snapshot_dir: str | None = None, snapshot_keep: int = 4,
                 snapshot_resume: str = "auto",
                 telemetry="off",
                 _clock=time.monotonic):
        if selector.medoids_ is None:
            raise RuntimeError("AssignmentEngine needs a *fitted* selector "
                               "(call fit() or load a checkpoint)")
        if micro_batch < 1:
            raise ValueError(f"micro_batch must be >= 1, got {micro_batch}")
        if refit_timeout is not None and refit_timeout <= 0:
            raise ValueError(
                f"refit_timeout must be > 0 seconds (or None), got "
                f"{refit_timeout}")
        if snapshot_resume not in ("auto", "never"):
            raise ValueError(f"snapshot_resume must be 'auto' or 'never', "
                             f"got {snapshot_resume!r}")
        self._selector = selector
        self.metric = selector.metric
        self.backend = selector.backend
        self.block_dtype = (None if selector.block_dtype is None
                            else jnp.dtype(selector.block_dtype).name)
        self.micro_batch = int(micro_batch)
        self.k, self.p = np.asarray(selector.medoids_).shape
        self.drift_threshold = float(drift_threshold)
        self.drift_decay = float(drift_decay)
        self.refit_window = int(refit_window)
        self.auto_refit = bool(auto_refit)
        self.validate = guards.check_validate(validate)
        self.on_invalid = guards.check_on_invalid(on_invalid)
        self.refit_timeout = refit_timeout
        self.snapshot_dir = snapshot_dir
        self.snapshot_keep = int(snapshot_keep)
        # Fingerprint of the snapshot-defining config: a durable
        # generation (or, later, a broadcast one) installs only onto an
        # engine whose model-defining config matches (DESIGN.md §9a).
        self._fingerprint = guards.snapshot_fingerprint(
            {**selector.serving_config(), "p": int(self.p)})

        self._model = self._snapshot(selector, version=0)
        self._fn = _assign_fn(self.metric, self.backend, self.block_dtype,
                              self.micro_batch, self.p)
        self.timer = StepTimer(warmup=warmup)   # per-micro-batch latency
        # One lock serialises ALL host-side bookkeeping (counters, EMA,
        # window, timer, breaker, model install). Kernel calls stay
        # outside it — concurrent assign() callers overlap on the device
        # and only briefly serialise to record what happened.
        self._lock = threading.Lock()
        self.queries_served = 0
        self.quarantined = 0
        self.refits = 0
        self.refit_failures = 0
        self.snapshot_recoveries = 0
        self.snapshots_persisted = 0
        self.last_refit_error: BaseException | None = None
        self.last_snapshot_error: BaseException | None = None
        self._drift_ema: float | None = None
        tel = telemetry_mod.resolve(telemetry)
        self._stel = (_ServingTelemetry(tel) if tel is not None else None)
        self._metrics_server = None
        if self._stel is not None:
            self._stel.g_version.set(self._model.version)
            self._stel.g_breaker.set(0.0)
        self._breaker = guards.RefitBreaker(
            backoff=refit_backoff, backoff_cap=refit_backoff_cap,
            threshold=breaker_threshold, cooldown=breaker_cooldown,
            clock=_clock,
            on_transition=(self._stel.breaker if self._stel is not None
                           else None))
        self._window = (guards.ReservoirWindow(
            self.refit_window, self.p, mode=window_mode,
            seed=int(selector.seed))
            if self.refit_window > 0 else None)
        self._refit_thread: threading.Thread | None = None
        self._refit_cancel = threading.Event()
        self._refit_hook = None       # test seam: runs just before install
        if self.snapshot_dir is not None:
            if snapshot_resume == "auto":
                try:
                    self.load_snapshot(self.snapshot_dir)
                except FileNotFoundError:
                    pass              # nothing on disk yet — fresh start
            from repro import checkpoint as ckpt
            if ckpt.latest_step(self.snapshot_dir) is None:
                self._persist_snapshot(self._model)

    # ------------------------------------------------------ constructors

    @classmethod
    def from_selector(cls, selector: MedoidSelector,
                      **kw) -> "AssignmentEngine":
        return cls(selector, **kw)

    @classmethod
    def from_checkpoint(cls, path: str, **kw) -> "AssignmentEngine":
        """Boot straight from a ``MedoidSelector.save()`` artifact — the
        config and fitted medoids both come from the checkpoint. Pass
        ``snapshot_dir=`` to also resume the last installed serving
        generation (a rebooted process picks up exactly where the
        SIGKILL'd one left off — tests/test_serving_faults.py)."""
        return cls(MedoidSelector.from_checkpoint(path), **kw)

    # ---------------------------------------------------------- serving

    def assign(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Nearest-medoid labels + distances for query rows (q, p):
        ``(labels, d1)`` of shapes (q,) i32 / (q,) f32 — index into the
        *current* medoid snapshot and distance to it. ``q == 0`` returns
        the empty shapes (the pinned edge contract); a wrong feature
        width raises. Under ``validate="cheap"`` non-finite rows come
        back quarantined: label ``guards.QUARANTINE_LABEL`` (−1), NaN
        distance (or the whole call raises with ``on_invalid="raise"``);
        finite rows are answered bitwise as if the bad rows were never
        there (per-row math is row-local)."""
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"queries must be 2-D (q, p), got {q.shape}")
        if q.shape[1] != self.p and q.shape[0] != 0:
            raise ValueError(
                f"queries have p={q.shape[1]}, engine serves p={self.p}")
        n = q.shape[0]
        if n == 0:
            return (np.zeros((0,), np.int32), np.zeros((0,), np.float32))

        if self.validate == "off":
            # The untouched PR 8 fast path: no admission scan, no
            # compaction, no output check (benched + gated).
            labels, d1, model = self._serve(q)
            self._bookkeep(q, d1, model)
            return labels, d1

        ok = guards.admit(q)
        n_bad = int(n - np.count_nonzero(ok))
        if n_bad == 0:
            labels, d1, model = self._serve(q)
            self._bookkeep(q, d1, model)
            return labels, d1
        if self.on_invalid == "raise":
            raise ValueError(
                f"{n_bad} non-finite query row(s) in a batch of {n} "
                f"(first at row {int(np.argmin(ok))}); serving "
                "validate='cheap' with on_invalid='raise' — sanitize "
                "the feed or serve with on_invalid='quarantine'")
        with self._lock:
            self.quarantined += n_bad
        if self._stel is not None:
            self._stel.quarantined(n_bad)
        labels = np.full((n,), guards.QUARANTINE_LABEL, np.int32)
        d1 = np.full((n,), np.nan, np.float32)
        qf = q[ok]
        if qf.shape[0]:
            lf, df, model = self._serve(qf)
            labels[ok] = lf
            d1[ok] = df
            self._bookkeep(qf, df, model)
        return labels, d1

    # Suspect-answer threshold: a poisoned medoid column surfaces as NaN
    # on the XLA paths but as the kernel's +BIG init (1e30 — NaN loses
    # every strictly-less merge) on the Pallas path. Any d1 that is NaN,
    # inf, or >= this is treated as suspect and the snapshot is checked.
    _SUSPECT = 1e29

    def _serve(self, q: np.ndarray):
        """Run the kernel over admitted rows; under ``validate="cheap"``
        a suspect answer (NaN / inf / the kernel's +BIG sentinel) for
        finite queries is diagnosed against the medoid snapshot and, if
        it is poisoned, recovered (re-prepare from raw rows, else reload
        the durable snapshot) — then served again on the healthy
        generation."""
        model = self._model
        labels, d1 = self._serve_on(q, model)
        if self.validate == "cheap" and not bool(
                np.all(d1 < self._SUSPECT)):
            rows_bad = not np.isfinite(model.rows).all()
            prepared_bad = (rows_bad or
                            not bool(np.isfinite(
                                np.asarray(model.prepared)).all()))
            if prepared_bad:
                model = self._recover_model(model)
                labels, d1 = self._serve_on(q, model)
                if not bool(np.all(d1 < self._SUSPECT)):
                    raise RuntimeError(
                        "suspect assignment distances for finite "
                        "queries persist after snapshot recovery — the "
                        "recovered generation is itself unhealthy")
            # else: the snapshot is finite — the huge distances are
            # genuine (extreme-magnitude features), not poison; serve
            # the floats as computed.
        return labels, d1, model

    def _serve_on(self, q: np.ndarray,
                  model: _Medoids) -> tuple[np.ndarray, np.ndarray]:
        # One model per call: every micro-batch of this call sees the
        # same snapshot even if a refit installs a new one mid-call.
        n = q.shape[0]
        mb = self.micro_batch
        labels = np.empty((n,), np.int32)
        d1 = np.empty((n,), np.float32)
        for s in range(0, n, mb):
            chunk = q[s:s + mb]
            rows = chunk.shape[0]
            if rows < mb:
                chunk = np.concatenate(
                    [chunk, np.zeros((mb - rows, self.p), np.float32)])
            t0 = time.perf_counter()
            t0_ns = (time.perf_counter_ns() if self._stel is not None
                     else 0)
            with warnings.catch_warnings():
                # re-assert the module filter: pytest (and any
                # catch_warnings user) resets the global filter list, and
                # the nag fires at trace time inside this call
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                lab, dd = self._fn(jnp.asarray(chunk), model.prepared)
                lab = np.asarray(lab)       # blocks: the timed latency is
                dd = np.asarray(dd)         # submit + compute + readback
            dt = time.perf_counter() - t0
            with self._lock:                # timer state is host-shared
                self.timer.record(dt)
            if self._stel is not None:
                self._stel.micro_batch(t0_ns, time.perf_counter_ns(),
                                       rows)
            labels[s:s + rows] = lab[:rows]
            d1[s:s + rows] = dd[:rows]
        return labels, d1

    # ---------------------------------------------------- drift + refit

    def _bookkeep(self, q_ok: np.ndarray, d1_ok: np.ndarray,
                  model: _Medoids) -> None:
        """All post-serve host bookkeeping, under the engine lock:
        counters, window push, EMA fold, refit arming. ``q_ok``/``d1_ok``
        are the *admitted* rows only — quarantined rows never reach the
        EMA or the window."""
        batch_objective = float(d1_ok.mean()) if d1_ok.size else None
        arm = None
        with self._lock:
            self.queries_served += q_ok.shape[0]
            if self._window is not None:
                self._window.push(q_ok, d1_ok)
            ema = self._drift_ema
            if ema is not None and not np.isfinite(ema):
                ema = None          # self-healing: a poisoned EMA
                # (validate="off" fed it NaN) re-seeds from the next
                # finite batch instead of holding NaN forever
            if batch_objective is not None and np.isfinite(batch_objective):
                self._drift_ema = (batch_objective if ema is None else
                                   self.drift_decay * ema +
                                   (1.0 - self.drift_decay)
                                   * batch_objective)
            else:
                self._drift_ema = ema
            if (self.auto_refit and self._window is not None
                    and self.drift_ratio() > self.drift_threshold
                    and self._window.fill >= max(4 * self.k,
                                                 self.micro_batch)
                    and not self.refit_in_flight
                    and self._breaker.allow()):
                arm = self._window.content()
        if self._stel is not None:
            self._stel.served(q_ok.shape[0], self._drift_ema,
                              self.drift_ratio(), self._model.version)
        if arm is not None:
            self._start_refit(arm)

    def _window_rows(self) -> np.ndarray:
        return self._window.content()

    def drift_ratio(self) -> float:
        """EMA assignment objective / fit-time estimated objective.
        ~1.0 = queries look like the fit data; > drift_threshold arms
        the background refit."""
        base = self._model.est_objective
        ema = self._drift_ema
        if ema is None or not np.isfinite(ema) or not base or base <= 0:
            return 1.0
        return ema / base

    @property
    def refit_in_flight(self) -> bool:
        t = self._refit_thread
        return t is not None and t.is_alive()

    def _snapshot(self, sel: MedoidSelector, version: int) -> _Medoids:
        return self._build_model(
            np.asarray(sel.medoids_, np.float32),
            np.asarray(sel.medoid_indices_, np.int32),
            float(sel.est_objective_ or 0.0), version)

    def _build_model(self, rows: np.ndarray, indices: np.ndarray,
                     est_objective: float, version: int) -> _Medoids:
        spec = metrics.get(self.metric)
        dev = jnp.asarray(rows)
        prepared = spec.prepare(dev) if spec.prepare is not None else dev
        return _Medoids(rows=rows, prepared=prepared, indices=indices,
                        est_objective=est_objective, version=version)

    # ------------------------------------------------- supervised refit

    def _start_refit(self, x: np.ndarray) -> None:
        cancel = threading.Event()
        attempt = {"cancel": cancel, "installed": False, "timed_out": False,
                   "t0_ns": (time.perf_counter_ns()
                             if self._stel is not None else 0)}
        worker = threading.Thread(
            target=self._refit_worker, args=(x, attempt),
            name="assignment-engine-refit", daemon=True)
        supervisor = threading.Thread(
            target=self._supervise_refit, args=(worker, attempt),
            name="assignment-engine-refit-supervisor", daemon=True)
        self._refit_cancel = cancel
        self._refit_thread = supervisor
        supervisor.start()

    def _supervise_refit(self, worker: threading.Thread,
                         attempt: dict) -> None:
        """Per-attempt supervision: join the worker under the
        ``refit_timeout`` deadline. On timeout the attempt's cancel flag
        fences the (possibly hung) worker off the install and the worker
        thread is *abandoned* — a daemon thread stuck in a kernel call
        cannot be killed, but a fenced one cannot corrupt anything, and
        the engine is immediately free to arm a fresh attempt (each
        attempt carries its own cancel event)."""
        cancel = attempt["cancel"]
        deadline = (None if self.refit_timeout is None
                    else time.monotonic() + self.refit_timeout)
        worker.start()
        while True:
            worker.join(0.02)
            if not worker.is_alive():
                return              # worker recorded its own outcome
            if cancel.is_set() and not attempt["timed_out"]:
                return              # external cancel: not a failure
            if deadline is not None and time.monotonic() >= deadline:
                with self._lock:
                    if attempt["installed"]:
                        return      # success landed at the wire
                    attempt["timed_out"] = True
                    cancel.set()
                self._record_refit_failure(TimeoutError(
                    f"refit exceeded refit_timeout={self.refit_timeout}s "
                    "and was cancelled (hung worker abandoned; the old "
                    "generation keeps serving)"), attempt=attempt)
                return

    def _refit_worker(self, x: np.ndarray, attempt: dict) -> None:
        cancel = attempt["cancel"]
        try:
            # Refit a *copy*: the live selector (and the serving
            # snapshot derived from it) stays untouched until the new
            # snapshot is complete. Shallow copy is enough — refit()
            # replaces the fitted fields, never mutates them in place.
            with self._lock:
                sel = copy.copy(self._selector)
            sel.refit(x)
            rows = np.asarray(sel.medoids_, np.float32)
            indices = np.asarray(sel.medoid_indices_, np.int32)
            est = float(sel.est_objective_ or 0.0)
            if cancel.is_set():
                if self._stel is not None and not attempt["timed_out"]:
                    self._stel.refit_outcome("cancelled",
                                             attempt["t0_ns"])
                return                      # killed: old snapshot serves on
            if self._refit_hook is not None:
                self._refit_hook()
            # prepare() outside the lock (device work), install inside
            spec = metrics.get(self.metric)
            dev = jnp.asarray(rows)
            prepared = spec.prepare(dev) if spec.prepare is not None else dev
            with self._lock:
                if cancel.is_set():
                    if self._stel is not None and not attempt["timed_out"]:
                        self._stel.refit_outcome("cancelled",
                                                 attempt["t0_ns"])
                    return
                new = _Medoids(rows=rows, prepared=prepared,
                               indices=indices, est_objective=est,
                               version=self._model.version + 1)
                # The swap: one reference assignment — readers hold
                # either the old snapshot or this one, never a mix.
                self._model = new
                self._selector = sel
                self._drift_ema = None      # drift restarts vs the new fit
                self.refits += 1
                self.last_refit_error = None    # a success clears the
                # stale failure stats() used to report forever
                self._breaker.record_success()
                attempt["installed"] = True
            if self._stel is not None:
                self._stel.refit_outcome("success", attempt["t0_ns"],
                                         version=new.version)
            self._persist_snapshot(new)     # disk IO outside the lock
        except BaseException as e:          # noqa: BLE001 — report, don't die
            if not cancel.is_set():
                # an externally-cancelled or timed-out attempt already
                # has its outcome recorded (or deliberately unrecorded)
                self._record_refit_failure(e, attempt=attempt)

    def _record_refit_failure(self, e: BaseException,
                              attempt: dict | None = None) -> None:
        with self._lock:
            self.last_refit_error = e
            self.refit_failures += 1
            self._breaker.record_failure()
        if self._stel is not None:
            self._stel.refit_outcome(
                "timeout" if isinstance(e, TimeoutError) else "failure",
                attempt["t0_ns"] if attempt is not None else 0)

    def refit_now(self, x=None, *, wait: bool = True) -> bool:
        """Trigger a refit explicitly (on ``x`` or the query window).
        Returns True if one was started. ``wait`` joins it. Bypasses the
        backoff/breaker schedule — this is the operator override; the
        attempt's outcome still feeds the breaker."""
        if self.refit_in_flight:
            if wait:
                self._refit_thread.join()
            return False
        if x is None:
            if self._window is None or self._window.fill == 0:
                raise RuntimeError("no refit data: pass x= or serve "
                                   "queries with refit_window > 0")
            with self._lock:
                x = self._window.content()
        x = np.asarray(x, np.float32)
        if self.validate == "cheap":
            ok = guards.admit(x)
            if not ok.all():
                x = x[ok]           # refit data rides the same admission
        self._start_refit(x)
        if wait:
            self._refit_thread.join()
        return True

    def cancel_refit(self, *, wait: bool = True) -> None:
        """Kill an in-flight refit: the old medoid snapshot keeps
        serving; whatever the refit computed is discarded (not counted
        as a failure — the breaker only sees crashes and timeouts)."""
        self._refit_cancel.set()
        t = self._refit_thread
        if wait and t is not None and t.is_alive():
            t.join()

    # ------------------------------------------------ durable snapshots

    def _persist_snapshot(self, model: _Medoids) -> None:
        """Write one installed generation through the atomic-rename
        checkpoint machinery (fsync'd: the rename is durable before it
        is visible). Persistence failure must never take serving down —
        it is recorded in ``stats()`` instead."""
        if self.snapshot_dir is None:
            return
        from repro import checkpoint as ckpt
        try:
            ckpt.save(self.snapshot_dir, model.version,
                      {"rows": model.rows, "indices": model.indices},
                      extra={"kind": "serving_medoids",
                             "fingerprint": self._fingerprint,
                             "version": int(model.version),
                             "est_objective": float(model.est_objective)},
                      keep=self.snapshot_keep, fsync=True)
            with self._lock:
                self.snapshots_persisted += 1
                self.last_snapshot_error = None
            if self._stel is not None:
                self._stel.snapshot_persisted()
        except Exception as e:              # noqa: BLE001
            with self._lock:
                self.last_snapshot_error = e

    def install_snapshot(self, rows, indices, version: int,
                         est_objective: float | None = None, *,
                         force: bool = False, persist: bool = True) -> int:
        """Install a medoid generation received from outside the refit
        loop (a durable snapshot, or — the broadcast protocol — another
        process's refit). Validates shape and finiteness, rejects stale
        versions (``version <=`` the installed one) unless ``force=True``
        (the poisoned-rows recovery path re-installs the current
        generation from disk). Returns the installed version."""
        rows = np.asarray(rows, np.float32)
        indices = np.asarray(indices, np.int32)
        if rows.shape != (self.k, self.p):
            raise ValueError(
                f"snapshot rows have shape {rows.shape}, engine serves "
                f"(k, p)=({self.k}, {self.p})")
        if indices.shape != (self.k,):
            raise ValueError(
                f"snapshot indices have shape {indices.shape}, "
                f"expected ({self.k},)")
        if not np.isfinite(rows).all():
            raise ValueError(
                "snapshot rows contain non-finite values — refusing to "
                "install a poisoned generation")
        est = float(est_objective if est_objective is not None
                    else self._model.est_objective)
        new = self._build_model(rows, indices, est, int(version))
        with self._lock:
            cur = self._model
            if not force and int(version) < cur.version:
                raise ValueError(
                    f"stale snapshot: version {int(version)} <= installed "
                    f"version {cur.version} (pass force=True only for "
                    "recovery re-installs)")
            if (not force and int(version) == cur.version
                    and cur.rows.tobytes() != rows.tobytes()):
                raise ValueError(
                    f"snapshot version {int(version)} equals the installed "
                    "version but carries different medoid rows — version "
                    "collision; bump the version or pass force=True")
            self._model = new
            self._drift_ema = None
        if self._stel is not None:
            self._stel.g_version.set(int(version))
        if persist:
            self._persist_snapshot(new)
        return int(version)

    def load_snapshot(self, path: str | None = None,
                      version: int | None = None, *,
                      force: bool = False) -> int:
        """Restore the newest loadable generation from a snapshot
        directory (default: this engine's ``snapshot_dir``) and install
        it. Walks back over corrupt steps with a warning (the same
        discipline as ``checkpoint.restore_latest_valid``); a config
        fingerprint mismatch is a loud error, never silently skipped;
        stale versions are rejected unless ``force=True``. Returns the
        installed version — the reboot path after a SIGKILL'd process
        (tests/test_serving_faults.py pins version + rows bitwise)."""
        import jax

        from repro import checkpoint as ckpt
        root = path if path is not None else self.snapshot_dir
        if root is None:
            raise ValueError("no snapshot directory: pass path= or build "
                             "the engine with snapshot_dir=")
        steps = ([int(version)] if version is not None
                 else list(reversed(ckpt.all_steps(root))))
        if not steps:
            raise FileNotFoundError(f"no snapshots under {root}")
        target = {"rows": jax.ShapeDtypeStruct((self.k, self.p),
                                               np.float32),
                  "indices": jax.ShapeDtypeStruct((self.k,), np.int32)}
        last_err = None
        for step in steps:
            try:
                state, extra = ckpt.restore(root, target, step)
            except Exception as e:          # noqa: BLE001
                last_err = e
                warnings.warn(
                    f"skipping corrupt serving snapshot version {step} "
                    f"under {root}: {e}", UserWarning, stacklevel=2)
                continue
            fp = extra.get("fingerprint")
            if fp != self._fingerprint:
                raise ValueError(
                    f"serving snapshot version {step} under {root} was "
                    f"written under a different config (fingerprint {fp!r}"
                    f" != this engine's {self._fingerprint!r}) — a medoid "
                    "generation must only serve under the config that fit "
                    "it")
            if not np.isfinite(state["rows"]).all():
                last_err = ValueError("non-finite medoid rows on disk")
                warnings.warn(
                    f"skipping serving snapshot version {step} under "
                    f"{root}: non-finite medoid rows", UserWarning,
                    stacklevel=2)
                continue
            v = int(extra.get("version", step))
            cur = self._model
            if not force and v == cur.version \
                    and cur.rows.tobytes() == np.asarray(
                        state["rows"], np.float32).tobytes():
                return v                    # already serving this one
            return self.install_snapshot(
                state["rows"], state["indices"], v,
                est_objective=extra.get("est_objective"),
                force=force, persist=False)
        raise FileNotFoundError(
            f"no restorable serving snapshot under {root} "
            f"({len(steps)} version(s) tried; last: {last_err})")

    def _recover_model(self, bad: _Medoids) -> _Medoids:
        """Poisoned-snapshot recovery (``validate="cheap"``): if the raw
        rows are healthy the device-side prepared cache was poisoned —
        rebuild it; otherwise reload the generation from the durable
        snapshot dir. Raises when nothing healthy remains."""
        with self._lock:
            cur = self._model
            if cur is not bad:
                return cur                  # someone already recovered
            if np.isfinite(cur.rows).all():
                new = self._build_model(cur.rows, cur.indices,
                                        cur.est_objective, cur.version)
                self._model = new
                self.snapshot_recoveries += 1
                if self._stel is not None:
                    self._stel.snapshot_recovery()
                return new
        if self.snapshot_dir is None:
            raise RuntimeError(
                "medoid snapshot is poisoned (non-finite rows) and no "
                "snapshot_dir= is configured to recover from — rebuild "
                "the engine from a selector checkpoint")
        self.load_snapshot(self.snapshot_dir, force=True)
        with self._lock:
            self.snapshot_recoveries += 1
            if self._stel is not None:
                self._stel.snapshot_recovery()
            return self._model

    # ------------------------------------------------------------ intro

    @property
    def medoids(self) -> np.ndarray:
        return self._model.rows

    @property
    def medoid_version(self) -> int:
        return self._model.version

    def stats(self) -> dict:
        """Serving counters + per-micro-batch latency summary (StepTimer
        percentiles, warmup excluded) + drift/guard/refit-supervision/
        snapshot state."""
        with self._lock:
            window = (self._window.stats() if self._window is not None
                      else None)
            return {"queries_served": self.queries_served,
                    "micro_batch": self.micro_batch,
                    "validate": self.validate,
                    "quarantined": self.quarantined,
                    "medoid_version": self._model.version,
                    "refits": self.refits,
                    "refit_failures": self.refit_failures,
                    "refit_in_flight": self.refit_in_flight,
                    "last_refit_error": repr(self.last_refit_error)
                    if self.last_refit_error else None,
                    "breaker": self._breaker.stats(),
                    "window": window,
                    "snapshots": {
                        "dir": self.snapshot_dir,
                        "persisted": self.snapshots_persisted,
                        "recoveries": self.snapshot_recoveries,
                        "last_error": repr(self.last_snapshot_error)
                        if self.last_snapshot_error else None},
                    "drift_ema": self._drift_ema,
                    "drift_ratio": self.drift_ratio(),
                    "latency": self.timer.summary()}

    # -------------------------------------------------------- telemetry

    @property
    def telemetry(self) -> telemetry_mod.Telemetry | None:
        """The resolved telemetry handle (None when built with
        ``telemetry="off"``)."""
        return self._stel.tel if self._stel is not None else None

    def serve_metrics(self, *, host: str = "127.0.0.1",
                      port: int = 0) -> telemetry_mod.MetricsServer:
        """Start a Prometheus scrape endpoint over this engine's
        registry (``GET /metrics``; ``port=0`` binds an ephemeral port —
        read it back from ``.port``). Requires the engine to have been
        built with telemetry on; one endpoint per engine, closed with
        the engine (or explicitly via the returned server)."""
        if self._stel is None:
            raise RuntimeError(
                "serve_metrics() needs telemetry: build the engine with "
                "telemetry='on' (or a Telemetry instance)")
        if self._metrics_server is None:
            self._metrics_server = telemetry_mod.start_metrics_server(
                self._stel.tel.registry, host=host, port=port)
        return self._metrics_server

    def write_trace(self, path: str) -> str:
        """Export the span buffer (micro-batches, refits, breaker and
        quarantine markers) as Chrome trace-event JSON — atomic write,
        loads in Perfetto / chrome://tracing. Returns ``path``."""
        if self._stel is None:
            raise RuntimeError(
                "write_trace() needs telemetry: build the engine with "
                "telemetry='on' (or a Telemetry instance)")
        return self._stel.tel.write_chrome_trace(path)

    def close(self) -> None:
        self.cancel_refit(wait=True)
        if self._metrics_server is not None:
            self._metrics_server.close()
            self._metrics_server = None
