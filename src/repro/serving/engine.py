"""Serving: prefill + single-token decode steps and a small batched engine.

``make_serve_step``/``make_prefill`` return the pure functions the dry-run
lowers (decode_32k / long_500k / prefill_32k shapes). ``Engine`` is a
host-side convenience for the examples: batched greedy generation with a
fixed cache budget.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.training.trainer import cast_for_compute


def make_serve_step(cfg: ModelConfig):
    """decode one token: (params, cache, token (B,), t) -> (logits, cache)."""

    def serve_step(params, cache, token, t):
        pc = cast_for_compute(params, cfg.compute_dtype)
        return transformer.decode_step(pc, cfg, token, cache, t)

    return serve_step


def make_prefill(cfg: ModelConfig, max_len: int):
    def prefill_step(params, tokens, frames=None):
        pc = cast_for_compute(params, cfg.compute_dtype)
        return transformer.prefill(pc, cfg, tokens, max_len,
                                   enc_frames=frames)

    return prefill_step


@dataclasses.dataclass
class Engine:
    """Batched greedy-decoding engine (host loop) for the examples."""
    cfg: ModelConfig
    params: dict
    max_len: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill(self.cfg, self.max_len))
        self._step = jax.jit(make_serve_step(self.cfg))

    def generate(self, prompts: np.ndarray, new_tokens: int,
                 frames=None) -> np.ndarray:
        """prompts: (B, S0) int32 -> (B, S0 + new_tokens)."""
        B, S0 = prompts.shape
        assert S0 + new_tokens <= self.max_len
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      frames)
        out = [jnp.argmax(logits, -1)]
        for i in range(new_tokens - 1):
            logits, cache = self._step(self.params, cache, out[-1],
                                       jnp.int32(S0 + i))
            out.append(jnp.argmax(logits, -1))
        gen = jnp.stack(out, axis=1)
        return np.concatenate([prompts, np.asarray(gen)], axis=1)
