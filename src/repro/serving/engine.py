"""High-QPS k-medoids assignment serving (DESIGN.md §9).

The serving workload for this repo is the paper's own: given a fitted
medoid set, answer "which medoid, how far" for streams of query rows —
prompt/embedding clustering, data curation routing, active-learning
picks. :class:`AssignmentEngine` is the host-side loop around the
batched nearest-medoid top-1 kernel (``ops.assign``, kernels/assign.py):

  * **Micro-batching** — queries are served in fixed-shape micro-batches
    (pad the tail, slice the result), so the jitted assign function
    compiles exactly once per (micro_batch, p) and every call reuses it.
    The query buffer is *donated* to the jit: the device reuses it
    in place instead of holding a second (micro_batch, p) allocation.
  * **Medoid residency** — the metric-prepared (k, p) medoid rows are
    device-resident across calls and VMEM-resident across each kernel
    sweep (constant-index BlockSpec — one DMA per call).
  * **Drift monitor** — an EMA of the per-batch assignment objective
    (mean d1) is compared against the fit-time ``est_objective_``; when
    the ratio exceeds ``drift_threshold``, the engine triggers ONE
    background refit warm-started from the live medoid set
    (``MedoidSelector.refit`` -> ``solver.one_batch_pam(init_idx=...)``,
    the FasterPAM warm-start discipline) on a ring buffer of recent
    query rows.
  * **Atomic swap** — the refit builds its complete :class:`_Medoids`
    snapshot off to the side and installs it with a single reference
    assignment. Serving threads read ``self._model`` exactly once per
    call, so they see either the old snapshot or the new one, never a
    torn mix; a refit cancelled (or crashed) mid-flight leaves the old
    snapshot serving untouched (tests/test_serving.py pins it).

Labels are bitwise ``streaming.stream_assign`` / the numpy mirror in
``core/baselines.py`` per backend (tests/test_assign.py), so swapping
the host predict loop for this engine changes throughput, not answers.
"""
from __future__ import annotations

import copy
import functools
import threading
import warnings

import jax.numpy as jnp
import numpy as np

# CPU cannot honor buffer donation (XLA:CPU aliasing); the donation is a
# TPU-path optimisation and the fallback — a copy, exactly what an
# undonated call does — is correct, so the once-per-compile nag is noise.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from repro.core.selector import MedoidSelector
from repro.kernels import metrics, ops
from repro.monitoring.metrics import StepTimer


class _Medoids:
    """Immutable snapshot of one medoid generation. Built fully before
    it is installed; the engine swaps whole snapshots, never fields."""

    __slots__ = ("rows", "prepared", "indices", "est_objective", "version")

    def __init__(self, rows, prepared, indices, est_objective, version):
        self.rows = rows                    # (k, p) f32 numpy
        self.prepared = prepared            # (k, p) device array, prepared
        self.indices = indices              # (k,) i32 numpy (into fit data)
        self.est_objective = est_objective  # float, fit-time estimate
        self.version = version              # int, bumps per refit


@functools.lru_cache(maxsize=None)
def _assign_fn(metric: str, backend: str, block_dtype: str | None,
               micro_batch: int, p: int):
    """The jitted fixed-shape assign step, one compile per signature.

    Prepare runs on the query tile *inside* the jit (row-local, fuses
    with the kernel launch); the medoid operand arrives pre-prepared
    (once per snapshot, not per batch). ``donate_argnums=0`` donates the
    query buffer — it is a fresh host upload every call, so the device
    may overwrite it freely.
    """
    import jax

    spec = metrics.get(metric)

    def fn(queries, med_prepared):
        q = spec.prepare(queries) if spec.prepare is not None else queries
        return ops.assign(q, med_prepared, metric=metric, backend=backend,
                          block_dtype=block_dtype, skip_prepare=True)

    return jax.jit(fn, donate_argnums=0)


class AssignmentEngine:
    """Serve nearest-medoid assignment at high throughput, with drift
    detection and background warm-start refit.

    Build one with :meth:`from_selector` (a fitted
    :class:`MedoidSelector`) or :meth:`from_checkpoint` (a selector
    ``save()`` artifact). Then::

        labels, d1 = engine.assign(queries)   # (q,) i32, (q,) f32
        engine.stats()                        # latency + drift + refits

    Knobs: ``micro_batch`` (rows per jitted step), ``drift_threshold``
    (EMA objective / fit objective ratio that arms a refit),
    ``drift_decay`` (EMA smoothing), ``refit_window`` (ring-buffer rows
    the refit trains on; 0 disables buffering and auto-refit),
    ``auto_refit`` (arm the background refit at all).
    """

    def __init__(self, selector: MedoidSelector, *, micro_batch: int = 4096,
                 drift_threshold: float = 1.25, drift_decay: float = 0.9,
                 refit_window: int = 65536, auto_refit: bool = True,
                 warmup: int = 1):
        if selector.medoids_ is None:
            raise RuntimeError("AssignmentEngine needs a *fitted* selector "
                               "(call fit() or load a checkpoint)")
        if micro_batch < 1:
            raise ValueError(f"micro_batch must be >= 1, got {micro_batch}")
        self._selector = selector
        self.metric = selector.metric
        self.backend = selector.backend
        self.block_dtype = (None if selector.block_dtype is None
                            else jnp.dtype(selector.block_dtype).name)
        self.micro_batch = int(micro_batch)
        self.k, self.p = np.asarray(selector.medoids_).shape
        self.drift_threshold = float(drift_threshold)
        self.drift_decay = float(drift_decay)
        self.refit_window = int(refit_window)
        self.auto_refit = bool(auto_refit)

        self._model = self._snapshot(selector, version=0)
        self._fn = _assign_fn(self.metric, self.backend, self.block_dtype,
                              self.micro_batch, self.p)
        self.timer = StepTimer(warmup=warmup)   # per-micro-batch latency
        self.queries_served = 0
        self.refits = 0
        self.last_refit_error: BaseException | None = None
        self._drift_ema: float | None = None
        self._window = (np.empty((self.refit_window, self.p), np.float32)
                        if self.refit_window > 0 else None)
        self._window_fill = 0
        self._window_pos = 0
        self._refit_thread: threading.Thread | None = None
        self._refit_cancel = threading.Event()
        self._refit_hook = None       # test seam: runs just before install

    # ------------------------------------------------------ constructors

    @classmethod
    def from_selector(cls, selector: MedoidSelector,
                      **kw) -> "AssignmentEngine":
        return cls(selector, **kw)

    @classmethod
    def from_checkpoint(cls, path: str, **kw) -> "AssignmentEngine":
        """Boot straight from a ``MedoidSelector.save()`` artifact — the
        config and fitted medoids both come from the checkpoint."""
        return cls(MedoidSelector.from_checkpoint(path), **kw)

    # ---------------------------------------------------------- serving

    def assign(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Nearest-medoid labels + distances for query rows (q, p):
        ``(labels, d1)`` of shapes (q,) i32 / (q,) f32 — index into the
        *current* medoid snapshot and distance to it. ``q == 0`` returns
        the empty shapes (the pinned edge contract); a wrong feature
        width raises."""
        q = np.asarray(queries, np.float32)
        if q.ndim != 2:
            raise ValueError(f"queries must be 2-D (q, p), got {q.shape}")
        if q.shape[1] != self.p and q.shape[0] != 0:
            raise ValueError(
                f"queries have p={q.shape[1]}, engine serves p={self.p}")
        n = q.shape[0]
        if n == 0:
            return (np.zeros((0,), np.int32), np.zeros((0,), np.float32))

        # One read: every micro-batch of this call sees the same snapshot
        # even if a refit installs a new one mid-call.
        model = self._model
        mb = self.micro_batch
        labels = np.empty((n,), np.int32)
        d1 = np.empty((n,), np.float32)
        for s in range(0, n, mb):
            chunk = q[s:s + mb]
            rows = chunk.shape[0]
            if rows < mb:
                chunk = np.concatenate(
                    [chunk, np.zeros((mb - rows, self.p), np.float32)])
            with self.timer, warnings.catch_warnings():
                # re-assert the module filter: pytest (and any
                # catch_warnings user) resets the global filter list, and
                # the nag fires at trace time inside this call
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                lab, dd = self._fn(jnp.asarray(chunk), model.prepared)
                lab = np.asarray(lab)       # blocks: the timed latency is
                dd = np.asarray(dd)         # submit + compute + readback
            labels[s:s + rows] = lab[:rows]
            d1[s:s + rows] = dd[:rows]
        self.queries_served += n

        self._observe(q, float(d1.mean()), model)
        return labels, d1

    # ---------------------------------------------------- drift + refit

    def _observe(self, q: np.ndarray, batch_objective: float,
                 model: _Medoids) -> None:
        if self._window is not None:
            self._window_push(q)
        ema = self._drift_ema
        self._drift_ema = (batch_objective if ema is None else
                           self.drift_decay * ema +
                           (1.0 - self.drift_decay) * batch_objective)
        if (self.auto_refit and self._window is not None
                and self.drift_ratio() > self.drift_threshold
                and self._window_fill >= max(4 * self.k, self.micro_batch)
                and not self.refit_in_flight):
            self._start_refit(self._window_rows())

    def _window_push(self, q: np.ndarray) -> None:
        w = self._window.shape[0]
        take = q[-w:] if q.shape[0] > w else q
        r = take.shape[0]
        end = self._window_pos + r
        if end <= w:
            self._window[self._window_pos:end] = take
        else:
            split = w - self._window_pos
            self._window[self._window_pos:] = take[:split]
            self._window[:end - w] = take[split:]
        self._window_pos = end % w
        self._window_fill = min(self._window_fill + r, w)

    def _window_rows(self) -> np.ndarray:
        return self._window[:self._window_fill].copy()

    def drift_ratio(self) -> float:
        """EMA assignment objective / fit-time estimated objective.
        ~1.0 = queries look like the fit data; > drift_threshold arms
        the background refit."""
        base = self._model.est_objective
        if self._drift_ema is None or not base or base <= 0:
            return 1.0
        return self._drift_ema / base

    @property
    def refit_in_flight(self) -> bool:
        t = self._refit_thread
        return t is not None and t.is_alive()

    def _snapshot(self, sel: MedoidSelector, version: int) -> _Medoids:
        rows = np.asarray(sel.medoids_, np.float32)
        spec = metrics.get(self.metric)
        dev = jnp.asarray(rows)
        prepared = spec.prepare(dev) if spec.prepare is not None else dev
        return _Medoids(rows=rows, prepared=prepared,
                        indices=np.asarray(sel.medoid_indices_, np.int32),
                        est_objective=float(sel.est_objective_ or 0.0),
                        version=version)

    def _start_refit(self, x: np.ndarray) -> None:
        self._refit_cancel.clear()
        t = threading.Thread(target=self._refit_worker, args=(x,),
                             name="assignment-engine-refit", daemon=True)
        self._refit_thread = t
        t.start()

    def _refit_worker(self, x: np.ndarray) -> None:
        old = self._model
        try:
            # Refit a *copy*: the live selector (and the serving
            # snapshot derived from it) stays untouched until the new
            # snapshot is complete. Shallow copy is enough — refit()
            # replaces the fitted fields, never mutates them in place.
            sel = copy.copy(self._selector)
            sel.refit(x)
            new = self._snapshot(sel, version=old.version + 1)
            if self._refit_cancel.is_set():
                return                      # killed: old snapshot serves on
            if self._refit_hook is not None:
                self._refit_hook()
            if self._refit_cancel.is_set():
                return
            # The swap: one reference assignment — readers hold either
            # the old snapshot or this one, never a mix.
            self._model = new
            self._selector = sel
            self._drift_ema = None          # drift restarts vs the new fit
            self.refits += 1
        except BaseException as e:          # noqa: BLE001 — report, don't die
            self.last_refit_error = e

    def refit_now(self, x=None, *, wait: bool = True) -> bool:
        """Trigger a refit explicitly (on ``x`` or the query window).
        Returns True if one was started. ``wait`` joins it."""
        if self.refit_in_flight:
            if wait:
                self._refit_thread.join()
            return False
        if x is None:
            if self._window is None or self._window_fill == 0:
                raise RuntimeError("no refit data: pass x= or serve "
                                   "queries with refit_window > 0")
            x = self._window_rows()
        self._start_refit(np.asarray(x, np.float32))
        if wait:
            self._refit_thread.join()
        return True

    def cancel_refit(self, *, wait: bool = True) -> None:
        """Kill an in-flight refit: the old medoid snapshot keeps
        serving; whatever the refit computed is discarded."""
        self._refit_cancel.set()
        t = self._refit_thread
        if wait and t is not None and t.is_alive():
            t.join()

    # ------------------------------------------------------------ intro

    @property
    def medoids(self) -> np.ndarray:
        return self._model.rows

    @property
    def medoid_version(self) -> int:
        return self._model.version

    def stats(self) -> dict:
        """Serving counters + per-micro-batch latency summary (StepTimer
        percentiles, warmup excluded) + drift state."""
        return {"queries_served": self.queries_served,
                "micro_batch": self.micro_batch,
                "medoid_version": self._model.version,
                "refits": self.refits,
                "refit_in_flight": self.refit_in_flight,
                "last_refit_error": repr(self.last_refit_error)
                if self.last_refit_error else None,
                "drift_ema": self._drift_ema,
                "drift_ratio": self.drift_ratio(),
                "latency": self.timer.summary()}

    def close(self) -> None:
        self.cancel_refit(wait=True)
