"""Serving-side robustness primitives (DESIGN.md §9a).

The solver got its ``validate=`` tiers, degradation ladder, and
checkpoint/resume in PR 7 (``core/guards.py``/``core/runtime.py``); this
module is the serving mirror of that discipline. Three pieces, each a
plain host-side primitive the :class:`~repro.serving.AssignmentEngine`
composes under its bookkeeping lock:

  * **Query admission** (:func:`admit`) — the serve path ingests
    *untrusted* rows. One non-finite query row used to (a) poison the
    drift EMA with a NaN that never decays out, (b) contaminate the
    refit window so the *next* medoid generation was fit on garbage, and
    (c) still burn a kernel launch. ``validate="cheap"`` scans each
    batch once (O(n·p) against the kernel's O(n·p·k)) and quarantines
    bad rows: sentinel label ``QUARANTINE_LABEL`` (−1), NaN distance,
    excluded from the EMA, the window, and the kernel call.
    ``validate="off"`` is the untouched PR 8 jitted fast path — no scan,
    no branch (benchmarks/serving_bench.py records both;
    tools/bench_compare.py holds the overhead).
  * **Refit supervision** (:class:`RefitBreaker`) — a deterministic
    (jitterless) exponential-backoff schedule plus a three-state circuit
    breaker over background refit attempts. The schedule is a pure
    function of the consecutive-failure count, so two replicas seeing
    the same failure sequence retry at the same offsets — no thundering
    herd *randomness* to reason about in tests, and the fault matrix can
    pin exact transition times through an injected clock.
  * **Weighted reservoir** (:class:`ReservoirWindow`) — the refit
    window. The PR 8 ring buffer kept the *most recent* rows, so a
    bursty tail owned the whole window; the paper's m = O(log n)
    guarantee wants a small *representative* sample instead. A-Res
    weighted reservoir sampling (Efraimidis & Spirakis) with per-row
    weight = assignment distance d1: rows contributing most to the
    objective — the ones the current medoid set explains worst — are
    overrepresented, which is exactly where a refit can help. Seeded
    from the selector's PRNG seed: the same query stream yields the same
    refit inputs, bit for bit.

Snapshot durability (the fourth piece) lives on the engine itself
(``snapshot_dir=``), through the ``repro.checkpoint`` atomic-rename
machinery; :func:`snapshot_fingerprint` pins a generation to the config
that produced it so a reboot (or, later, a cross-process broadcast)
can reject a generation fit under a different model.
"""
from __future__ import annotations

import hashlib
import json
import time

import numpy as np

VALIDATE_MODES = ("off", "cheap")
ON_INVALID = ("quarantine", "raise")

#: Label for quarantined (non-finite) query rows. Real labels are
#: >= 0 indices into the medoid set, so -1 can never collide.
QUARANTINE_LABEL = -1


def check_validate(mode: str) -> str:
    if mode not in VALIDATE_MODES:
        raise ValueError(
            f"unknown serving validate mode {mode!r}; options "
            f"{VALIDATE_MODES} (the solver's 'paranoid' tier has no "
            "serving analogue — the assign kernel is already pinned "
            "bitwise against stream_assign)")
    return mode


def check_on_invalid(policy: str) -> str:
    if policy not in ON_INVALID:
        raise ValueError(
            f"unknown on_invalid policy {policy!r}; options {ON_INVALID}")
    return policy


def admit(q: np.ndarray) -> np.ndarray:
    """Row admission mask for a (n, p) query batch: True where every
    feature is finite. One vectorised pass; the caller compacts."""
    return np.isfinite(q).all(axis=1)


# ------------------------------------------------------------- breaker --

class RefitBreaker:
    """Deterministic backoff + circuit breaker for background refits.

    States (``state``):

      ``closed``    — refits allowed, subject to the backoff schedule:
          after the f-th consecutive failure the next attempt is allowed
          ``backoff * 2**(f-1)`` seconds later (capped at
          ``backoff_cap``). Jitterless by design: the delay is a pure
          function of f, so retry times are reproducible.
      ``open``      — ``threshold`` consecutive failures tripped the
          breaker: no attempts at all for ``cooldown`` seconds
          (serve-only mode; the engine keeps answering queries from the
          last good generation).
      ``half_open`` — the cooldown elapsed: exactly ONE probe attempt is
          allowed. Success closes the breaker and resets the failure
          count; failure re-opens it for another full cooldown.

    All transitions happen inside :meth:`allow` / :meth:`record_failure`
    / :meth:`record_success`, which the engine calls under its
    bookkeeping lock — the breaker itself is lock-free. ``clock`` is
    injectable (tests drive transitions with a fake clock; production
    uses ``time.monotonic``).

    ``on_transition(old, new)`` is an optional callback fired on every
    state change, from whichever of the three mutators caused it (so
    under the same engine lock) — the telemetry hook point: the engine
    counts ``serving_breaker_transitions_total{from,to}`` through it
    without the breaker importing the telemetry module. Exceptions it
    raises propagate (a broken observer should fail loudly in tests,
    and the engine's hook never raises).
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, *, backoff: float = 1.0, backoff_cap: float = 60.0,
                 threshold: int = 3, cooldown: float = 30.0,
                 clock=time.monotonic, on_transition=None):
        if backoff < 0 or backoff_cap < 0 or cooldown < 0:
            raise ValueError("backoff, backoff_cap and cooldown must be >= 0")
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.backoff = float(backoff)
        self.backoff_cap = float(backoff_cap)
        self.threshold = int(threshold)
        self.cooldown = float(cooldown)
        self._clock = clock
        self.on_transition = on_transition
        self.state = self.CLOSED
        self.consecutive_failures = 0
        self.total_failures = 0
        self._next_allowed = 0.0        # closed-state backoff deadline
        self._opened_at = 0.0
        self._probe_in_flight = False

    def _set_state(self, new: str) -> None:
        old = self.state
        if new == old:
            return
        self.state = new
        if self.on_transition is not None:
            self.on_transition(old, new)

    def backoff_delay(self, failures: int) -> float:
        """The deterministic schedule: delay after ``failures``
        consecutive failures (0 -> no delay)."""
        if failures <= 0:
            return 0.0
        return min(self.backoff * 2.0 ** (failures - 1), self.backoff_cap)

    def allow(self) -> bool:
        """May a refit attempt start now? Mutates state (open ->
        half_open when the cooldown elapsed; half_open admits one
        probe). Call under the engine lock."""
        now = self._clock()
        if self.state == self.OPEN:
            if now - self._opened_at < self.cooldown:
                return False
            self._set_state(self.HALF_OPEN)
            self._probe_in_flight = False
        if self.state == self.HALF_OPEN:
            if self._probe_in_flight:
                return False
            self._probe_in_flight = True
            return True
        return now >= self._next_allowed

    def record_success(self) -> None:
        self._set_state(self.CLOSED)
        self.consecutive_failures = 0
        self._next_allowed = 0.0
        self._probe_in_flight = False

    def record_failure(self) -> None:
        now = self._clock()
        self.consecutive_failures += 1
        self.total_failures += 1
        if (self.state == self.HALF_OPEN
                or self.consecutive_failures >= self.threshold):
            self._set_state(self.OPEN)
            self._opened_at = now
            self._probe_in_flight = False
        else:
            self._next_allowed = now + self.backoff_delay(
                self.consecutive_failures)

    def retry_in(self) -> float:
        """Seconds until the next attempt may start (0.0 = now)."""
        now = self._clock()
        if self.state == self.OPEN:
            return max(0.0, self.cooldown - (now - self._opened_at))
        if self.state == self.HALF_OPEN:
            return 0.0 if not self._probe_in_flight else float("inf")
        return max(0.0, self._next_allowed - now)

    def stats(self) -> dict:
        retry = self.retry_in()
        return {"state": self.state,
                "consecutive_failures": self.consecutive_failures,
                "total_failures": self.total_failures,
                "threshold": self.threshold,
                "cooldown": self.cooldown,
                "retry_in": None if retry == float("inf") else retry}


# ----------------------------------------------------------- reservoir --

class ReservoirWindow:
    """Objective-weighted reservoir of query rows (A-Res).

    Every pushed row i gets key ``u_i ** (1 / w_i)`` with ``u_i`` drawn
    from a seeded PRNG and ``w_i`` its assignment distance d1; the
    window keeps the ``capacity`` largest keys. Inclusion probability is
    proportional to weight (Efraimidis & Spirakis 2006), so the sample
    is representative of the *objective mass* of the whole stream, not
    of its last ``capacity`` rows — and it is reproducible: the PRNG is
    seeded once, rows are consumed in arrival order.

    ``mode="ring"`` keeps the PR 8 recency window (last ``capacity``
    rows, wrap-around overwrite) for callers that explicitly want
    recency bias; the weights are ignored there.

    Not thread-safe on its own: the engine serialises pushes under its
    bookkeeping lock (satellite: tests/test_serving.py pins threaded
    serving).
    """

    MODES = ("reservoir", "ring")
    #: Weight floor: rows at distance exactly 0 (duplicates of a medoid)
    #: carry no objective information; the floor keeps their keys
    #: defined (u ** (1/eps) underflows to 0 — they lose every contest
    #: against any positively-weighted row, which is the right limit).
    MIN_WEIGHT = 1e-30

    def __init__(self, capacity: int, p: int, *, mode: str = "reservoir",
                 seed: int = 0):
        if mode not in self.MODES:
            raise ValueError(
                f"unknown window mode {mode!r}; options {self.MODES}")
        if capacity < 1:
            raise ValueError(f"window capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.mode = mode
        self.rows = np.empty((capacity, p), np.float32)
        self.keys = np.zeros((capacity,), np.float64)
        self.fill = 0
        self.pushed = 0
        self._pos = 0                     # ring write head
        self._rng = np.random.default_rng(np.uint64(seed) ^ np.uint64(
            0x9E3779B97F4A7C15))          # decorrelate from the solve draw

    def push(self, rows: np.ndarray, weights: np.ndarray) -> None:
        r = rows.shape[0]
        if r == 0:
            return
        self.pushed += r
        if self.mode == "ring":
            self._push_ring(rows)
            return
        w = self.capacity
        keys = self._rng.random(r) ** (
            1.0 / np.maximum(np.asarray(weights, np.float64),
                             self.MIN_WEIGHT))
        start = 0
        if self.fill < w:                 # fill free slots first (A-Res)
            take = min(w - self.fill, r)
            self.rows[self.fill:self.fill + take] = rows[:take]
            self.keys[self.fill:self.fill + take] = keys[:take]
            self.fill += take
            start = take
        if start == r:
            return
        # Saturated: only keys beating the current minimum can enter.
        # Candidate counts shrink as ~capacity/pushed once the stream is
        # long, so the per-candidate argmin stays off the hot path.
        thr = self.keys.min()
        for i in range(start, r):
            if keys[i] <= thr:
                continue
            j = int(self.keys.argmin())
            if keys[i] <= self.keys[j]:
                continue
            self.keys[j] = keys[i]
            self.rows[j] = rows[i]
            thr = self.keys.min()

    def _push_ring(self, rows: np.ndarray) -> None:
        w = self.capacity
        take = rows[-w:] if rows.shape[0] > w else rows
        r = take.shape[0]
        end = self._pos + r
        if end <= w:
            self.rows[self._pos:end] = take
        else:
            split = w - self._pos
            self.rows[self._pos:] = take[:split]
            self.rows[:end - w] = take[split:]
        self._pos = end % w
        self.fill = min(self.fill + r, w)

    def content(self) -> np.ndarray:
        """A copy of the current window rows, shape (fill, p)."""
        return self.rows[:self.fill].copy()

    def stats(self) -> dict:
        return {"mode": self.mode, "fill": self.fill,
                "capacity": self.capacity, "pushed": self.pushed}


# ----------------------------------------------------------- snapshots --

def snapshot_fingerprint(config: dict) -> str:
    """Stable 16-hex-digit fingerprint of a snapshot-defining config.

    Persisted in every durable snapshot's manifest and checked on load:
    a generation fit under a different (k, p, metric, ...) must be
    rejected loudly — fitted rows divorced from their config are the
    same silent-wrong-answer factory ``MedoidSelector.load`` guards
    against. JSON with sorted keys so dict order can't shift the hash.
    """
    blob = json.dumps(config, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]
