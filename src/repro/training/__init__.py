from .optimizer import OptConfig, adamw_update, init_moments, schedule  # noqa: F401
from .trainer import cast_for_compute, init_train_state, make_train_step  # noqa: F401
from .losses import next_token_loss  # noqa: F401
