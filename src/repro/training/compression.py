"""Cross-pod gradient compression: int8 quantisation with error feedback.

The pod axis crosses DCN (12.5 GB/s/chip vs 50 GB/s ICI), so the
cross-pod gradient reduction is the one collective worth compressing.
``compressed_psum`` moves int8 on the wire (4x fewer bytes than f32: an
all-gather of int8 shards + local dequant-sum) and returns the
quantisation residual for error feedback — adding it to the next step's
grads makes the compression error telescope instead of accumulate
(1-bit/8-bit EF-SGD literature).

Usage is shard_map over the "pod" axis (grads are per-pod partials
there); see tests/helpers/dist_compression_check.py for the wiring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Per-tensor symmetric int8. Returns (q, scale, residual)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    residual = xf - q.astype(jnp.float32) * scale
    return q, scale, residual


def dequantize_int8(q: jnp.ndarray, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str, error: jnp.ndarray | None = None):
    """psum over `axis_name` with int8 wire format + error feedback.

    Returns (reduced f32 (the SAME value on every participant), new error
    state to carry into the next call). Wire bytes: |x| int8 + one f32
    scale per participant, vs 2x|x| f32 for a ring all-reduce.
    """
    if error is not None:
        x = x.astype(jnp.float32) + error
    q, scale, residual = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)          # int8 on the wire
    scales = jax.lax.all_gather(scale, axis_name)  # (P,) f32
    out = jnp.tensordot(scales,
                        qs.astype(jnp.float32), axes=((0,), (0,)))
    return out, residual


def compressed_psum_tree(tree, axis_name: str, error_tree=None):
    """Tree version; error_tree=None initialises feedback state."""
    if error_tree is None:
        error_tree = jax.tree.map(lambda _: None, tree,
                                  is_leaf=lambda x: x is None)
    outs = jax.tree.map(
        lambda g, e: compressed_psum(g, axis_name, e), tree, error_tree,
        is_leaf=lambda x: x is None or not isinstance(x, tuple))
    out = jax.tree.map(lambda o: o[0], outs,
                       is_leaf=lambda o: isinstance(o, tuple))
    err = jax.tree.map(lambda o: o[1], outs,
                       is_leaf=lambda o: isinstance(o, tuple))
    return out, err
