"""Next-token cross entropy with z-loss, computed against vocab-sharded
logits (the logsumexp reduction crosses the model axis; GSPMD inserts the
psum)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_loss(logits, tokens, *, z_loss: float = 1e-4,
                    moe_aux=None, moe_aux_weight: float = 0.01):
    """logits: (B, S, V) f32 over positions 0..S-1; tokens: (B, S) int32.
    Predicts tokens[:, 1:] from logits[:, :-1]."""
    lg = logits[:, :-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    nll = lse - picked
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse * lse)
    if moe_aux is not None:
        loss = loss + moe_aux_weight * moe_aux
    return loss, {"nll": jnp.mean(nll), "ppl_log": jnp.mean(nll)}
