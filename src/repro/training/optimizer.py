"""AdamW with decoupled weight decay, global-norm clipping, cosine
schedule, and configurable moment dtype (bf16 moments for the 200B+ archs,
DESIGN.md §7)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def schedule(step, oc: OptConfig):
    step = step.astype(jnp.float32) + 1.0  # lr > 0 from the first step
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - oc.warmup_steps)
                    / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


def init_moments(params, oc: OptConfig):
    dt = jnp.dtype(oc.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _decayable(path) -> bool:
    """No weight decay on norms / biases / 1-D leaves."""
    name = str(getattr(path[-1], "key", ""))
    return name not in ("scale", "bias", "b_gates", "bq", "bk", "bv",
                        "conv_b", "dt_bias", "skip_d")


def adamw_update(params, grads, m, v, step, oc: OptConfig):
    """One AdamW step. Returns (new_params, new_m, new_v, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if oc.clip_norm else jnp.float32(1.0)
    lr = schedule(step, oc)
    b1, b2 = jnp.float32(oc.b1), jnp.float32(oc.b2)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t
    mdt = jnp.dtype(oc.moment_dtype)

    def upd(path, p, g, m_, v_):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m_.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v_.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        if oc.weight_decay and _decayable(path):
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

    out = jax.tree_util.tree_map_with_path(upd, params, grads, m, v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda o: isinstance(o, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda o: isinstance(o, tuple))
    return new_params, new_m, new_v, {"grad_norm": gnorm, "lr": lr}
