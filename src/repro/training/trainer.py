"""Train-step builder: mixed precision (f32 master -> bf16 compute),
microbatch gradient accumulation (lax.scan), remat, AdamW.

The returned step is a pure function (state, batch) -> (state, metrics)
suitable for jit/pjit; launch/dryrun.py lowers it on the production mesh
and launch/train.py drives it for real.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import precision, transformer
from repro.training import losses, optimizer

_KEEP_F32 = precision.KEEP_F32  # back-compat alias


def cast_for_compute(params, compute_dtype):
    """Whole-tree cast WITHOUT resharding constraints (serving path: for
    one-token decode, contracting against fully-sharded weights is
    cheaper than gathering them)."""
    return precision.cast_tree(params, compute_dtype)


def cast_for_train(params, compute_dtype):
    """Training-path cast: non-block params (embeddings, head, norms) are
    cast + ZeRO-3-constrained up front (small); the block stack stays in
    master layout and is cast PER PERIOD inside the layer scan (see
    transformer._stack_apply block_cast) so gathered bf16 weights are
    transient — one period live at a time."""
    blocks = {k: params[k] for k in ("blocks", "enc_blocks") if k in params}
    rest = {k: v for k, v in params.items() if k not in blocks}
    out = precision.cast_tree(rest, compute_dtype,
                              constrain_model_only=True)
    out.update(blocks)
    return out


def init_train_state(key, cfg: ModelConfig, oc: optimizer.OptConfig):
    params = transformer.init_lm(key, cfg)
    m, v = optimizer.init_moments(params, oc)
    return {"params": params, "m": m, "v": v, "step": jnp.int32(0)}


def make_train_step(cfg: ModelConfig, oc: optimizer.OptConfig, *,
                    grad_accum: int = 1, remat: bool = True,
                    z_loss: float = 1e-4, accum_dtype: str = "float32"):
    """Builds train_step(state, batch). batch: {"tokens": (B, S) int32
    [, "frames": (B, S_enc, D)]}. B must divide by grad_accum.

    accum_dtype: dtype of the gradient-accumulation buffer. bf16 halves
    the buffer for 100B+ models (grok: -4.9 GB/device) at the cost of
    accumulation rounding across grad_accum microbatches — the moments
    and update math stay f32 either way."""

    def loss_fn(params, tokens, frames):
        pc = cast_for_train(params, cfg.compute_dtype)
        logits, aux = transformer.forward(pc, cfg, tokens,
                                          enc_frames=frames, remat=remat,
                                          block_cast=cfg.compute_dtype)
        return losses.next_token_loss(logits, tokens, z_loss=z_loss,
                                      moe_aux=aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        tokens = batch["tokens"]
        frames = batch.get("frames")
        if grad_accum == 1:
            (loss, aux_metrics), grads = grad_fn(state["params"], tokens,
                                                 frames)
        else:
            B = tokens.shape[0]
            mb = B // grad_accum
            tok_mb = tokens.reshape(grad_accum, mb, *tokens.shape[1:])
            frm_mb = (frames.reshape(grad_accum, mb, *frames.shape[1:])
                      if frames is not None else None)

            acc_dt = jnp.dtype(accum_dtype)

            def micro(carry, xs):
                g_acc, l_acc = carry
                tok = xs[0]
                frm = xs[1] if frames is not None else None
                (l, _), g = grad_fn(state["params"], tok, frm)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                              state["params"])
            xs = (tok_mb, frm_mb) if frames is not None else (tok_mb,)
            (grads, loss_sum), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)),
                                                xs)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / grad_accum, grads)
            loss = loss_sum / grad_accum
            aux_metrics = {}

        new_params, m, v, opt_metrics = optimizer.adamw_update(
            state["params"], grads, state["m"], state["v"], state["step"], oc)
        new_state = {"params": new_params, "m": m, "v": v,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **opt_metrics, **aux_metrics}
        return new_state, metrics

    return train_step
