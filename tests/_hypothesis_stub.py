"""Deterministic stand-in for the optional ``hypothesis`` dependency.

The property suites guard ``from hypothesis import ...`` and fall back
here, so environments without hypothesis (the dependency stays in
requirements-dev.txt, never a hard requirement) still *run* the
property tests instead of skipping them: each ``@given`` test executes
a fixed number of examples drawn from a seeded generator instead of a
shrinking search. The seed mixes the test's module-qualified name and
its (parametrized) call arguments, so every example set is stable
across runs and processes — rerunning a red test replays the identical
failure.

Only the API surface the repo's suites use is provided: ``given``
(keyword form), ``settings`` (no-op decorator), and the strategies
``integers``, ``floats``, ``booleans``, ``sampled_from`` and ``data``
(with ``draw(strategy, label=...)``). With real hypothesis installed
this module is never imported.
"""
from __future__ import annotations

import functools
import inspect
import os
import zlib

import numpy as np

# Mirror the conftest profiles loosely: the ci profile runs more seeded
# examples; both stay far below real hypothesis' search budget (this is
# a determinism fallback, not a search engine).
_EXAMPLES = {"ci": 10, "dev": 5}.get(
    os.environ.get("HYPOTHESIS_PROFILE", "dev"), 5)


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng):
        return self._draw(rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(None)


class _DataObject:
    """The ``st.data()`` value: sequential draws off one example rng."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example_from(self._rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(0, len(elements)))])

    @staticmethod
    def data():
        return _DataStrategy()


def _example_seed(fn, call_args, call_kw, example) -> int:
    """Stable per-example seed: test identity + parametrization + index."""
    tag = (f"{fn.__module__}.{fn.__qualname__}|{call_args!r}|"
           f"{sorted(call_kw.items())!r}|{example}")
    return zlib.crc32(tag.encode())


def given(**strategies_kw):
    """Keyword-only ``@given``: runs ``_EXAMPLES`` seeded examples."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            for example in range(_EXAMPLES):
                rng = np.random.default_rng(
                    _example_seed(fn, args, kw, example))
                drawn = {}
                for name, strat in strategies_kw.items():
                    if isinstance(strat, _DataStrategy):
                        drawn[name] = _DataObject(rng)
                    else:
                        drawn[name] = strat.example_from(rng)
                fn(*args, **kw, **drawn)
        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution, as real hypothesis does.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for p in sig.parameters.values()
            if p.name not in strategies_kw])
        return wrapper
    return deco


def settings(**_kw):
    """No-op decorator; example counts come from ``_EXAMPLES``."""
    def deco(fn):
        return fn
    return deco
