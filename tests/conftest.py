"""Shared test configuration.

Registers hypothesis profiles so CI is deterministic (ISSUE 3):

  * ``ci``  — >= 50 examples per test, derandomized (fixed example
    sequence — no flaky shrink paths, reruns are reproducible),
    deadline=None (JIT compilation makes first examples slow).
    Selected in .github/workflows/ci.yml via HYPOTHESIS_PROFILE=ci.
  * ``dev`` — the local default: fewer examples, still no deadline.

hypothesis stays optional (requirements-dev.txt): without it the
property tests fall back to tests/_hypothesis_stub.py — deterministic
seeded example draws through the same @given API, honouring the same
HYPOTHESIS_PROFILE env var — and this conftest is a no-op.
"""
import os

try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=60, derandomize=True,
                              deadline=None, print_blob=True)
    settings.register_profile("dev", max_examples=20, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
