"""Fault injectors for the fault-tolerant runtime (DESIGN.md §6).

Each injector is either a ``_fault_hook`` factory — called by
``runtime.solve_fault_tolerant`` at the top of every sweep with a
mutable ``{"sweep", "state", "ub", "lb"}`` dict whose entries are read
back — or a filesystem mutation against a checkpoint directory.
tests/test_solver_faults.py drives every one of them through the guard
ladder; tests/helpers/kill_resume_check.py uses :func:`kill_at` for the
real-SIGKILL resume tests.
"""
from __future__ import annotations

import json
import os
import signal

import jax.numpy as jnp
import numpy as np


class StopRun(Exception):
    """Raised by :func:`stop_at` to abandon a solve mid-run — a
    controlled in-process stand-in for preemption (completed sweeps are
    already checkpointed when the hook fires)."""


def stop_at(sweep: int):
    def hook(run):
        if run["sweep"] == sweep:
            raise StopRun(f"injected stop at sweep {sweep}")
    return hook


def kill_at(sweep: int):
    """SIGKILL the current process at the top of sweep ``sweep`` — the
    real thing; only usable from a subprocess."""
    def hook(run):
        if run["sweep"] == sweep:
            os.kill(os.getpid(), signal.SIGKILL)
    return hook


def state_poison(sweep: int, mode: str = "nan"):
    """Corrupt the solver state ahead of sweep ``sweep``. ``"nan"``
    writes NaN into d1 (trips the nonfinite/objective guards);
    ``"order"`` lifts d1 above d2 (trips top2_order / the objective
    guard, depending on where the poison surfaces first). Works on
    single-restart and R-lane states alike (leading axes broadcast)."""
    def hook(run):
        if run["sweep"] != sweep:
            return
        st = run["state"]
        if mode == "nan":
            run["state"] = st._replace(d1=st.d1.at[..., 0].set(jnp.nan))
        elif mode == "order":
            run["state"] = st._replace(d1=st.d2 + 1.0)
        else:
            raise ValueError(f"unknown state_poison mode {mode!r}")
    return hook


def cache_poison(sweep: int, mode: str = "ub"):
    """Corrupt the pruned strategy's bound caches ahead of sweep
    ``sweep``: ``"ub"`` clamps every upper bound below any true gain,
    ``"lb"`` lifts every lower bound above it — both break the
    lo <= G <= hi containment invariant the paranoid tier checks.
    No-op for strategies without caches."""
    def hook(run):
        if run["sweep"] != sweep or run["ub"] is None:
            return
        from repro.core import pruned
        if mode == "ub":
            run["ub"] = jnp.full_like(run["ub"], -pruned.BIG)
        elif mode == "lb":
            run["lb"] = jnp.full_like(run["lb"], pruned.BIG)
        else:
            raise ValueError(f"unknown cache_poison mode {mode!r}")
    return hook


def corrupt_latest_checkpoint(root: str, mode: str) -> int:
    """Damage the newest checkpoint under ``root``; returns the damaged
    step. ``restore_latest_valid`` must skip it (warning) and fall back
    to the next-older step; ``"truncate_manifest"`` removes the manifest
    entirely, which makes the step invisible (an interrupted writer
    would never have renamed the dir, so a manifest-less step dir is by
    definition debris)."""
    from repro import checkpoint as ckpt
    step = ckpt.latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    if mode == "truncate_manifest":
        os.remove(os.path.join(d, "manifest.json"))
    elif mode == "garbage_manifest":
        with open(os.path.join(d, "manifest.json"), "w") as f:
            f.write("{this is not json")
    elif mode == "missing_leaf":
        with open(os.path.join(d, "manifest.json")) as f:
            man = json.load(f)
        os.remove(os.path.join(d, man["leaves"][0]["name"] + ".npy"))
    elif mode == "shape":
        with open(os.path.join(d, "manifest.json")) as f:
            man = json.load(f)
        leaf = man["leaves"][0]
        np.save(os.path.join(d, leaf["name"] + ".npy"),
                np.zeros((1,) + tuple(leaf["shape"]), np.float32))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return step
