"""Fault injectors for the fault-tolerant runtime (DESIGN.md §6, §9a).

Solver-side: each injector is either a ``_fault_hook`` factory — called
by ``runtime.solve_fault_tolerant`` at the top of every sweep with a
mutable ``{"sweep", "state", "ub", "lb"}`` dict whose entries are read
back — or a filesystem mutation against a checkpoint directory.
tests/test_solver_faults.py drives every one of them through the guard
ladder; tests/helpers/kill_resume_check.py uses :func:`kill_at` for the
real-SIGKILL resume tests.

Serving-side (DESIGN.md §9a): :func:`refit_crash` / :func:`refit_hang`
target the engine's ``_refit_hook`` seam (the instant between "new
medoids computed" and "snapshot installed"); :func:`nonfinite_storm`
poisons query batches; :func:`poison_medoids` corrupts the *installed*
snapshot in place (the prepared device cache, or the raw host rows);
:func:`corrupt_latest_checkpoint` doubles for serving snapshot dirs
(same atomic machinery). tests/test_serving_faults.py drives all of
them.
"""
from __future__ import annotations

import json
import os
import signal
import threading

import jax.numpy as jnp
import numpy as np


class StopRun(Exception):
    """Raised by :func:`stop_at` to abandon a solve mid-run — a
    controlled in-process stand-in for preemption (completed sweeps are
    already checkpointed when the hook fires)."""


def stop_at(sweep: int):
    def hook(run):
        if run["sweep"] == sweep:
            raise StopRun(f"injected stop at sweep {sweep}")
    return hook


def kill_at(sweep: int):
    """SIGKILL the current process at the top of sweep ``sweep`` — the
    real thing; only usable from a subprocess."""
    def hook(run):
        if run["sweep"] == sweep:
            os.kill(os.getpid(), signal.SIGKILL)
    return hook


def state_poison(sweep: int, mode: str = "nan"):
    """Corrupt the solver state ahead of sweep ``sweep``. ``"nan"``
    writes NaN into d1 (trips the nonfinite/objective guards);
    ``"order"`` lifts d1 above d2 (trips top2_order / the objective
    guard, depending on where the poison surfaces first). Works on
    single-restart and R-lane states alike (leading axes broadcast)."""
    def hook(run):
        if run["sweep"] != sweep:
            return
        st = run["state"]
        if mode == "nan":
            run["state"] = st._replace(d1=st.d1.at[..., 0].set(jnp.nan))
        elif mode == "order":
            run["state"] = st._replace(d1=st.d2 + 1.0)
        else:
            raise ValueError(f"unknown state_poison mode {mode!r}")
    return hook


def cache_poison(sweep: int, mode: str = "ub"):
    """Corrupt the pruned strategy's bound caches ahead of sweep
    ``sweep``: ``"ub"`` clamps every upper bound below any true gain,
    ``"lb"`` lifts every lower bound above it — both break the
    lo <= G <= hi containment invariant the paranoid tier checks.
    No-op for strategies without caches."""
    def hook(run):
        if run["sweep"] != sweep or run["ub"] is None:
            return
        from repro.core import pruned
        if mode == "ub":
            run["ub"] = jnp.full_like(run["ub"], -pruned.BIG)
        elif mode == "lb":
            run["lb"] = jnp.full_like(run["lb"], pruned.BIG)
        else:
            raise ValueError(f"unknown cache_poison mode {mode!r}")
    return hook


def corrupt_latest_checkpoint(root: str, mode: str) -> int:
    """Damage the newest checkpoint under ``root``; returns the damaged
    step. ``restore_latest_valid`` must skip it (warning) and fall back
    to the next-older step; ``"truncate_manifest"`` removes the manifest
    entirely, which makes the step invisible (an interrupted writer
    would never have renamed the dir, so a manifest-less step dir is by
    definition debris)."""
    from repro import checkpoint as ckpt
    step = ckpt.latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    if mode == "truncate_manifest":
        os.remove(os.path.join(d, "manifest.json"))
    elif mode == "garbage_manifest":
        with open(os.path.join(d, "manifest.json"), "w") as f:
            f.write("{this is not json")
    elif mode == "missing_leaf":
        with open(os.path.join(d, "manifest.json")) as f:
            man = json.load(f)
        os.remove(os.path.join(d, man["leaves"][0]["name"] + ".npy"))
    elif mode == "shape":
        with open(os.path.join(d, "manifest.json")) as f:
            man = json.load(f)
        leaf = man["leaves"][0]
        np.save(os.path.join(d, leaf["name"] + ".npy"),
                np.zeros((1,) + tuple(leaf["shape"]), np.float32))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return step


# ------------------------------------------------------- serving faults --

class RefitBoom(Exception):
    """Raised by :func:`refit_crash` inside the refit worker — a
    controlled stand-in for a crashing background refit (OOM, bad
    kernel, poisoned window). The engine must record the failure, feed
    the breaker, and keep serving the old generation."""


def refit_crash(engine):
    """Arm the engine's refit hook to crash every attempt (until the
    hook is cleared). Returns the engine for chaining."""
    def boom():
        raise RefitBoom("injected refit crash")
    engine._refit_hook = boom
    return engine


def refit_hang(engine):
    """Arm the engine's refit hook to hang the worker on an Event.
    Returns the release Event — ``.set()`` un-hangs any parked workers
    (call it in test teardown so abandoned daemon threads exit). With
    ``refit_timeout`` set, the supervisor must cancel the attempt,
    record a TimeoutError, and leave the hung worker fenced off the
    install."""
    release = threading.Event()
    engine._refit_hook = release.wait
    return release


def nonfinite_storm(x: np.ndarray, frac: float = 0.25, mode: str = "nan",
                    seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Poison a random ``frac`` of the rows of a query batch with NaN
    (``"nan"``), +/-inf (``"inf"``), or an alternating mix (``"mixed"``).
    Returns ``(poisoned_copy, bad_row_mask)`` — the untouched rows are
    bitwise the originals, so tests can assert the engine's answers on
    the clean complement."""
    rng = np.random.default_rng(seed)
    out = np.array(x, np.float32, copy=True)
    n = out.shape[0]
    n_bad = max(1, int(round(frac * n)))
    bad = np.zeros((n,), bool)
    bad[rng.choice(n, size=n_bad, replace=False)] = True
    idx = np.flatnonzero(bad)
    cols = rng.integers(0, out.shape[1], size=n_bad)
    if mode == "nan":
        vals = np.full(n_bad, np.nan, np.float32)
    elif mode == "inf":
        vals = np.where(np.arange(n_bad) % 2 == 0, np.inf,
                        -np.inf).astype(np.float32)
    elif mode == "mixed":
        vals = np.where(np.arange(n_bad) % 2 == 0, np.nan,
                        np.inf).astype(np.float32)
    else:
        raise ValueError(f"unknown nonfinite_storm mode {mode!r}")
    out[idx, cols] = vals
    return out, bad


def poison_medoids(engine, mode: str = "prepared"):
    """Corrupt the engine's *installed* medoid snapshot in place — the
    in-memory analogue of a flipped bit / bad DMA in the serving
    replica. ``"prepared"`` poisons only the device-side prepared cache
    (raw host rows stay healthy — recovery is a re-prepare);
    ``"rows"`` poisons both (recovery needs the durable snapshot).
    Returns the poisoned version number."""
    model = engine._model
    prepared = np.array(model.prepared, np.float32, copy=True)
    prepared[0, 0] = np.nan
    model.prepared = jnp.asarray(prepared)
    if mode == "rows":
        model.rows = np.array(model.rows, np.float32, copy=True)
        model.rows[0, 0] = np.nan
    elif mode != "prepared":
        raise ValueError(f"unknown poison_medoids mode {mode!r}")
    return model.version
