"""Subprocess body: int8+EF compressed cross-pod psum vs exact psum.
Checks (1) one-shot error bound, (2) error-feedback telescoping over a
simulated accumulation, (3) int8 (not f32) crosses the wire in the HLO."""
import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.compat import shard_map  # noqa: E402
from repro.training.compression import compressed_psum  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("pod",))
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(8, 4096)).astype(np.float32))

    @functools.partial(shard_map, mesh=mesh, in_specs=P("pod"),
                       out_specs=(P("pod"), P("pod")), check_vma=False)
    def one_shot(x):
        out, err = compressed_psum(x[0], "pod")
        return out[None], err[None]

    got, _ = one_shot(xs)
    want = np.asarray(xs).sum(0)
    scale = np.abs(np.asarray(xs)).max(axis=1).sum() / 127
    err = np.abs(np.asarray(got[0]) - want).max()
    assert err <= scale + 1e-5, (err, scale)
    print(f"one-shot ok: max err {err:.4f} (bound {scale:.4f})")

    # error feedback: accumulated mean over T rounds converges to exact
    T = 30
    @functools.partial(shard_map, mesh=mesh, in_specs=P("pod"),
                       out_specs=P("pod"), check_vma=False)
    def accumulate(x):
        def body(carry, _):
            acc, e = carry
            out, e = compressed_psum(x[0], "pod", e)
            return (acc + out, e), None
        (acc, _), _ = jax.lax.scan(
            body, (jnp.zeros_like(x[0]), jnp.zeros_like(x[0])), None,
            length=T)
        return (acc / T)[None]

    acc = np.asarray(accumulate(xs))[0]
    rel = np.abs(acc - want).max() / np.abs(want).max()
    assert rel < 2e-3, rel   # EF telescopes: avg error ~ bound/T
    print(f"error-feedback ok: rel err after {T} rounds = {rel:.2e}")

    # wire format: the all-gather must move s8, not f32
    hlo = jax.jit(one_shot).lower(xs).compile().as_text()
    assert any("all-gather" in ln and "s8[" in ln
               for ln in hlo.splitlines()), "int8 all-gather not found"
    assert not any("all-gather" in ln and "f32[8,4096]" in ln
                   for ln in hlo.splitlines()), "f32 payload on the wire"
    print("wire format ok: s8 all-gather in HLO")


if __name__ == "__main__":
    main()
