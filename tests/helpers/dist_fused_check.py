"""Subprocess body: the fused sharded sweep (swap_select partials + scalar
election + incremental repair) on 2 fake host devices must be bit-for-bit
identical to the single-device fused solver — same medoid array (same slot
order, not just the same set), same swap count, same estimated objective —
including on tie-heavy quantized instances and with a bf16 block. Invoked
by tests/test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=2 in the environment."""
import os

assert "--xla_force_host_platform_device_count=2" in os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import solver  # noqa: E402
from repro.core.distributed import make_distributed_obp, shard_over_batch  # noqa: E402
from repro.kernels import ops  # noqa: E402


def _host_block(x, batch_idx, weights, block_dtype):
    """Single-device block with the cast order the mesh path mirrors."""
    d = ops.pairwise_distance(x, x[batch_idx], metric="l1")
    if block_dtype is not None:
        d = d.astype(block_dtype)
    d = d * weights[None, :]
    return d.astype(block_dtype) if block_dtype is not None else d


def main() -> None:
    assert jax.device_count() == 2, jax.device_count()
    mesh = jax.make_mesh((2,), ("data",))

    rng = np.random.default_rng(0)
    n, p, k, m = 256, 8, 6, 32

    for case, quantize, block_dtype in (
            ("plain", None, None),
            ("ties", 2, None),          # coarse grid -> duplicate gains
            ("bf16", None, "bfloat16")):
        xv = rng.normal(size=(n, p)).astype(np.float32)
        if quantize:
            xv = np.round(xv * quantize) / quantize
        x = jnp.asarray(xv)
        batch_idx = jnp.asarray(rng.choice(n, size=m, replace=False))
        weights = jnp.asarray(rng.uniform(0.5, 1.5, size=m).astype(np.float32))
        init_idx = jnp.asarray(rng.choice(n, size=k, replace=False))

        ref = solver.solve_batched(
            _host_block(x, batch_idx, weights, block_dtype), init_idx)

        run = make_distributed_obp(mesh, k=k, metric="l1",
                                   block_dtype=block_dtype)
        got = run(shard_over_batch(mesh, x), batch_idx, weights, init_idx)

        # Bitwise: identical slot-for-slot medoid array, swap count, and
        # estimated objective — not just the same medoid set.
        np.testing.assert_array_equal(np.asarray(ref.medoid_idx),
                                      np.asarray(got.medoid_idx))
        assert int(got.n_swaps) == int(ref.n_swaps), case
        np.testing.assert_array_equal(np.float32(ref.est_objective),
                                      np.float32(got.est_objective))
        print(f"OK {case} swaps={int(got.n_swaps)} "
              f"obj={float(got.est_objective):.6f}")


if __name__ == "__main__":
    main()
