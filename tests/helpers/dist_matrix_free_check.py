"""Subprocess body: the distributed matrix-free sweep (per-shard fused
distance+select partials, 3-scalar election, owner-recomputed winning
row) on 2 fake host devices must be bit-for-bit identical to the
single-device ``solver.solve_matrix_free`` — same medoid array (slot
order included), same swap count, same estimated objective — across the
in-mesh weight variants, and the in-mesh nniw weights must equal the
host streaming histogram. No shard ever materialises a distance block.
Invoked by tests/test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=2 in the environment."""
import os

assert "--xla_force_host_platform_device_count=2" in os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import solver, streaming  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    make_distributed_obp_matrix_free,
    shard_over_batch,
)


def main() -> None:
    assert jax.device_count() == 2, jax.device_count()
    mesh = jax.make_mesh((2,), ("data",))

    rng = np.random.default_rng(0)
    n, p, k, m = 256, 8, 6, 32

    for case, variant, metric, quantize in (
            ("plain", "unif", "l1", None),
            ("ties", "unif", "l1", 2),     # coarse grid -> duplicate gains
            ("nniw", "nniw", "l2", None),
            ("debias", "debias", "cosine", None)):
        xv = rng.normal(size=(n, p)).astype(np.float32)
        if quantize:
            xv = np.round(xv * quantize) / quantize
        x = jnp.asarray(xv)
        batch_idx = jnp.asarray(
            rng.choice(n, size=m, replace=False)).astype(jnp.int32)
        init_idx = jnp.asarray(rng.choice(n, size=k, replace=False))

        if variant == "nniw":
            w = streaming.stream_nn_counts(x, x[batch_idx],
                                           metric=metric) * (m / n)
        else:
            w = jnp.ones((m,), jnp.float32)
        ref = solver.solve_matrix_free(x, batch_idx, w, init_idx,
                                       metric=metric,
                                       debias=(variant == "debias"))

        run = make_distributed_obp_matrix_free(mesh, k=k, metric=metric,
                                               variant=variant)
        got, w_mesh = run(shard_over_batch(mesh, x), batch_idx, init_idx)

        np.testing.assert_array_equal(np.asarray(w), np.asarray(w_mesh))
        np.testing.assert_array_equal(np.asarray(ref.medoid_idx),
                                      np.asarray(got.medoid_idx))
        assert int(got.n_swaps) == int(ref.n_swaps), case
        np.testing.assert_array_equal(np.float32(ref.est_objective),
                                      np.float32(got.est_objective))
        print(f"OK {case} swaps={int(got.n_swaps)} "
              f"obj={float(got.est_objective):.6f}")

    # The one_batch_pam mesh route reaches the same factory.
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(3)
    host_res, host_batch = solver.one_batch_pam(
        key, x, k, m=m, variant="nniw", strategy="matrix_free")
    with mesh:
        mesh_res, mesh_batch = solver.one_batch_pam(
            key, x, k, m=m, variant="nniw", strategy="matrix_free",
            mesh=mesh)
    np.testing.assert_array_equal(np.asarray(host_batch.weights),
                                  np.asarray(mesh_batch.weights))
    np.testing.assert_array_equal(np.asarray(host_res.medoid_idx),
                                  np.asarray(mesh_res.medoid_idx))
    assert host_batch.d is None and mesh_batch.d is None
    print("OK one_batch_pam matrix_free mesh path")


if __name__ == "__main__":
    main()
