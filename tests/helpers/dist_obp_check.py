"""Subprocess body: distributed OBP on an 8-device host mesh must equal the
single-device solver. Invoked by tests/test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8 in the environment."""
import os
import sys

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import sampling, solver  # noqa: E402
from repro.core.distributed import make_distributed_obp  # noqa: E402
from repro.kernels import ops  # noqa: E402


def main(mesh_kind: str) -> None:
    assert jax.device_count() == 8, jax.device_count()
    if mesh_kind == "multipod":
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    else:
        mesh = jax.make_mesh((4, 2), ("data", "model"))

    rng = np.random.default_rng(0)
    n, p, k, m = 512, 16, 7, 64
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    batch_idx = jnp.asarray(rng.choice(n, size=m, replace=False))
    weights = jnp.asarray(rng.uniform(0.5, 1.5, size=m).astype(np.float32))
    init_idx = jnp.asarray(rng.choice(n, size=k, replace=False))

    # single-device reference
    d = ops.pairwise_distance(x, x[batch_idx], metric="l1") * weights[None, :]
    ref = solver.solve_batched(d, init_idx)

    run = make_distributed_obp(mesh, k=k, metric="l1")
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")
    xs = jax.device_put(x, NamedSharding(mesh, P(batch_axes, "model")))
    got = run(xs, batch_idx, weights, init_idx)

    ref_med = np.sort(np.asarray(ref.medoid_idx))
    got_med = np.sort(np.asarray(got.medoid_idx))
    np.testing.assert_array_equal(ref_med, got_med)
    np.testing.assert_allclose(float(got.est_objective),
                               float(ref.est_objective), rtol=1e-5)
    assert int(got.n_swaps) == int(ref.n_swaps)
    print(f"OK {mesh_kind} swaps={int(got.n_swaps)} "
          f"obj={float(got.est_objective):.4f}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "singlepod")
