"""Run in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=2.

Pins the distributed multi-restart path (restart axis composed with the
shard axis, DESIGN.md §2a/§5) bit-for-bit against the host engine
(core/restarts.py) on the same draws: per-restart medoid arrays
(slot-exact), swap counts, batch objectives, nniw weights, held-out
election scores, and the elected winner — for plain f32, debias, and
bf16 pooled blocks — plus the one_batch_pam(restarts=, mesh=) wiring.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, restarts, solver


def check(variant, block_dtype, tag):
    n, p, k, R, m = 240, 5, 4, 3, 20
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(11)

    host_rr, host_pool = restarts.one_batch_pam_restarts(
        key, x, k, restarts=R, m=m, variant=variant, metric="l1",
        backend="ref", block_dtype=block_dtype)

    mesh = jax.make_mesh((2,), ("data",))
    mesh_rr, mesh_pool = restarts.one_batch_pam_restarts(
        key, x, k, restarts=R, m=m, variant=variant, metric="l1",
        backend="ref", block_dtype=block_dtype, mesh=mesh)

    np.testing.assert_array_equal(np.asarray(host_pool.idx),
                                  np.asarray(mesh_pool.idx))
    np.testing.assert_array_equal(np.asarray(host_pool.eval_idx),
                                  np.asarray(mesh_pool.eval_idx))
    np.testing.assert_array_equal(np.asarray(host_pool.weights),
                                  np.asarray(mesh_pool.weights))
    np.testing.assert_array_equal(np.asarray(host_rr.results.medoid_idx),
                                  np.asarray(mesh_rr.results.medoid_idx))
    np.testing.assert_array_equal(np.asarray(host_rr.results.n_swaps),
                                  np.asarray(mesh_rr.results.n_swaps))
    np.testing.assert_array_equal(
        np.float32(np.asarray(host_rr.results.est_objective)),
        np.float32(np.asarray(mesh_rr.results.est_objective)))
    np.testing.assert_array_equal(
        np.float32(np.asarray(host_rr.eval_objectives)),
        np.float32(np.asarray(mesh_rr.eval_objectives)))
    assert int(host_rr.best_restart) == int(mesh_rr.best_restart)
    print(f"OK {tag}")


def check_public_wiring():
    """one_batch_pam(restarts=, mesh=) == one_batch_pam(restarts=) bitwise."""
    n, p, k = 160, 4, 3
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(5)
    host_res, host_batch = solver.one_batch_pam(
        key, x, k, m=16, restarts=4, variant="nniw", backend="ref")
    mesh = jax.make_mesh((2,), ("data",))
    mesh_res, mesh_batch = solver.one_batch_pam(
        key, x, k, m=16, restarts=4, variant="nniw", backend="ref",
        mesh=mesh)
    np.testing.assert_array_equal(np.asarray(host_res.medoid_idx),
                                  np.asarray(mesh_res.medoid_idx))
    np.testing.assert_array_equal(np.asarray(host_batch.idx),
                                  np.asarray(mesh_batch.idx))
    np.testing.assert_array_equal(np.asarray(host_batch.weights),
                                  np.asarray(mesh_batch.weights))
    assert mesh_batch.d is None and host_batch.d is not None
    print("OK one_batch_pam restarts mesh path")


if __name__ == "__main__":
    assert jax.device_count() == 2, jax.device_count()
    check("nniw", None, "nniw")
    check("debias", None, "debias")
    check("unif", "bfloat16", "bf16")
    check_public_wiring()
    distributed.make_distributed_obp_restarts.cache_clear()
