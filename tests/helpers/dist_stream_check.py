"""Subprocess body: the e2e sharded pipeline (in-mesh batch build + solve)
on 2 fake host devices must match the single-device build_batch + solver
bit-for-bit — same medoids, same swap count, same weights, same estimated
objective. Invoked by tests/test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=2 in the environment."""
import os

assert "--xla_force_host_platform_device_count=2" in os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import sampling, solver  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    make_distributed_obp_e2e,
    shard_over_batch,
)


def main() -> None:
    assert jax.device_count() == 2, jax.device_count()
    mesh = jax.make_mesh((2,), ("data",))

    rng = np.random.default_rng(0)
    n, p, k, m = 256, 8, 5, 32
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    key = jax.random.PRNGKey(7)

    for variant in ("unif", "debias", "nniw"):
        # single-device reference: host-side batch build + batched solver,
        # with the batch indices the mesh path will also use (build_batch
        # draws idx from the same key, so they coincide).
        key_b, key_i = jax.random.split(key)
        batch_idx = jax.random.choice(key_b, n, shape=(m,), replace=False)
        init_idx = jax.random.choice(key_i, n, shape=(k,), replace=False)
        ref_batch = sampling.build_batch(key_b, x, m, variant=variant)
        np.testing.assert_array_equal(np.asarray(ref_batch.idx),
                                      np.asarray(batch_idx))
        ref = solver.solve_batched(ref_batch.d, init_idx)
        ref_w = ref_batch.weights

        run = make_distributed_obp_e2e(mesh, k=k, metric="l1",
                                       variant=variant, chunk_size=32)
        got, got_w = run(shard_over_batch(mesh, x), batch_idx, init_idx)

        np.testing.assert_array_equal(np.sort(np.asarray(ref.medoid_idx)),
                                      np.sort(np.asarray(got.medoid_idx)))
        assert int(got.n_swaps) == int(ref.n_swaps), variant
        np.testing.assert_array_equal(np.asarray(ref_w), np.asarray(got_w))
        np.testing.assert_array_equal(np.float32(ref.est_objective),
                                      np.float32(got.est_objective))
        print(f"OK {variant} swaps={int(got.n_swaps)} "
              f"obj={float(got.est_objective):.6f}")

    # mesh path through the public API (one_batch_pam + MedoidSelector knob)
    res, batch = solver.one_batch_pam(key, x, k, m=m, variant="nniw",
                                      mesh=mesh, chunk_size=64)
    res_1d, batch_1d = solver.one_batch_pam(key, x, k, m=m, variant="nniw")
    np.testing.assert_array_equal(np.sort(np.asarray(res.medoid_idx)),
                                  np.sort(np.asarray(res_1d.medoid_idx)))
    np.testing.assert_array_equal(np.asarray(batch.weights),
                                  np.asarray(batch_1d.weights))
    assert batch.d is None
    print("OK one_batch_pam mesh path")


if __name__ == "__main__":
    main()
