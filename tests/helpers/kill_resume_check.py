"""Subprocess body for the kill/resume bitwise-identity tests
(tests/test_solver_faults.py).

Modes (argv[1]):
  straight — run the fault-tolerant solve start-to-finish with no
             checkpointing; dump the trajectory payload as JSON.
  kill     — run with ``checkpoint_dir``; SIGKILL ourselves at the top
             of sweep ``kill_at``. The parent asserts we died with
             -SIGKILL and left checkpoints behind.
  resume   — run with the same ``checkpoint_dir``, ``resume="auto"``;
             dump the payload. The parent diffs it against "straight":
             medoid slots, swap count, the objective's f32 bit pattern,
             and the full per-sweep log must all be identical.

argv: mode strategy restarts kill_at ckpt_dir out_json [backend]

The problem is pinned (n=96, p=6, k=4, m=24, key=PRNGKey(7), nniw,
validate="cheap", ckpt_every=1) so all three runs share one trajectory.
"""
import json
import os
import signal
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import runtime


def main() -> None:
    mode, strategy = sys.argv[1], sys.argv[2]
    restarts, kill_at = int(sys.argv[3]), int(sys.argv[4])
    ckpt_dir, out = sys.argv[5], sys.argv[6]
    backend = sys.argv[7] if len(sys.argv) > 7 else "auto"

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(96, 6)).astype(np.float32))
    key = jax.random.PRNGKey(7)
    kw = dict(m=24, variant="nniw", strategy=strategy, restarts=restarts,
              backend=backend, validate="cheap", ckpt_every=1)

    if mode == "straight":
        res, _, rep = runtime.solve_fault_tolerant(key, x, 4, **kw)
    elif mode == "kill":
        def hook(run):
            if run["sweep"] == kill_at:
                os.kill(os.getpid(), signal.SIGKILL)
        runtime.solve_fault_tolerant(key, x, 4, checkpoint_dir=ckpt_dir,
                                     _fault_hook=hook, **kw)
        raise SystemExit(f"kill hook never fired (solve ended before "
                         f"sweep {kill_at})")
    elif mode == "resume":
        res, _, rep = runtime.solve_fault_tolerant(
            key, x, 4, checkpoint_dir=ckpt_dir, resume="auto", **kw)
    else:
        raise SystemExit(f"unknown mode {mode!r}")

    payload = {
        "medoids": np.asarray(res.medoid_idx).tolist(),
        "n_swaps": int(res.n_swaps),
        "objective_hex": np.float32(res.est_objective).tobytes().hex(),
        "converged": bool(res.converged),
        "resumed_from": rep.resumed_from,
        "log": rep.sweep_log,
    }
    with open(out, "w") as f:
        json.dump(payload, f)
    print(f"OK {mode} {strategy} r={restarts}")


if __name__ == "__main__":
    main()
