"""Subprocess body: matrix-free peak-memory smoke (DESIGN.md §2b).

Solves OneBatchPAM at an n·m whose *materialized* f32 block would need
4 GB — above a hard 3 GB ``RLIMIT_AS`` cap this process installs on
itself before touching jax — so the run can only succeed if the
matrix-free path truly never builds the (n, m) block:
``build_batch(materialize=False)`` + ``solve_matrix_free`` with a
row-chunked ref sweep keep peak residency at O(np + chunk·m). The
kernel-enforced cap is the assertion (an ``ru_maxrss`` comparison is
noisy under a loaded parent — thread-count-dependent malloc arenas —
and flaked at exactly the wrong times); the printed RSS is informational.
Run in a subprocess (tests/test_matrix_free.py) so the cap and the
measurement apply to this workload alone.
"""
import resource

N, M, P, K = 262_144, 4_096, 4, 4
CHUNK = 2_048
BLOCK_MB = N * M * 4 // 2**20            # 4096 MB if ever materialized
CAP_BYTES = 3 * 2**30                    # hard 3 GB address-space ceiling

# Install the cap before jax allocates anything. RLIMIT_AS bounds every
# mmap on any kernel (RLIMIT_DATA only covers mmap from Linux 4.7): a
# materialized block fails its own allocation instead of us having to
# observe it. The cap is ~6x the observed steady-state footprint
# (~0.5 GB RSS), so only an O(nm) allocation can trip it.
resource.setrlimit(resource.RLIMIT_AS, (CAP_BYTES, CAP_BYTES))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import sampling, solver  # noqa: E402


def main() -> None:
    assert BLOCK_MB * 2**20 > CAP_BYTES, "shape no longer proves anything"
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(N, P)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    batch = sampling.build_batch(key, x, M, variant="unif", backend="ref",
                                 chunk_size=CHUNK, materialize=False)
    assert batch.d is None
    init = jnp.asarray(rng.choice(N, size=K, replace=False))
    res = solver.solve_matrix_free(x, batch.idx, batch.weights, init,
                                   backend="ref", chunk_size=CHUNK,
                                   max_swaps=40)
    idx = np.asarray(res.medoid_idx)
    assert len(np.unique(idx)) == K and ((idx >= 0) & (idx < N)).all()
    assert np.isfinite(float(res.est_objective))
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    print(f"OK peak_mem rss={rss_mb:.0f}MB cap={CAP_BYTES // 2**20}MB "
          f"block_would_be={BLOCK_MB}MB swaps={int(res.n_swaps)}")


if __name__ == "__main__":
    main()
