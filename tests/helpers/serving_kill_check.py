"""Subprocess body for the serving SIGKILL/reboot durability test
(tests/test_serving_faults.py).

Modes (argv[1]):
  kill   — fit a pinned selector, save() it, build an engine with
           ``snapshot_dir``, drive one refit (installing generation 1,
           persisted fsync'd), serve a pinned query batch, dump the
           served labels/d1 + installed version/rows to ``out_json``,
           then SIGKILL ourselves. The parent asserts -SIGKILL.
  reboot — build a fresh engine from the *selector checkpoint* (which
           only knows generation 0) with the same ``snapshot_dir``:
           ``snapshot_resume="auto"`` must land it on the exact last
           installed generation — version AND medoid rows bitwise —
           and the same query batch must serve bitwise-identical
           labels/d1. Dump the same payload; the parent diffs.

argv: mode ckpt_dir snapshot_dir out_json

The problem is pinned (n=384, p=8, k=6, m=48, seed=11; refit on the
first 192 rows scaled 1.05x) so both runs agree on every float.
"""
import json
import os
import signal
import sys

import numpy as np

from repro.core.selector import MedoidSelector
from repro.serving import AssignmentEngine


def _payload(eng, q):
    labels, d1 = eng.assign(q)
    return {
        "version": int(eng.medoid_version),
        "rows_hex": eng.medoids.tobytes().hex(),
        "labels": labels.tolist(),
        "d1_hex": d1.tobytes().hex(),
    }


def main() -> None:
    mode, ckpt_dir, snap_dir, out = sys.argv[1:5]

    rng = np.random.default_rng(42)
    x = rng.normal(size=(384, 8)).astype(np.float32)
    q = rng.normal(size=(64, 8)).astype(np.float32)

    if mode == "kill":
        sel = MedoidSelector(k=6, m=48, seed=11).fit(x)
        sel.save(ckpt_dir)
        eng = AssignmentEngine(sel, micro_batch=32, snapshot_dir=snap_dir)
        started = eng.refit_now(x[:192] * 1.05, wait=True)
        assert started and eng.last_refit_error is None, eng.last_refit_error
        assert eng.medoid_version == 1, eng.medoid_version
        with open(out, "w") as f:
            json.dump(_payload(eng, q), f)
            f.flush()
            os.fsync(f.fileno())
        os.kill(os.getpid(), signal.SIGKILL)
        raise SystemExit("unreachable: SIGKILL did not take")
    elif mode == "reboot":
        eng = AssignmentEngine.from_checkpoint(
            ckpt_dir, micro_batch=32, snapshot_dir=snap_dir)
        with open(out, "w") as f:
            json.dump(_payload(eng, q), f)
            f.flush()
            os.fsync(f.fileno())
        eng.close()
        print("OK reboot", flush=True)
        # skip interpreter teardown: the XLA runtime's exit-time thread
        # shutdown can std::terminate after our work is already durable
        os._exit(0)
    else:
        raise SystemExit(f"unknown mode {mode!r}")


if __name__ == "__main__":
    main()
