"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting shapes and finite outputs; plus
a prefill+decode consistency check on the serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get, reduced
from repro.models import transformer as T
from repro.training import OptConfig, init_train_state, make_train_step

B, S = 2, 16


def _batch(cfg, key=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_forward_and_train_step(arch):
    cfg = reduced(get(arch))
    oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    batch = _batch(cfg)

    logits, aux = T.forward(state["params"], cfg, batch["tokens"],
                            enc_frames=batch.get("frames"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite logits"

    step = jax.jit(make_train_step(cfg, oc))
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), f"{arch}: non-finite loss"
    assert jnp.isfinite(metrics["grad_norm"]), f"{arch}: non-finite grads"
    assert int(new_state["step"]) == 1
    # params must actually change
    delta = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        new_state["params"], state["params"])
    assert max(jax.tree.leaves(delta)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_serving_consistency(arch):
    """prefill(S-1) + decode(1) == full forward at the last position,
    modulo MoE capacity drops (disabled via a large capacity factor)."""
    cfg = reduced(get(arch))
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    tokens = batch["tokens"]
    frames = batch.get("frames")

    full, _ = T.forward(params, cfg, tokens, enc_frames=frames, remat=False)
    _, cache = T.prefill(params, cfg, tokens[:, :S - 1], max_len=S + 4,
                         enc_frames=frames)
    ld, _ = T.decode_step(params, cfg, tokens[:, S - 1], cache,
                          jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_ring_window_cache_matches_full_attention():
    """Local-attention ring cache (L = window slots): prefill past the
    window + multi-step decode must match the full forward exactly."""
    cfg = reduced(get("gemma2-27b"))   # window=8, [local, global] pattern
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    S = 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    full, _ = T.forward(params, cfg, tokens, remat=False)
    lp, cache = T.prefill(params, cfg, tokens[:, :20], max_len=S)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, 19]),
                               rtol=2e-3, atol=2e-3)
    for t in range(20, S):
        ld, cache = T.decode_step(params, cfg, tokens[:, t], cache,
                                  jnp.int32(t))
        np.testing.assert_allclose(np.asarray(ld), np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)
    # the local layers' cache really is window-sized
    k_local = cache["pos0"]["k"]
    assert k_local.shape[2] == cfg.sliding_window


def test_whisper_bf16_mixed_precision_train_step():
    """Regression: encoder frames must match the live compute dtype (bf16
    params) — a f32 enc_out used to poison the decoder scan carry."""
    cfg = dataclasses.replace(reduced(get("whisper-base")),
                              compute_dtype="bfloat16")
    oc = OptConfig(warmup_steps=1, total_steps=5)
    state = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    batch = _batch(cfg)
    state, m = jax.jit(make_train_step(cfg, oc, grad_accum=2))(state, batch)
    assert jnp.isfinite(m["loss"])


def test_train_loss_decreases_tinyllama():
    """A few steps on a repeated batch must reduce loss (end-to-end sanity
    of loss/grad/optimizer plumbing)."""
    cfg = reduced(get("tinyllama-1.1b"))
    oc = OptConfig(lr=3e-3, warmup_steps=1, total_steps=50)
    state = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    step = jax.jit(make_train_step(cfg, oc))
    batch = _batch(cfg)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_grad_accum_matches_single_batch():
    """grad_accum=2 must match grad_accum=1 on the same global batch."""
    cfg = reduced(get("tinyllama-1.1b"))
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    batch = _batch(cfg)
    s1, m1 = jax.jit(make_train_step(cfg, oc, grad_accum=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, oc, grad_accum=2))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    leaves1, leaves2 = jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_param_counts_match_scale():
    """Full-config param counts are in the advertised ballpark."""
    expect = {
        "grok-1-314b": (250e9, 380e9),
        "qwen3-moe-235b-a22b": (190e9, 280e9),
        "chameleon-34b": (28e9, 42e9),
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "gemma2-27b": (22e9, 34e9),
        "qwen1.5-32b": (26e9, 40e9),
        "phi4-mini-3.8b": (3.0e9, 5.0e9),
        "jamba-v0.1-52b": (42e9, 62e9),
        "xlstm-1.3b": (0.9e9, 2.3e9),  # block internals are our design
                                       # choice (DESIGN.md §8); scale-class
                                       # matches the 1.3B family
    }
    for arch, (lo, hi) in expect.items():
        n = get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9}, {hi/1e9}]"
