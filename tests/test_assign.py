"""Differential suite for the nearest-medoid assign kernel (DESIGN.md §9).

The serving path's correctness contract, pinned three ways:

  * ``ops.assign`` is *bitwise* ``streaming.stream_assign`` on the same
    backend — labels and d1 — across all registered metrics × f32/bf16
    tiles × ref/interpret backends, ties included (the engine swaps the
    host predict loop for the kernel; answers must not move).
  * ``ops.assign`` agrees with the framework-free numpy oracle
    (``core.baselines.assign``): exact label equality away from ties,
    distances to the cross-oracle tolerances of
    tests/test_baseline_metrics.py.
  * Tie-break = lowest medoid index (``jnp.argmin``), exercised with
    duplicated medoid rows so the k-tile sweep's cross-tile combine is
    on the hook, not just the within-tile argmin.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, streaming
from repro.kernels import metrics, ops

METRICS = sorted(metrics.names())
BACKENDS = ("ref", "interpret")
DTYPES = (None, "bfloat16")

# Cross-oracle (numpy vs jax) distance tolerances, per
# tests/test_baseline_metrics.py precedent: l2's sqrt(maximum(...)) chain
# amplifies the sqeuclidean cancellation, so it gets the loose bound.
_RTOL = {"l2": 2e-3}
_DEF_RTOL = 1e-4


def _data(n=300, k=13, p=37, seed=0, dup=True):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p)).astype(np.float32)
    b = rng.standard_normal((k, p)).astype(np.float32)
    if dup:
        b[7] = b[2]     # exact duplicate rows -> exact distance ties
    return x, b


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("block_dtype", DTYPES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_assign_bitwise_vs_stream_assign(metric, block_dtype, backend):
    """Kernel path == host streaming loop, bit for bit, per backend."""
    x, b = _data()
    la, da = streaming.stream_assign(jnp.asarray(x), jnp.asarray(b),
                                     metric=metric, backend=backend,
                                     block_dtype=block_dtype)
    lk, dk = ops.assign(jnp.asarray(x), jnp.asarray(b), metric=metric,
                        backend=backend, block_dtype=block_dtype)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lk))
    np.testing.assert_array_equal(
        np.asarray(da, np.float32).view(np.uint32),
        np.asarray(dk).view(np.uint32))
    assert np.asarray(lk).dtype == np.int32
    assert np.asarray(dk).dtype == np.float32


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_assign_vs_numpy_oracle(metric, backend):
    """Framework-free ground truth: labels equal (no ties by
    construction), distances within the cross-oracle tolerance."""
    x, b = _data(dup=False)
    ln, dn = baselines.assign(x, b, metric)
    lk, dk = ops.assign(jnp.asarray(x), jnp.asarray(b), metric=metric,
                        backend=backend)
    np.testing.assert_array_equal(ln, np.asarray(lk))
    np.testing.assert_allclose(dn, np.asarray(dk),
                               rtol=_RTOL.get(metric, _DEF_RTOL), atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("block_dtype", DTYPES)
def test_assign_tie_break_lowest_index(backend, block_dtype):
    """Queries placed exactly on a duplicated medoid row must label to
    the *lower* duplicate index — jnp.argmin's tie-break — including
    across k-tiles (the duplicate pair straddles the AS_TK=128 tile
    boundary, so the cross-tile strict-less combine is what's tested)."""
    rng = np.random.default_rng(3)
    k = 140                                  # > one k-tile
    b = rng.standard_normal((k, 16)).astype(np.float32)
    b[130] = b[5]                            # duplicates in different tiles
    b[60] = b[20]                            # duplicates in the same tile
    x = np.stack([b[130], b[60], b[5] + 0.25])
    labels, _ = ops.assign(jnp.asarray(x), jnp.asarray(b), metric="l1",
                           backend=backend, block_dtype=block_dtype)
    labels = np.asarray(labels)
    assert labels[0] == 5                    # cross-tile tie -> lower index
    assert labels[1] == 20                   # within-tile tie -> lower index


@pytest.mark.parametrize("shape", [(1, 1, 1), (127, 3, 5), (129, 128, 8),
                                   (256, 200, 513)])
def test_assign_ragged_shapes(shape):
    """Padding contract: every non-multiple (n, k, p) slices back clean,
    and padded medoid rows (zeros — distance-to-origin can be small!)
    never win the min."""
    n, k, p = shape
    rng = np.random.default_rng(n + k + p)
    # Rows far from the origin, so an unmasked zero-padded medoid row
    # WOULD win the min — the masking is what this test bites on.
    x = (rng.standard_normal((n, p)) + 50.0).astype(np.float32)
    b = (rng.standard_normal((k, p)) + 50.0).astype(np.float32)
    la, da = streaming.stream_assign(jnp.asarray(x), jnp.asarray(b),
                                     metric="l1", backend="interpret")
    lk, dk = ops.assign(jnp.asarray(x), jnp.asarray(b), metric="l1",
                        backend="interpret")
    assert np.asarray(lk).shape == (n,) and np.asarray(dk).shape == (n,)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lk))
    np.testing.assert_array_equal(
        np.asarray(da, np.float32).view(np.uint32),
        np.asarray(dk).view(np.uint32))
    assert np.asarray(lk).max() < k


def test_assign_chunked_stream_matches_kernel():
    """stream_assign's chunked sweep and the kernel agree bitwise (both
    row-local; the serving bench compares exactly these two paths)."""
    x, b = _data(n=500, k=9, p=24, seed=7)
    la, da = streaming.stream_assign(jnp.asarray(x), jnp.asarray(b),
                                     metric="l1", backend="interpret",
                                     chunk_size=128)
    lk, dk = ops.assign(jnp.asarray(x), jnp.asarray(b), metric="l1",
                        backend="interpret")
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lk))
    np.testing.assert_array_equal(
        np.asarray(da, np.float32).view(np.uint32),
        np.asarray(dk).view(np.uint32))


def test_assign_block_dtype_rounds_distances():
    """bf16 tiles actually round: d1 values are representable in bf16
    (the f32 upcast is exact), and differ from the f32 path somewhere."""
    x, b = _data(n=200, k=8, p=33, seed=11, dup=False)
    _, d32 = ops.assign(jnp.asarray(x), jnp.asarray(b), metric="l1",
                        backend="ref")
    _, d16 = ops.assign(jnp.asarray(x), jnp.asarray(b), metric="l1",
                        backend="ref", block_dtype="bfloat16")
    d16 = np.asarray(d16)
    round_trip = d16.astype(jnp.bfloat16).astype(np.float32)
    np.testing.assert_array_equal(d16, round_trip)
    assert not np.array_equal(d16, np.asarray(d32))


def test_assign_unregistered_tile_math_raises():
    """A metric without MetricSpec.tile gets the same actionable error
    as the fused sweep, not a kernel-side crash."""
    import dataclasses
    x, b = _data(n=128, k=4, p=8, dup=False)
    spec = metrics.get("l1")
    try:
        metrics._REGISTRY["_notile"] = dataclasses.replace(
            spec, name="_notile", tile=None)
        with pytest.raises(ValueError, match="tile"):
            ops.assign(jnp.asarray(x), jnp.asarray(b), metric="_notile",
                       backend="interpret")
    finally:
        metrics._REGISTRY.pop("_notile", None)
