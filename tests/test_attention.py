"""Flash (chunked online-softmax) attention vs the plain path, plus
mask/window/GQA behaviours."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def _qkv(key, B, S, T, KV, G, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, T, KV, hd))
    v = jax.random.normal(ks[2], (B, T, KV, hd))
    return q, k, v


# causal requires aligned q/k positions, so the cross-ish (T > S) shape
# pairs only with the non-causal mask combo — the product is filtered at
# parametrize time instead of skipping at run time.
_MASKS = [
    (True, None, None),
    (True, 24, None),
    (True, None, 30.0),
    (False, None, None),
]
_SHAPES = [
    (64, 64, 16, 16),
    (64, 64, 16, 32),   # ragged diagonal chunk
    (32, 96, 8, 16),    # cross-ish (T > S) non-causal only meaningful
]


@pytest.mark.parametrize("causal,window,softcap,S,T,qc,kc", [
    (causal, window, softcap, S, T, qc, kc)
    for causal, window, softcap in _MASKS
    for S, T, qc, kc in _SHAPES
    if not (causal and T != S)
])
def test_flash_matches_plain(causal, window, softcap, S, T, qc, kc):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, S, T, 2, 2, 8)
    mask = A._train_mask(S, T, causal=causal, window=window)
    want = A._attend(q, k, v, mask, softcap)
    got = A._attend_flash(q, k, v, causal=causal, window=window,
                          attn_softcap=softcap, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_gradients_match():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 32, 32, 2, 1, 8)

    def loss_plain(q, k, v):
        mask = A._train_mask(32, 32, causal=True, window=None)
        return jnp.sum(A._attend(q, k, v, mask, None) ** 2)

    def loss_flash(q, k, v):
        return jnp.sum(A._attend_flash(q, k, v, causal=True, window=None,
                                       attn_softcap=None, q_chunk=8,
                                       kv_chunk=8) ** 2)

    g1 = jax.grad(loss_plain, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_use_flash_threshold():
    assert not A._use_flash(16, 16)
    assert A._use_flash(4096, 32768)
    assert not A._use_flash(4096, 1500)   # whisper cross stays on plain
