"""Oracle metric registry fix (ISSUE 3 satellite): the numpy baselines
must support every metric the jax pipeline registers — kernels/metrics.py
and core/baselines.py used to disagree (cosine/chebyshev raised)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines
from repro.kernels import metrics, ops

METRICS = sorted(metrics.names())


@pytest.mark.parametrize("metric", METRICS)
def test_oracle_block_matches_jax_registry(metric):
    """Oracle.block == ops.pairwise_distance for every registered metric,
    including rectangular blocks and the eps-guarded cosine zero row."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(40, 6)).astype(np.float32)
    x[7] = 0.0                                    # cosine zero-row guard
    oracle = baselines.Oracle(x, metric=metric)
    rows = rng.choice(40, size=12, replace=False)
    cols = rng.choice(40, size=9, replace=False)
    got = oracle.block(rows, cols)
    want = np.asarray(ops.pairwise_distance(
        jnp.asarray(x[rows]), jnp.asarray(x[cols]), metric=metric,
        backend="ref"))
    # l2's sqrt amplifies the gram-trick cancellation noise on (near-)self
    # distances (sqrt(eps * |x|^2) ~ 1e-3 where the true value is 0), so
    # it gets an absolute floor at that noise scale; every other metric
    # agrees to f32 rounding.
    atol = 2e-3 if metric == "l2" else 1e-5
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=atol)
    assert oracle.count == 12 * 9, "block must count its evaluations"


def test_oracle_unknown_metric_raises_with_options():
    with pytest.raises(ValueError, match="chebyshev"):
        baselines.Oracle(np.zeros((4, 2), np.float32), metric="mahalanobis")


@pytest.mark.parametrize("metric", ["cosine", "chebyshev"])
@pytest.mark.parametrize("name", ["clara", "kmeans_pp", "banditpam_lite"])
def test_baselines_run_on_new_metrics(metric, name):
    """The previously-raising metrics now run end to end through the
    counted baselines and return finite, valid medoid sets."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(200, 5)).astype(np.float32)
    oracle = baselines.Oracle(x, metric=metric)
    res = baselines.ALL_BASELINES[name](np.random.default_rng(0), oracle, 4)
    assert len(np.unique(res.medoids)) == 4
    assert np.isfinite(res.objective)
    assert res.n_dissim > 0


@pytest.mark.parametrize("metric", METRICS)
def test_fasterpam_baseline_quality_per_metric(metric):
    """PAM-family beats random under every metric (sanity that the new
    metric blocks feed coherent objectives, not garbage)."""
    rng = np.random.default_rng(2)
    c = rng.normal(size=(5, 4)) * 3.0
    x = (c[rng.integers(0, 5, 150)]
         + rng.normal(size=(150, 4)) * 0.3).astype(np.float32)
    oracle = baselines.Oracle(x, metric=metric)
    fp = baselines.fasterpam(np.random.default_rng(0), oracle, 5)
    rnd = baselines.random_select(np.random.default_rng(0), oracle, 5)
    assert fp.objective <= rnd.objective + 1e-6
