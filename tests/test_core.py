"""System-behaviour tests for the OneBatchPAM core library.

hypothesis is optional (requirements-dev.txt): without it the property
tests run through the deterministic seeded-example stub
(tests/_hypothesis_stub.py) instead of skipping.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, same tests still run
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import baselines, sampling, solver
from repro.core.selector import MedoidSelector


def _blobs(rng, n=300, p=8, centers=5, spread=0.3):
    c = rng.normal(size=(centers, p)) * 4.0
    assign = rng.integers(0, centers, size=n)
    return (c[assign] + rng.normal(size=(n, p)) * spread).astype(np.float32)


# ---------------------------------------------------------------- solver --

def test_obp_beats_random_and_close_to_fasterpam():
    rng = np.random.default_rng(0)
    x = jnp.asarray(_blobs(rng, n=400, p=6, centers=8))
    k = 8
    key = jax.random.PRNGKey(1)
    res, _ = solver.one_batch_pam(key, x, k, variant="nniw")
    obj_obp = float(solver.objective(x, res.medoid_idx))

    fp = solver.fasterpam(key, x, k, strategy="eager")
    obj_fp = float(solver.objective(x, fp.medoid_idx))

    rand_idx = jax.random.choice(jax.random.PRNGKey(2), x.shape[0], (k,),
                                 replace=False)
    obj_rand = float(solver.objective(x, rand_idx))

    assert obj_obp < obj_rand, "OBP must beat random selection"
    # Paper: ~2% gap to FasterPAM; allow slack on tiny synthetic data.
    assert obj_obp <= obj_fp * 1.15


def test_full_batch_batched_equals_bruteforce_first_swap():
    """With m = n (Theorem 1 limit), the batched solver's first swap must be
    the brute-force best swap."""
    rng = np.random.default_rng(4)
    n, k = 60, 4
    x = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
    from repro.kernels import ops
    d = ops.pairwise_distance(x, x, metric="l1")
    init = jnp.asarray(rng.choice(n, size=k, replace=False))
    res = solver.solve_batched(d, init, max_swaps=1)

    dm = np.asarray(d)
    med0 = np.asarray(init)
    base = dm[med0].min(0).sum()
    best_val, best_pair = -np.inf, None
    for i in range(n):
        if i in med0:
            continue
        for l in range(k):
            new = med0.copy()
            new[l] = i
            gain = base - dm[new].min(0).sum()
            if gain > best_val:
                best_val, best_pair = gain, (i, l)
    if best_val > 0:
        med_expected = med0.copy()
        med_expected[best_pair[1]] = best_pair[0]
        np.testing.assert_array_equal(np.sort(np.asarray(res.medoid_idx)),
                                      np.sort(med_expected))
    else:
        np.testing.assert_array_equal(np.asarray(res.medoid_idx), med0)


def test_eager_full_batch_matches_numpy_fasterpam_swaps():
    """JAX eager solver == numpy reference FasterPAM on the same full matrix
    and the same init: identical medoid sets (Theorem 1, m = n)."""
    rng = np.random.default_rng(7)
    n, k = 80, 5
    x = rng.normal(size=(n, 4)).astype(np.float32)
    from repro.kernels import ops
    d = ops.pairwise_distance(jnp.asarray(x), jnp.asarray(x), metric="l1")
    init = rng.choice(n, size=k, replace=False)
    jres = solver.solve_eager(d, jnp.asarray(init), max_passes=8)
    nres = baselines._eager_pam(np.asarray(d), init, max_passes=8)
    np.testing.assert_array_equal(np.sort(np.asarray(jres.medoid_idx)),
                                  np.sort(nres))


def test_objective_nonincreasing_across_swap_budgets():
    rng = np.random.default_rng(5)
    x = jnp.asarray(_blobs(rng, n=200, p=4, centers=6))
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    batch = sampling.build_batch(key, x, 64, variant="unif")
    init = jax.random.choice(jax.random.PRNGKey(1), 200, (6,), replace=False)
    prev = np.inf
    for budget in (0, 1, 2, 4, 8, 500):
        res = solver.solve_batched(batch.d, init, max_swaps=budget)
        est = float(res.est_objective)
        assert est <= prev + 1e-5, "objective must not increase with more swaps"
        prev = est


def test_medoids_are_dataset_members_and_unique():
    rng = np.random.default_rng(6)
    x = jnp.asarray(_blobs(rng, n=150, p=4))
    res, _ = solver.one_batch_pam(jax.random.PRNGKey(0), x, 10)
    idx = np.asarray(res.medoid_idx)
    assert ((idx >= 0) & (idx < 150)).all()
    assert len(np.unique(idx)) == 10


# ------------------------------------------------------------- sampling --

@pytest.mark.parametrize("variant", sampling.VARIANTS)
def test_variants_run_and_weight_invariants(variant):
    rng = np.random.default_rng(8)
    x = jnp.asarray(_blobs(rng, n=120, p=4))
    b = sampling.build_batch(jax.random.PRNGKey(2), x, 32, variant=variant)
    assert b.idx.shape == (32,)
    assert len(np.unique(np.asarray(b.idx))) == 32
    w = np.asarray(b.weights)
    assert (w >= 0).all()
    np.testing.assert_allclose(w.mean(), 1.0, rtol=0.35)
    if variant == "debias":
        diag = np.asarray(b.d)[np.asarray(b.idx), np.arange(32)]
        assert (diag >= 1e14).all(), "self-distances must be LARGE"
    if variant == "unif":
        np.testing.assert_allclose(w, 1.0)


def test_nniw_weights_are_nn_counts():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(50, 3)).astype(np.float32))
    b = sampling.build_batch(jax.random.PRNGKey(3), x, 10, variant="nniw")
    from repro.kernels import ops
    d_raw = ops.pairwise_distance(x, x[b.idx], metric="l1")
    counts = np.bincount(np.asarray(jnp.argmin(d_raw, 1)), minlength=10)
    np.testing.assert_allclose(np.asarray(b.weights), counts * 10 / 50,
                               rtol=1e-5)


# ------------------------------------------------------ property tests --

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(20, 60),
    k=st.integers(2, 5),
    seed=st.integers(0, 10_000),
)
def test_property_swap_gain_equals_objective_delta(n, k, seed):
    """For random instances, the gain matrix == brute-force objective delta
    (the invariant that makes OBP's swaps exactly Algorithm 2's)."""
    rng = np.random.default_rng(seed)
    m = rng.integers(5, 20)
    d = rng.uniform(0.0, 3.0, (n, m)).astype(np.float32)
    med = rng.choice(n, size=k, replace=False)
    rows = d[med]
    d1, d2, near = baselines._top2_from(rows)
    from repro.kernels import ref
    gain = np.asarray(ref.swap_gain(
        jnp.asarray(d), jnp.asarray(d1), jnp.asarray(np.minimum(d2, 1e30)),
        jax.nn.one_hot(jnp.asarray(near), k, dtype=jnp.float32)))
    base = rows.min(0).sum()
    # check a random subset of swaps
    for _ in range(10):
        i = int(rng.integers(n))
        l = int(rng.integers(k))
        if i in med:
            continue
        new = med.copy()
        new[l] = i
        want = base - d[new].min(0).sum()
        np.testing.assert_allclose(gain[i, l], want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_batched_never_worse_than_init(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(30, 100))
    k = int(rng.integers(2, 6))
    x = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    from repro.kernels import ops
    d = ops.pairwise_distance(x, x, metric="l1")
    init = jnp.asarray(rng.choice(n, size=k, replace=False))
    before = float(jnp.mean(jnp.min(d[init], axis=0)))
    res = solver.solve_batched(d, init)
    after = float(res.est_objective)
    assert after <= before + 1e-5
    assert bool(res.converged)


# ------------------------------------------------------------ selector --

def test_medoid_selector_end_to_end():
    rng = np.random.default_rng(11)
    x = _blobs(rng, n=250, p=6, centers=5)
    sel = MedoidSelector(k=5, seed=0).fit(x)
    assert sel.medoid_indices_.shape == (5,)
    labels = sel.predict(x)
    assert labels.shape == (250,)
    assert set(np.unique(labels)) <= set(range(5))
    assert sel.objective(x) < MedoidSelector(k=5, max_swaps=0, seed=0).fit(x).objective(x) + 1e-6


# ---------------------------------------------------------- compression --

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-3, 1e3))
def test_property_int8_quantization_error_bound(seed, scale):
    """|x - dequant(quant(x))| <= max|x|/254 elementwise, and the residual
    returned for error feedback is exactly that difference."""
    from repro.training.compression import dequantize_int8, quantize_int8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(257,)).astype(np.float32) * scale)
    q, s, resid = quantize_int8(x)
    back = dequantize_int8(q, s)
    bound = float(jnp.max(jnp.abs(x))) / 254 + 1e-6 * scale
    assert float(jnp.max(jnp.abs(x - back))) <= bound * 1.01
    np.testing.assert_allclose(np.asarray(resid), np.asarray(x - back),
                               rtol=1e-6, atol=1e-6 * scale)


# ----------------------------------------------------------- baselines --

def test_baselines_run_and_count():
    rng = np.random.default_rng(12)
    n = 600  # large enough that CLARA's m = 80 + 4k subsample pays off
    x = _blobs(rng, n=n, p=5, centers=6)
    oracle = baselines.Oracle(x, metric="l1")
    k = 6
    results = {}
    for name, fn in baselines.ALL_BASELINES.items():
        oracle.count = 0
        results[name] = fn(np.random.default_rng(0), oracle, k)
        assert len(np.unique(results[name].medoids)) == k, name
        assert np.isfinite(results[name].objective), name
    # complexity ordering: fasterpam counts ~ n^2; kmeans++ ~ nk; clara << n^2
    assert results["fasterpam"].n_dissim >= n * n
    assert results["kmeans_pp"].n_dissim <= 2 * n * k
    assert results["clara"].n_dissim < results["fasterpam"].n_dissim
    # quality ordering on easy blobs: pam-family <= random
    assert results["fasterpam"].objective <= results["random"].objective
