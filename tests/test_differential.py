"""Differential test harness (ISSUE 3): the jax solvers against
independent numpy re-implementations, swap for swap.

The missing cross-implementation oracle: ``solve_batched`` (steepest
descent) and ``solve_eager`` (paper Algorithm 2, first-improvement) are
replayed via ``core/trace.py`` and compared against numpy references
written from the paper's pseudocode — same distance matrix in, identical
swap *sequences* out, across every registered metric, f32/bf16 blocks,
and k. At m = n with unit weights the batch objective is exact, so this
is Theorem 1's limit case: the eager path must also land on the numpy
FasterPAM baseline (``baselines._eager_pam``).

Exactness discipline: comparing float implementations swap-for-swap is
only sound when no rounding can flip an argmax/argmin, so every instance
is snapped to a dyadic grid — distances become multiples of 2^-6 (2^-1
for the bf16 cases) with magnitudes far below 2^18, making every sum the
solvers form *exact* in f32 (and in numpy's f64 accumulators). Summation
order then cannot matter, exact ties are frequent (small integer
feature grids collide constantly), and both sides' first-index tie-break
rules must coincide — which is precisely the contract under test. The
hypothesis suites run >= 50 cases per metric under the derandomized "ci"
profile (tests/conftest.py); the seeded example tests keep the harness
exercised when hypothesis is not installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback, same tests still run
    HAVE_HYPOTHESIS = False
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import baselines, trace
from repro.kernels import metrics, ops

METRICS = sorted(metrics.names())
BIG = np.float32(1e30)   # mirrors solver.BIG for the second-nearest mask


# ------------------------------------------------ numpy references ------
# Written from the paper's Algorithm 2 / FasterPAM, independent of the
# jax code: explicit python loops, numpy reductions, recorded swaps.

def _np_top2(rows):
    m = rows.shape[1]
    near = rows.argmin(0)
    d1 = rows[near, np.arange(m)]
    masked = rows.copy()
    masked[near, np.arange(m)] = BIG
    near2 = masked.argmin(0)
    d2 = masked[near2, np.arange(m)]
    return d1, d2, near, near2


def np_steepest_trace(d, init, max_swaps=500):
    """Steepest-descent PAM on a fixed (n, m) matrix, recording swaps."""
    d = np.asarray(d, np.float32)
    n, m = d.shape
    med = np.array(init, np.int64).copy()
    k = len(med)
    swaps = []
    converged = False
    while len(swaps) < max_swaps:
        d1, d2, near, _ = _np_top2(d[med])
        g = np.maximum(d1[None, :] - d, 0.0).sum(1)
        r = d1[None, :] - np.minimum(np.maximum(d, d1[None, :]), d2[None, :])
        big_r = np.zeros((n, k), np.float32)
        for l in range(k):
            big_r[:, l] = r[:, near == l].sum(1)
        gain = g[:, None] + big_r
        gain[med] = -np.inf
        flat = int(gain.argmax())
        if not gain.reshape(-1)[flat] > 0.0:
            converged = True
            break
        i, l = divmod(flat, k)
        med[l] = i
        swaps.append((i, l))
    d1 = _np_top2(d[med])[0]
    return swaps, med, float(d1.mean()), converged


def np_eager_trace(d, init, max_passes=8):
    """First-improvement PAM (paper Algorithm 2), recording swaps."""
    d = np.asarray(d, np.float32)
    n, m = d.shape
    med = np.array(init, np.int64).copy()
    k = len(med)
    swaps = []
    converged = False
    for _ in range(max_passes):
        d1, d2, near, _ = _np_top2(d[med])
        swapped = False
        for i in range(n):
            if (med == i).any():
                continue
            row = d[i]
            g = np.maximum(d1 - row, 0.0).sum()
            r = d1 - np.minimum(np.maximum(row, d1), d2)
            big_r = np.zeros(k, np.float32)
            for l in range(k):
                big_r[l] = r[near == l].sum()
            l = int(big_r.argmax())
            if g + big_r[l] > 0.0:
                med[l] = i
                swaps.append((i, l))
                swapped = True
                d1, d2, near, _ = _np_top2(d[med])
        if not swapped:
            converged = True
            break
    d1 = _np_top2(d[med])[0]
    return swaps, med, float(d1.mean()), converged


# -------------------------------------------------- instance builder ----

def _dyadic_instance(seed, metric, quant=64, n_max=72):
    """A full m = n distance matrix on the dyadic grid, plus a random init.

    Integer features in [0, 8) keep every metric's distances small; the
    post-metric snap to multiples of 1/quant makes all downstream solver
    sums exact in f32 (see module docstring).
    """
    rng = np.random.default_rng(seed)
    n = int(rng.integers(24, n_max))
    k = int(rng.integers(2, 7))
    p = int(rng.integers(2, 7))
    x = rng.integers(0, 8, size=(n, p)).astype(np.float32)
    d = np.asarray(ops.pairwise_distance(jnp.asarray(x), jnp.asarray(x),
                                         metric=metric, backend="ref"))
    d = np.round(d * quant) / quant
    init = rng.choice(n, size=k, replace=False)
    return d.astype(np.float32), init


def _check_differential(d, init, backend="ref", dtype=None):
    """The harness core: jax traces == numpy traces, swap for swap."""
    dj = jnp.asarray(d) if dtype is None else jnp.asarray(d).astype(dtype)
    ij = jnp.asarray(init)

    tb = trace.trace_batched(dj, ij, backend=backend)
    sw, med, obj, conv = np_steepest_trace(d, init)
    assert tb.swaps == tuple(sw), "steepest swap sequences diverge"
    np.testing.assert_array_equal(np.asarray(tb.result.medoid_idx), med)
    assert bool(tb.result.converged) == conv
    np.testing.assert_allclose(float(tb.result.est_objective), obj,
                               rtol=1e-6)

    te = trace.trace_eager(dj, ij)
    sw, med, obj, conv = np_eager_trace(d, init)
    assert te.swaps == tuple(sw), "eager swap sequences diverge"
    np.testing.assert_array_equal(np.asarray(te.result.medoid_idx), med)
    assert bool(te.result.converged) == conv
    np.testing.assert_allclose(float(te.result.est_objective), obj,
                               rtol=1e-6)

    # Theorem 1 limit case: the numpy FasterPAM baseline (independent
    # third implementation, 1e-9 threshold — equivalent on the dyadic
    # grid where positive gains are >= 1/64) lands on the same medoid set
    # as the eager path.
    fp = baselines._eager_pam(d, init)
    np.testing.assert_array_equal(np.sort(np.asarray(te.result.medoid_idx)),
                                  np.sort(fp))


# ------------------------------------------------------- hypothesis -----

@pytest.mark.parametrize("metric", METRICS)
@settings(deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_differential_per_metric(metric, seed):
    """>= 50 cases per metric under the ci profile: batched == numpy
    steepest and eager == numpy first-improvement == FasterPAM, swap for
    swap, on exact dyadic instances (ties included)."""
    d, init = _dyadic_instance(seed, metric)
    _check_differential(d, init)


@settings(deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_differential_bf16_blocks(seed):
    """bf16-stored blocks: snap to multiples of 1/2 below 64 (exactly
    representable in bf16), so the f32-accumulating solvers must still
    match numpy bit for bit."""
    d, init = _dyadic_instance(seed, "l1", quant=2, n_max=48)
    d = np.minimum(d, 63.5)
    _check_differential(d, init, dtype="bfloat16")


# ----------------------------------------------- seeded (no hypothesis) --

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("seed", range(3))
def test_differential_seeded(metric, seed):
    """Example-based slice of the same harness, so the differential
    oracle runs even where hypothesis is not installed."""
    d, init = _dyadic_instance(100 + seed, metric)
    _check_differential(d, init)


@pytest.mark.parametrize("seed", range(2))
def test_differential_interpret_backend(seed):
    """The Pallas interpret path feeds the same trajectory: kernels
    accumulate the same exact sums on the dyadic grid."""
    d, init = _dyadic_instance(200 + seed, "l1")
    _check_differential(d, init, backend="interpret")


def test_rectangular_block_differential():
    """m < n blocks (the actual OneBatchPAM shape): same harness on a
    rectangular dyadic matrix."""
    rng = np.random.default_rng(5)
    n, m, k = 80, 24, 5
    d = (rng.integers(0, 512, size=(n, m)) / np.float32(64)).astype(
        np.float32)
    init = rng.choice(n, size=k, replace=False)
    _check_differential(d, init)
