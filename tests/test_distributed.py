"""Multi-device behaviour, exercised in subprocesses with 8 fake host
devices (the main test process must keep seeing 1 device — XLA locks the
platform device count at first init)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(script: str, *args: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{env.get('PYTHONPATH', '')}"
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / script), *args],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("mesh_kind", ["singlepod", "multipod"])
def test_distributed_obp_matches_single_device(mesh_kind):
    out = _run("dist_obp_check.py", mesh_kind)
    assert f"OK {mesh_kind}" in out


def test_sharded_e2e_bitwise_matches_single_device():
    """In-mesh batch build + streamed sharded solve == host build_batch +
    solve_batched, bit-for-bit, on 2 simulated devices (ISSUE 1)."""
    out = _run("dist_stream_check.py", devices=2)
    for variant in ("unif", "debias", "nniw"):
        assert f"OK {variant}" in out
    assert "OK one_batch_pam mesh path" in out


def test_fused_sharded_sweep_bitwise_matches_single_device():
    """Fused swap_select partials + scalar election + incremental repair on
    2 devices == the single-device fused solver, bit-for-bit — slot-exact
    medoid array, swap count, objective — on plain, tie-heavy, and bf16
    blocks (ISSUE 2)."""
    out = _run("dist_fused_check.py", devices=2)
    for case in ("plain", "ties", "bf16"):
        assert f"OK {case}" in out


def test_matrix_free_sharded_sweep_bitwise_matches_single_device():
    """Matrix-free sharded sweep (per-shard fused distance+select
    partials, 3-scalar election, owner-recomputed winning row — no shard
    ever holds a block) on 2 devices == the single-device
    solve_matrix_free, bit-for-bit, across plain/tie-heavy/nniw/debias
    cases, plus the one_batch_pam mesh route (ISSUE 4)."""
    out = _run("dist_matrix_free_check.py", devices=2)
    for case in ("plain", "ties", "nniw", "debias"):
        assert f"OK {case}" in out
    assert "OK one_batch_pam matrix_free mesh path" in out


def test_restart_axis_composes_with_shard_axis_bitwise():
    """Vmapped multi-restart sweep under shard_map (per-shard fused
    partials per restart, one-psum election) == the host restart engine,
    bit-for-bit on the same draws — per-restart medoids, swap counts,
    objectives, nniw weights, election scores, elected winner — on plain,
    debias, and bf16 pooled blocks, 2 devices (ISSUE 3)."""
    out = _run("dist_restart_check.py", devices=2)
    for case in ("nniw", "debias", "bf16"):
        assert f"OK {case}" in out
    assert "OK one_batch_pam restarts mesh path" in out


def test_compressed_crosspod_psum():
    out = _run("dist_compression_check.py")
    assert "one-shot ok" in out
    assert "error-feedback ok" in out
    assert "wire format ok" in out
