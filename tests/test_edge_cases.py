"""Edge-case coverage (ISSUE 3): degenerate shapes and data the paper's
pseudocode glosses over — k=1, m=n, m>n requests, duplicate points,
all-equal rows — plus the `_repair_top2` hard-column fallback,
`default_batch_size` floors, and MedoidSelector lifecycle errors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling, solver
from repro.core.selector import MedoidSelector
from repro.kernels import ops


def _x(seed=0, n=80, p=4):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))


# ---------------------------------------------------------------- k = 1 --

def test_k1_solver_picks_the_medoid_minimising_row_sums():
    """k=1 exercises the no-second-medoid path: d2 is the BIG sentinel
    everywhere, removal corrections vanish, and steepest descent must
    land on the 1-medoid optimum of the batch estimate in one swap."""
    x = _x(1, n=60)
    d = ops.pairwise_distance(x, x, metric="l1")
    init = jnp.asarray([7])
    res = solver.solve_batched(d, init)
    want = int(np.asarray(jnp.sum(d, axis=1)).argmin())
    assert int(res.medoid_idx[0]) == want
    assert bool(res.converged)


def test_k1_end_to_end_all_strategies():
    x = _x(2, n=50)
    for strategy in ("batched", "eager"):
        res, _ = solver.one_batch_pam(jax.random.PRNGKey(0), x, 1, m=20,
                                      strategy=strategy)
        assert res.medoid_idx.shape == (1,)
        assert 0 <= int(res.medoid_idx[0]) < 50


def test_repair_top2_k1_hard_column():
    """With k=1 every swap makes every column 'hard' (the removed slot is
    always the top-1 and there is no second) — the fallback must keep
    d2 at the BIG sentinel, not invent a finite second distance."""
    rows = jnp.asarray(np.random.default_rng(3).uniform(
        1.0, 2.0, (1, 7)).astype(np.float32))
    d1, d2, near, near2 = solver._top2(rows)
    assert (np.asarray(d2) >= 1e29).all()
    r = jnp.asarray(np.full(7, 5.0, np.float32))  # worse row: d2 path taken
    _, rd1, rd2, rnear, _ = solver._repair_top2(rows, d1, d2, near, near2,
                                                r, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(rd1), np.asarray(r))
    assert (np.asarray(rd2) >= 1e29).all()


# ------------------------------------------------------- m = n and m > n --

def test_m_equals_n_matches_full_matrix_solve():
    x = _x(4, n=40)
    key = jax.random.PRNGKey(1)
    res, batch = solver.one_batch_pam(key, x, 4, m=40, variant="unif")
    assert batch.idx.shape == (40,)
    assert len(np.unique(np.asarray(batch.idx))) == 40  # all of X, permuted
    assert len(np.unique(np.asarray(res.medoid_idx))) == 4


def test_m_request_larger_than_n_is_clamped():
    x = _x(5, n=30)
    res, batch = solver.one_batch_pam(jax.random.PRNGKey(0), x, 3, m=500)
    assert batch.idx.shape == (30,)
    assert len(np.unique(np.asarray(res.medoid_idx))) == 3


def test_build_batch_m_larger_than_n_raises():
    """Direct build_batch keeps the without-replacement contract explicit
    instead of silently clamping."""
    x = _x(6, n=10)
    with pytest.raises(ValueError):
        sampling.build_batch(jax.random.PRNGKey(0), x, 11)


# ------------------------------------------- degenerate data geometries --

def test_duplicate_points_keep_medoids_unique():
    rng = np.random.default_rng(7)
    base = rng.normal(size=(20, 4)).astype(np.float32)
    x = jnp.asarray(np.repeat(base, 4, axis=0))      # every point x4
    res, _ = solver.one_batch_pam(jax.random.PRNGKey(2), x, 5, m=30)
    idx = np.asarray(res.medoid_idx)
    assert len(np.unique(idx)) == 5, "duplicate rows must not collapse slots"
    assert ((idx >= 0) & (idx < 80)).all()


def test_all_equal_rows_converge_with_zero_objective():
    """All-zero pairwise distances: every gain is 0, so no swap is ever
    accepted and the solver must converge immediately at objective 0."""
    x = jnp.ones((40, 3), jnp.float32) * 2.5
    for strategy in ("batched", "eager"):
        res, _ = solver.one_batch_pam(jax.random.PRNGKey(3), x, 3, m=10,
                                      strategy=strategy)
        assert int(res.n_swaps) == 0
        assert float(res.est_objective) == 0.0
        assert bool(res.converged)


# ------------------------------------------------- default_batch_size ----

def test_default_batch_size_floors():
    import math
    # The 2k+1 floor dominates once k outgrows the log term.
    assert sampling.default_batch_size(2, 1000) == 2001
    # n*k <= 1 is clamped to log(2), never log(<=1) = 0 (or negative).
    assert sampling.default_batch_size(1, 1) == int(100 * math.log(2))
    n, k = 100_000, 10
    assert sampling.default_batch_size(n, k) == int(100 * math.log(k * n))
    # Floors are monotone safe: always enough columns for a k-medoid
    # top-2 state plus one candidate.
    for k in (1, 2, 5, 17, 400):
        assert sampling.default_batch_size(3, k) >= 2 * k + 1


# ----------------------------------------------------- selector lifecycle --

def test_selector_predict_and_objective_before_fit_raise():
    sel = MedoidSelector(k=3)
    with pytest.raises(RuntimeError, match="fit"):
        sel.predict(np.zeros((5, 2), np.float32))
    with pytest.raises(RuntimeError, match="fit"):
        sel.objective(np.zeros((5, 2), np.float32))


def test_selector_predict_after_fit_covers_new_points():
    x = np.asarray(_x(8, n=90))
    sel = MedoidSelector(k=4, seed=0).fit(x)
    fresh = np.asarray(_x(9, n=25))
    labels = sel.predict(fresh)
    assert labels.shape == (25,)
    assert set(np.unique(labels)) <= set(range(4))
