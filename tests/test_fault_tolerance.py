"""Fault-tolerance substrate: checkpoint atomicity/restore/reshard,
resumable data iterators, straggler detection."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get, reduced
from repro.data import TokenIterator, TokenStore, build_synthetic
from repro.monitoring import StepTimer
from repro.training import OptConfig, init_train_state, make_train_step


@pytest.fixture
def tmproot(tmp_path):
    return str(tmp_path / "ckpt")


def _tiny_state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,))},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmproot):
    state = _tiny_state()
    ckpt.save(tmproot, 7, state, extra={"data": {"step": 3, "seed": 1}})
    target = jax.tree.map(lambda a: jnp.zeros_like(a), state)
    got, extra = ckpt.restore(tmproot, target)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra == {"data": {"step": 3, "seed": 1}}


def test_checkpoint_atomicity_partial_write_recovery(tmproot):
    state = _tiny_state()
    ckpt.save(tmproot, 1, state)
    # simulate a preempted writer: leave a corrupt .tmp dir + a step dir
    # without a manifest
    os.makedirs(os.path.join(tmproot, "step_00000002.tmp"))
    os.makedirs(os.path.join(tmproot, "step_00000003"))
    assert ckpt.latest_step(tmproot) == 1   # incomplete dirs are invisible
    got, _ = ckpt.restore(tmproot, jax.tree.map(jnp.zeros_like, state))
    assert int(got["step"]) == 7
    # next save garbage-collects the .tmp
    ckpt.save(tmproot, 4, state)
    assert not os.path.exists(os.path.join(tmproot, "step_00000002.tmp"))


def test_checkpoint_keep_last(tmproot):
    state = _tiny_state()
    for s in range(6):
        ckpt.save(tmproot, s, state, keep=2)
    assert ckpt.all_steps(tmproot) == [4, 5]


def test_train_resume_equivalence(tmp_path):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg = reduced(get("tinyllama-1.1b"))
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=20)
    step_fn = jax.jit(make_train_step(cfg, oc))
    root = str(tmp_path / "ck")

    store = build_synthetic(str(tmp_path / "toks.bin"), 50_000,
                            cfg.vocab_size, seed=0)
    def run(state, it, n):
        for _ in range(n):
            state, m = step_fn(state, it.__next__())
        return state

    state_a = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    it_a = TokenIterator(store, 2, 16, seed=5)
    state_a = run(state_a, it_a, 4)

    state_b = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    it_b = TokenIterator(store, 2, 16, seed=5)
    state_b = run(state_b, it_b, 2)
    ckpt.save(root, 2, state_b, extra={"data": it_b.state()})

    target = jax.tree.map(lambda a: jnp.zeros_like(a), state_b)
    state_c, extra = ckpt.restore(root, target)
    it_c = TokenIterator(store, 2, 16).restore(extra["data"])
    state_c = run(state_c, it_c, 2)

    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_c["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_token_iterator_determinism_and_sharding(tmp_path):
    store = build_synthetic(str(tmp_path / "t.bin"), 10_000, 1000, seed=1)
    a = TokenIterator(store, 4, 32, seed=3, shard_id=0, num_shards=2)
    b = TokenIterator(store, 4, 32, seed=3, shard_id=0, num_shards=2)
    np.testing.assert_array_equal(a.__next__()["tokens"],
                                  b.__next__()["tokens"])
    c = TokenIterator(store, 4, 32, seed=3, shard_id=1, num_shards=2)
    assert not np.array_equal(a.__next__()["tokens"],
                              c.__next__()["tokens"])
    # tokens are valid ids
    batch = a.__next__()["tokens"]
    assert batch.shape == (4, 32)
    assert batch.min() >= 0 and batch.max() < 1000


def test_straggler_detection():
    t = StepTimer(warmup=1, threshold=2.0)
    flags = [t.record(0.1) for _ in range(10)]
    assert not any(flags)
    assert t.record(1.0) is True      # 10x EMA -> straggler
    assert t.stragglers == 1


def test_steptimer_first_steady_step_seeds_ema():
    """The first post-warmup record seeds the steady EMA instead of
    being judged against the compile-step EMA the warmup left behind:
    a fast first real step must not poison the EMA with compile time
    (which would inflate every later threshold), and must never be
    flagged itself."""
    t = StepTimer(warmup=2, threshold=2.0)
    t.record(30.0)                    # compile
    t.record(25.0)                    # compile
    assert t.record(0.1) is False     # seeds, not compared vs ema=25
    assert t.ema == 0.1               # compile time fully displaced
    # a genuine straggler right after the seed is caught (under the old
    # compile-seeded EMA, 0.3 vs 2*25 could never flag)
    assert t.record(0.3) is True
    assert t.stragglers == 1


def test_steptimer_summary_excludes_warmup():
    t = StepTimer(warmup=2, threshold=100.0)
    for dt in (9.0, 9.0, 0.1, 0.2, 0.3, 0.4):   # 2 compile-ish outliers
        t.record(dt)
    s = t.summary()
    # count now describes the same population as the percentiles
    # (steady steps only), with the dropped warmup reported explicitly.
    assert s["count"] == 4
    assert s["warmup_excluded"] == 2
    assert s["max"] == 0.4            # warmup steps out of the stats
    assert 0.1 <= s["p50"] <= s["p95"] <= s["max"]
    assert s["stragglers"] == 0
    empty = StepTimer().summary()
    assert empty["count"] == 0 and empty["p50"] == 0.0
    assert empty["warmup_excluded"] == 0
    # fewer records than warmup: stats fall back to the full history,
    # so count matches what the percentiles were computed over
    short = StepTimer(warmup=3)
    short.record(1.0)
    s = short.summary()
    assert s["count"] == 1 and s["warmup_excluded"] == 0


def test_csvlogger_quotes_and_flushes(tmp_path):
    """Values containing commas/newlines/quotes survive the round-trip
    (RFC 4180 quoting), and every row is on disk immediately — a
    SIGKILL'd run loses nothing already logged."""
    import csv

    from repro.monitoring import CSVLogger
    path = str(tmp_path / "log.csv")
    nasty = 'a,b\n"c"'
    with CSVLogger(path, ["step", "msg"]) as log:
        log.log(step=1, msg=nasty)
        log.log(step=2)                       # missing field -> ""
        # read back BEFORE close: rows must already be flushed
        with open(path, newline="") as f:
            rows = list(csv.reader(f))
        assert rows[0] == ["step", "msg"]
        assert rows[1] == ["1", nasty]
        assert rows[2] == ["2", ""]
    log.close()                               # idempotent


def test_restore_aggregates_all_leaf_problems(tmproot):
    """One error lists EVERY missing/mismatched leaf (a schema
    migration sees the full diff, not the first casualty); unknown
    extra leaves on disk are tolerated with a warning."""
    ckpt.save(tmproot, 1, {"a": jnp.zeros((2, 3)), "b": jnp.ones((4,)),
                           "c": jnp.zeros((5,))})
    target = {"a": jnp.zeros((9, 9)),          # shape mismatch
              "b": jnp.zeros((4,)),            # fine
              "missing": jnp.zeros((1,))}      # not on disk
    with pytest.raises(ValueError) as ei:
        ckpt.restore(tmproot, target)
    msg = str(ei.value)
    assert "2 leaf problem(s)" in msg
    assert "a: shape (2, 3) != expected (9, 9)" in msg
    assert "missing: missing from checkpoint" in msg
    # older reader, newer writer: extra leaf "c" ignored with a warning
    with pytest.warns(UserWarning, match="unknown to this reader"):
        got, _ = ckpt.restore(tmproot, {"a": jnp.zeros((2, 3)),
                                        "b": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(got["b"]), np.ones((4,)))


def test_restore_latest_valid_walks_back(tmproot):
    state = _tiny_state()
    ckpt.save(tmproot, 1, state)
    ckpt.save(tmproot, 2, state)
    # corrupt the newest step's manifest
    with open(os.path.join(tmproot, "step_00000002", "manifest.json"),
              "w") as f:
        f.write("not json")
    with pytest.warns(UserWarning, match="skipping corrupt checkpoint"):
        got, _, step = ckpt.restore_latest_valid(
            tmproot, jax.tree.map(jnp.zeros_like, state))
    assert step == 1
    assert int(got["step"]) == 7
    # all corrupt -> FileNotFoundError naming the failure
    with open(os.path.join(tmproot, "step_00000001", "manifest.json"),
              "w") as f:
        f.write("also not json")
    with pytest.warns(UserWarning):
        with pytest.raises(FileNotFoundError, match="no restorable"):
            ckpt.restore_latest_valid(tmproot,
                                      jax.tree.map(jnp.zeros_like, state))


# --------------------------------------------- durable fitted selectors --

def _fitted_selector(**kw):
    from repro.core import MedoidSelector
    rng = np.random.default_rng(3)
    x = rng.normal(size=(60, 4)).astype(np.float32)
    return MedoidSelector(k=3, m=16, **kw).fit(x), x


def test_selector_save_load_roundtrip(tmp_path):
    from repro.core import MedoidSelector
    sel, x = _fitted_selector(restarts=2)
    path = str(tmp_path / "sel")
    sel.save(path)
    fresh = MedoidSelector(k=3, m=16, restarts=2).load(path)
    np.testing.assert_array_equal(fresh.medoid_indices_,
                                  sel.medoid_indices_)
    np.testing.assert_array_equal(fresh.medoids_, sel.medoids_)
    np.testing.assert_array_equal(fresh.eval_objectives_,
                                  sel.eval_objectives_)
    assert fresh.est_objective_ == sel.est_objective_
    assert fresh.n_swaps_ == sel.n_swaps_
    assert fresh.best_restart_ == sel.best_restart_
    np.testing.assert_array_equal(fresh.predict(x), sel.predict(x))


def test_selector_load_config_mismatch_lists_fields(tmp_path):
    from repro.core import MedoidSelector
    sel, _ = _fitted_selector()
    path = str(tmp_path / "sel")
    sel.save(path)
    other = MedoidSelector(k=3, m=32, metric="l2")
    with pytest.raises(ValueError) as ei:
        other.load(path)
    msg = str(ei.value)
    assert "m: saved 16" in msg and "metric: saved 'l1'" in msg
    assert "from_checkpoint" in msg


def test_selector_from_checkpoint_rebuilds_config(tmp_path):
    from repro.core import MedoidSelector
    sel, x = _fitted_selector(strategy="pruned")
    path = str(tmp_path / "sel")
    sel.save(path)
    fresh = MedoidSelector.from_checkpoint(path)
    assert fresh.k == 3 and fresh.m == 16 and fresh.strategy == "pruned"
    np.testing.assert_array_equal(fresh.medoid_indices_,
                                  sel.medoid_indices_)
    np.testing.assert_array_equal(fresh.predict(x), sel.predict(x))


def test_selector_save_requires_fit(tmp_path):
    from repro.core import MedoidSelector
    with pytest.raises(RuntimeError, match="fit"):
        MedoidSelector(k=3).save(str(tmp_path / "sel"))


def test_selector_robust_fit_reports_and_resumes(tmp_path):
    """validate= routes fit() through the fault-tolerant runtime
    (bitwise — seed discipline unchanged), attaches the SolveReport,
    and checkpoint_dir makes the fit itself restartable."""
    from repro.core import MedoidSelector
    sel_plain, x = _fitted_selector()
    d = str(tmp_path / "fitckpt")
    sel = MedoidSelector(k=3, m=16, validate="paranoid",
                         checkpoint_dir=d).fit(x)
    np.testing.assert_array_equal(sel.medoid_indices_,
                                  sel_plain.medoid_indices_)
    assert sel.report_ is not None
    assert sel.report_.violations == []
    assert sel.report_.checkpoint_writes
    assert os.path.isdir(d)
    # a second fit resumes from the finished checkpoint: zero sweeps
    sel2 = MedoidSelector(k=3, m=16, validate="cheap",
                          checkpoint_dir=d).fit(x)
    assert sel2.report_.resumed_from is not None
    np.testing.assert_array_equal(sel2.medoid_indices_,
                                  sel_plain.medoid_indices_)
