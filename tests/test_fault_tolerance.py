"""Fault-tolerance substrate: checkpoint atomicity/restore/reshard,
resumable data iterators, straggler detection."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get, reduced
from repro.data import TokenIterator, TokenStore, build_synthetic
from repro.monitoring import StepTimer
from repro.training import OptConfig, init_train_state, make_train_step


@pytest.fixture
def tmproot(tmp_path):
    return str(tmp_path / "ckpt")


def _tiny_state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,))},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmproot):
    state = _tiny_state()
    ckpt.save(tmproot, 7, state, extra={"data": {"step": 3, "seed": 1}})
    target = jax.tree.map(lambda a: jnp.zeros_like(a), state)
    got, extra = ckpt.restore(tmproot, target)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra == {"data": {"step": 3, "seed": 1}}


def test_checkpoint_atomicity_partial_write_recovery(tmproot):
    state = _tiny_state()
    ckpt.save(tmproot, 1, state)
    # simulate a preempted writer: leave a corrupt .tmp dir + a step dir
    # without a manifest
    os.makedirs(os.path.join(tmproot, "step_00000002.tmp"))
    os.makedirs(os.path.join(tmproot, "step_00000003"))
    assert ckpt.latest_step(tmproot) == 1   # incomplete dirs are invisible
    got, _ = ckpt.restore(tmproot, jax.tree.map(jnp.zeros_like, state))
    assert int(got["step"]) == 7
    # next save garbage-collects the .tmp
    ckpt.save(tmproot, 4, state)
    assert not os.path.exists(os.path.join(tmproot, "step_00000002.tmp"))


def test_checkpoint_keep_last(tmproot):
    state = _tiny_state()
    for s in range(6):
        ckpt.save(tmproot, s, state, keep=2)
    assert ckpt.all_steps(tmproot) == [4, 5]


def test_train_resume_equivalence(tmp_path):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg = reduced(get("tinyllama-1.1b"))
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=20)
    step_fn = jax.jit(make_train_step(cfg, oc))
    root = str(tmp_path / "ck")

    store = build_synthetic(str(tmp_path / "toks.bin"), 50_000,
                            cfg.vocab_size, seed=0)
    def run(state, it, n):
        for _ in range(n):
            state, m = step_fn(state, it.__next__())
        return state

    state_a = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    it_a = TokenIterator(store, 2, 16, seed=5)
    state_a = run(state_a, it_a, 4)

    state_b = init_train_state(jax.random.PRNGKey(0), cfg, oc)
    it_b = TokenIterator(store, 2, 16, seed=5)
    state_b = run(state_b, it_b, 2)
    ckpt.save(root, 2, state_b, extra={"data": it_b.state()})

    target = jax.tree.map(lambda a: jnp.zeros_like(a), state_b)
    state_c, extra = ckpt.restore(root, target)
    it_c = TokenIterator(store, 2, 16).restore(extra["data"])
    state_c = run(state_c, it_c, 2)

    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_c["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_token_iterator_determinism_and_sharding(tmp_path):
    store = build_synthetic(str(tmp_path / "t.bin"), 10_000, 1000, seed=1)
    a = TokenIterator(store, 4, 32, seed=3, shard_id=0, num_shards=2)
    b = TokenIterator(store, 4, 32, seed=3, shard_id=0, num_shards=2)
    np.testing.assert_array_equal(a.__next__()["tokens"],
                                  b.__next__()["tokens"])
    c = TokenIterator(store, 4, 32, seed=3, shard_id=1, num_shards=2)
    assert not np.array_equal(a.__next__()["tokens"],
                              c.__next__()["tokens"])
    # tokens are valid ids
    batch = a.__next__()["tokens"]
    assert batch.shape == (4, 32)
    assert batch.min() >= 0 and batch.max() < 1000


def test_straggler_detection():
    t = StepTimer(warmup=1, threshold=2.0)
    flags = [t.record(0.1) for _ in range(10)]
    assert not any(flags)
    assert t.record(1.0) is True      # 10x EMA -> straggler
    assert t.stragglers == 1
