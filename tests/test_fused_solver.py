"""The fused swap-select sweep must be trajectory-identical to the
pre-fusion solver (ISSUE 2 acceptance): same medoids, same swap count,
same estimated objective, on both backends, ties included — and the
incremental d1/d2 repair must be value-exact against a full top-2
recompute at every step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling, solver


def _instance(seed, quantize=None):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(60, 320))
    k = int(rng.integers(2, 9))
    m = int(rng.integers(2 * k + 1, 64))
    d = rng.uniform(0.1, 8.0, (n, m)).astype(np.float32)
    if quantize:
        d = np.round(d * quantize) / quantize
    init = rng.choice(n, size=k, replace=False)
    return jnp.asarray(d), jnp.asarray(init)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("seed", range(5))
def test_fused_matches_naive_trajectory(backend, seed):
    d, init = _instance(seed)
    fused = solver.solve_batched(d, init, backend=backend)
    naive = solver.solve_batched_naive(d, init, backend=backend)
    np.testing.assert_array_equal(np.asarray(fused.medoid_idx),
                                  np.asarray(naive.medoid_idx))
    assert int(fused.n_swaps) == int(naive.n_swaps)
    np.testing.assert_array_equal(np.float32(fused.est_objective),
                                  np.float32(naive.est_objective))
    assert bool(fused.converged) == bool(naive.converged)


@pytest.mark.parametrize("seed", range(4))
def test_fused_matches_naive_with_gain_ties(seed):
    """Quantized distances plateau the gains; tie-broken selections must
    still coincide swap for swap."""
    d, init = _instance(seed + 50, quantize=2)
    fused = solver.solve_batched(d, init, backend="ref")
    naive = solver.solve_batched_naive(d, init, backend="ref")
    np.testing.assert_array_equal(np.asarray(fused.medoid_idx),
                                  np.asarray(naive.medoid_idx))
    assert int(fused.n_swaps) == int(naive.n_swaps)


@pytest.mark.parametrize("seed", range(12))
def test_repair_top2_value_exact_vs_full_recompute(seed):
    """_repair_top2 == _top2 on the swapped rows, value-for-value: d1/d2
    bitwise, and the chosen slots attain those values (slot identity may
    legitimately differ only under exact ties, where it cannot reach the
    gains — DESIGN.md §2)."""
    rng = np.random.default_rng(seed)
    k, m = int(rng.integers(1, 9)), int(rng.integers(3, 50))
    rows = rng.uniform(0.0, 4.0, (k, m)).astype(np.float32)
    r = rng.uniform(0.0, 4.0, (m,)).astype(np.float32)
    if seed % 2:   # force exact ties through a coarse value grid
        rows = np.round(rows * 2) / 2
        r = np.round(r * 2) / 2
    rows = jnp.asarray(rows)
    l = int(rng.integers(k))
    d1, d2, near, near2 = solver._top2(rows)
    new_rows, rd1, rd2, rnear, rnear2 = solver._repair_top2(
        rows, d1, d2, near, near2, jnp.asarray(r), jnp.int32(l))
    fd1, fd2, _, _ = solver._top2(rows.at[l].set(jnp.asarray(r)))
    np.testing.assert_array_equal(np.asarray(rd1), np.asarray(fd1))
    np.testing.assert_array_equal(np.asarray(rd2), np.asarray(fd2))
    nr = np.asarray(new_rows)
    cols = np.arange(m)
    np.testing.assert_array_equal(nr[np.asarray(rnear), cols], np.asarray(rd1))
    # near2 attains d2 whenever a second medoid exists (k >= 2).
    if k >= 2:
        np.testing.assert_array_equal(nr[np.asarray(rnear2), cols],
                                      np.asarray(rd2))
        assert (np.asarray(rnear) != np.asarray(rnear2)).all()


def test_block_dtype_bf16_stores_narrow_and_solves():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(200, 6)).astype(np.float32))
    key = jax.random.PRNGKey(1)
    b32 = sampling.build_batch(key, x, 40, variant="nniw")
    b16 = sampling.build_batch(key, x, 40, variant="nniw",
                               block_dtype="bfloat16")
    assert b16.d.dtype == jnp.bfloat16
    # Weights come off the f32 distances: storage dtype cannot move them.
    np.testing.assert_array_equal(np.asarray(b32.weights),
                                  np.asarray(b16.weights))
    init = jnp.asarray(rng.choice(200, size=6, replace=False))
    r16 = solver.solve_batched(b16.d, init)
    r32 = solver.solve_batched(b32.d, init)
    idx = np.asarray(r16.medoid_idx)
    assert len(np.unique(idx)) == 6 and ((idx >= 0) & (idx < 200)).all()
    # bf16 rounding perturbs each block entry by <= 2^-8 relative, which
    # can steer the search to a *different* local optimum — so the bound
    # is on optimum quality, not on the rounding itself: within 5%.
    assert abs(float(r16.est_objective) - float(r32.est_objective)) \
        <= 0.05 * float(r32.est_objective)


def test_block_dtype_threads_through_public_api():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(150, 5)).astype(np.float32)
    res, batch = solver.one_batch_pam(jax.random.PRNGKey(0), jnp.asarray(x),
                                      5, block_dtype="bfloat16")
    assert batch.d.dtype == jnp.bfloat16
    assert len(np.unique(np.asarray(res.medoid_idx))) == 5
    from repro.core.selector import MedoidSelector
    sel = MedoidSelector(k=4, seed=0, block_dtype="bfloat16").fit(x)
    assert sel.medoid_indices_.shape == (4,)


def test_streaming_rejects_block_dtype_on_raw_partials():
    from repro.core import streaming
    x = jnp.zeros((8, 3))
    with pytest.raises(ValueError, match="raw partials"):
        streaming.stream_block(x, x[:2], raw=True, block_dtype="bfloat16")


def test_fasterpam_eps_reaches_both_strategies():
    """Satellite fix: eps used to be dropped on the eager path. A huge eps
    must veto every swap for both strategies."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(60, 4)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    for strategy in ("eager", "batched"):
        res = solver.fasterpam(key, x, 4, strategy=strategy, eps=1e9)
        assert int(res.n_swaps) == 0, strategy
    # sanity: with eps=0 the same instance does swap
    assert int(solver.fasterpam(key, x, 4, strategy="eager").n_swaps) > 0
