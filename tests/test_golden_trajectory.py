"""Golden-trajectory regression suite (ISSUE 3): seeded instances with
committed swap sequences. A kernel or solver refactor that changes any
swap decision — even one that lands on an equally good optimum — fails
here loudly instead of drifting silently.

Instances live on dyadic grids with power-of-two row counts, so every
sum and mean the solvers form is exact in f32: the committed numbers are
environment-independent, and comparisons are exact (==), not allclose.
Regenerate deliberately with tools/make_golden_trajectories.py and
commit the diff alongside the intended trajectory change.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import trace
from tools.make_golden_trajectories import (
    e2e_instance,
    matrix_free_instance,
    matrix_instance,
)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "trajectories.json"
CASES = json.loads(GOLDEN.read_text())["cases"]


def _assert_matches(tr, want, name):
    got_swaps = [list(s) for s in tr.swaps]
    assert got_swaps == want["swaps"], (
        f"{name}: swap sequence changed — if intended, regenerate with "
        "tools/make_golden_trajectories.py and commit the diff")
    np.testing.assert_array_equal(np.asarray(tr.result.medoid_idx),
                                  np.asarray(want["medoids"]))
    assert int(tr.result.n_swaps) == want["n_swaps"]
    # Exact: dyadic grid + power-of-two divisor => no rounding anywhere.
    assert float(tr.result.est_objective) == want["objective"], name
    assert bool(tr.result.converged) == want["converged"]


@pytest.mark.parametrize("case", CASES, ids=[c["name"] for c in CASES])
def test_golden_trajectory(case):
    if case["kind"] == "pruned":
        # Bound-pruned replay (ISSUE 6): most sweeps only exactly rescore
        # bound-surviving candidates, yet the committed swap sequence —
        # generated with a three-way identity assert against the
        # matrix-free and block traces — must replay exactly.
        spec = case["spec"]
        x, batch, init = matrix_free_instance(spec)
        np.testing.assert_array_equal(np.asarray(init), case["init"])
        tr = trace.trace_pruned(x, batch.idx, batch.weights, init,
                                metric=spec["metric"],
                                debias=(spec["variant"] == "debias"),
                                backend="ref")
        _assert_matches(tr, case["batched"], case["name"])
        return
    if case["kind"] == "matrix_free":
        # Block-free replay (ISSUE 4): the (n, m) block is never built,
        # yet the committed swap sequence — generated with a cross-path
        # identity assert against the block trace — must replay exactly.
        spec = case["spec"]
        x, batch, init = matrix_free_instance(spec)
        np.testing.assert_array_equal(np.asarray(init), case["init"])
        tr = trace.trace_matrix_free(x, batch.idx, batch.weights, init,
                                     metric=spec["metric"],
                                     debias=(spec["variant"] == "debias"),
                                     backend="ref")
        _assert_matches(tr, case["batched"], case["name"])
        return
    if case["kind"] == "matrix":
        d, init = matrix_instance(case["spec"])
    else:
        d, init = e2e_instance(case["spec"])
    np.testing.assert_array_equal(np.asarray(init), case["init"])
    _assert_matches(trace.trace_batched(d, init, backend="ref"),
                    case["batched"], case["name"])
    if "eager" in case:
        _assert_matches(trace.trace_eager(d, init), case["eager"],
                        case["name"])


def test_golden_fixture_is_sane():
    assert len(CASES) >= 5
    for c in CASES:
        assert c["batched"]["n_swaps"] == len(c["batched"]["swaps"]) > 0, (
            f"{c['name']} must exercise at least one swap")
