"""Loop-aware HLO cost parser: trip-count expansion, dot flops,
slice-aware fusion byte accounting, collective classification."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo as H


def _compiled_text(fn, *shapes):
    return jax.jit(fn).lower(*shapes).compile().as_text()


def test_scan_flops_expand_by_trip_count():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)

    def f1(x, w):
        return x @ w

    def f10(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    c1 = H.analyze(_compiled_text(f1, x, w))
    c10 = H.analyze(_compiled_text(f10, x, w))
    dot = 2 * 128 * 256 * 256
    assert abs(c1["flops"] - dot) / dot < 0.1
    assert abs(c10["flops"] - 10 * dot) / (10 * dot) < 0.1


def test_scan_slice_updates_not_overcounted():
    """A scan writing one row per step into a (1000, 1024) buffer must
    count ~2 * 1000 * 4KB of slice traffic, not 1000 * 4MB of full-buffer
    traffic (XLA aliases the dynamic-update-slice in place)."""
    x = jax.ShapeDtypeStruct((1024,), jnp.float32)

    def f(x):
        def body(c, _):
            c = c * 1.0001
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=1000)
        return ys

    costs = H.analyze(_compiled_text(f, x))
    buffer_bytes = 1000 * 1024 * 4
    # generous bound: well under one full-buffer-per-step (1000x)
    assert costs["hbm_bytes"] < 30 * buffer_bytes, costs["hbm_bytes"]


def test_shape_bytes_parsing():
    assert H.shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert H.shape_bytes("bf16[8]") == 16
    assert H.shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert H.shape_bytes("pred[]") == 1


def test_wire_bytes_model():
    assert H._wire_bytes("all-reduce", 100, 100, 4) == 2 * 100 * 3 / 4
    assert H._wire_bytes("all-gather", 25, 100, 4) == 100 * 3 / 4
    assert H._wire_bytes("reduce-scatter", 100, 25, 4) == 100 * 3 / 4
    assert H._wire_bytes("collective-permute", 64, 64, 1) == 64
    assert H._wire_bytes("all-reduce", 100, 100, 1) == 0.0


def test_dot_contract_dims():
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    costs = H.analyze(_compiled_text(lambda a, b: a @ b, a, b))
    want = 2 * 64 * 16 * 32
    assert abs(costs["flops"] - want) / want < 0.05
