"""Per-kernel correctness: Pallas (interpret mode) vs the ref.py oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _data(n, m, p, dtype):
    kx, kb = jax.random.split(KEY)
    x = jax.random.normal(kx, (n, p), dtype=jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (m, p), dtype=jnp.float32).astype(dtype)
    return x, b


# Shapes chosen to hit: exact tile multiples, sub-tile, ragged overhang.
PAIR_SHAPES = [
    (128, 128, 512),   # exactly one L1 tile
    (256, 128, 1024),  # multi-tile grid
    (100, 37, 64),     # everything ragged / sub-tile
    (257, 129, 513),   # off-by-one over tile edges
    (8, 8, 8),         # tiny
]


@pytest.mark.parametrize("metric", list(ops.metrics.names()))
@pytest.mark.parametrize("n,m,p", PAIR_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_interpret_matches_ref(metric, n, m, p, dtype):
    x, b = _data(n, m, p, dtype)
    got = ops.pairwise_distance(x, b, metric=metric, backend="interpret")
    want = ops.pairwise_distance(x, b, metric=metric, backend="ref")
    assert got.shape == (n, m)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_metric_registry_is_exhaustive_and_validates():
    """Every registered metric round-trips through ops; unknown names fail
    with the registered list in the message."""
    assert {"l1", "l2", "sqeuclidean", "cosine", "chebyshev"} <= set(
        ops.metrics.names())
    with pytest.raises(ValueError, match="registered"):
        ops.pairwise_distance(jnp.zeros((4, 2)), jnp.zeros((3, 2)),
                              metric="mahalanobis")


def test_pairwise_chebyshev_known_values():
    x = jnp.array([[0.0, 0.0], [1.0, 5.0]])
    b = jnp.array([[1.0, 1.0]])
    for backend in ("ref", "interpret"):
        d = ops.pairwise_distance(x, b, metric="chebyshev", backend=backend)
        np.testing.assert_allclose(d, [[1.0], [4.0]], atol=1e-6)


def test_pairwise_cosine_known_values():
    x = jnp.array([[1.0, 0.0], [0.0, 2.0], [-3.0, 0.0]])
    b = jnp.array([[2.0, 0.0]])
    for backend in ("ref", "interpret"):
        d = ops.pairwise_distance(x, b, metric="cosine", backend=backend)
        # parallel -> 0, orthogonal -> 1, antiparallel -> 2
        np.testing.assert_allclose(d, [[0.0], [1.0], [2.0]], atol=1e-6)


@pytest.mark.parametrize("n,m,k", [
    (256, 256, 128),   # exact tiles
    (256, 256, 4),     # tiny k (pad to 128 lanes)
    (100, 33, 7),      # ragged everything
    (300, 260, 130),   # k overhangs one lane tile
])
def test_swap_gain_interpret_matches_ref(n, m, k):
    kd, k1, kn = jax.random.split(KEY, 3)
    d = jax.random.uniform(kd, (n, m), minval=0.0, maxval=10.0)
    # Build a consistent (d1 <= d2) pair and a nearest-slot assignment.
    a = jax.random.uniform(k1, (m,), minval=0.0, maxval=10.0)
    bgap = jax.random.uniform(jax.random.fold_in(k1, 1), (m,), minval=0.0, maxval=5.0)
    d1, d2 = a, a + bgap
    near = jax.random.randint(kn, (m,), 0, k)
    nh = jax.nn.one_hot(near, k, dtype=jnp.float32)
    got = ops.swap_gain(d, d1, d2, nh, backend="interpret")
    want = ops.swap_gain(d, d1, d2, nh, backend="ref")
    assert got.shape == (n, k)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def _select_fixture(n, m, k, seed=0, quantize=None, d2_eq_d1=False):
    """Consistent (d, d1, d2, nh) swap-sweep inputs; ``quantize`` rounds the
    distances to a coarse grid to force duplicate gains (tie coverage)."""
    ks = jax.random.split(jax.random.fold_in(KEY, seed * 1_000_003 + n), 3)
    d = jax.random.uniform(ks[0], (n, m), minval=0.0, maxval=10.0)
    a = jax.random.uniform(ks[1], (m,), minval=0.0, maxval=10.0)
    gap = jax.random.uniform(jax.random.fold_in(ks[1], 1), (m,),
                             minval=0.0, maxval=5.0)
    if quantize:
        d = jnp.round(d * quantize) / quantize
        a = jnp.round(a * quantize) / quantize
        gap = jnp.round(gap * quantize) / quantize
    d1, d2 = a, (a if d2_eq_d1 else a + gap)
    near = jax.random.randint(ks[2], (m,), 0, k)
    return d, d1, d2, jax.nn.one_hot(near, k, dtype=jnp.float32)


def _select_oracle(d, d1, d2, nh, row_mask, backend):
    """argmax over the same backend's gain matrix — the exact contract."""
    gain = ops.swap_gain(d, d1, d2, nh, backend=backend)
    if row_mask is not None:
        gain = jnp.where(row_mask[:, None] > 0, gain, ref.NEG)
    k = nh.shape[1]
    flat = int(jnp.argmax(gain))
    return np.float32(gain.reshape(-1)[flat]), flat // k, flat % k


# Seeded property grid over n, m, k: tile-aligned, sub-tile, ragged
# overhang, k over one lane tile — plus per-seed random masks.
SELECT_SHAPES = [
    (256, 256, 128),   # exact tiles
    (256, 256, 4),     # tiny k (pad to 128 lanes)
    (100, 33, 7),      # ragged everything
    (300, 260, 130),   # k overhangs one lane tile
    (513, 40, 6),      # n overhangs two row tiles
    (24, 8, 2),        # tiny
]


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("n,m,k", SELECT_SHAPES)
@pytest.mark.parametrize("seed", [0, 1])
def test_swap_select_matches_argmax_swap_gain(backend, n, m, k, seed):
    """swap_select == argmax(swap_gain) exactly (value and coordinates),
    per backend, with and without a row mask."""
    d, d1, d2, nh = _select_fixture(n, m, k, seed=seed)
    km = jax.random.fold_in(KEY, seed + 17)
    mask = (jax.random.uniform(km, (n,)) > 0.2).astype(jnp.float32)
    for rm in (None, mask):
        got_g, got_i, got_l = ops.swap_select(d, d1, d2, nh, row_mask=rm,
                                              backend=backend)
        want_g, want_i, want_l = _select_oracle(d, d1, d2, nh, rm, backend)
        assert (int(got_i), int(got_l)) == (want_i, want_l)
        np.testing.assert_array_equal(np.float32(got_g), want_g)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("seed", range(6))
def test_swap_select_tie_break_on_duplicate_gains(backend, seed):
    """Coarsely quantized distances produce exact duplicate gains; the
    selection must still be the first flat index, matching jnp.argmax."""
    rng = np.random.default_rng(seed)
    n, m, k = int(rng.integers(40, 600)), int(rng.integers(5, 80)), int(rng.integers(2, 12))
    d, d1, d2, nh = _select_fixture(n, m, k, seed=seed, quantize=2)
    got_g, got_i, got_l = ops.swap_select(d, d1, d2, nh, backend=backend)
    want_g, want_i, want_l = _select_oracle(d, d1, d2, nh, None, backend)
    assert (int(got_i), int(got_l)) == (want_i, want_l)
    np.testing.assert_array_equal(np.float32(got_g), want_g)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_swap_select_all_slots_tied_picks_first(backend):
    """d1 == d2 zeroes every removal correction, so each row ties across
    all k slots; identical rows tie across rows too -> flat index 0."""
    n, m, k = 300, 33, 7
    d, d1, d2, nh = _select_fixture(1, m, k, d2_eq_d1=True)
    d = jnp.tile(d, (n, 1))
    _, i, l = ops.swap_select(d, d1, d2, nh, backend=backend)
    assert (int(i), int(l)) == (0, 0)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_swap_select_row_mask_excludes_winner(backend):
    """Masking the winning row must move the selection to the runner-up;
    padded tile rows (mask 0 via ops padding) must never win."""
    n, m, k = 130, 20, 3   # n deliberately not a tile multiple
    d, d1, d2, nh = _select_fixture(n, m, k, seed=3)
    _, i0, l0 = ops.swap_select(d, d1, d2, nh, backend=backend)
    mask = jnp.ones((n,), jnp.float32).at[i0].set(0.0)
    got_g, i1, l1 = ops.swap_select(d, d1, d2, nh, row_mask=mask,
                                    backend=backend)
    assert int(i1) != int(i0)
    want_g, want_i, want_l = _select_oracle(d, d1, d2, nh, mask, backend)
    assert (int(i1), int(l1)) == (want_i, want_l)
    np.testing.assert_array_equal(np.float32(got_g), want_g)
    assert 0 <= int(i1) < n, "padded rows must be masked out"


def test_pairwise_l1_known_values():
    x = jnp.array([[0.0, 0.0], [1.0, 2.0]])
    b = jnp.array([[1.0, 1.0]])
    for backend in ("ref", "interpret"):
        d = ops.pairwise_distance(x, b, metric="l1", backend=backend)
        np.testing.assert_allclose(d, [[2.0], [1.0]], atol=1e-6)


@pytest.mark.parametrize("B,S,NH,hd", [
    (1, 8, 1, 4),
    (2, 12, 2, 8),
    (2, 33, 4, 16),   # ragged S, realistic head count
])
def test_slstm_scan_kernel_matches_core(B, S, NH, hd):
    """Fused sLSTM kernel (VMEM-resident state/weights) vs the jnp scan."""
    from repro.kernels.slstm_scan import slstm_scan
    from repro.models import ssm
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    gx = jax.random.normal(ks[0], (B, S, 4, NH, hd))
    r = jax.random.normal(ks[1], (NH, 4, hd, hd)) * 0.3
    state = {"c": jnp.zeros((B, NH, hd)), "n": jnp.zeros((B, NH, hd)) + 1e-6,
             "h": jnp.zeros((B, NH, hd)), "m": jnp.zeros((B, NH))}
    ys_ref, st_ref = ssm._slstm_core({"r_gates": r}, gx, state)
    ys, (c, n, h, m) = slstm_scan(gx, r, state["c"], state["n"],
                                  state["h"], state["m"], interpret=True)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys_ref),
                               rtol=1e-5, atol=1e-5)
    for got, want in ((c, st_ref["c"]), (n, st_ref["n"]),
                      (h, st_ref["h"]), (m, st_ref["m"])):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_swap_gain_matches_bruteforce_objective_delta():
    """G(i, l) must equal the actual batch-objective reduction of the swap."""
    rng = np.random.default_rng(3)
    n, m, k = 40, 12, 3
    d = jnp.asarray(rng.uniform(0.1, 5.0, (n, m)).astype(np.float32))
    med = jnp.asarray(rng.choice(n, size=k, replace=False))
    rows = d[med]
    near = jnp.argmin(rows, axis=0)
    d1 = jnp.take_along_axis(rows, near[None], 0)[0]
    masked = jnp.where(jax.nn.one_hot(near, k, axis=0, dtype=bool), 1e30, rows)
    d2 = jnp.min(masked, axis=0)
    gain = ref.swap_gain(d, d1, d2, jax.nn.one_hot(near, k, dtype=jnp.float32))

    med_np = np.asarray(med)
    base = np.asarray(d)[med_np].min(0).sum()
    for i in range(n):
        if i in med_np:
            continue
        for l in range(k):
            new = med_np.copy()
            new[l] = i
            val = np.asarray(d)[new].min(0).sum()
            np.testing.assert_allclose(gain[i, l], base - val, rtol=1e-4,
                                       atol=1e-4)
