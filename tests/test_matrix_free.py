"""Matrix-free fused sweep test suite (ISSUE 4 acceptance).

The matrix-free path recomputes distance tiles on the fly instead of
reading a materialized (n, m) block — *same floats, different data
movement* — so every test here is an exact-equality test, not allclose:

  * ops.fused_swap_select == ops.swap_select on the materialized block,
    per backend, ties and masks included;
  * solve_matrix_free is swap-for-swap solve_batched across all 5
    registered metrics x {f32, bf16 inputs} x k (hypothesis on ref,
    seeded on interpret);
  * block-free nniw weights == materialized weights, bitwise, single
    batch and grouped restart pools;
  * the restart engine's vmapped matrix-free lanes == the unbatched
    solver per lane;
  * a peak-memory subprocess smoke solves at an n·m whose f32 block
    (4 GB) could not be allocated under the helper's self-installed
    hard 3 GB RLIMIT_AS cap.

hypothesis is optional (requirements-dev.txt): without it the property
tests run through the deterministic seeded-example stub
(tests/_hypothesis_stub.py) instead of skipping.
"""
import os
import pathlib
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, same tests still run
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import restarts as restarts_mod
from repro.core import sampling, solver, streaming, trace
from repro.core.selector import MedoidSelector
from repro.kernels import metrics, ops

ROOT = pathlib.Path(__file__).resolve().parents[1]
METRICS = metrics.names()


def _assert_same_solve(a, b, ctx=""):
    np.testing.assert_array_equal(np.asarray(a.medoid_idx),
                                  np.asarray(b.medoid_idx), err_msg=str(ctx))
    assert int(a.n_swaps) == int(b.n_swaps), ctx
    np.testing.assert_array_equal(np.float32(a.est_objective),
                                  np.float32(b.est_objective))
    assert bool(a.converged) == bool(b.converged), ctx


def _instance(seed, n=90, p=7, m=28, k=5, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    if dtype is not np.float32:
        x = x.astype(dtype)
    init = jnp.asarray(rng.choice(n, size=k, replace=False))
    return x, init


# ------------------------------------------------ ops-level contract -----

@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("metric", METRICS)
def test_fused_select_matches_block_select(backend, metric):
    """One fused sweep == swap_select on the materialized weighted block
    of the same backend: same gain bits, same (i, l), masks honoured."""
    rng = np.random.default_rng(1000 + list(METRICS).index(metric))
    n, p, m, k = 70, 6, 22, 4
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    idx = jnp.asarray(rng.choice(n, size=m, replace=False)).astype(jnp.int32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=m).astype(np.float32))
    d = ops.pairwise_distance(x, x[idx], metric=metric, backend=backend)
    dw = d * w[None, :]
    a = jnp.asarray(rng.uniform(0.0, 3.0, size=m).astype(np.float32))
    d1, d2 = a, a + 0.25
    nh = jax.nn.one_hot(jnp.asarray(rng.integers(0, k, size=m)), k,
                        dtype=jnp.float32)
    mask = jnp.ones((n,), jnp.float32).at[jnp.asarray([0, 3, n - 1])].set(0.0)

    g_blk, i_blk, l_blk = ops.swap_select(dw, d1, d2, nh, row_mask=mask,
                                          backend=backend)
    g_mf, i_mf, l_mf = ops.fused_swap_select(x, x[idx], w, d1, d2, nh,
                                             metric=metric, row_mask=mask,
                                             backend=backend)
    assert (int(i_mf), int(l_mf)) == (int(i_blk), int(l_blk))
    np.testing.assert_array_equal(np.float32(g_mf), np.float32(g_blk))


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_fused_select_ties_and_debias(backend):
    """Quantized distances plateau the gains; the fused tie-break must
    still pick the block path's first flat index — with the debias owner
    diagonal applied in-flight."""
    rng = np.random.default_rng(7)
    n, p, m, k = 65, 5, 18, 3
    x = jnp.asarray(np.round(rng.normal(size=(n, p)) * 2).astype(np.float32) / 2)
    idx = jnp.asarray(rng.choice(n, size=m, replace=False)).astype(jnp.int32)
    w = jnp.ones((m,), jnp.float32)
    d = ops.pairwise_distance(x, x[idx], metric="l1", backend=backend)
    d = d.at[idx, jnp.arange(m)].set(jnp.float32(1e15))   # debias diagonal
    a = jnp.asarray(np.round(rng.uniform(0, 3, size=m) * 2).astype(np.float32) / 2)
    d1, d2 = a, a + 0.5
    nh = jax.nn.one_hot(jnp.asarray(rng.integers(0, k, size=m)), k,
                        dtype=jnp.float32)
    g_blk, i_blk, l_blk = ops.swap_select(d * w[None, :], d1, d2, nh,
                                          backend=backend)
    g_mf, i_mf, l_mf = ops.fused_swap_select(x, x[idx], w, d1, d2, nh,
                                             metric="l1", owner=idx,
                                             backend=backend)
    assert (int(i_mf), int(l_mf)) == (int(i_blk), int(l_blk))
    np.testing.assert_array_equal(np.float32(g_mf), np.float32(g_blk))


def test_fused_select_rejects_metric_without_tile_math():
    """A metric registered without the optional ``tile`` field (the
    registry's one-call contract predates it) must fail the kernel path
    with the intended ValueError, not an AttributeError — and still work
    on the ref backend, which needs no tile math."""
    spec = metrics.get("l1")
    metrics.register(metrics.MetricSpec(
        name="_test_no_tile", ref=spec.ref, kernel=spec.kernel,
        tiles=spec.tiles))
    x = jnp.zeros((8, 4), jnp.float32)
    args = (x, x[:4], jnp.ones((4,)), jnp.zeros((4,)), jnp.zeros((4,)),
            jnp.eye(4, 2, dtype=jnp.float32))
    with pytest.raises(ValueError, match="tile math"):
        ops.fused_swap_select(*args, metric="_test_no_tile",
                              backend="interpret")
    g, i, l = ops.fused_swap_select(*args, metric="_test_no_tile",
                                    backend="ref")
    assert np.isfinite(float(g))


def test_fused_select_ref_row_chunking_is_exact():
    """The ref backend's O(chunk·m) streamed evaluation computes the
    identical selection (gains are row-local; chunk-major reduce keeps
    the first-flat tie-break)."""
    rng = np.random.default_rng(11)
    n, p, m, k = 103, 6, 17, 4
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    idx = jnp.asarray(rng.choice(n, size=m, replace=False)).astype(jnp.int32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=m).astype(np.float32))
    a = jnp.asarray(rng.uniform(0, 3, size=m).astype(np.float32))
    d1, d2 = a, a + 0.25
    nh = jax.nn.one_hot(jnp.asarray(rng.integers(0, k, size=m)), k,
                        dtype=jnp.float32)
    full = ops.fused_swap_select(x, x[idx], w, d1, d2, nh, owner=idx,
                                 backend="ref")
    for chunk in (8, 16, 50, 103, 500):
        got = ops.fused_swap_select(x, x[idx], w, d1, d2, nh, owner=idx,
                                    backend="ref", row_chunk=chunk)
        assert (int(got[1]), int(got[2])) == (int(full[1]), int(full[2]))
        np.testing.assert_array_equal(np.float32(got[0]), np.float32(full[0]))


# ------------------------------------------- solver-level trajectories ---

@settings(deadline=None, max_examples=25)
@given(data=st.data())
def test_matrix_free_matches_batched_hypothesis(data):
    """ISSUE 4 acceptance: swap-for-swap identity with solve_batched on
    ref, across all registered metrics x {f32, bf16 inputs} x k x
    variant."""
    metric = data.draw(st.sampled_from(METRICS), label="metric")
    dtype = data.draw(st.sampled_from([np.float32, jnp.bfloat16]),
                      label="dtype")
    seed = data.draw(st.integers(0, 2**16), label="seed")
    k = data.draw(st.integers(2, 7), label="k")
    variant = data.draw(st.sampled_from(["unif", "debias", "nniw", "lwcs"]),
                        label="variant")
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 140))
    p = int(rng.integers(2, 12))
    m = int(rng.integers(2 * k + 1, max(2 * k + 2, n // 2)))
    x, init = _instance(seed, n=n, p=p, m=m, k=k, dtype=dtype)
    key = jax.random.PRNGKey(seed)
    blk = sampling.build_batch(key, x, m, variant=variant, metric=metric,
                               backend="ref")
    mf = sampling.build_batch(key, x, m, variant=variant, metric=metric,
                              backend="ref", materialize=False)
    assert mf.d is None
    np.testing.assert_array_equal(np.asarray(blk.weights),
                                  np.asarray(mf.weights))
    r_blk = solver.solve_batched(blk.d, init, backend="ref")
    r_mf = solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                    metric=metric,
                                    debias=(variant == "debias"),
                                    backend="ref")
    _assert_same_solve(r_blk, r_mf, (metric, variant, np.dtype(dtype).name))


@pytest.mark.parametrize("metric", METRICS)
def test_matrix_free_matches_batched_interpret(metric):
    """Seeded interpret-mode parity: the Pallas fused-sweep kernel's
    on-the-fly tiles reproduce the pairwise kernels' block bits."""
    x, init = _instance(31, n=80, p=7, m=24, k=4)
    key = jax.random.PRNGKey(31)
    blk = sampling.build_batch(key, x, 24, variant="nniw", metric=metric,
                               backend="interpret")
    mf = sampling.build_batch(key, x, 24, variant="nniw", metric=metric,
                              backend="interpret", materialize=False)
    r_blk = solver.solve_batched(blk.d, init, backend="interpret")
    r_mf = solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                    metric=metric, backend="interpret")
    _assert_same_solve(r_blk, r_mf, metric)


def test_matrix_free_trace_matches_solver():
    """trace_matrix_free replays solve_matrix_free bit-for-bit (it drives
    the literal loop body), and the recorded swaps equal the block
    trace's."""
    x, init = _instance(5, n=96, p=6, m=30, k=5)
    key = jax.random.PRNGKey(5)
    blk = sampling.build_batch(key, x, 30, variant="nniw", backend="ref")
    mf = sampling.build_batch(key, x, 30, variant="nniw", backend="ref",
                              materialize=False)
    tr_blk = trace.trace_batched(blk.d, init, backend="ref")
    tr_mf = trace.trace_matrix_free(x, mf.idx, mf.weights, init,
                                    backend="ref")
    assert tr_mf.swaps == tr_blk.swaps
    assert tr_mf.gains == tr_blk.gains
    res = solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                   backend="ref")
    _assert_same_solve(tr_mf.result, res)


def test_matrix_free_chunked_solve_is_exact():
    x, init = _instance(13, n=120, p=5, m=26, k=4)
    key = jax.random.PRNGKey(13)
    mf = sampling.build_batch(key, x, 26, variant="unif", backend="ref",
                              materialize=False)
    full = solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                    backend="ref")
    chunked = solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                       backend="ref", chunk_size=32)
    _assert_same_solve(full, chunked)


# ------------------------------------------------ pipeline threading -----

def test_one_batch_pam_matrix_free_matches_batched():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(150, 6)).astype(np.float32))
    key = jax.random.PRNGKey(2)
    r_blk, b_blk = solver.one_batch_pam(key, x, 5, strategy="batched",
                                        backend="ref")
    r_mf, b_mf = solver.one_batch_pam(key, x, 5, strategy="matrix_free",
                                      backend="ref")
    assert b_mf.d is None and b_blk.d is not None
    np.testing.assert_array_equal(np.asarray(b_blk.idx), np.asarray(b_mf.idx))
    np.testing.assert_array_equal(np.asarray(b_blk.weights),
                                  np.asarray(b_mf.weights))
    _assert_same_solve(r_blk, r_mf)


def test_build_batch_materialize_false_rejects_block_dtype():
    x = jnp.zeros((20, 3))
    with pytest.raises(ValueError, match="block"):
        sampling.build_batch(jax.random.PRNGKey(0), x, 5,
                             materialize=False, block_dtype="bfloat16")
    with pytest.raises(ValueError, match="block_dtype"):
        solver.one_batch_pam(jax.random.PRNGKey(0), x, 3,
                             strategy="matrix_free", block_dtype="bfloat16")


def test_stream_nn_counts_matches_block_counts():
    """Block-free histogram == fused in-block histogram, bitwise, chunked
    and unchunked, grouped and not."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(130, 5)).astype(np.float32))
    b = x[jnp.asarray(rng.choice(130, size=24, replace=False))]
    for metric in ("l1", "cosine"):
        for chunk in (None, 33):
            for groups in (1, 4):
                want = streaming.stream_block(
                    x, b, metric=metric, backend="ref", chunk_size=chunk,
                    count_nn=True, count_groups=groups).nn_counts
                got = streaming.stream_nn_counts(
                    x, b, metric=metric, backend="ref", chunk_size=chunk,
                    count_groups=groups)
                np.testing.assert_array_equal(np.asarray(want),
                                              np.asarray(got))


def test_restart_lanes_matrix_free_bitwise():
    """Matrix-free restart lanes == the batched engine's (same draws,
    same per-lane swaps, same election), Pool.d stays None, and each
    vmapped lane == the unbatched solver."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(160, 6)).astype(np.float32))
    key = jax.random.PRNGKey(6)
    rr_b, pool_b = restarts_mod.one_batch_pam_restarts(
        key, x, 4, restarts=3, m=20, backend="ref")
    rr_m, pool_m = restarts_mod.one_batch_pam_restarts(
        key, x, 4, restarts=3, m=20, backend="ref", strategy="matrix_free")
    assert pool_m.d is None
    np.testing.assert_array_equal(np.asarray(pool_b.weights),
                                  np.asarray(pool_m.weights))
    np.testing.assert_array_equal(np.asarray(rr_b.results.medoid_idx),
                                  np.asarray(rr_m.results.medoid_idx))
    assert int(rr_b.best_restart) == int(rr_m.best_restart)
    np.testing.assert_array_equal(np.asarray(rr_b.eval_objectives),
                                  np.asarray(rr_m.eval_objectives))
    # lane r of the vmapped program == the unbatched matrix-free solver
    init = restarts_mod._init_draws(jax.random.split(key)[1], 160, 4, 3)
    lanes = restarts_mod.solve_restarts_matrix_free(
        x, pool_m.idx, pool_m.weights, init, backend="ref")
    for r in range(3):
        solo = solver.solve_matrix_free(x, pool_m.idx[r], pool_m.weights[r],
                                        init[r], backend="ref")
        _assert_same_solve(jax.tree.map(lambda a: a[r], lanes), solo, r)


def test_selector_matrix_free():
    rng = np.random.default_rng(8)
    x = rng.normal(size=(140, 5)).astype(np.float32)
    sel = MedoidSelector(k=4, strategy="matrix_free", backend="ref",
                         seed=3).fit(x)
    ref = MedoidSelector(k=4, strategy="batched", backend="ref",
                         seed=3).fit(x)
    np.testing.assert_array_equal(sel.medoid_indices_, ref.medoid_indices_)
    sel_r = MedoidSelector(k=3, strategy="matrix_free", restarts=3,
                           backend="ref", seed=3).fit(x)
    assert sel_r.best_restart_ is not None
    assert sel_r.eval_objectives_.shape == (3,)


def test_restart_m_clamp_warns():
    """Satellite: a user-passed m above the pooled budget n // R warns
    instead of shrinking silently; the default m still clamps quietly."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(120, 4)).astype(np.float32))
    key = jax.random.PRNGKey(9)
    with pytest.warns(UserWarning, match="clamped"):
        solver.one_batch_pam(key, x, 3, m=80, restarts=4, backend="ref")
    with pytest.warns(UserWarning, match="clamped"):
        MedoidSelector(k=3, m=80, restarts=4, backend="ref", seed=0).fit(
            np.asarray(x))
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # default m: no warning
        solver.one_batch_pam(key, x, 3, restarts=4, backend="ref")


def test_eager_pass_incremental_sum_matches_fresh_sum():
    """Satellite: the carried sum(d1) in _eager_pass must reproduce the
    former per-candidate fresh recompute bitwise — pinned against a
    straight-line numpy reference of Algorithm 2 at eps > 0, where the
    threshold actually consumes the sum."""
    rng = np.random.default_rng(10)
    n, m, k, eps = 90, 24, 4, 0.01
    # Dyadic grid: every sum/scatter the scan forms is exact in f32, so
    # the reference cannot drift from the solver by summation order.
    d = np.round(rng.uniform(0.1, 8.0, (n, m)) * 64).astype(np.float32) / 64
    init = rng.choice(n, size=k, replace=False)

    # Reference: candidate scan with sum(d1) recomputed fresh each step.
    rows = d[init].copy()
    med = list(init)
    swaps = []
    for _ in range(8):
        swapped = False
        for i in range(n):
            order = np.argsort(rows, axis=0, kind="stable")
            d1 = rows[order[0], np.arange(m)]
            d2 = rows[order[1], np.arange(m)]
            near = order[0]
            row = d[i]
            g = np.maximum(d1 - row, 0.0).sum(dtype=np.float32)
            r = d1 - np.minimum(np.maximum(row, d1), d2)
            big_r = np.zeros(k, np.float32)
            np.add.at(big_r, near, r)
            l = int(np.argmax(big_r))
            gain = np.float32(g + big_r[l])
            if i not in med and gain > np.float32(eps) * d1.sum(dtype=np.float32):
                rows[l] = row
                med[l] = i
                swaps.append((i, l))
                swapped = True
        if not swapped:
            break

    tr = trace.trace_eager(jnp.asarray(d), jnp.asarray(init), eps=eps)
    assert list(tr.swaps) == swaps
    res = solver.solve_eager(jnp.asarray(d), jnp.asarray(init), eps=eps)
    np.testing.assert_array_equal(np.asarray(res.medoid_idx),
                                  np.asarray(tr.result.medoid_idx))


def test_fasterpam_chunk_size_is_exact():
    """Satellite: the streamed n x n build changes no numbers."""
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.normal(size=(70, 5)).astype(np.float32))
    key = jax.random.PRNGKey(12)
    for strategy in ("eager", "batched"):
        a = solver.fasterpam(key, x, 4, strategy=strategy, backend="ref")
        b = solver.fasterpam(key, x, 4, strategy=strategy, backend="ref",
                             chunk_size=16)
        _assert_same_solve(a, b, strategy)


# ----------------------------------------------------- peak memory -------

def test_matrix_free_peak_memory_smoke():
    """Solve at an n·m whose materialized f32 block (4 GB) exceeds the
    hard 3 GB RLIMIT_AS cap the subprocess installs on itself (AS, not
    DATA: this kernel predates Linux 4.7, where RLIMIT_DATA started
    covering mmap) — only a genuinely block-free pipeline can finish.
    Subprocess so the cap applies to this run alone."""
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{env.get('PYTHONPATH', '')}"
    env["JAX_PLATFORMS"] = "cpu"
    env["MALLOC_ARENA_MAX"] = "2"   # tame thread-count-dependent RSS noise
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "helpers" / "peak_mem_check.py")],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK peak_mem" in out.stdout
