"""PR 10 monitoring suite: StepTimer ring window, CSVLogger append +
rotation, and the telemetry substrate (registry, spans, exposition,
scrape endpoint). The EMA-seeding / straggler / quoting basics live in
tests/test_fault_tolerance.py; this file owns everything PR 10 added.
"""
from __future__ import annotations

import csv
import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.monitoring import (
    CSVLogger,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    SpanTracer,
    StepTimer,
    Telemetry,
    start_metrics_server,
    telemetry as telemetry_mod,
)


# ------------------------------------------------------- StepTimer ring --

def test_steptimer_history_is_bounded():
    """The unbounded-list leak: a serving process records one step per
    micro-batch forever. The ring must cap retention at ``window`` while
    ``total_recorded`` keeps the lifetime count."""
    t = StepTimer(warmup=0, window=8)
    for i in range(100):
        t.record(float(i))
    assert len(t.history) == 8
    assert list(t.history) == [float(i) for i in range(92, 100)]
    assert t.total_recorded == 100
    assert t.count == 100


def test_steptimer_summary_windows_percentiles():
    """Percentiles describe the last ``window`` steps, not the process
    lifetime — an early slow regime must wash out once the ring rotates
    past it."""
    t = StepTimer(warmup=0, window=4, threshold=1e9)
    for _ in range(50):
        t.record(100.0)              # yesterday's slow regime
    for _ in range(4):
        t.record(0.1)                # today's steady state
    s = t.summary()
    assert s["count"] == 4
    assert s["max"] == 0.1           # the 100.0s are gone
    assert s["p50"] == 0.1


def test_steptimer_warmup_interacts_with_window():
    """Warmup exclusion applies only while the warmup records are still
    in the ring; after rotation nothing is double-dropped."""
    t = StepTimer(warmup=2, window=4, threshold=1e9)
    t.record(9.0)
    t.record(8.0)                    # both warmup records in the ring
    t.record(0.1)
    s = t.summary()
    assert s["count"] == 1 and s["warmup_excluded"] == 2
    for _ in range(4):               # rotate the warmup out entirely
        t.record(0.2)
    s = t.summary()
    assert s["count"] == 4 and s["warmup_excluded"] == 0
    assert s["max"] == 0.2


def test_steptimer_unbounded_and_invalid_window():
    t = StepTimer(warmup=0, window=None)
    for i in range(5000):
        t.record(0.1)
    assert len(t.history) == 5000    # None = historical semantics
    with pytest.raises(ValueError):
        StepTimer(window=0)


# ------------------------------------------------- CSVLogger lifecycle --

def test_csvlogger_append_resumes_existing_log(tmp_path):
    """The snapshot-resume truncation bug: mode='a' (default) continues
    a log whose header matches instead of wiping it."""
    path = str(tmp_path / "serve.csv")
    with CSVLogger(path, ["step", "msg"]) as log:
        log.log(step=1, msg="before kill")
    with CSVLogger(path, ["step", "msg"]) as log:   # "rebooted" process
        log.log(step=2, msg="after resume")
    with open(path, newline="") as f:
        rows = list(csv.reader(f))
    assert rows == [["step", "msg"], ["1", "before kill"],
                    ["2", "after resume"]]


def test_csvlogger_mode_w_truncates(tmp_path):
    path = str(tmp_path / "run.csv")
    with CSVLogger(path, ["a"], mode="w") as log:
        log.log(a=1)
    with CSVLogger(path, ["a"], mode="w") as log:
        log.log(a=2)
    with open(path, newline="") as f:
        rows = list(csv.reader(f))
    assert rows == [["a"], ["2"]]


def test_csvlogger_header_mismatch_rotates_old_schema(tmp_path):
    """Schema drift must not interleave two field sets in one file: the
    old log is rotated aside and a fresh one started."""
    path = str(tmp_path / "log.csv")
    with CSVLogger(path, ["old_field"]) as log:
        log.log(old_field="x")
    with CSVLogger(path, ["new_a", "new_b"]) as log:
        log.log(new_a=1, new_b=2)
    with open(path, newline="") as f:
        assert list(csv.reader(f)) == [["new_a", "new_b"], ["1", "2"]]
    with open(path + ".1", newline="") as f:
        assert list(csv.reader(f)) == [["old_field"], ["x"]]


def test_csvlogger_size_rotation_bounds_disk(tmp_path):
    path = str(tmp_path / "log.csv")
    log = CSVLogger(path, ["v"], max_bytes=200, backups=2)
    for i in range(200):
        log.log(v=f"value-{i:04d}")
    log.close()
    assert log.rotations >= 2
    import os
    assert os.path.getsize(path) <= 200 + 64     # fresh file + header slack
    # backups exist, each starts with the header, oldest fell off
    for bak in (path + ".1", path + ".2"):
        with open(bak, newline="") as f:
            assert next(csv.reader(f)) == ["v"]
    assert not os.path.exists(path + ".3")
    # every surviving row is intact (no sheared half-rows at rotation)
    rows = []
    for p in (path + ".2", path + ".1", path):
        with open(p, newline="") as f:
            rows += [r for r in list(csv.reader(f))[1:]]
    assert all(r[0].startswith("value-") for r in rows)
    assert rows[-1] == ["value-0199"]


def test_csvlogger_close_idempotent_and_validates(tmp_path):
    log = CSVLogger(str(tmp_path / "x.csv"), ["a"])
    log.close()
    log.close()                                   # second close: no raise
    with pytest.raises(ValueError):
        CSVLogger(str(tmp_path / "y.csv"), ["a"], mode="rb")
    with pytest.raises(ValueError):
        CSVLogger(str(tmp_path / "z.csv"), ["a"], max_bytes=0)
    with pytest.raises(ValueError):
        CSVLogger(str(tmp_path / "w.csv"), ["a"], backups=0)


# ------------------------------------------------------------ registry --

def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "help text")
    assert reg.counter("requests_total") is c
    with pytest.raises(ValueError):
        reg.gauge("requests_total")               # kind conflict
    assert isinstance(reg.gauge("g"), Gauge)
    assert isinstance(reg.histogram("h"), Histogram)


def test_counter_labels_and_monotonicity():
    reg = MetricsRegistry()
    c = reg.counter("swaps_total")
    c.inc(strategy="pruned")
    c.inc(2.0, strategy="pruned")
    c.inc(strategy="batched")
    assert c.value(strategy="pruned") == 3.0
    assert c.value(strategy="batched") == 1.0
    assert c.value(strategy="absent") == 0.0
    assert c.total() == 4.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    with pytest.raises(ValueError):
        reg.counter("bad name with spaces")
    with pytest.raises(ValueError):
        c.inc(**{"bad-label": "x"})


def test_gauge_set_add():
    g = MetricsRegistry().gauge("drift_ema")
    g.set(0.5)
    g.add(0.25)
    assert g.value() == 0.75
    g.set(-1.0)                                   # gauges may go negative
    assert g.value() == -1.0


def test_histogram_buckets_and_summary():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 0.005 and s["max"] == 5.0
    assert s["mean"] == pytest.approx(5.555 / 4)
    text = reg.render_prometheus()
    # cumulative le buckets + the +Inf catch-all
    assert 'lat_bucket{le="0.01"} 1' in text
    assert 'lat_bucket{le="0.1"} 2' in text
    assert 'lat_bucket{le="1"} 3' in text
    assert 'lat_bucket{le="+Inf"} 4' in text
    assert "lat_count 4" in text


def test_histogram_boundary_lands_in_le_bucket():
    """Prometheus buckets are upper-inclusive: an observation exactly on
    a bound counts into that bound's bucket."""
    reg = MetricsRegistry()
    h = reg.histogram("b", buckets=(1.0, 2.0))
    h.observe(1.0)
    assert 'b_bucket{le="1"} 1' in reg.render_prometheus()


def test_render_prometheus_format():
    reg = MetricsRegistry()
    c = reg.counter("c_total", 'help with "quotes"')
    c.inc(3, shard="a\nb")
    g = reg.gauge("g")
    g.set(2.5)
    text = reg.render_prometheus()
    assert "# TYPE c_total counter\n" in text
    assert '# HELP c_total help with \\"quotes\\"\n' in text
    assert 'c_total{shard="a\\nb"} 3\n' in text   # label value escaping
    assert "# TYPE g gauge\ng 2.5\n" in text
    reg.reset()
    assert reg.render_prometheus() == ""


def test_counter_multithreaded_race():
    """The lost-update race: N threads x M increments must land exactly
    N*M — an unlocked read-modify-write would drop some under the GIL's
    preemption points."""
    reg = MetricsRegistry()
    c = reg.counter("raced_total")
    n_threads, n_incs = 8, 2000

    def work():
        for _ in range(n_incs):
            c.inc(thread="shared")

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value(thread="shared") == n_threads * n_incs


# -------------------------------------------------------------- tracing --

def test_span_nesting_and_attrs():
    tr = SpanTracer()
    with tr.span("outer", sweep=1):
        with tr.span("inner"):
            pass
    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # close order
    outer = evs[1]
    assert outer["ph"] == "X" and outer["args"] == {"sweep": 1}
    inner = evs[0]
    # containment: inner lies within outer on the same track
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert inner["tid"] == outer["tid"]


def test_tracer_instant_and_complete():
    import time
    tr = SpanTracer()
    tr.instant("guard_violation", guard="objective_monotone")
    t0 = time.perf_counter_ns()
    t1 = t0 + 2_000_000                           # a 2 ms span, post-hoc
    tr.complete("sweep", t0, t1, sweep=3)
    inst, comp = tr.events()
    assert inst["ph"] == "i"
    assert comp["ph"] == "X" and comp["dur"] == pytest.approx(2000.0)
    assert comp["args"] == {"sweep": 3}


def test_tracer_ring_drops_oldest_and_counts():
    tr = SpanTracer(max_events=4)
    for i in range(10):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 4
    assert [e["name"] for e in evs] == ["e6", "e7", "e8", "e9"]
    assert tr.dropped == 6
    with pytest.raises(ValueError):
        SpanTracer(max_events=0)


def test_chrome_trace_export_is_valid_and_atomic(tmp_path):
    import os
    tr = SpanTracer(max_events=8)
    with tr.span("solve", n=100):
        tr.instant("checkpoint")
    path = str(tmp_path / "traces" / "trace.json")
    assert tr.write_chrome_trace(path) == path
    doc = json.load(open(path))                   # valid JSON, loadable
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"solve", "checkpoint"} <= names
    assert doc["otherData"]["dropped_events"] == 0
    assert not os.path.exists(path + ".tmp")      # atomic: no tmp left
    # re-export overwrites atomically
    tr.instant("more")
    tr.write_chrome_trace(path)
    assert len(json.load(open(path))["traceEvents"]) == 3


def test_tracer_jsonl_event_log(tmp_path):
    path = str(tmp_path / "events.jsonl")
    tr = SpanTracer(jsonl_path=path, fsync_every=1)
    with tr.span("a"):
        pass
    tr.instant("b")
    # readable BEFORE close: flushed (and fsync'd) per event
    lines = [json.loads(ln) for ln in open(path)]
    assert [e["name"] for e in lines] == ["a", "b"]
    tr.close()
    tr.close()                                    # idempotent
    # a new tracer APPENDS to the same durable log
    tr2 = SpanTracer(jsonl_path=path)
    tr2.instant("c")
    tr2.close()
    assert len(open(path).readlines()) == 3


# ---------------------------------------------------- facade + resolve --

def test_resolve_knob():
    for off in ("off", None, False):
        assert telemetry_mod.resolve(off) is None
    on = telemetry_mod.resolve("on")
    assert isinstance(on, Telemetry)
    assert telemetry_mod.resolve(True) is on      # same process handle
    assert on.registry is telemetry_mod.REGISTRY
    mine = Telemetry(MetricsRegistry(), SpanTracer())
    assert telemetry_mod.resolve(mine) is mine
    with pytest.raises(ValueError):
        telemetry_mod.resolve("loud")


def test_facade_passthrough_and_profiler_noops():
    tel = Telemetry(MetricsRegistry(), SpanTracer())
    tel.counter("c_total").inc()
    tel.gauge("g").set(1.0)
    tel.histogram("h").observe(0.2)
    with tel.span("s"):
        tel.instant("i")
    assert len(tel.tracer.events()) == 2
    assert "c_total 1" in tel.render_prometheus()
    # profile_dir=None: annotate is a free nullcontext, fence is identity
    with tel.annotate("hot"):
        pass
    sentinel = object()
    assert tel.fence(sentinel) is sentinel
    with pytest.raises(ValueError):
        tel.start_profile()                       # needs profile_dir=
    tel.close()                                   # stop_profile no-op path


def test_metrics_server_scrape():
    reg = MetricsRegistry()
    reg.counter("scraped_total", "a counter").inc(7)
    srv = start_metrics_server(reg)
    try:
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            assert resp.status == 200
            assert "0.0.4" in resp.headers["Content-Type"]
            body = resp.read().decode()
        assert "scraped_total 7" in body
        assert "# TYPE scraped_total counter" in body
        # non-metrics path 404s
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://{srv.host}:{srv.port}/nope", timeout=5)
    finally:
        srv.close()


def test_solve_report_metrics_are_registry_views():
    """``SolveReport.metrics`` must be the per-solve registry deltas —
    equal to the report's own counts, with the trajectory bitwise
    identical to telemetry-off (telemetry observes, never steers)."""
    import jax
    import numpy as np

    from repro.core import runtime, solver

    tel = Telemetry(MetricsRegistry(), SpanTracer())
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 8)).astype(np.float32)
    key = jax.random.PRNGKey(0)
    res_on, _, report = runtime.solve_fault_tolerant(
        key, x, 4, m=32, backend="ref", telemetry=tel)
    res_off = solver.one_batch_pam(key, x, 4, m=32, backend="ref")[0]
    assert np.array_equal(np.asarray(res_on.medoid_idx),
                          np.asarray(res_off.medoid_idx))
    m = report.metrics
    assert m is not None
    assert m["sweeps"] == report.sweeps
    assert m["swaps"] == report.swaps
    assert m["fallbacks"] == len(report.fallbacks)
    assert m["guard_violations"] == len(report.violations)
    assert m["checkpoint_writes"] == len(report.checkpoint_writes)
    # registry totals carry the same counts (strategy-labelled)
    reg = tel.registry
    assert reg.counter("solve_sweeps_total").value(
        strategy="batched") == report.sweeps
    # a second solve accumulates in the registry but the report deltas
    # stay per-solve
    _, _, report2 = runtime.solve_fault_tolerant(
        key, x, 4, m=32, backend="ref", telemetry=tel)
    assert report2.metrics["sweeps"] == report2.sweeps
    assert reg.counter("solve_sweeps_total").value(
        strategy="batched") == report.sweeps + report2.sweeps
    # the solve emitted its span tree
    names = {e["name"] for e in tel.tracer.events()}
    assert {"solve", "solve/sweep"} <= names


def test_isolated_instances_do_not_touch_global_registry():
    """Benches and tests hand the solve their own Telemetry; the
    process-wide REGISTRY must stay untouched."""
    before = set(telemetry_mod.REGISTRY.metrics())
    tel = Telemetry(MetricsRegistry(), SpanTracer())
    tel.counter("private_total").inc()
    assert set(telemetry_mod.REGISTRY.metrics()) == before
