"""Bound-pruned sweep exactness proof-harness (ISSUE 6 acceptance).

The pruned solver's headline invariant is *bitwise* trajectory identity
with the matrix-free sweep (and hence with the block path) — not
approximate agreement — so every comparison here is exact equality, and
every gain is evaluated through jitted entry points: eager (op-by-op)
execution rounds some l2 chains differently from compiled code, so the
solvers, the traces, and the direct bound probes below all go through
``jax.jit`` like the production paths do.

The harness has teeth: the adversarial-bounds test shrinks every
interval width (``bound_scale < 1``, deliberately un-sound) and asserts
the differential comparison *catches* the resulting wrong swap — a
mutation check proving a broken bound cannot slip through this suite.

hypothesis is optional (requirements-dev.txt): without it the property
suites run through the deterministic seeded-example stub
(tests/_hypothesis_stub.py). Under the derandomized "ci" profile the
differential suite runs >= 50 cases per metric (tests/conftest.py).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, same tests still run
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import pruned, sampling, solver, trace
from repro.core import restarts as restarts_mod
from repro.core.selector import MedoidSelector
from repro.kernels import metrics, ops

METRICS = metrics.names()
VARIANTS = ["unif", "debias", "nniw", "lwcs"]
# A small fixed shape pool keeps XLA recompilation bounded while the
# example draws cover metrics x dtypes x k x variants x prune knobs.
SHAPES = [(64, 6, 24), (96, 5, 32), (48, 4, 16)]


def _assert_same_solve(a, b, ctx=""):
    np.testing.assert_array_equal(np.asarray(a.medoid_idx),
                                  np.asarray(b.medoid_idx), err_msg=str(ctx))
    assert int(a.n_swaps) == int(b.n_swaps), ctx
    np.testing.assert_array_equal(np.float32(a.est_objective),
                                  np.float32(b.est_objective))
    assert bool(a.converged) == bool(b.converged), ctx


def _instance(seed, n, p, k, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    if dtype is not np.float32:
        x = x.astype(dtype)
    init = jnp.asarray(rng.choice(n, size=k, replace=False))
    return x, init


def _dyadic_instance(seed, n, p, k):
    """Integer features in [0, 8): every distance/gain sum the solvers
    form is exact in f32, so bound containment is a hard inequality."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 8, size=(n, p)).astype(np.float32))
    init = jnp.asarray(rng.choice(n, size=k, replace=False))
    return x, init


def _batches(seed, x, m, variant, metric, backend="ref"):
    key = jax.random.PRNGKey(seed)
    blk = sampling.build_batch(key, x, m, variant=variant, metric=metric,
                               backend=backend)
    mf = sampling.build_batch(key, x, m, variant=variant, metric=metric,
                              backend=backend, materialize=False)
    return blk, mf


# ------------------------------------------- differential (hypothesis) --

@pytest.mark.parametrize("metric", METRICS)
@settings(deadline=None)
@given(data=st.data())
def test_property_pruned_matches_matrix_free_and_block(metric, data):
    """ISSUE 6 acceptance: pruned == matrix-free == block, bitwise, per
    metric x {f32, bf16} x k x variant x prune knobs."""
    dtype = data.draw(st.sampled_from([np.float32, jnp.bfloat16]),
                      label="dtype")
    seed = data.draw(st.integers(0, 2**16), label="seed")
    k = data.draw(st.integers(2, 7), label="k")
    variant = data.draw(st.sampled_from(VARIANTS), label="variant")
    n, p, m = data.draw(st.sampled_from(SHAPES), label="shape")
    prune_m = data.draw(st.sampled_from([None, 1, m // 4]), label="prune_m")
    survivor_frac = data.draw(st.sampled_from([0.25, 0.5, 1.0]),
                              label="survivor_frac")
    x, init = _instance(seed, n, p, k, dtype=dtype)
    blk, mf = _batches(seed, x, m, variant, metric)
    debias = variant == "debias"
    r_blk = solver.solve_batched(blk.d, init, backend="ref")
    r_mf = solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                    metric=metric, debias=debias,
                                    backend="ref")
    r_pr = pruned.solve_pruned(x, mf.idx, mf.weights, init, metric=metric,
                               debias=debias, backend="ref",
                               prune_m=prune_m,
                               survivor_frac=survivor_frac)
    ctx = (metric, np.dtype(dtype).name if dtype is np.float32 else "bf16",
           seed, k, variant, prune_m, survivor_frac)
    _assert_same_solve(r_mf, r_blk, ctx)
    _assert_same_solve(r_pr, r_mf, ctx)


@settings(deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_pruned_never_falls_back_still_exact(seed):
    """survivor_frac=1.0 disables the dense fallback entirely (the
    threshold is n, never exceeded), so every sweep past the vacuous
    first one runs the bound-pruned scan — and the trajectory must still
    be bitwise the full sweep's. This is the end-to-end form of
    'the survivor set always contains the exact argmax'."""
    x, init = _dyadic_instance(seed, 72, 5, 4)
    _, mf = _batches(seed, x, 24, "unif", "l1")
    r_mf = solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                    metric="l1", backend="ref")
    r_pr, stats = pruned.solve_pruned_stats(
        x, mf.idx, mf.weights, init, metric="l1", backend="ref",
        survivor_frac=1.0)
    _assert_same_solve(r_pr, r_mf, seed)
    sw = int(stats.sweeps)
    assert not np.asarray(stats.fallback)[1:sw].any()


# ------------------------------------------------- bound properties -----

@functools.partial(jax.jit, static_argnames=("metric", "debias", "prune_m"))
def _bounds_and_exact(x, batch_idx, weights, init_idx, *, metric, debias,
                      prune_m):
    """One jitted program: the phase-1 interval through the solver's own
    helper, and the exact per-row max gains through the identical fused
    rowmax chain the dense sweep uses."""
    xp = solver._prepared(x, metric)
    b = xp[batch_idx]
    w = weights.astype(jnp.float32)
    batch_idx = batch_idx.astype(jnp.int32)
    state = solver._init_state_matrix_free(xp, b, w, batch_idx, init_idx,
                                           metric=metric, debias=debias,
                                           backend="ref")
    hi, lo, slack = pruned._phase1_bounds(
        xp, b, w, batch_idx, state, metric=metric, debias=debias,
        backend="ref", row_chunk=solver._mf_chunk(None), prune_m=prune_m)
    k = init_idx.shape[0]
    nh = jax.nn.one_hot(state.near, k, dtype=jnp.float32)
    exact, _ = ops.fused_swap_select_rowmax(
        xp, b, w, state.d1, state.d2, nh, metric=metric,
        owner=batch_idx if debias else None, backend="ref",
        skip_prepare=True)
    valid = jnp.ones((x.shape[0],), jnp.bool_).at[state.medoid_idx].set(False)
    return hi, lo, slack, exact, valid


@pytest.mark.parametrize("metric", METRICS)
@settings(deadline=None)
@given(seed=st.integers(0, 10_000), prune_m=st.integers(1, 12))
def test_property_interval_contains_exact_gain(metric, seed, prune_m):
    """On dyadic grids (all sums exact in f32) the phase-1 interval must
    contain the exact max gain of every row: lo <= G_max <= hi."""
    x, init = _dyadic_instance(seed, 60, 5, 4)
    _, mf = _batches(seed, x, 20, "unif", metric)
    hi, lo, slack, exact, valid = _bounds_and_exact(
        x, mf.idx, mf.weights, init, metric=metric, debias=False,
        prune_m=prune_m)
    hi, lo, exact = (np.asarray(hi), np.asarray(lo), np.asarray(exact))
    ok = np.asarray(valid)
    assert (lo[ok] <= exact[ok]).all(), (metric, seed, prune_m)
    assert (exact[ok] <= hi[ok]).all(), (metric, seed, prune_m)
    assert float(slack) >= 0.0


@settings(deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_survivors_contain_exact_argmax(seed):
    """The phase-1 survivor rule (UB >= best LB) keeps every row
    attaining the exact max — the single-sweep core of the exactness
    proof (non-survivors are *strictly* below the best lower bound)."""
    x, init = _dyadic_instance(seed, 80, 6, 5)
    _, mf = _batches(seed, x, 28, "unif", "l1")
    hi, lo, _, exact, valid = _bounds_and_exact(
        x, mf.idx, mf.weights, init, metric="l1", debias=False, prune_m=3)
    hi, lo, exact = (np.asarray(hi), np.asarray(lo), np.asarray(exact))
    ok = np.asarray(valid)
    best_lb = lo[ok].max()
    surv = ok & (hi >= best_lb)
    gmax = exact[ok].max()
    attain = ok & (exact == gmax)
    assert (surv | ~attain).all(), seed     # every argmax row survives


@settings(deadline=None)
@given(seed=st.integers(0, 10_000),
       survivor_frac=st.sampled_from([0.1, 0.3, 0.6]))
def test_property_fallback_triggers_exactly_when_predicted(seed,
                                                           survivor_frac):
    """The recorded per-sweep fallback flag must equal the documented
    predicate on the recorded survivor count — no hidden hysteresis."""
    x, init = _dyadic_instance(seed, 64, 5, 4)
    _, mf = _batches(seed, x, 24, "unif", "l1")
    _, stats = pruned.solve_pruned_stats(
        x, mf.idx, mf.weights, init, metric="l1", backend="ref",
        survivor_frac=survivor_frac)
    sw = int(stats.sweeps)
    surv = np.asarray(stats.survivors)[:sw]
    fb = np.asarray(stats.fallback)[:sw]
    np.testing.assert_array_equal(
        fb, surv > int(survivor_frac * x.shape[0]))


def test_stats_accounting():
    """scored <= survivors on non-fallback sweeps (the ordered scan can
    only shrink the survivor set), sweep 0 always falls back (vacuous
    caches), and entries past ``sweeps`` stay zero."""
    x, init = _dyadic_instance(3, 100, 6, 5)
    _, mf = _batches(3, x, 30, "unif", "l1")
    res, stats = pruned.solve_pruned_stats(
        x, mf.idx, mf.weights, init, metric="l1", backend="ref",
        max_swaps=50)
    sw = int(stats.sweeps)
    assert sw >= int(res.n_swaps)
    scored = np.asarray(stats.scored)
    surv = np.asarray(stats.survivors)
    fb = np.asarray(stats.fallback)
    assert bool(fb[0])
    assert (scored[:sw] >= 1).all()
    nonfb = ~fb[:sw]
    assert (scored[:sw][nonfb] <= surv[:sw][nonfb]).all()
    assert (scored[sw:] == 0).all() and (surv[sw:] == 0).all()
    assert not fb[sw:].any()


# ------------------------------------------------ adversarial bounds ----

def test_adversarial_bounds_are_caught():
    """Mutation check: ``bound_scale=0.0`` collapses every interval to
    the subsample point estimate — deliberately un-sound — and the
    differential harness must CATCH the resulting wrong swap on at least
    one seed. If this test ever fails, the suite has lost its teeth (a
    broken bound would pass the trajectory comparison)."""
    caught = 0
    for seed in range(25):
        x, init = _instance(seed, 64, 6, 4)
        _, mf = _batches(seed, x, 24, "unif", "l2")
        r_mf = solver.solve_matrix_free(x, mf.idx, mf.weights, init,
                                        metric="l2", backend="ref")
        r_bad = pruned.solve_pruned(x, mf.idx, mf.weights, init,
                                    metric="l2", backend="ref", prune_m=1,
                                    survivor_frac=1.0, bound_scale=0.0)
        same = (np.array_equal(np.asarray(r_mf.medoid_idx),
                               np.asarray(r_bad.medoid_idx))
                and int(r_mf.n_swaps) == int(r_bad.n_swaps))
        if not same:
            caught += 1
    assert caught > 0, ("bound_scale=0.0 never changed a trajectory — "
                        "the exactness harness cannot detect broken bounds")


def test_sound_scale_is_the_default():
    """bound_scale is a test-only knob: the public entry points run at
    1.0 (sound) and accept no override through one_batch_pam."""
    import inspect
    sig = inspect.signature(solver.one_batch_pam)
    assert "bound_scale" not in sig.parameters
    assert inspect.signature(
        pruned.solve_pruned_stats).parameters["bound_scale"].default == 1.0


# ----------------------------------------------------- trace parity -----

def test_trace_pruned_matches_solver_and_peers():
    """trace_pruned replays solve_pruned bit-for-bit (it drives the
    literal loop body with the same cache init), and the recorded swap
    sequence equals the matrix-free and block traces'."""
    x, init = _instance(5, 96, 6, 5)
    blk, mf = _batches(5, x, 30, "nniw", "l1")
    tr_blk = trace.trace_batched(blk.d, init, backend="ref")
    tr_mf = trace.trace_matrix_free(x, mf.idx, mf.weights, init,
                                    backend="ref")
    tr_pr = trace.trace_pruned(x, mf.idx, mf.weights, init, backend="ref")
    assert tr_pr.swaps == tr_mf.swaps == tr_blk.swaps
    assert tr_pr.gains == tr_mf.gains == tr_blk.gains
    res = pruned.solve_pruned(x, mf.idx, mf.weights, init, backend="ref")
    _assert_same_solve(tr_pr.result, res)


@pytest.mark.parametrize("seed", range(2))
def test_pruned_interpret_backend(seed):
    """The Pallas rowmax kernel (interpret) feeds phase 1 the same
    bounds the ref oracle computes, so the interpret trajectory equals
    ref's — and both equal the interpret block path's."""
    x, init = _dyadic_instance(300 + seed, 64, 5, 4)
    blk, mf = _batches(300 + seed, x, 24, "unif", "l1",
                       backend="interpret")
    r_blk = solver.solve_batched(blk.d, init, backend="interpret")
    r_ref = pruned.solve_pruned(x, mf.idx, mf.weights, init, metric="l1",
                                backend="ref")
    r_int = pruned.solve_pruned(x, mf.idx, mf.weights, init, metric="l1",
                                backend="interpret")
    _assert_same_solve(r_int, r_blk, seed)
    _assert_same_solve(r_int, r_ref, seed)


# ------------------------------------------------ pipeline threading ----

def test_one_batch_pam_pruned_matches_matrix_free():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(150, 6)).astype(np.float32))
    key = jax.random.PRNGKey(2)
    r_mf, b_mf = solver.one_batch_pam(key, x, 5, strategy="matrix_free",
                                      backend="ref")
    r_pr, b_pr = solver.one_batch_pam(key, x, 5, strategy="pruned",
                                      backend="ref")
    assert b_pr.d is None
    np.testing.assert_array_equal(np.asarray(b_mf.idx), np.asarray(b_pr.idx))
    np.testing.assert_array_equal(np.asarray(b_mf.weights),
                                  np.asarray(b_pr.weights))
    _assert_same_solve(r_pr, r_mf)


def test_one_batch_pam_pruned_rejects_block_dtype():
    x = jnp.zeros((20, 3))
    with pytest.raises(ValueError, match="block_dtype"):
        solver.one_batch_pam(jax.random.PRNGKey(0), x, 3,
                             strategy="pruned", block_dtype="bfloat16")


def test_restart_lanes_pruned_bitwise():
    """Pruned restart lanes == matrix-free lanes (same draws, same
    per-lane swaps, same election); each vmapped lane == the unbatched
    pruned solver; all lanes share the positional phase-1 subsample."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(160, 6)).astype(np.float32))
    key = jax.random.PRNGKey(6)
    rr_m, pool_m = restarts_mod.one_batch_pam_restarts(
        key, x, 4, restarts=3, m=20, backend="ref",
        strategy="matrix_free")
    rr_p, pool_p = restarts_mod.one_batch_pam_restarts(
        key, x, 4, restarts=3, m=20, backend="ref", strategy="pruned")
    assert pool_p.d is None
    np.testing.assert_array_equal(np.asarray(pool_m.weights),
                                  np.asarray(pool_p.weights))
    np.testing.assert_array_equal(np.asarray(rr_m.results.medoid_idx),
                                  np.asarray(rr_p.results.medoid_idx))
    assert int(rr_m.best_restart) == int(rr_p.best_restart)
    np.testing.assert_array_equal(np.asarray(rr_m.eval_objectives),
                                  np.asarray(rr_p.eval_objectives))
    # lane r of the vmapped program == the unbatched pruned solver
    init = restarts_mod._init_draws(jax.random.split(key)[1], 160, 4, 3)
    lanes = restarts_mod.solve_restarts_pruned(
        x, pool_p.idx, pool_p.weights, init, backend="ref")
    for r in range(3):
        solo = pruned.solve_pruned(x, pool_p.idx[r], pool_p.weights[r],
                                   init[r], backend="ref")
        _assert_same_solve(jax.tree.map(lambda a: a[r], lanes), solo, r)
    # the phase-1 subsample is positional — static in (m, m'), identical
    # across lanes by construction, never a per-lane data draw
    sel = pruned._prune_positions(20, pruned.default_prune_m(20))
    assert isinstance(sel, np.ndarray)
    np.testing.assert_array_equal(
        sel, pruned._prune_positions(20, pruned.default_prune_m(20)))
    assert (np.diff(sel) > 0).all() and sel[0] == 0 and sel[-1] < 20


def test_restarts_pruned_mesh_rejected():
    with pytest.raises(ValueError, match="mesh"):
        restarts_mod.one_batch_pam_restarts(
            jax.random.PRNGKey(0), jnp.zeros((40, 3)), 3, restarts=2,
            m=10, strategy="pruned", mesh=object())


def test_selector_pruned_strategy():
    rng = np.random.default_rng(9)
    x = rng.normal(size=(120, 5)).astype(np.float32)
    a = MedoidSelector(k=4, strategy="matrix_free", backend="ref",
                       seed=3).fit(x)
    b = MedoidSelector(k=4, strategy="pruned", backend="ref", seed=3,
                       prune_m=4, survivor_frac=0.5).fit(x)
    np.testing.assert_array_equal(a.medoid_indices_, b.medoid_indices_)
    assert a.n_swaps_ == b.n_swaps_
    assert np.float32(a.est_objective_) == np.float32(b.est_objective_)
    # restart path threads the knobs too
    c = MedoidSelector(k=4, strategy="pruned", backend="ref", seed=3,
                       restarts=2, m=20).fit(x)
    d = MedoidSelector(k=4, strategy="matrix_free", backend="ref", seed=3,
                       restarts=2, m=20).fit(x)
    np.testing.assert_array_equal(c.medoid_indices_, d.medoid_indices_)
    assert c.best_restart_ == d.best_restart_
