"""Multi-restart engine (ISSUE 3 tentpole): pooled sampling, vmapped
fused sweeps, held-out election — and the restarts=1 bit-for-bit pin."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import restarts, sampling, solver


def _data(seed=0, n=300, p=6):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))


# ------------------------------------------------------- restarts=1 pin --

def test_restarts_1_is_bitwise_todays_trajectory():
    """one_batch_pam(restarts=1) must replay the historical single-restart
    path exactly: same key splits, same build_batch draw, same fused
    sweep — medoids, swap count, objective, batch, all bit-for-bit."""
    x = _data(1)
    key = jax.random.PRNGKey(7)
    res, batch = solver.one_batch_pam(key, x, 5, m=40, restarts=1)

    # The documented historical trajectory, reconstructed by hand.
    key_b, key_i = jax.random.split(key)
    init = jax.random.choice(key_i, x.shape[0], shape=(5,), replace=False)
    want_batch = sampling.build_batch(key_b, x, 40, variant="nniw")
    want = solver.solve_batched(want_batch.d, init)

    np.testing.assert_array_equal(np.asarray(batch.idx),
                                  np.asarray(want_batch.idx))
    np.testing.assert_array_equal(np.asarray(batch.d), np.asarray(want_batch.d))
    np.testing.assert_array_equal(np.asarray(res.medoid_idx),
                                  np.asarray(want.medoid_idx))
    assert int(res.n_swaps) == int(want.n_swaps)
    np.testing.assert_array_equal(np.float32(res.est_objective),
                                  np.float32(want.est_objective))


# ------------------------------------------------------- vmapped solve --

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_vmapped_lanes_equal_per_slice_solve_batched(backend):
    """Every lane of the vmapped sweep must be bit-for-bit the unbatched
    fused solver on that restart's (n, m) slice."""
    x = _data(2, n=220)
    key = jax.random.PRNGKey(0)
    R, k, m = 3, 4, 18
    pool = restarts.build_pool(key, x, m, R, variant="nniw", backend=backend)
    init = restarts._init_draws(jax.random.PRNGKey(1), x.shape[0], k, R)
    batched = restarts.solve_restarts(pool.d, init, backend=backend)
    for r in range(R):
        single = solver.solve_batched(pool.d[r], init[r], backend=backend)
        np.testing.assert_array_equal(np.asarray(batched.medoid_idx[r]),
                                      np.asarray(single.medoid_idx))
        assert int(batched.n_swaps[r]) == int(single.n_swaps)
        np.testing.assert_array_equal(np.float32(batched.est_objective[r]),
                                      np.float32(single.est_objective))
        assert bool(batched.converged[r]) == bool(single.converged)


# ----------------------------------------------------------- pool build --

def test_pooled_nniw_counts_match_per_slice_argmin():
    """Grouped count fusion: restart r's histogram == the direct argmin
    count over that restart's own m columns (f32 distances)."""
    x = _data(3, n=150, p=4)
    R, m = 4, 12
    pool = restarts.build_pool(jax.random.PRNGKey(2), x, m, R, variant="nniw")
    from repro.kernels import ops
    for r in range(R):
        d_raw = ops.pairwise_distance(x, x[pool.idx[r]], metric="l1")
        counts = np.bincount(np.asarray(jnp.argmin(d_raw, axis=1)),
                             minlength=m)
        np.testing.assert_allclose(np.asarray(pool.weights[r]),
                                   counts * m / x.shape[0], rtol=1e-6)


def test_pool_columns_disjoint_and_eval_held_out():
    x = _data(4, n=200)
    R, m, eval_m = 3, 20, 30
    pool = restarts.build_pool(jax.random.PRNGKey(3), x, m, R,
                               eval_m=eval_m, variant="unif")
    flat = np.asarray(pool.idx).reshape(-1)
    assert len(np.unique(flat)) == R * m, "pool must be without replacement"
    ev = np.asarray(pool.eval_idx)
    assert len(np.unique(ev)) == eval_m
    assert not set(ev) & set(flat), "eval batch must be held out"


def test_pool_debias_diagonal_and_variant_invariants():
    x = _data(5, n=120, p=4)
    R, m = 2, 10
    pool = restarts.build_pool(jax.random.PRNGKey(4), x, m, R,
                               variant="debias")
    d = np.asarray(pool.d)     # (R, n, m)
    idx = np.asarray(pool.idx)
    for r in range(R):
        diag = d[r][idx[r], np.arange(m)]
        assert (diag >= 1e14).all(), "per-restart self-distances must be LARGE"
    np.testing.assert_allclose(np.asarray(pool.weights), 1.0)


def test_pool_lwcs_per_restart_weight_normalisation():
    x = _data(6, n=160, p=4)
    pool = restarts.build_pool(jax.random.PRNGKey(5), x, 12, 3,
                               variant="lwcs")
    w = np.asarray(pool.weights)
    assert (w > 0).all()
    np.testing.assert_allclose(w.mean(axis=1), 1.0, rtol=1e-5)


def test_pool_block_dtype_narrow_and_weights_dtype_independent():
    x = _data(7, n=140, p=4)
    kw = dict(m=10, restarts=3, variant="nniw")
    p32 = restarts.build_pool(jax.random.PRNGKey(6), x, kw["m"],
                              kw["restarts"], variant=kw["variant"])
    p16 = restarts.build_pool(jax.random.PRNGKey(6), x, kw["m"],
                              kw["restarts"], variant=kw["variant"],
                              block_dtype="bfloat16")
    assert p16.d.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(p32.weights),
                                  np.asarray(p16.weights))


def test_pool_chunked_build_is_bitwise_one_shot():
    x = _data(8, n=130, p=5)
    a = restarts.build_pool(jax.random.PRNGKey(7), x, 11, 3, variant="nniw")
    b = restarts.build_pool(jax.random.PRNGKey(7), x, 11, 3, variant="nniw",
                            chunk_size=32)
    np.testing.assert_array_equal(np.asarray(a.d), np.asarray(b.d))
    np.testing.assert_array_equal(np.asarray(a.weights),
                                  np.asarray(b.weights))


def test_pool_too_large_raises_and_one_batch_pam_clamps():
    x = _data(9, n=50, p=3)
    with pytest.raises(ValueError, match="pooled sample"):
        restarts.build_pool(jax.random.PRNGKey(0), x, 20, 4)
    # one_batch_pam clamps m to n // restarts instead of raising — and
    # since ISSUE 4 the shrinkage warns instead of happening silently.
    with pytest.warns(UserWarning, match="clamped"):
        res, batch = solver.one_batch_pam(jax.random.PRNGKey(0), x, 3, m=40,
                                          restarts=4)
    assert batch.idx.shape[0] == 50 // 4
    assert len(np.unique(np.asarray(res.medoid_idx))) == 3


# ------------------------------------------------------------- election --

def test_election_scores_match_manual_estimator():
    """elect()'s score for restart r == mean over eval points of the
    distance to r's nearest medoid, computed independently in numpy."""
    x = _data(10, n=180, p=4)
    R, k = 3, 4
    rng = np.random.default_rng(0)
    med = jnp.asarray(rng.choice(180, size=(R, k), replace=False))
    eval_idx = jnp.asarray(rng.choice(180, size=25, replace=False))
    best_r, evals = restarts.elect(x, med, eval_idx, metric="l1")
    xn = np.asarray(x)
    for r in range(R):
        d = np.abs(xn[np.asarray(eval_idx)][:, None, :]
                   - xn[np.asarray(med[r])][None, :, :]).sum(-1)
        np.testing.assert_allclose(float(evals[r]), d.min(1).mean(),
                                   rtol=1e-5)
    assert int(best_r) == int(np.argmin(np.asarray(evals)))


def test_election_tie_breaks_to_lowest_restart():
    x = _data(11, n=60, p=3)
    med = jnp.asarray([[0, 1], [0, 1], [2, 3]])   # lanes 0 and 1 identical
    eval_idx = jnp.arange(20)
    best_r, evals = restarts.elect(x, med, eval_idx)
    assert float(evals[0]) == float(evals[1])
    if float(evals[0]) <= float(evals[2]):
        assert int(best_r) == 0


def test_multi_restart_beats_or_matches_single_restart_quality():
    """With a large held-out eval batch, the elected R=6 medoid set's
    exact objective must be within a hair of the best lane's exact
    objective, and no worse than the single-restart run."""
    rng = np.random.default_rng(12)
    c = rng.normal(size=(6, 5)) * 4.0
    x = jnp.asarray((c[rng.integers(0, 6, 360)]
                     + rng.normal(size=(360, 5)) * 0.4).astype(np.float32))
    key = jax.random.PRNGKey(9)
    rr, pool = restarts.one_batch_pam_restarts(key, x, 6, restarts=6, m=24,
                                               eval_m=180)
    objs = [float(solver.objective(x, rr.results.medoid_idx[r]))
            for r in range(6)]
    elected = float(solver.objective(x, rr.best.medoid_idx))
    assert elected <= min(objs) * 1.05
    single, _ = solver.one_batch_pam(key, x, 6, m=24)
    assert elected <= float(solver.objective(x, single.medoid_idx)) * 1.02


def test_selector_rejects_non_batched_strategy_with_restarts():
    """Same contract as one_batch_pam: the restart engine is the batched
    sweep only — both entry points must refuse eager+restarts alike."""
    from repro.core import MedoidSelector
    x = np.zeros((30, 3), np.float32)
    with pytest.raises(ValueError, match="batched"):
        MedoidSelector(k=3, restarts=4, strategy="eager").fit(x)
    with pytest.raises(ValueError, match="batched"):
        solver.one_batch_pam(jax.random.PRNGKey(0), jnp.asarray(x), 3,
                             restarts=4, strategy="eager")


def test_selector_threads_restart_knobs():
    x = np.asarray(_data(13, n=200, p=4))
    sel = __import__("repro.core", fromlist=["MedoidSelector"]) \
        .MedoidSelector(k=4, restarts=4, eval_m=60, seed=1).fit(x)
    assert sel.medoid_indices_.shape == (4,)
    assert 0 <= sel.best_restart_ < 4
    assert sel.eval_objectives_.shape == (4,)
    labels = sel.predict(x)
    assert labels.shape == (200,)
