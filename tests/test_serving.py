"""AssignmentEngine serving-path suite (DESIGN.md §9).

Pins the engine's shape/answer contracts (empty batch, micro-batch
invariance, bitwise agreement with the predict path), the bf16 serving
opt-in (block_dtype threading that predict()/objective() used to drop),
the drift monitor -> background warm-start refit loop, the no-torn-swap
guarantee when a refit is killed mid-flight, and the warm-start claim
itself: a refit from saved medoids reaches <= the cold-start objective
in strictly fewer sweeps.
"""
import copy
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MedoidSelector, solver, streaming
from repro.serving import AssignmentEngine


def _clusters(n=600, k=6, p=12, sep=8.0, noise=0.3, seed=0):
    """Well-separated Gaussian blobs: label decisions are robust to bf16
    rounding and to medoid drift within a blob."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, p)).astype(np.float32) * sep
    x = (centers[rng.integers(0, k, n)]
         + rng.standard_normal((n, p)).astype(np.float32) * noise)
    return x


@pytest.fixture(scope="module")
def fitted():
    x = _clusters()
    sel = MedoidSelector(k=6, seed=0).fit(x)
    return x, sel


def test_empty_batch_shape_contract(fitted):
    """Zero queries -> ((0,) i32, (0,) f32), no kernel launch, no crash
    (the old LLM engine's new_tokens=0 sibling bug: it returned S0+1
    tokens because the prefill argmax was stacked unconditionally)."""
    _, sel = fitted
    eng = AssignmentEngine.from_selector(sel, auto_refit=False)
    labels, d1 = eng.assign(np.zeros((0, eng.p), np.float32))
    assert labels.shape == (0,) and labels.dtype == np.int32
    assert d1.shape == (0,) and d1.dtype == np.float32
    # a zero-row array of any width is accepted (there is nothing to
    # misinterpret), but a nonzero batch with the wrong width raises
    labels, d1 = eng.assign(np.zeros((0, 3), np.float32))
    assert labels.shape == (0,)
    with pytest.raises(ValueError, match="p="):
        eng.assign(np.zeros((4, eng.p + 1), np.float32))
    with pytest.raises(ValueError, match="2-D"):
        eng.assign(np.zeros((8,), np.float32))


def test_engine_bitwise_vs_predict_path(fitted):
    """The engine answers exactly what the host predict loop answers —
    swapping in the serving path changes throughput, not labels."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, micro_batch=128,
                                         auto_refit=False)
    labels, d1 = eng.assign(x)
    np.testing.assert_array_equal(labels, sel.predict(x))
    _, dref = streaming.stream_assign(jnp.asarray(x),
                                      jnp.asarray(sel.medoids_),
                                      metric=sel.metric, backend=sel.backend)
    np.testing.assert_array_equal(d1.view(np.uint32),
                                  np.asarray(dref).view(np.uint32))


def test_micro_batch_invariance(fitted):
    """Identical answers for any micro_batch (the tail pad is sliced,
    per-row math is batch-size independent)."""
    x, sel = fitted
    outs = []
    for mb in (64, 100, len(x), 4 * len(x)):
        eng = AssignmentEngine.from_selector(sel, micro_batch=mb,
                                             auto_refit=False)
        outs.append(eng.assign(x))
    for labels, d1 in outs[1:]:
        np.testing.assert_array_equal(labels, outs[0][0])
        np.testing.assert_array_equal(d1.view(np.uint32),
                                      outs[0][1].view(np.uint32))


def test_bf16_vs_f32_label_agreement_on_separated_clusters(fitted):
    """Satellite: block_dtype now reaches predict()/objective() (it used
    to be dropped). On separated clusters bf16 tile rounding cannot flip
    a label; the bf16 selector/engine agree with f32 while the bf16
    distances really are rounded."""
    x, sel = fitted
    sel16 = copy.copy(sel)
    sel16.block_dtype = "bfloat16"
    np.testing.assert_array_equal(sel16.predict(x), sel.predict(x))

    # objective() threads it too: bitwise the solver objective with the
    # same block_dtype, and != the f32 objective (rounding is real)
    obj16 = sel16.objective(x)
    assert obj16 == float(solver.objective(
        jnp.asarray(x), jnp.asarray(sel.medoid_indices_), metric=sel.metric,
        backend=sel.backend, block_dtype="bfloat16"))
    assert obj16 != sel.objective(x)

    eng16 = AssignmentEngine.from_selector(sel16, auto_refit=False)
    assert eng16.block_dtype == "bfloat16"
    labels16, d16 = eng16.assign(x)
    np.testing.assert_array_equal(labels16, sel.predict(x))
    np.testing.assert_array_equal(
        d16, d16.astype(jnp.bfloat16).astype(np.float32))


def test_drift_monitor_triggers_auto_refit(fitted):
    """Drifted queries push the objective EMA past the threshold; the
    engine refits in the background (warm-started from the live medoids
    on the query window) and atomically installs the new snapshot."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(copy.copy(sel), micro_batch=256,
                                         drift_threshold=1.2,
                                         refit_window=4096)
    eng.assign(x)
    assert eng.medoid_version == 0 and eng.refits == 0
    assert eng.drift_ratio() == pytest.approx(1.0, rel=0.5)

    drifted = x + np.float32(5.0)
    for _ in range(12):
        eng.assign(drifted)
        if eng.refit_in_flight or eng.refits:
            break
    deadline = time.time() + 120
    while eng.refit_in_flight and time.time() < deadline:
        time.sleep(0.02)
    assert eng.last_refit_error is None
    assert eng.refits == 1 and eng.medoid_version == 1
    # serving continues against the new snapshot; drift is healed
    labels, d1 = eng.assign(drifted)
    assert labels.shape == (len(x),)
    eng.assign(drifted)
    assert eng.drift_ratio() < 1.2
    s = eng.stats()
    assert s["medoid_version"] == 1 and s["refits"] == 1
    assert s["latency"]["count"] >= 1 and "warmup_excluded" in s["latency"]


def test_kill_during_refit_leaves_old_medoids_serving(fitted):
    """No torn swap: a refit cancelled right before the install leaves
    the engine serving the OLD snapshot in full — medoids, version, and
    answers — and a crashed refit is reported, not installed."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(copy.copy(sel), auto_refit=False)
    before_labels, before_d1 = eng.assign(x)
    old_rows = eng.medoids.copy()

    # "kill" lands between the refit's compute and its install
    eng._refit_hook = lambda: eng._refit_cancel.set()
    assert eng.refit_now(x + np.float32(5.0), wait=True)
    assert eng.medoid_version == 0 and eng.refits == 0
    assert eng.last_refit_error is None
    np.testing.assert_array_equal(eng.medoids, old_rows)
    labels, d1 = eng.assign(x)
    np.testing.assert_array_equal(labels, before_labels)
    np.testing.assert_array_equal(d1.view(np.uint32),
                                  before_d1.view(np.uint32))

    # a refit that *crashes* mid-flight: old snapshot intact, error kept
    def boom():
        raise RuntimeError("refit died")
    eng._refit_hook = boom
    eng._refit_cancel.clear()
    eng.refit_now(x, wait=True)
    assert eng.medoid_version == 0
    assert isinstance(eng.last_refit_error, RuntimeError)
    np.testing.assert_array_equal(eng.medoids, old_rows)


def test_warm_start_refit_beats_cold_start(fitted, tmp_path):
    """The FasterPAM warm-start claim, through the saved artifact: a
    selector restored from save() and refit on drifted data reaches <=
    the cold-start objective in strictly fewer sweeps."""
    x, sel = fitted
    rng = np.random.default_rng(42)
    drifted = x + rng.standard_normal(x.shape).astype(np.float32) * 0.15

    path = str(tmp_path / "sel_ckpt")
    sel.save(path)
    warm = MedoidSelector.from_checkpoint(path)
    warm.refit(drifted)

    cold = MedoidSelector(k=sel.k, seed=sel.seed).fit(drifted)
    assert warm.objective(drifted) <= cold.objective(drifted) + 1e-6
    assert warm.n_swaps_ < cold.n_swaps_


def test_refit_requires_fit_and_warm_init_repairs_collisions(fitted):
    x, sel = fitted
    with pytest.raises(RuntimeError, match="fit"):
        MedoidSelector(k=3).refit(x)
    # collision repair: exactly k rows, but the last two sit far away so
    # several medoids snap to the same near row — the greedy repair must
    # still hand back a permutation of all k rows
    p = sel.medoids_.shape[1]
    tiny = np.concatenate([sel.medoids_[:4],
                           np.full((2, p), 1e3, np.float32)])
    tiny[5] += 7.0                       # keep the two far rows distinct
    init = sel.warm_init(tiny)
    assert init.shape == (sel.k,)
    assert sorted(init.tolist()) == list(range(sel.k))
    # fewer rows than k cannot produce distinct indices -> refused
    with pytest.raises(ValueError, match="distinct"):
        sel.warm_init(tiny[:3])


def test_engine_from_checkpoint_serves_identically(fitted, tmp_path):
    x, sel = fitted
    path = str(tmp_path / "sel_ckpt2")
    sel.save(path)
    a = AssignmentEngine.from_selector(sel, auto_refit=False)
    b = AssignmentEngine.from_checkpoint(path, auto_refit=False)
    la, da = a.assign(x)
    lb, db = b.assign(x)
    np.testing.assert_array_equal(la, lb)
    np.testing.assert_array_equal(da.view(np.uint32), db.view(np.uint32))


def test_concurrent_assign_is_safe_and_bitwise(fitted):
    """Satellite: multi-threaded serving. Host bookkeeping
    (queries_served, EMA, window pushes, StepTimer.record) is serialised
    under the engine lock while kernel calls overlap — every thread's
    answers stay bitwise and no count is lost."""
    import threading

    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, micro_batch=64,
                                         auto_refit=False,
                                         refit_window=256)
    ref_labels, ref_d1 = eng.assign(x)
    served_before = eng.queries_served
    n_threads, reps = 6, 4
    errs = []

    def worker():
        try:
            for _ in range(reps):
                labels, d1 = eng.assign(x)
                np.testing.assert_array_equal(labels, ref_labels)
                np.testing.assert_array_equal(d1.view(np.uint32),
                                              ref_d1.view(np.uint32))
        except Exception as e:          # pragma: no cover — failure path
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs[0]
    # no lost update: every row of every call was counted exactly once
    assert eng.queries_served == served_before + n_threads * reps * len(x)
    s = eng.stats()
    # every micro-batch was timed exactly once (summary excludes warmup)
    assert (s["latency"]["count"] + s["latency"]["warmup_excluded"]
            == eng.timer.count == (1 + n_threads * reps) * 10)
    assert s["window"]["fill"] == 256   # saturated, never overfilled


def test_ring_window_wraparound_and_content_invariants():
    """Satellite: the legacy ring mode's wrap-around — pushes larger
    than the window keep the LAST capacity rows, multi-wrap sequences
    land where a flat tail-slice says they should."""
    from repro.serving import ReservoirWindow

    win = ReservoirWindow(8, 2, mode="ring")
    rows = np.arange(40, dtype=np.float32).reshape(20, 2)
    win.push(rows[:3], np.ones(3, np.float32))
    assert win.fill == 3
    np.testing.assert_array_equal(win.content(), rows[:3])
    # oversized push: only the last 8 rows of the push survive
    win.push(rows, np.ones(20, np.float32))
    assert win.fill == 8 and win.pushed == 23
    np.testing.assert_array_equal(np.sort(win.content(), axis=0),
                                  np.sort(rows[-8:], axis=0))
    # multi-wrap: a long sequence of small pushes == the flat tail
    win2 = ReservoirWindow(8, 2, mode="ring")
    for i in range(0, 20, 3):
        win2.push(rows[i:i + 3], np.ones(rows[i:i + 3].shape[0],
                                         np.float32))
    np.testing.assert_array_equal(np.sort(win2.content(), axis=0),
                                  np.sort(rows[-8:], axis=0))
    with pytest.raises(ValueError, match="mode"):
        ReservoirWindow(8, 2, mode="nope")
    with pytest.raises(ValueError, match="capacity"):
        ReservoirWindow(0, 2)


def test_reservoir_window_weighted_representative_and_reproducible():
    """The objective-weighted reservoir: content rows are always a
    subset of what was pushed, saturation holds fill == capacity across
    oversized and repeated pushes, heavy-weight rows are
    overrepresented (A-Res inclusion ~ weight), and the same seed +
    stream reproduces the same window bit for bit."""
    from repro.serving import ReservoirWindow

    def feed(seed):
        win = ReservoirWindow(32, 1, seed=seed)
        rng = np.random.default_rng(99)
        for _ in range(6):
            rows = rng.normal(size=(100, 1)).astype(np.float32)
            # weight 100x on negative rows: they should dominate
            w = np.where(rows[:, 0] < 0, 100.0, 1.0).astype(np.float32)
            win.push(rows, w)
        return win

    a, b = feed(7), feed(7)
    assert a.fill == 32 and a.pushed == 600
    np.testing.assert_array_equal(a.content(), b.content())      # seeded
    assert (a.content()[:, 0] < 0).mean() > 0.8   # weight bias is real
    c = feed(8)
    assert not np.array_equal(a.content(), c.content())

    # single oversized push saturates and samples from the whole push
    win = ReservoirWindow(4, 1)
    rows = np.arange(64, dtype=np.float32).reshape(64, 1)
    win.push(rows, np.ones(64, np.float32))
    assert win.fill == 4
    assert set(win.content()[:, 0]).issubset(set(rows[:, 0]))
    # zero-weight rows lose every contest against weighted ones
    win.push(np.full((50, 1), -1.0, np.float32),
             np.zeros(50, np.float32))
    assert (win.content()[:, 0] >= 0).all()


def test_successful_refit_clears_stale_error(fitted):
    """Satellite fix: stats() used to report the last refit failure
    forever; a subsequent success must clear it."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(copy.copy(sel), auto_refit=False)

    def boom():
        raise RuntimeError("refit died")
    eng._refit_hook = boom
    eng.refit_now(x, wait=True)
    assert isinstance(eng.last_refit_error, RuntimeError)
    assert eng.stats()["last_refit_error"] is not None
    assert eng.stats()["breaker"]["consecutive_failures"] == 1

    eng._refit_hook = None
    assert eng.refit_now(x, wait=True)
    assert eng.last_refit_error is None
    assert eng.stats()["last_refit_error"] is None
    assert eng.stats()["breaker"]["consecutive_failures"] == 0
    assert eng.medoid_version == 1


def test_solver_init_idx_contract():
    """one_batch_pam(init_idx=...): validated, honored, and fenced off
    from restarts/runtime composition."""
    import jax
    x = jnp.asarray(_clusters(n=120, k=4, p=6, seed=9))
    key = jax.random.PRNGKey(0)
    init = jnp.asarray([3, 50, 80, 110], jnp.int32)
    res, _ = solver.one_batch_pam(key, x, 4, init_idx=init, max_swaps=0)
    np.testing.assert_array_equal(np.asarray(res.medoid_idx),
                                  np.asarray(init))
    with pytest.raises(ValueError, match="shape"):
        solver.one_batch_pam(key, x, 4, init_idx=init[:2])
    with pytest.raises(ValueError, match="restarts"):
        solver.one_batch_pam(key, x, 4, init_idx=init, restarts=2)
    with pytest.raises(ValueError, match="runtime"):
        solver.one_batch_pam(key, x, 4, init_idx=init, validate="cheap")
