"""Serving fault-injection matrix (DESIGN.md §9a).

Every serving fault mode in tests/faults.py must leave the
:class:`AssignmentEngine` serving labels/d1 **bitwise equal to
``stream_assign``** for finite queries — faults degrade the refit loop
or quarantine bad rows, never the answers:

  * non-finite query storms (nan / inf / mixed) -> quarantined rows get
    label −1 + NaN distance, clean rows are answered as if the storm
    never happened, the drift EMA and refit window stay clean (and a
    poisoned EMA under ``validate="off"`` self-heals);
  * refit crash -> failure recorded, deterministic backoff, breaker
    opens after N consecutive failures (open -> half_open -> closed
    pinned on an injected clock), serving never blocks;
  * refit hang + ``refit_timeout`` -> supervisor cancels, the zombie
    worker is fenced off the install forever;
  * poisoned medoid snapshot (prepared cache / raw rows) -> detected on
    the served distances, recovered (re-prepare, else durable snapshot),
    the retried batch is bitwise clean;
  * corrupt snapshot file -> load walks back to the newest healthy
    generation with a warning; config-fingerprint mismatch and stale
    versions are loud errors;
  * SIGKILL'd process -> reboot via ``snapshot_dir`` resumes the exact
    last installed generation (version + rows + answers bitwise,
    subprocess-verified).
"""
import json
import os
import pathlib
import signal
import subprocess
import sys
import time

import jax.numpy as jnp
import numpy as np
import pytest

import faults
from repro.core import MedoidSelector, streaming
from repro.serving import AssignmentEngine, RefitBreaker
from repro.serving.guards import QUARANTINE_LABEL

ROOT = pathlib.Path(__file__).resolve().parents[1]
HELPER = ROOT / "tests" / "helpers" / "serving_kill_check.py"


def _clusters(n=600, k=6, p=12, sep=8.0, noise=0.3, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, p)).astype(np.float32) * sep
    return (centers[rng.integers(0, k, n)]
            + rng.standard_normal((n, p)).astype(np.float32) * noise)


@pytest.fixture(scope="module")
def fitted():
    x = _clusters()
    sel = MedoidSelector(k=6, seed=0).fit(x)
    return x, sel


def _reference(sel, q):
    """The ground truth the engine must match bitwise on finite rows."""
    lab, d1 = streaming.stream_assign(
        jnp.asarray(q), jnp.asarray(sel.medoids_), metric=sel.metric,
        backend=sel.backend)
    return np.asarray(lab, np.int32), np.asarray(d1, np.float32)


def _assert_bitwise(labels, d1, ref_labels, ref_d1):
    np.testing.assert_array_equal(labels, ref_labels)
    np.testing.assert_array_equal(d1.view(np.uint32),
                                  ref_d1.view(np.uint32))


def _join_refit(eng, deadline_s=120):
    deadline = time.time() + deadline_s
    while eng.refit_in_flight and time.time() < deadline:
        time.sleep(0.02)
    assert not eng.refit_in_flight, "refit did not settle"


class FakeClock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# --------------------------------------------- non-finite query storms --

@pytest.mark.parametrize("mode", ["nan", "inf", "mixed"])
def test_storm_quarantines_bad_rows_serves_clean_rows_bitwise(fitted, mode):
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, micro_batch=128,
                                         auto_refit=False,
                                         refit_window=1024)
    q, bad = faults.nonfinite_storm(x[:256], frac=0.3, mode=mode, seed=1)
    labels, d1 = eng.assign(q)
    assert (labels[bad] == QUARANTINE_LABEL).all()
    assert np.isnan(d1[bad]).all()
    ref_labels, ref_d1 = _reference(sel, q[~bad])
    _assert_bitwise(labels[~bad], d1[~bad], ref_labels, ref_d1)
    s = eng.stats()
    assert s["quarantined"] == int(bad.sum())
    assert s["queries_served"] == int((~bad).sum())
    # the EMA never saw the poison and the window holds only finite rows
    assert np.isfinite(s["drift_ema"])
    assert s["window"]["pushed"] == int((~bad).sum())
    assert np.isfinite(eng._window.content()).all()


def test_storm_of_only_bad_rows_and_on_invalid_raise(fitted):
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, auto_refit=False)
    q = np.full((8, eng.p), np.nan, np.float32)
    labels, d1 = eng.assign(q)          # no finite row -> no kernel call
    assert (labels == QUARANTINE_LABEL).all() and np.isnan(d1).all()
    assert eng.stats()["drift_ema"] is None

    strict = AssignmentEngine.from_selector(sel, auto_refit=False,
                                            on_invalid="raise")
    with pytest.raises(ValueError, match="non-finite"):
        strict.assign(q)
    q2 = np.array(x[:4], copy=True)
    q2[2, 0] = np.inf
    with pytest.raises(ValueError, match="row 2"):
        strict.assign(q2)


def test_validate_off_ema_self_heals_after_poisoned_batch(fitted):
    """The PR 8 bug this issue names: under validate="off" a NaN batch
    poisoned ``_drift_ema`` with a NaN that never decayed out. Now a
    non-finite batch objective is simply not folded (the EMA holds), and
    even an EMA poisoned out-of-band re-seeds from the next finite batch
    instead of propagating NaN*decay forever."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, validate="off",
                                         auto_refit=False)
    eng.assign(x[:128])
    before = eng._drift_ema
    assert np.isfinite(before)
    q = np.array(x[:64], copy=True)
    q[0, 0] = np.nan
    eng.assign(q)                        # NaN batch objective: not folded
    assert eng._drift_ema == before
    eng._drift_ema = float("nan")        # the legacy poisoned state
    assert eng.drift_ratio() == 1.0      # a poisoned EMA cannot arm refits
    eng.assign(x[:128])
    assert np.isfinite(eng._drift_ema)   # healed: re-seeded, not NaN*decay


# ------------------------------------------------ breaker + supervision --

def test_breaker_state_machine_on_fake_clock():
    """open -> half_open -> closed transitions and the deterministic
    backoff schedule, driven entirely by an injected clock."""
    clk = FakeClock()
    br = RefitBreaker(backoff=1.0, backoff_cap=8.0, threshold=3,
                      cooldown=10.0, clock=clk)
    # deterministic schedule: 1, 2, 4, 8, 8 (capped) — pure function of f
    assert [br.backoff_delay(f) for f in range(6)] == [0, 1, 2, 4, 8, 8]

    assert br.allow()
    br.record_failure()                  # f=1 -> next allowed at t+1
    assert not br.allow() and br.retry_in() == pytest.approx(1.0)
    clk.advance(1.0)
    assert br.allow()
    br.record_failure()                  # f=2 -> backoff 2s
    assert br.retry_in() == pytest.approx(2.0)
    clk.advance(2.0)
    assert br.allow()
    br.record_failure()                  # f=3 == threshold -> OPEN
    assert br.state == RefitBreaker.OPEN
    assert not br.allow()
    assert br.retry_in() == pytest.approx(10.0)
    clk.advance(9.0)
    assert not br.allow()                # still cooling down
    clk.advance(1.0)
    assert br.allow()                    # cooldown elapsed -> HALF_OPEN
    assert br.state == RefitBreaker.HALF_OPEN
    assert not br.allow()                # exactly ONE probe
    br.record_failure()                  # probe failed -> OPEN again
    assert br.state == RefitBreaker.OPEN
    clk.advance(10.0)
    assert br.allow()                    # second probe
    br.record_success()                  # probe succeeded -> CLOSED, reset
    assert br.state == RefitBreaker.CLOSED
    assert br.consecutive_failures == 0 and br.total_failures == 4
    assert br.allow() and br.retry_in() == 0.0


def test_refit_crash_opens_breaker_engine_serves_on(fitted):
    """Consecutive refit crashes trip the breaker; while open the drift
    monitor arms nothing (serve-only); after the cooldown one half-open
    probe runs and a success closes the breaker and installs."""
    x, sel = fitted
    clk = FakeClock()
    eng = AssignmentEngine.from_selector(sel, micro_batch=128,
                                         drift_threshold=1.2,
                                         refit_window=2048,
                                         breaker_threshold=2,
                                         breaker_cooldown=30.0,
                                         _clock=clk)
    ref_labels, ref_d1 = _reference(sel, x)
    faults.refit_crash(eng)
    for _ in range(2):
        assert eng.refit_now(x, wait=True)
        labels, d1 = eng.assign(x)       # serving never blocked
        _assert_bitwise(labels, d1, ref_labels, ref_d1)
    s = eng.stats()
    assert s["refit_failures"] == 2 and s["medoid_version"] == 0
    assert s["breaker"]["state"] == "open"
    assert isinstance(eng.last_refit_error, faults.RefitBoom)

    # open = serve-only: heavy drift arms nothing
    drifted = x + np.float32(5.0)
    for _ in range(10):
        eng.assign(drifted)
    assert not eng.refit_in_flight and eng.refits == 0

    # cooldown elapses; the fault clears; the half-open probe closes it
    clk.advance(30.0)
    eng._refit_hook = None
    eng.assign(drifted)
    _join_refit(eng)
    assert eng.refits == 1 and eng.medoid_version == 1
    s = eng.stats()
    assert s["breaker"]["state"] == "closed"
    assert s["breaker"]["consecutive_failures"] == 0
    assert s["last_refit_error"] is None     # success cleared the stale
    # failure stats() used to report forever (satellite fix)
    eng.close()


def test_refit_hang_timeout_fences_zombie_worker(fitted):
    """A hung refit under ``refit_timeout``: the supervisor records a
    TimeoutError and moves on; the abandoned worker can never install —
    even after it un-hangs — and a fresh attempt succeeds meanwhile."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, auto_refit=False,
                                         refit_timeout=0.25)
    old_rows = eng.medoids.copy()
    release = faults.refit_hang(eng)
    try:
        t0 = time.monotonic()
        assert eng.refit_now(x, wait=True)
        assert time.monotonic() - t0 < 60
        assert isinstance(eng.last_refit_error, TimeoutError)
        assert eng.refit_failures == 1 and eng.medoid_version == 0
        assert not eng.refit_in_flight    # supervisor done; zombie parked
        np.testing.assert_array_equal(eng.medoids, old_rows)

        # the zombie wakes up... and is fenced: no install, ever
        release.set()
        time.sleep(0.3)
        assert eng.medoid_version == 0 and eng.refits == 0

        # the engine is immediately free to refit again (fresh attempt,
        # fresh cancel event), and the success clears the stale error
        eng._refit_hook = None
        assert eng.refit_now(x + np.float32(2.0), wait=True)
        assert eng.last_refit_error is None
        assert eng.medoid_version == 1 and eng.refits == 1
    finally:
        release.set()
    eng.close()


def test_refit_timeout_validation(fitted):
    _, sel = fitted
    with pytest.raises(ValueError, match="refit_timeout"):
        AssignmentEngine.from_selector(sel, refit_timeout=0.0)


# ------------------------------------------- poisoned medoid snapshots --

def test_poisoned_prepared_cache_recovered_inline(fitted):
    """Cache-poisoned device medoids (raw rows healthy): the cheap tier
    spots non-finite distances for finite queries, re-prepares from the
    rows, and serves the retried batch bitwise clean — no snapshot dir
    needed."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, micro_batch=128,
                                         auto_refit=False)
    ref_labels, ref_d1 = _reference(sel, x)
    faults.poison_medoids(eng, mode="prepared")
    labels, d1 = eng.assign(x)
    _assert_bitwise(labels, d1, ref_labels, ref_d1)
    s = eng.stats()
    assert s["snapshots"]["recoveries"] == 1
    assert s["medoid_version"] == 0      # same generation, re-prepared


def test_poisoned_rows_recovered_from_durable_snapshot(fitted, tmp_path):
    """Rows poisoned too: recovery reloads the generation from the
    fsync'd snapshot dir and the retried batch is bitwise clean."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(
        sel, micro_batch=128, auto_refit=False,
        snapshot_dir=str(tmp_path / "snaps"))
    ref_labels, ref_d1 = _reference(sel, x)
    faults.poison_medoids(eng, mode="rows")
    labels, d1 = eng.assign(x)
    _assert_bitwise(labels, d1, ref_labels, ref_d1)
    assert eng.stats()["snapshots"]["recoveries"] == 1
    np.testing.assert_array_equal(eng.medoids, sel.medoids_)


def test_poisoned_rows_without_snapshot_dir_is_loud(fitted):
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, auto_refit=False)
    faults.poison_medoids(eng, mode="rows")
    with pytest.raises(RuntimeError, match="snapshot_dir"):
        eng.assign(x[:64])


def test_validate_off_serves_poison_unchecked(fitted):
    """The fast path really is unguarded: with validate="off" a poisoned
    prepared cache flows straight to the caller (that is the contract —
    the cheap tier exists for feeds that need the check)."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, validate="off",
                                         auto_refit=False)
    faults.poison_medoids(eng, mode="prepared")
    _, d1 = eng.assign(x[:64])
    # NaN on the XLA paths, the +BIG sentinel on the Pallas path —
    # either way the poison reached the caller unchecked
    assert not (d1 < 1e29).all()


# --------------------------------------------- durable snapshot faults --

def test_corrupt_snapshot_walks_back_to_previous_generation(
        fitted, tmp_path):
    x, sel = fitted
    snap = str(tmp_path / "snaps")
    eng = AssignmentEngine.from_selector(sel, auto_refit=False,
                                         snapshot_dir=snap)
    assert eng.refit_now(x[:300] * np.float32(1.05), wait=True)
    assert eng.medoid_version == 1 and eng.snapshots_persisted == 2
    gen0_rows = np.asarray(sel.medoids_, np.float32)

    # newest generation corrupted on disk -> a rebooting engine warns
    # and resumes the previous one
    faults.corrupt_latest_checkpoint(snap, "garbage_manifest")
    with pytest.warns(UserWarning, match="skipping corrupt"):
        eng2 = AssignmentEngine.from_selector(sel, auto_refit=False,
                                              snapshot_dir=snap)
    assert eng2.medoid_version == 0
    np.testing.assert_array_equal(eng2.medoids, gen0_rows)
    ref_labels, ref_d1 = _reference(sel, x[:128])
    labels, d1 = eng2.assign(x[:128])
    _assert_bitwise(labels, d1, ref_labels, ref_d1)
    eng.close(), eng2.close()


def test_fingerprint_mismatch_and_stale_versions_are_loud(
        fitted, tmp_path):
    x, sel = fitted
    snap = str(tmp_path / "snaps")
    eng = AssignmentEngine.from_selector(sel, auto_refit=False,
                                         snapshot_dir=snap)
    # a selector fit under a different config must not adopt these
    # generations silently
    other = MedoidSelector(k=6, seed=123).fit(x)
    with pytest.raises(ValueError, match="fingerprint"):
        AssignmentEngine.from_selector(other, auto_refit=False,
                                       snapshot_dir=snap)

    # stale-version rejection: an older (or colliding) generation
    # arriving through install_snapshot is refused
    assert eng.refit_now(x[:300] * np.float32(1.05), wait=True)
    assert eng.medoid_version == 1
    with pytest.raises(ValueError, match="stale"):
        eng.install_snapshot(sel.medoids_, sel.medoid_indices_, version=0)
    with pytest.raises(ValueError, match="collision"):
        eng.install_snapshot(sel.medoids_, sel.medoid_indices_, version=1)
    with pytest.raises(ValueError, match="non-finite"):
        eng.install_snapshot(np.full_like(sel.medoids_, np.nan),
                             sel.medoid_indices_, version=2)
    # a genuinely newer generation installs and resets drift tracking
    v = eng.install_snapshot(sel.medoids_, sel.medoid_indices_, version=2)
    assert v == 2 and eng.medoid_version == 2
    eng.close()


def test_install_snapshot_shape_validation(fitted):
    _, sel = fitted
    eng = AssignmentEngine.from_selector(sel, auto_refit=False)
    with pytest.raises(ValueError, match="shape"):
        eng.install_snapshot(sel.medoids_[:, :-1], sel.medoid_indices_, 1)
    with pytest.raises(ValueError, match="indices"):
        eng.install_snapshot(sel.medoids_, sel.medoid_indices_[:-1], 1)


# ------------------------------------------------------ SIGKILL reboot --

def test_sigkill_reboot_resumes_exact_generation(tmp_path):
    """The process dies hard after installing generation 1; a rebooted
    process (selector checkpoint only knows generation 0 +
    snapshot_dir) resumes on the exact last installed generation:
    version, medoid rows, and served answers all bitwise."""
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{env.get('PYTHONPATH', '')}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    ckpt, snap = str(tmp_path / "sel"), str(tmp_path / "snaps")
    out_kill, out_boot = str(tmp_path / "kill.json"), str(
        tmp_path / "boot.json")

    p = subprocess.run(
        [sys.executable, str(HELPER), "kill", ckpt, snap, out_kill],
        capture_output=True, text=True, env=env, timeout=600)
    assert p.returncode == -signal.SIGKILL, \
        f"rc={p.returncode}\nSTDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"

    p = subprocess.run(
        [sys.executable, str(HELPER), "reboot", ckpt, snap, out_boot],
        capture_output=True, text=True, env=env, timeout=600)
    assert p.returncode == 0, \
        f"rc={p.returncode}\nSTDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"

    with open(out_kill) as f:
        before = json.load(f)
    with open(out_boot) as f:
        after = json.load(f)
    assert before["version"] == 1
    assert after == before      # version + rows + labels + d1, bitwise


# ----------------------------------------------- refit data admission --

def test_refit_now_scrubs_nonfinite_rows(fitted):
    """Explicit refit data rides the same admission: a storm-poisoned
    window cannot poison the next generation."""
    x, sel = fitted
    eng = AssignmentEngine.from_selector(sel, auto_refit=False)
    q, bad = faults.nonfinite_storm(x, frac=0.2, seed=5)
    assert eng.refit_now(q, wait=True)
    assert eng.last_refit_error is None and eng.medoid_version == 1
    assert np.isfinite(eng.medoids).all()
    eng.close()
