"""Fault-injection tests for the fault-tolerant runtime (DESIGN.md §6).

Every injector in tests/faults.py must trip its guard, and every
recovery must leave the trajectory bitwise-identical to a clean run:

  * SIGKILL at sweep s (subprocess) + resume="auto" == the straight
    run — medoids, swap count, objective f32 bits, full sweep log —
    across strategies x restart counts.
  * state/cache poison under validate="cheap"/"paranoid" -> violation
    recorded, degradation ladder fires (state_reanchor /
    pruned->matrix_free / bf16->f32_rescore), final result bitwise
    clean.
  * corrupt checkpoints are skipped with a warning; resume continues
    from the newest healthy step.
  * poisoned inputs never reach the solver (clear ValueError).
"""
import json
import os
import pathlib
import signal
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import faults
from repro.core import runtime, solver

ROOT = pathlib.Path(__file__).resolve().parents[1]
HELPER = ROOT / "tests" / "helpers" / "kill_resume_check.py"

KEY = jax.random.PRNGKey(7)


def _problem(n=96, p=6, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))


def _payload(res, rep):
    return {"medoids": np.asarray(res.medoid_idx).tolist(),
            "n_swaps": int(res.n_swaps),
            "objective_hex": np.float32(res.est_objective).tobytes().hex(),
            "converged": bool(res.converged),
            "resumed_from": rep.resumed_from,
            "log": rep.sweep_log}


def _solve(strategy, restarts=1, backend="auto", **kw):
    kw.setdefault("validate", "cheap")
    return runtime.solve_fault_tolerant(
        KEY, _problem(), 4, m=24, variant="nniw", strategy=strategy,
        restarts=restarts, backend=backend, **kw)


# ------------------------------------------------- kill/resume (SIGKILL) --

def _child(mode, strategy, restarts, kill_at, ckpt_dir, out,
           backend="auto", expect_kill=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{env.get('PYTHONPATH', '')}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    p = subprocess.run(
        [sys.executable, str(HELPER), mode, strategy, str(restarts),
         str(kill_at), ckpt_dir, out, backend],
        capture_output=True, text=True, env=env, timeout=600)
    if expect_kill:
        assert p.returncode == -signal.SIGKILL, \
            f"rc={p.returncode}\nSTDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    else:
        assert p.returncode == 0, \
            f"rc={p.returncode}\nSTDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p


@pytest.mark.parametrize("strategy,restarts,kill_at,backend", [
    ("batched", 1, 1, "auto"),
    ("batched", 1, 3, "auto"),
    ("batched", 4, 2, "auto"),
    ("matrix_free", 1, 2, "auto"),
    ("matrix_free", 4, 2, "auto"),
    ("pruned", 1, 2, "auto"),
    ("pruned", 4, 2, "auto"),
    ("batched", 1, 2, "interpret"),
])
def test_sigkill_resume_bitwise(tmp_path, strategy, restarts, kill_at,
                                backend):
    """A solve SIGKILL'd at sweep ``kill_at`` and resumed from its
    checkpoints replays the remaining trajectory bitwise: the resumed
    run's payload equals the straight run's, entry for entry."""
    # straight reference, in-process (same platform; jits are cached
    # across params so only the first case pays compilation)
    res, _, rep = _solve(strategy, restarts, backend=backend)
    straight = _payload(res, rep)
    assert len(straight["log"]) > kill_at, "problem too easy to kill"

    ckpt_dir = str(tmp_path / "ck")
    out = str(tmp_path / "resumed.json")
    _child("kill", strategy, restarts, kill_at, ckpt_dir, out,
           backend=backend, expect_kill=True)
    _child("resume", strategy, restarts, kill_at, ckpt_dir, out,
           backend=backend)
    with open(out) as f:
        resumed = json.load(f)

    assert resumed["resumed_from"] == kill_at
    assert resumed["medoids"] == straight["medoids"]
    assert resumed["n_swaps"] == straight["n_swaps"]
    assert resumed["objective_hex"] == straight["objective_hex"]
    assert resumed["converged"] == straight["converged"]
    # pre-kill entries come back from the checkpointed report; post-kill
    # entries are recomputed — together they must be the straight log
    assert resumed["log"] == straight["log"]


# ------------------------------------- clean runs == one_batch_pam, bitwise --

@pytest.mark.parametrize("strategy",
                         ["batched", "matrix_free", "pruned", "eager"])
def test_runtime_bitwise_matches_solver(strategy):
    """validate="paranoid" re-derives every sweep's selection through
    the exact oracle: a clean solve must sail through with zero
    violations and the exact one_batch_pam trajectory."""
    x = _problem()
    res, _, rep = _solve(strategy, validate="paranoid")
    ref, _ = solver.one_batch_pam(KEY, x, 4, m=24, variant="nniw",
                                  strategy=strategy)
    np.testing.assert_array_equal(np.asarray(res.medoid_idx),
                                  np.asarray(ref.medoid_idx))
    assert (np.float32(res.est_objective).tobytes()
            == np.float32(ref.est_objective).tobytes())
    assert int(res.n_swaps) == int(ref.n_swaps)
    assert rep.violations == [] and rep.fallbacks == []
    assert rep.sweeps == len(rep.sweep_log) > 0
    assert rep.converged == bool(ref.converged)


@pytest.mark.parametrize("strategy", ["batched", "pruned"])
def test_runtime_bitwise_matches_solver_restarts(strategy):
    x = _problem()
    res, _, rep = _solve(strategy, restarts=4, validate="paranoid")
    ref, _ = solver.one_batch_pam(KEY, x, 4, m=24, variant="nniw",
                                  strategy=strategy, restarts=4)
    np.testing.assert_array_equal(np.asarray(res.medoid_idx),
                                  np.asarray(ref.medoid_idx))
    assert (np.float32(res.est_objective).tobytes()
            == np.float32(ref.est_objective).tobytes())
    assert rep.violations == [] and rep.fallbacks == []
    assert rep.election is not None and "best_restart" in rep.election


# ------------------------------------------------------------ input guards --

def test_input_guard_nan_rows():
    x = np.array(_problem())
    x[3, :] = np.nan
    x[7, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite.*2 row"):
        runtime.solve_fault_tolerant(KEY, jnp.asarray(x), 4, m=24,
                                     validate="cheap")


@pytest.mark.parametrize("bad,match", [
    (np.zeros((0, 5), np.float32), "empty/degenerate"),
    (np.zeros((8, 0), np.float32), "empty/degenerate"),
    (np.zeros((8, 3), np.int32), "floating dtype"),
    (np.zeros((8,), np.float32), "2-d"),
])
def test_input_guard_shape_dtype(bad, match):
    with pytest.raises(ValueError, match=match):
        runtime.solve_fault_tolerant(KEY, bad, 4, validate="cheap")


def test_input_guard_k_and_restarts():
    x = _problem(n=8, p=3)
    with pytest.raises(ValueError, match="1 <= k <= n"):
        runtime.solve_fault_tolerant(KEY, x, 10, validate="cheap")
    with pytest.raises(ValueError, match="batch size m"):
        runtime.solve_fault_tolerant(KEY, x, 2, m=0, validate="cheap")
    with pytest.raises(ValueError, match="restarts=4"):
        runtime.solve_fault_tolerant(KEY, x, 4, restarts=4,
                                     validate="cheap")


def test_runtime_rejects_bad_knobs():
    x = _problem(n=16, p=3)
    with pytest.raises(ValueError, match="validate"):
        runtime.solve_fault_tolerant(KEY, x, 2, validate="sometimes")
    with pytest.raises(ValueError, match="resume"):
        runtime.solve_fault_tolerant(KEY, x, 2, resume="maybe")
    with pytest.raises(ValueError, match="block_dtype"):
        runtime.solve_fault_tolerant(KEY, x, 2, strategy="pruned",
                                     block_dtype="bfloat16")
    with pytest.raises(ValueError, match="restarts > 1"):
        runtime.solve_fault_tolerant(KEY, x, 2, strategy="eager",
                                     restarts=2)


# --------------------------------------------------- guard ladder recovery --

@pytest.mark.parametrize("strategy", ["batched", "matrix_free"])
def test_state_poison_recovers_bitwise(strategy):
    """NaN injected into the solver state trips the cheap tier; the
    re-anchor recovery rebuilds the top-2 state from the medoid set
    (value-exact), so the remaining trajectory — including the poisoned
    sweep's own selection — is bitwise the clean run's."""
    clean_res, _, clean_rep = _solve(strategy)
    res, _, rep = _solve(strategy,
                         _fault_hook=faults.state_poison(1, "nan"))
    assert rep.violations and rep.violations[0]["sweep"] == 1
    assert rep.fallbacks == [{"sweep": 1, "kind": "state_reanchor"}]
    assert _payload(res, rep)["medoids"] == \
        _payload(clean_res, clean_rep)["medoids"]
    assert rep.sweep_log == clean_rep.sweep_log
    assert (np.float32(res.est_objective).tobytes()
            == np.float32(clean_res.est_objective).tobytes())


def test_state_poison_order_recovers():
    res_c, _, rep_c = _solve("batched")
    res, _, rep = _solve("batched",
                         _fault_hook=faults.state_poison(1, "order"))
    assert rep.violations and rep.violations[0]["sweep"] == 1
    assert rep.fallbacks and rep.fallbacks[0]["kind"] == "state_reanchor"
    assert rep.sweep_log == rep_c.sweep_log


@pytest.mark.parametrize("mode", ["ub", "lb"])
def test_paranoid_catches_cache_poison(mode):
    """A corrupted bound cache silently mis-prunes under cheap
    validation; paranoid detects it (containment / selection oracle),
    falls back to the matrix-free sweep for that sweep, resets the
    caches, and the final trajectory stays bitwise-correct."""
    clean_res, _, clean_rep = _solve("pruned", validate="paranoid")
    assert clean_rep.violations == []   # no spurious firings
    res, _, rep = _solve("pruned", validate="paranoid",
                         _fault_hook=faults.cache_poison(1, mode))
    assert rep.violations and rep.violations[0]["sweep"] == 1
    assert rep.fallbacks == [{"sweep": 1, "kind": "pruned->matrix_free"}]
    assert rep.sweep_log == clean_rep.sweep_log
    assert _payload(res, rep)["medoids"] == \
        _payload(clean_res, clean_rep)["medoids"]
    assert (np.float32(res.est_objective).tobytes()
            == np.float32(clean_res.est_objective).tobytes())


def test_paranoid_cache_poison_restart_lanes():
    """Same detection through the R-lane ladder: only the poisoned
    sweep falls back (lane-masked), the election still matches the
    clean run's."""
    clean_res, _, clean_rep = _solve("pruned", restarts=4,
                                     validate="paranoid")
    res, _, rep = _solve("pruned", restarts=4, validate="paranoid",
                         _fault_hook=faults.cache_poison(1, "ub"))
    assert rep.fallbacks and \
        rep.fallbacks[0]["kind"] == "pruned->matrix_free"
    assert rep.fallbacks[0]["lanes"], "lane list missing"
    assert rep.election == clean_rep.election
    np.testing.assert_array_equal(np.asarray(res.medoid_idx),
                                  np.asarray(clean_res.medoid_idx))


def test_bf16_sweep_escalates_to_f32():
    """With a bf16 block, a tripped guard escalates the offending sweep
    to an f32 re-score on the deterministically rebuilt f32 block."""
    # a clean bf16 run must not trip anything
    _, _, rep_c = _solve("batched", block_dtype="bfloat16")
    assert rep_c.violations == [] and rep_c.fallbacks == []
    res, _, rep = _solve("batched", block_dtype="bfloat16",
                         _fault_hook=faults.state_poison(1, "nan"))
    assert rep.violations and rep.violations[0]["sweep"] == 1
    assert rep.fallbacks == [{"sweep": 1, "kind": "bf16->f32_rescore"}]
    assert np.isfinite(float(res.est_objective))
    assert rep.converged


def test_eager_state_poison_recovers():
    clean_res, _, clean_rep = _solve("eager")
    res, _, rep = _solve("eager",
                         _fault_hook=faults.state_poison(1, "nan"))
    assert rep.violations and rep.violations[0]["sweep"] == 1
    assert rep.fallbacks == [{"sweep": 1, "kind": "state_reanchor"}]
    assert rep.sweep_log == clean_rep.sweep_log
    assert (np.float32(res.est_objective).tobytes()
            == np.float32(clean_res.est_objective).tobytes())


# --------------------------------------------- checkpoint-level resilience --

@pytest.mark.parametrize("strategy,every", [("batched", 1), ("pruned", 2),
                                            ("eager", 1)])
def test_stop_resume_inprocess(tmp_path, strategy, every):
    """In-process preemption stand-in: stop at sweep 2, resume, full
    log + result bitwise vs the straight run (also covers ckpt_every>1:
    resume then restarts from the newest multiple)."""
    clean_res, _, clean_rep = _solve(strategy)
    d = str(tmp_path / "ck")
    with pytest.raises(faults.StopRun):
        _solve(strategy, checkpoint_dir=d, ckpt_every=every,
               _fault_hook=faults.stop_at(2))
    res, _, rep = _solve(strategy, checkpoint_dir=d, ckpt_every=every)
    assert rep.resumed_from == 2
    assert rep.sweep_log == clean_rep.sweep_log
    assert _payload(res, rep)["medoids"] == \
        _payload(clean_res, clean_rep)["medoids"]
    assert (np.float32(res.est_objective).tobytes()
            == np.float32(clean_res.est_objective).tobytes())


@pytest.mark.parametrize("mode", ["truncate_manifest", "garbage_manifest",
                                  "missing_leaf", "shape"])
def test_corrupt_checkpoint_skipped_resume_still_bitwise(tmp_path, mode):
    """A corrupt newest checkpoint is skipped (warning) and the solve
    resumes from the next-older healthy step — final result still
    bitwise the straight run's."""
    clean_res, _, clean_rep = _solve("batched")
    d = str(tmp_path / "ck")
    with pytest.raises(faults.StopRun):
        _solve("batched", checkpoint_dir=d, _fault_hook=faults.stop_at(3))
    damaged = faults.corrupt_latest_checkpoint(d, mode)
    assert damaged == 3
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res, _, rep = _solve("batched", checkpoint_dir=d)
    if mode != "truncate_manifest":   # manifest-less dirs are invisible
        assert any("skipping corrupt checkpoint step 3" in str(x.message)
                   for x in w), [str(x.message) for x in w]
    assert rep.resumed_from == 2
    assert rep.sweep_log == clean_rep.sweep_log
    assert (np.float32(res.est_objective).tobytes()
            == np.float32(clean_res.est_objective).tobytes())


def test_resume_config_mismatch_is_a_clear_error(tmp_path):
    d = str(tmp_path / "ck")
    _solve("batched", checkpoint_dir=d)
    with pytest.raises(ValueError) as ei:
        runtime.solve_fault_tolerant(KEY, _problem(), 4, m=32,
                                     variant="nniw", strategy="batched",
                                     checkpoint_dir=d)
    msg = str(ei.value)
    assert "m: checkpoint has 24, this run has 32" in msg
    assert "resume='never'" in msg
    # the escape hatch actually works
    _, _, rep = runtime.solve_fault_tolerant(
        KEY, _problem(), 4, m=32, variant="nniw", strategy="batched",
        checkpoint_dir=d, resume="never")
    assert rep.resumed_from is None


def test_fully_corrupt_dir_warns_and_starts_fresh(tmp_path):
    d = str(tmp_path / "ck")
    clean_res, _, _ = _solve("batched")
    _solve("batched", checkpoint_dir=d, keep=1)
    faults.corrupt_latest_checkpoint(d, "garbage_manifest")
    with pytest.warns(UserWarning, match="starting fresh"):
        res, _, rep = _solve("batched", checkpoint_dir=d)
    assert rep.resumed_from is None
    assert (np.float32(res.est_objective).tobytes()
            == np.float32(clean_res.est_objective).tobytes())


# ------------------------------------------------------------- API surface --

def test_one_batch_pam_robust_path_bitwise():
    """one_batch_pam(validate=...) routes through the runtime and stays
    bitwise the plain call; return_report adds the SolveReport."""
    x = _problem()
    ref, ref_batch = solver.one_batch_pam(KEY, x, 4, m=24)
    res, batch, rep = solver.one_batch_pam(KEY, x, 4, m=24,
                                           validate="paranoid",
                                           return_report=True)
    assert isinstance(rep, runtime.SolveReport)
    np.testing.assert_array_equal(np.asarray(res.medoid_idx),
                                  np.asarray(ref.medoid_idx))
    np.testing.assert_array_equal(np.asarray(batch.idx),
                                  np.asarray(ref_batch.idx))
    assert rep.violations == []
    # report survives a JSON round-trip (it rides checkpoint extras)
    d = json.loads(json.dumps(rep.to_dict()))
    assert d["sweeps"] == rep.sweeps
    assert {"count", "p50", "p95", "max"} <= set(d["timer_summary"])
