"""Recurrent-mixer equivalences: the chunked (train-path) forms must match
the sequential (decode-path) recurrences exactly — this is what makes the
§Perf chunked-mLSTM hillclimb a pure schedule change, not a model change."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, reduced
from repro.models import ssm


def _mlstm_inputs(key, B, S, NH, hd):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, NH, hd))
    k = jax.random.normal(ks[1], (B, S, NH, hd))
    v = jax.random.normal(ks[2], (B, S, NH, hd))
    i_pre = jax.random.normal(ks[3], (B, S, NH)) * 2.0
    f_pre = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, NH)) + 3.0)
    return q, k, v, i_pre, f_pre


@pytest.mark.parametrize("S,chunk", [(32, 8), (33, 11), (64, 64), (16, 16)])
def test_mlstm_chunked_matches_sequential(S, chunk):
    B, NH, hd = 2, 2, 16
    q, k, v, i_pre, f_pre = _mlstm_inputs(jax.random.PRNGKey(0), B, S, NH, hd)
    state = (jnp.zeros((B, NH, hd, hd)), jnp.zeros((B, NH, hd)),
             jnp.full((B, NH), -1e30))
    y_seq, (c1, n1, m1) = ssm._mlstm_core(q, k, v, i_pre, f_pre, state)
    y_chk, (c2, n2, m2) = ssm._mlstm_core_chunked(q, k, v, i_pre, f_pre,
                                                  state, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(n2), np.asarray(n1),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_chunked_extreme_gates_stable():
    """Large input-gate preactivations must not overflow (the stabiliser)."""
    B, S, NH, hd = 1, 32, 2, 8
    q, k, v, i_pre, f_pre = _mlstm_inputs(jax.random.PRNGKey(1), B, S, NH, hd)
    i_pre = i_pre + 80.0     # exp(80) overflows f32 without stabilisation
    state = (jnp.zeros((B, NH, hd, hd)), jnp.zeros((B, NH, hd)),
             jnp.full((B, NH), -1e30))
    y_seq, _ = ssm._mlstm_core(q, k, v, i_pre, f_pre, state)
    y_chk, _ = ssm._mlstm_core_chunked(q, k, v, i_pre, f_pre, state, chunk=8)
    assert jnp.isfinite(y_chk).all()
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)


def test_mamba_chunked_scan_matches_naive():
    """The chunked associative scan == a plain sequential recurrence."""
    B, S, di, st = 2, 24, 8, 4
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    a = jax.random.uniform(ks[0], (B, S, di, st), minval=0.5, maxval=0.99)
    b = jax.random.normal(ks[1], (B, S, di, st))
    c = jax.random.normal(ks[2], (B, S, st))
    h0 = jnp.zeros((B, di, st))
    y, h_fin = ssm._ssm_scan_chunked(a, b, c, h0, chunk=8)

    h = h0
    ys = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        ys.append(jnp.einsum("bds,bs->bd", h, c[:, t]))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h),
                               rtol=1e-4, atol=1e-4)


def test_xlstm_forward_consistency_after_chunking():
    """Full xlstm model: prefill+decode still equals full forward with the
    chunked train path enabled."""
    from repro.models import transformer as T
    cfg = reduced(get("xlstm-1.3b"))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    full, _ = T.forward(params, cfg, tokens, remat=False)
    _, cache = T.prefill(params, cfg, tokens[:, :S - 1], max_len=S)
    ld, _ = T.decode_step(params, cfg, tokens[:, S - 1], cache,
                          jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)
