"""Streaming pipeline invariants (DESIGN.md §4): the row-chunked sweep must
be *exact* — identical blocks, weights, and assignments to the one-shot
path — for every registered metric and every batch variant, with ragged
chunk boundaries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampling, solver, streaming
from repro.kernels import metrics, ops

METRICS = list(metrics.names())


def _blobs(rng, n=120, p=5):
    return jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("chunk", [16, 37, 120, 500])
def test_stream_block_matches_oneshot(metric, chunk):
    """Exact-divisor, ragged, whole-n, and larger-than-n chunk sizes."""
    rng = np.random.default_rng(0)
    x, b = _blobs(rng, n=112), _blobs(rng, n=21)
    want = ops.pairwise_distance(x, b, metric=metric, backend="ref")
    got = streaming.stream_block(x, b, metric=metric, backend="ref",
                                 chunk_size=chunk).d
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("variant", sampling.VARIANTS)
def test_build_batch_chunked_matches_oneshot(metric, variant):
    """The acceptance invariant: chunked build_batch == one-shot build_batch
    (indices, weights, and the weighted block) for all metric x variant."""
    rng = np.random.default_rng(1)
    x = _blobs(rng, n=123, p=6)
    key = jax.random.PRNGKey(3)
    one = sampling.build_batch(key, x, 24, variant=variant, metric=metric,
                               backend="ref")
    chunked = sampling.build_batch(key, x, 24, variant=variant, metric=metric,
                                   backend="ref", chunk_size=32)
    np.testing.assert_array_equal(np.asarray(one.idx), np.asarray(chunked.idx))
    np.testing.assert_array_equal(np.asarray(one.weights),
                                  np.asarray(chunked.weights))
    np.testing.assert_array_equal(np.asarray(one.d), np.asarray(chunked.d))


def test_nniw_counts_fused_into_sweep():
    """The fused per-chunk histogram == the full-block argmin bincount, and
    padded tail rows do not contribute."""
    rng = np.random.default_rng(2)
    x = _blobs(rng, n=101, p=4)   # 101 rows: every chunk size is ragged
    b = x[jnp.asarray(rng.choice(101, size=10, replace=False))]
    d = ops.pairwise_distance(x, b, metric="l1", backend="ref")
    want = np.bincount(np.asarray(jnp.argmin(d, axis=1)), minlength=10)
    for chunk in (7, 25, 101):
        got = streaming.stream_block(x, b, metric="l1", backend="ref",
                                     chunk_size=chunk, count_nn=True).nn_counts
        np.testing.assert_array_equal(np.asarray(got), want)
    assert float(np.asarray(want).sum()) == 101.0


@pytest.mark.parametrize("chunk", [None, 13, 40])
def test_nniw_weights_stay_mean_one_under_chunking(chunk):
    rng = np.random.default_rng(4)
    x = _blobs(rng, n=110, p=4)
    batch = sampling.build_batch(jax.random.PRNGKey(0), x, 22, variant="nniw",
                                 backend="ref", chunk_size=chunk)
    np.testing.assert_allclose(float(np.asarray(batch.weights).mean()), 1.0,
                               rtol=1e-6)


def test_stream_assign_matches_full_argmin():
    rng = np.random.default_rng(5)
    x, b = _blobs(rng, n=90, p=3), _blobs(rng, n=8, p=3)
    d = ops.pairwise_distance(x, b, metric="l2", backend="ref")
    labels, dmin = streaming.stream_assign(x, b, metric="l2", backend="ref",
                                           chunk_size=17)
    np.testing.assert_array_equal(np.asarray(labels),
                                  np.asarray(jnp.argmin(d, axis=1)))
    np.testing.assert_array_equal(np.asarray(dmin),
                                  np.asarray(jnp.min(d, axis=1)))


def test_chunked_solve_end_to_end_matches_oneshot():
    """one_batch_pam with chunk_size finds the identical medoids."""
    rng = np.random.default_rng(6)
    x = _blobs(rng, n=140, p=6)
    key = jax.random.PRNGKey(1)
    res0, _ = solver.one_batch_pam(key, x, 6, backend="ref")
    res1, _ = solver.one_batch_pam(key, x, 6, backend="ref", chunk_size=33)
    np.testing.assert_array_equal(np.asarray(res0.medoid_idx),
                                  np.asarray(res1.medoid_idx))
    assert float(res0.est_objective) == float(res1.est_objective)


def test_objective_chunked_matches_oneshot():
    rng = np.random.default_rng(7)
    x = _blobs(rng, n=75, p=4)
    med = jnp.asarray([3, 40, 66])
    full = float(solver.objective(x, med, backend="ref"))
    chunked = float(solver.objective(x, med, backend="ref", chunk_size=16))
    assert full == chunked


def test_stream_block_raw_excludes_post_transform():
    """raw=True returns the pre-post accumulator (distributed reduce input)."""
    rng = np.random.default_rng(8)
    x, b = _blobs(rng, n=40, p=4), _blobs(rng, n=6, p=4)
    raw = streaming.stream_block(x, b, metric="l2", backend="ref",
                                 chunk_size=16, raw=True).d
    d = streaming.stream_block(x, b, metric="l2", backend="ref",
                               chunk_size=16).d
    np.testing.assert_allclose(np.asarray(jnp.sqrt(jnp.maximum(raw, 0.0))),
                               np.asarray(d), rtol=1e-6)
    with pytest.raises(ValueError, match="count_nn"):
        streaming.stream_block(x, b, raw=True, count_nn=True)
