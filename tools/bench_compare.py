"""Compare a fresh benchmark JSON against a committed baseline (ISSUE 4).

    python benchmarks/run.py --only kernel --smoke --json BENCH_SMOKE.json
    python tools/bench_compare.py BENCH_PR4.json BENCH_SMOKE.json

Fails (exit 1) when any kernel-layer record regresses by more than
``--factor`` in ``us_per_call`` relative to the baseline, after
*median-normalising* the per-record ratios: the committed baseline was
timed on some machine, the fresh run on another, and a uniformly
slower/faster runner shifts every ratio together — dividing by the
median ratio cancels the machine and leaves only records that regressed
relative to their peers, which is what a code change looks like. Only
records present in BOTH files are compared (new kernels don't fail the
gate; renames drop out of it — rename deliberately), and records faster
than ``--min-us`` in the baseline are skipped: microsecond-scale
timings on a shared CI runner are noise, not signal (the floor also
keeps enough records in the median for it to be meaningful). Analytic records
(0.0 us byte accounting, check=ok markers) are skipped the same way.

The derived byte-accounting columns are compared for *exact* equality
when present in both: ``hbm_bytes_per_sweep`` is an analytic property of
the kernel's dataflow, so any drift is a real dataflow change and must
ship with a regenerated baseline.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _records(path: pathlib.Path) -> dict[str, dict]:
    data = json.loads(path.read_text())
    return {r["name"]: r for r in data["records"]}


def compare(baseline: dict[str, dict], fresh: dict[str, dict], *,
            factor: float, min_us: float,
            prefixes: tuple[str, ...] = ("kernel/", "serving/")) -> list[str]:
    """Return a list of human-readable failures (empty == gate passes)."""
    failures = []
    shared = sorted(set(baseline) & set(fresh))
    ratios = {}
    for name in shared:
        if not name.startswith(prefixes):
            continue
        base, new = baseline[name], fresh[name]
        if base["us_per_call"] >= min_us:
            ratios[name] = new["us_per_call"] / base["us_per_call"]
        # Serving-lane latency columns join the same median-normalised
        # gate as us_per_call: p95 blowing up while the mean holds is
        # precisely the serving regression (a straggler micro-batch)
        # that a whole-pass timing hides (ISSUE 8).
        for col in ("p50_us", "p95_us"):
            b_col = base.get("derived", {}).get(col)
            n_col = new.get("derived", {}).get(col)
            if (b_col or 0) >= min_us and n_col is not None:
                ratios[f"{name}:{col}"] = n_col / b_col
        # qps is us_per_query inverted: gate it the same way, inverted
        # (a *drop* past the factor fails).
        b_qps = base.get("derived", {}).get("qps")
        n_qps = new.get("derived", {}).get("qps")
        if name.startswith("serving/") and b_qps and n_qps:
            ratios[f"{name}:qps"] = b_qps / n_qps
        # Absolute acceptance bar, machine-independent (both sides of
        # the ratio ran in the same process): the engine must not lose
        # to the host loop it replaced.
        sp = new.get("derived", {}).get("speedup_vs_loop")
        if sp is not None and sp < 1.0:
            failures.append(
                f"{name}: speedup_vs_loop={sp:.2f} < 1.0 — the assign "
                "engine lost to the stream_assign host loop")
        # Same machine-independent absolute bar for the serving guard
        # tier (ISSUE 9): validate="cheap" is one O(n*p) finiteness scan
        # on top of the O(n*p*k) kernel pass — if it costs more than
        # factor x the unguarded path, the guard got onto the hot path.
        # Scoped to serving/ records: the kernel/guards/validate_* lane
        # carries overhead_vs_off too (PR 7), but there the ratio is the
        # *documented cost of the tier* (paranoid runs a full oracle
        # sweep, ~2x by design) — only the serving admission tier claims
        # to be factor-cheap.
        ov = new.get("derived", {}).get("overhead_vs_off")
        if name.startswith("serving/") and ov is not None and ov > factor:
            failures.append(
                f"{name}: overhead_vs_off={ov:.2f} > {factor} — the "
                "validate='cheap' admission tier is no longer a cheap "
                "scan over the unguarded serve path")
        # PR 10 overhead contract, same absolute shape: the full
        # telemetry stack (registry + spans) on the solve/serve path
        # must stay within factor x the telemetry-off path — both sides
        # of the ratio ran in the same process, so no machine
        # normalisation applies. A breach means a hook landed on the
        # hot path (or telemetry="off" stopped being the untouched
        # jitted path, which the bitwise in-bench asserts also catch).
        tov = new.get("derived", {}).get("telemetry_overhead_vs_off")
        if tov is not None and tov > factor:
            failures.append(
                f"{name}: telemetry_overhead_vs_off={tov:.2f} > {factor} "
                "— the telemetry stack is no longer observe-only cheap "
                "over the telemetry-off path")
        b_bytes = base.get("derived", {}).get("hbm_bytes_per_sweep")
        n_bytes = new.get("derived", {}).get("hbm_bytes_per_sweep")
        if b_bytes is not None and n_bytes is not None and b_bytes != n_bytes:
            failures.append(
                f"{name}: hbm_bytes_per_sweep changed "
                f"{b_bytes:.0f} -> {n_bytes:.0f} (dataflow change — "
                "regenerate the baseline deliberately)")
        # Same exact-equality contract for the pruned sweep's scoring
        # count (ISSUE 6): on the dyadic acceptance instance every bound
        # comparison is exact in f32, so the count is a deterministic
        # property of the pruning dataflow — any drift means the bounds,
        # the survivor rule, or the scan-order changed, and must ship
        # with a regenerated baseline (a *wrong* bound that still picks
        # the right swaps would otherwise be invisible to the gate).
        b_sc = base.get("derived", {}).get("candidates_scored_per_sweep")
        n_sc = new.get("derived", {}).get("candidates_scored_per_sweep")
        if b_sc is not None and n_sc is not None and b_sc != n_sc:
            failures.append(
                f"{name}: candidates_scored_per_sweep changed "
                f"{b_sc:.1f} -> {n_sc:.1f} (pruning dataflow change — "
                "regenerate the baseline deliberately)")
    if not ratios and shared:
        failures.append(
            f"no timed records above --min-us={min_us:.0f} to compare — "
            "the regression gate guarded nothing; lower --min-us or "
            "regenerate the baseline")
    if ratios:
        ordered = sorted(ratios.values())
        machine = ordered[(len(ordered) - 1) // 2]  # lower median = runner speed
        for name, ratio in sorted(ratios.items()):
            if ratio / machine > factor:
                rec, _, col = name.partition(":")
                b_val = (baseline[rec]["derived"][col] if col
                         else baseline[rec]["us_per_call"])
                n_val = (fresh[rec]["derived"][col] if col
                         else fresh[rec]["us_per_call"])
                failures.append(
                    f"{name}: {n_val:.0f} vs baseline {b_val:.0f} "
                    f"({ratio:.2f}x raw, {ratio / machine:.2f}x "
                    f"machine-normalised > {factor}x)")
    if not shared:
        failures.append("no shared records between baseline and fresh run")
    return failures


def _min_merge(runs: list[dict[str, dict]]) -> dict[str, dict]:
    """Per-record min us_per_call over several fresh runs: with best-of-N
    timing inside each run AND min across runs, only a genuine slowdown
    survives — one noisy run cannot fail the gate (scheduler noise only
    ever adds time). The faster run's whole record wins: analytic derived
    columns are equal across runs (drift is caught by the equality gate),
    and the serving lane's timing-derived columns (p50_us/p95_us/qps)
    should come from the least-noisy run, which is the fastest one."""
    merged = dict(runs[0])
    for run in runs[1:]:
        for name, rec in run.items():
            if name in merged and rec["us_per_call"] < merged[name]["us_per_call"]:
                merged[name] = rec
    return merged


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", type=pathlib.Path,
                    help="committed BENCH_PR*.json")
    ap.add_argument("fresh", type=pathlib.Path, nargs="+",
                    help="fresh --smoke --json output(s); several runs are "
                         "min-merged per record to filter runner noise")
    ap.add_argument("--factor", type=float, default=1.5,
                    help="max tolerated us_per_call regression (default 1.5x)")
    ap.add_argument("--min-us", type=float, default=500.0,
                    help="skip records faster than this in the baseline "
                         "(timing noise floor, default 0.5ms)")
    args = ap.parse_args()

    failures = compare(_records(args.baseline),
                       _min_merge([_records(f) for f in args.fresh]),
                       factor=args.factor, min_us=args.min_us)
    if failures:
        print(f"bench_compare: {len(failures)} regression(s) vs "
              f"{args.baseline}:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"bench_compare: OK vs {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
