"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
artifacts/dryrun/*.json. Prints markdown to stdout."""
import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def gb(x):
    return (x or 0) / 2**30


def main():
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        if f.endswith("skips.json"):
            continue
        d = json.load(open(f))
        rows.append(d)

    print("### Dry-run table (compiled cells)\n")
    print("| arch | shape | mesh | chips | compile s | arg GB/dev | "
          "temp GB/dev | fits 16GB | grad_accum |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        m = d["memory"]
        a, t = gb(m["argument_bytes"]), gb(m["temp_bytes"])
        fits = "yes" if a + t <= 16.0 else f"NO ({a+t:.0f} GB)"
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['chips']} "
              f"| {d['times']['compile_s']:.0f} | {a:.2f} | {t:.2f} "
              f"| {fits} | {d['meta'].get('grad_accum', '-')} |")

    sk = os.path.join(ART, "skips.json")
    if os.path.exists(sk):
        print("\nSkipped cells (documented in DESIGN.md §6):\n")
        for s in json.load(open(sk)):
            print(f"* {s['arch']} x {s['shape']} ({s['mesh']}): {s['skip']}")

    print("\n### Roofline table (per device, from the compiled artifact)\n")
    print("| arch | shape | mesh | compute s | memory s | collective s | "
          "DCN s | bottleneck | useful | MFU |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        r = d["roofline"]
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
              f"| {r['compute_s']:.4g} | {r['memory_s']:.4g} "
              f"| {r['collective_s']:.4g} | {r['dcn_s']:.3g} "
              f"| {r['bottleneck']} | {r['useful_ratio']:.2f} "
              f"| {r['mfu']:.4f} |")


if __name__ == "__main__":
    sys.exit(main())
